"""Sketch-as-a-service: a live multi-tenant estimator server.

    PYTHONPATH=src python examples/sketch_service.py

Everything the one-shot ``fit`` APIs do, behind a request queue that never
stops: producers push rows at named *tenants*, a single worker loop coalesces
contiguous same-group ingest into one jitted sketch+fold step (micro-batching
— the serving twin of ``fit_many``'s shared pass), estimators finalize lazily
when queried, overload answers with backpressure instead of OOM, and the
whole live state snapshots/restores bit-identically through the training
checkpoint protocol.
"""
import tempfile
import time

import numpy as np

from repro.api import Plan
from repro.sketchserve import SketchService, restore_service


def main():
    rng = np.random.default_rng(0)
    p, k = 128, 4
    centers = 3.0 * rng.normal(size=(k, p)).astype(np.float32)

    def make_rows(n):
        labels = rng.integers(0, k, size=n)
        return (centers[labels]
                + rng.normal(size=(n, p)).astype(np.float32)), labels

    # one Plan per tenant; co-registered tenants share one compression pass
    plan = Plan(backend="stream", gamma=0.25, batch_size=256,
                cov_path="lowrank", rank=16)

    with SketchService(max_batch=64) as svc:
        # --- tenants: a PCA and a K-means riding ONE shared sketch group ----
        svc.create_tenant("pca", "pca", plan=plan, key=7, n_components=k,
                          group="telemetry")
        svc.create_tenant("km", "kmeans", plan=plan, key=7, k=k,
                          algorithm="minibatch", group="telemetry")
        # ...and an unrelated solo tenant with its own pass and key
        svc.create_tenant("audit-mean", "mean", plan=plan, key=99)

        # --- async ingest: many small requests, folded in coalesced bursts --
        futs = []
        for _ in range(64):
            rows, _ = make_rows(32)
            futs.append(svc.ingest("telemetry", rows))
            futs.append(svc.ingest("audit-mean", rows))
        acks = [f.result() for f in futs]
        assert all(a.ok for a in acks)
        coalesced = max(a.info["coalesced"] for a in acks)
        print(f"ingested {sum(a.result for a in acks):,} rows; up to "
              f"{coalesced} requests coalesced into one sketch+fold step")

        # --- queries: lazy finalize, then reads against live state ----------
        comps = svc.query("pca", "components").unwrap()
        xq, labels = make_rows(8)
        pred = svc.query("km", "predict", xq).unwrap()
        stats = svc.query("pca", "stats").unwrap()
        print(f"pca components {comps['components'].shape}, "
              f"km prediction for 8 fresh rows: {pred.tolist()}")
        print(f"tenant state is sketch-sized: {stats['state_bytes']:,} B "
              f"(a dense (p,p) accumulator would be {p * p * 4:,} B); "
              f"finalized {stats['finalize_count']}x for "
              f"{stats['rows']:,} rows")

        # --- backpressure: a tiny admission cap rejects instead of buffering --
        with SketchService(max_pending_rows=64) as tiny:
            tiny.create_tenant("t", "mean", plan=plan, key=0)
            rows, _ = make_rows(48)
            a = tiny.ingest("t", rows)        # admitted (48 ≤ 64)
            b = tiny.ingest("t", rows)        # rejected (96 > 64): resubmit later
            print(f"admission control: first={a.result().status} "
                  f"second={b.result().status}")

        # --- snapshot the live service; restore answers bit-identically -----
        with tempfile.TemporaryDirectory() as d:
            svc.snapshot(d)
            svc2 = restore_service(d)
            with svc2:
                comps2 = svc2.query("pca", "components").unwrap()
                same = np.array_equal(comps["components"], comps2["components"])
                print(f"snapshot -> restore -> query bit-identical: {same}")
                assert same


if __name__ == "__main__":
    t0 = time.time()
    main()
    print(f"done in {time.time() - t0:.1f}s")
