"""End-to-end LM training with the paper's sketch as gradient compression.

    PYTHONPATH=src python examples/train_lm_sketched_grads.py

Trains a ~100M-param GLM-4-shaped model for a few hundred steps on synthetic
data, with the preconditioned-sparsification gradient compressor (γ=10%) and
error feedback; prints loss curves for compressed vs dense runs.
"""
import dataclasses
import time

import jax

from repro.configs.registry import get_arch
from repro.core.grad_compress import CompressConfig
from repro.data.pipeline import SyntheticLMSource
from repro.models.api import get_api
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainerConfig, init_state, make_train_fn
from repro.models.transformer import NO_DIST


def run(compress, steps=300, label=""):
    # ~100M params: glm4 topology, scaled down
    cfg = dataclasses.replace(
        get_arch("glm4-9b"), n_layers=6, d_model=512, n_heads=8, n_kv_heads=2,
        head_dim=64, d_ff=1536, vocab_size=8192, dtype="float32",
    )
    api = get_api(cfg)
    tcfg = TrainerConfig(
        opt=OptConfig(peak_lr=1e-3, warmup_steps=30, total_steps=steps),
        compress=compress, q_chunk=64, kv_chunk=64,
    )
    key = jax.random.PRNGKey(0)
    fn = jax.jit(make_train_fn(api, tcfg, NO_DIST, key), donate_argnums=0)
    state = init_state(api, tcfg, key)
    src = SyntheticLMSource(cfg.vocab_size, seq_len=64, global_batch=16, seed=0)
    t0, losses = time.time(), []
    for step in range(steps):
        state, m = fn(state, src.next_batch())
        losses.append(float(m["loss"]))
        if step % 50 == 0 or step == steps - 1:
            wire = f" wire_floats={int(m['wire_floats']):,}" if "wire_floats" in m else ""
            print(f"[{label}] step {step:4d} loss {losses[-1]:.4f}{wire}")
    print(f"[{label}] final avg-loss(last 20): {sum(losses[-20:])/20:.4f} "
          f"({time.time()-t0:.0f}s)")
    return losses


def main():
    dense = run(None, label="dense")
    comp = run(CompressConfig(gamma=0.1, chunk_p=1 << 12, error_feedback=True),
               label="sketch γ=0.1+EF")
    gap = sum(comp[-20:]) / 20 - sum(dense[-20:]) / 20
    print(f"compression loss gap after 300 steps: {gap:+.4f} nats "
          f"(wire traffic ↓ {1/0.1:.0f}×)")


if __name__ == "__main__":
    main()
