"""Quickstart: the paper's pipeline end-to-end through the unified API.

    PYTHONPATH=src python examples/quickstart.py

1. generate data  2. pick a Plan (one-pass sketch config + execution backend)
3. recover the mean, covariance spectrum, PCs and K-means clusters from 10% of
the entries — the same estimators re-run on the "stream" backend by flipping
one field.
"""
import jax
import jax.numpy as jnp

from repro.api import Plan, SparsifiedKMeans, SparsifiedMean, SparsifiedPCA
from repro.core import kmeans, pca


def main():
    key = jax.random.PRNGKey(0)
    n, p, k = 20_000, 256, 5

    # --- data: 5 separated clusters ------------------------------------------
    centers = 3.0 * jax.random.normal(key, (k, p))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, k)
    x = centers[labels] + jax.random.normal(jax.random.fold_in(key, 2), (n, p))

    # --- one Plan: keep 10% of entries, batch backend ------------------------
    plan = Plan(backend="batch", gamma=0.10, batch_size=4096)

    est = SparsifiedMean(plan, key=3).fit(x)
    s = est.sketch(x[:1])
    print(f"kept {s.m}/{est.spec_.p_pad} entries per sample "
          f"({s.nbytes() / (p * 4):.2%} of dense storage)")
    mean_err = float(jnp.linalg.norm(est.mean_ - x.mean(0)) / jnp.linalg.norm(x.mean(0)))
    print(f"mean estimate relative error: {mean_err:.3f}")

    # --- PCA straight from the sketch ----------------------------------------
    res = SparsifiedPCA(k, plan, key=3).fit(x)
    ev = float(pca.explained_variance(res.components_, x))
    ev_ideal = float(pca.explained_variance(pca.pca(x, k).components, x))
    print(f"explained variance from sketch: {ev:.3f} (dense PCA: {ev_ideal:.3f})")

    # --- same job, streaming backend: flip one field -------------------------
    res_s = SparsifiedPCA(k, plan.replace(backend="stream"), key=3).fit(x)
    drift = float(jnp.max(jnp.abs(jnp.abs(res_s.components_ @ res.components_.T)
                                  .diagonal() - 1.0)))
    print(f"stream backend reproduces batch PCs to {drift:.1e}")

    # --- sparsified K-means (Alg. 1): one pass, centers + assignments --------
    km = SparsifiedKMeans(k, plan, key=4, n_init=3, max_iter=50).fit(x)
    acc = kmeans.clustering_accuracy(km.labels_, labels, k)
    print(f"sparsified K-means accuracy vs ground truth: {acc:.3f}")


if __name__ == "__main__":
    main()
