"""Quickstart: the paper's pipeline end-to-end in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. generate data  2. one-pass sketch (precondition + sample)  3. recover the
mean, covariance, PCs and K-means clusters from 10% of the entries.
"""
import jax
import jax.numpy as jnp

from repro.core import estimators, kmeans, pca, sketch


def main():
    key = jax.random.PRNGKey(0)
    n, p, k = 20_000, 256, 5

    # --- data: 5 separated clusters ------------------------------------------
    centers = 3.0 * jax.random.normal(key, (k, p))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, k)
    x = centers[labels] + jax.random.normal(jax.random.fold_in(key, 2), (n, p))

    # --- one-pass compression: keep 10% of entries ---------------------------
    spec = sketch.make_spec(p, jax.random.fold_in(key, 3), gamma=0.10)
    s = sketch.sketch(x, spec)          # SparseRows: values (n, m) + indices
    print(f"kept {s.m}/{spec.p_pad} entries per sample "
          f"({s.nbytes() / (n * p * 4):.2%} of dense storage)")

    # --- estimators straight from the sketch ---------------------------------
    mean_hat = sketch.unmix_dense(estimators.mean_estimator(s)[None], spec)[0]
    mean_err = float(jnp.linalg.norm(mean_hat - x.mean(0)) / jnp.linalg.norm(x.mean(0)))
    print(f"mean estimate relative error: {mean_err:.3f}")

    res = pca.sparsified_pca(s, spec, k)
    ev = float(pca.explained_variance(res.components, x))
    ev_ideal = float(pca.explained_variance(pca.pca(x, k).components, x))
    print(f"explained variance from sketch: {ev:.3f} (dense PCA: {ev_ideal:.3f})")

    # --- sparsified K-means (Alg. 1): one pass, centers + assignments --------
    km = kmeans.sparsified_kmeans(x, k, jax.random.fold_in(key, 4), gamma=0.10,
                                  n_init=3, max_iter=50)
    acc = kmeans.clustering_accuracy(km.assignments, labels, k)
    print(f"sparsified K-means accuracy vs ground truth: {acc:.3f}")


if __name__ == "__main__":
    main()
