"""Fused multi-consumer fit: compress ONCE, answer every question.

    PYTHONPATH=src python examples/fused_fit.py

The paper's pitch is that a single sparsification pass makes ALL downstream
processing cheap — mean, covariance spectrum, PCA, K-means. ``fit_many``
realizes exactly that through the estimator API: every consumer registers on
one shared ``SketchCursor``, each (step, shard) chunk is sketched exactly
once, and the same compressed rows feed every accumulator. The results are
identical (≤1e-5) to fitting each estimator separately — but the data is
read and compressed once instead of once per consumer.
"""
import time

import jax
import jax.numpy as jnp

from repro.api import (Plan, SparsifiedKMeans, SparsifiedMean, SparsifiedPCA,
                       fit_many)
from repro.core import kmeans, pca


def main():
    key = jax.random.PRNGKey(0)
    n, p, k = 20_000, 256, 5

    # --- data: 5 separated clusters ------------------------------------------
    centers = 3.0 * jax.random.normal(key, (k, p))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, k)
    x = centers[labels] + jax.random.normal(jax.random.fold_in(key, 2), (n, p))

    # --- one Plan, one shared pass, three consumers --------------------------
    plan = Plan(backend="batch", gamma=0.10, batch_size=4096)
    mean_est = SparsifiedMean(plan, key=3)
    pca_est = SparsifiedPCA(k, plan, key=3)
    km_est = SparsifiedKMeans(k, plan, key=3, n_init=3, max_iter=50)

    run = fit_many(plan, [mean_est, pca_est, km_est], x)
    print(f"shared pass: {run.count:,} rows in {run.n_sketches} chunks — "
          f"{run.n_sketches} sketch calls for {len(run)} consumers "
          f"(separate fits would sketch {run.n_sketches * len(run)}×)")

    # --- every consumer is fully fitted from that one pass -------------------
    mean_err = float(jnp.linalg.norm(mean_est.mean_ - x.mean(0))
                     / jnp.linalg.norm(x.mean(0)))
    ev = float(pca.explained_variance(pca_est.components_, x))
    ev_ideal = float(pca.explained_variance(pca.pca(x, k).components, x))
    acc = kmeans.clustering_accuracy(km_est.labels_, labels, k)
    print(f"mean relative error:        {mean_err:.3f}")
    print(f"explained variance:         {ev:.3f} (dense PCA: {ev_ideal:.3f})")
    print(f"K-means accuracy:           {acc:.3f}")

    # --- and it matches the two-pass (separate-fit) result -------------------
    pca_sep = SparsifiedPCA(k, plan, key=3).fit(x)
    km_sep = SparsifiedKMeans(k, plan, key=3, n_init=3, max_iter=50).fit(x)
    drift = float(jnp.max(jnp.abs(pca_est.components_ - pca_sep.components_)))
    same_labels = bool(jnp.all(km_est.labels_ == km_sep.labels_))
    print(f"fused == separate fits: PC drift {drift:.1e}, "
          f"identical labels: {same_labels}")

    # --- ingest-only timing, warm jit caches: the win is the shared sketch
    # pass (finalize — the identical Lloyd solve in both arms — is excluded,
    # as in benchmarks/api_bench.py) ------------------------------------------
    t0 = time.perf_counter()
    SparsifiedPCA(k, plan, key=3).partial_fit(x).sync()
    SparsifiedKMeans(k, plan, key=3).partial_fit(x).sync()
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    fit_many(plan, [SparsifiedPCA(k, plan, key=3),
                    SparsifiedKMeans(k, plan, key=3)], x, finalize=False).sync()
    t_fused = time.perf_counter() - t0
    print(f"ingest wall time (warm): fused {t_fused:.2f}s vs sequential "
          f"{t_seq:.2f}s ({t_seq / t_fused:.1f}x)")


if __name__ == "__main__":
    main()
