"""Streaming sparsified PCA: constant memory, single pass over an unbounded stream.

    PYTHONPATH=src python examples/streaming_pca.py

The stream never exists densely in memory — each batch is sketched on arrival
(the paper's out-of-core setting, Tables III/IV) and folded into fixed-size
accumulators via ``SparsifiedPCA`` on the "stream" backend; PCs are recovered
at the end from the accumulators alone. ``fit_stream`` consumes any
``(seed, step, shard) → (b, p)`` source under the repo-wide batch-key
discipline, so the identical job runs sharded by flipping ``Plan.backend``.
"""
import jax
import jax.numpy as jnp

from repro.api import Plan, SparsifiedPCA
from repro.data.pipeline import VectorStreamSource


def main():
    p, batch, n_batches = 512, 2048, 40
    source = VectorStreamSource(p=p, batch=batch, seed=0, mode="lowrank", k=8)
    plan = Plan(backend="stream", gamma=0.08, batch_size=batch)

    est = SparsifiedPCA(8, plan, key=jax.random.PRNGKey(1))
    est.fit_stream(source, steps=n_batches)
    print(f"processed {est.count_:,} samples; "
          f"accumulators: {est.spec_.p_pad}+{est.spec_.p_pad}² floats (constant)")

    # compare against the stream's true planted basis
    from repro.core import pca

    u_true = jnp.asarray(source._u.T)
    overlap = jnp.abs(est.components_ @ u_true.T).max(axis=1)
    print("per-component |cos| overlap with planted basis:",
          [f"{float(o):.3f}" for o in overlap])
    rec = int(pca.recovered_components(est.components_, u_true, thresh=0.9))
    print(f"recovered {rec}/8 planted components from a {est.spec_.gamma:.0%} sketch")

    # the low-rank spectral path: same job, the (p, p) accumulator replaced by
    # the O(rank·p) repro.lowrank state — one Plan field flips the memory class
    rank = 64
    est_lr = SparsifiedPCA(8, plan.replace(cov_path="lowrank", rank=rank),
                           key=jax.random.PRNGKey(1))
    est_lr.fit_stream(source, steps=n_batches)
    pp = est_lr.spec_.p_pad
    print(f"lowrank path: accumulator {(rank + 3) * pp * 4 / 1024:.0f} KiB vs "
          f"{pp * pp * 4 / 1024:.0f} KiB for the (p, p) accumulator")
    rec_lr = int(pca.recovered_components(est_lr.components_, u_true, thresh=0.9))
    print(f"recovered {rec_lr}/8 planted components from the rank-{rank} state")

    # second-pass refinement: the stream regenerates from (seed, step, shard),
    # so a power-iteration replay costs zero stored data. At a TIGHT rank
    # (2×k instead of 8×k) the one-pass range-finder visibly leaks tail
    # directions; one replay pass squares the gap ratio away.
    tight = plan.replace(cov_path="lowrank", rank=16)
    one = SparsifiedPCA(8, tight, key=jax.random.PRNGKey(1))
    one.fit_stream(source, steps=n_batches)
    ref = SparsifiedPCA(8, tight, key=jax.random.PRNGKey(1))
    ref.fit_refine(source=source, steps=n_batches, passes=1)
    o_one = jnp.abs(one.components_ @ u_true.T).max(axis=1).min()
    o_ref = jnp.abs(ref.components_ @ u_true.T).max(axis=1).min()
    print(f"rank-16 one-pass worst |cos|: {float(o_one):.4f} → refined "
          f"{float(o_ref):.4f} (subspace change per pass: "
          f"{ref.refine_subspace_change_})")


if __name__ == "__main__":
    main()
