"""Streaming sparsified PCA: constant memory, single pass over an unbounded stream.

    PYTHONPATH=src python examples/streaming_pca.py

The stream never exists densely in memory — each batch is sketched on arrival
(the paper's out-of-core setting, Tables III/IV) and folded into fixed-size
accumulators; PCs are recovered at the end from the accumulators alone.
"""
import jax
import jax.numpy as jnp

from repro.core import estimators, pca, sketch
from repro.data.pipeline import SketchingPipeline, VectorStreamSource


def main():
    p, batch, n_batches = 512, 2048, 40
    source = VectorStreamSource(p=p, batch=batch, seed=0, mode="lowrank", k=8)
    spec = sketch.make_spec(p, jax.random.PRNGKey(1), gamma=0.08)
    pipe = SketchingPipeline(source, spec)

    state = estimators.stream_init(spec.p_pad)
    for i in range(n_batches):
        s = pipe.next_batch()                  # SparseRows — 8% of the stream
        state = estimators.stream_update(state, s)
    print(f"processed {int(state.count):,} samples; "
          f"accumulators: {spec.p_pad}+{spec.p_pad}² floats (constant)")

    res = pca.pca_from_stream(state, spec, k=8)
    # compare against the stream's true planted basis
    u_true = jnp.asarray(source._u.T)
    overlap = jnp.abs(res.components @ u_true.T).max(axis=1)
    print("per-component |cos| overlap with planted basis:",
          [f"{float(o):.3f}" for o in overlap])
    rec = int(pca.recovered_components(res.components, u_true, thresh=0.9))
    print(f"recovered {rec}/8 planted components from a {spec.gamma:.0%} sketch")


if __name__ == "__main__":
    main()
