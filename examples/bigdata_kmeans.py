"""Out-of-core sparsified K-means (paper Alg. 1/2, Tables III/IV analogue).

    PYTHONPATH=src python examples/bigdata_kmeans.py

Streams 500k samples in chunks through ``SparsifiedKMeans.partial_fit``
(backend "stream"), keeps only the 5% sketch, and clusters it at finalize.
Peak memory is the sketch (γ·dense) + one chunk. The mini-batch variant
(``algorithm="minibatch"``) drops even the sketch — constant memory.
"""
import time

import jax
import jax.numpy as jnp

from repro.api import Plan, SparsifiedKMeans
from repro.core import kmeans as km


def main():
    n, p, k, chunk, gamma = 500_000, 128, 3, 25_000, 0.05
    key = jax.random.PRNGKey(0)
    centers = 2.0 * jax.random.normal(key, (k, p))

    def make_chunk(i):
        kk = jax.random.fold_in(jax.random.PRNGKey(7), i)
        lab = jax.random.randint(kk, (chunk,), 0, k)
        return centers[lab] + 1.5 * jax.random.normal(jax.random.fold_in(kk, 1), (chunk, p)), lab

    plan = Plan(backend="stream", gamma=gamma, batch_size=chunk)
    est = SparsifiedKMeans(k, plan, key=jax.random.PRNGKey(1), n_init=2, max_iter=40)

    t0 = time.time()
    labels = []
    for i in range(n // chunk):
        x, lab = make_chunk(i)                         # "loaded from disk"
        est.partial_fit(x)
        labels.append(lab)
    labels = jnp.concatenate(labels)
    sketch_mb = est.spec_.m * chunk * (n // chunk) * 8 / 2**20
    print(f"pass 1 (sketch): {time.time()-t0:.1f}s — stored "
          f"{sketch_mb:.0f} MB vs {n*p*4/2**20:.0f} MB dense")

    t0 = time.time()
    est.finalize()
    acc = km.clustering_accuracy(est.labels_, labels, k)
    print(f"cluster ({est.n_iter_} iters): {time.time()-t0:.1f}s — accuracy {acc:.3f}")

    # centers come back to the original domain WITHOUT another pass (paper §VII-B)
    d = jnp.linalg.norm(est.centers_[:, None] - centers[None], axis=-1)
    print("center error (min-matched):", float(jnp.min(d, axis=1).mean()))

    # ---- two-pass (Alg. 2) refinement over the regenerable source ----------
    # The minibatch fold is constant-memory but its centers inherit assignment
    # noise (each chunk was assigned against the centers of its arrival time).
    # Because chunks regenerate from (seed, step, shard), fit_refine replays
    # them and rebuilds centers from ONE consistent frozen assignment — zero
    # stored data, zero extra accumulators.
    def source(seed, step, shard):
        return make_chunk(step)[0]

    def err(e):
        d1 = jnp.linalg.norm(e.centers_[:, None] - centers[None], axis=-1)
        return float(jnp.min(d1, axis=1).mean())

    steps = n // chunk
    mb = SparsifiedKMeans(k, plan, key=jax.random.PRNGKey(1), n_init=2,
                          algorithm="minibatch")
    t0 = time.time()
    mb.fit_stream(source, steps=steps)
    print(f"minibatch one-pass: {time.time()-t0:.1f}s — center error {err(mb):.4f}")
    t0 = time.time()
    mb.refine(source=source, steps=steps, passes=1)   # replay the SAME stream
    print(f"  + 1 refine pass: {time.time()-t0:.1f}s — center error "
          f"{err(mb):.4f}, rows reassigned by the rebuild: "
          f"{mb.refine_reassign_counts_}")


if __name__ == "__main__":
    main()
