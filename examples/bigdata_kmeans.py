"""Out-of-core sparsified K-means (paper Alg. 1/2, Tables III/IV analogue).

    PYTHONPATH=src python examples/bigdata_kmeans.py

Streams 500k samples in chunks, keeps only the 5% sketch, clusters it, and
optionally takes the second pass for exact centers. Peak memory is the sketch
(γ·dense) + one chunk.
"""
import time

import jax
import jax.numpy as jnp

from repro.core import kmeans as km
from repro.core import sketch


def main():
    n, p, k, chunk, gamma = 500_000, 128, 3, 25_000, 0.05
    key = jax.random.PRNGKey(0)
    centers = 2.0 * jax.random.normal(key, (k, p))
    spec = sketch.make_spec(p, jax.random.PRNGKey(1), gamma=gamma)

    def make_chunk(i):
        kk = jax.random.fold_in(jax.random.PRNGKey(7), i)
        lab = jax.random.randint(kk, (chunk,), 0, k)
        return centers[lab] + 1.5 * jax.random.normal(jax.random.fold_in(kk, 1), (chunk, p)), lab

    t0 = time.time()
    vals, idxs, labels = [], [], []
    for i in range(n // chunk):
        x, lab = make_chunk(i)                         # "loaded from disk"
        s = sketch.sketch(x, spec, batch_key=jax.random.fold_in(spec.mask_key(), i))
        vals.append(s.values); idxs.append(s.indices); labels.append(lab)
    vals, idxs = jnp.concatenate(vals), jnp.concatenate(idxs)
    labels = jnp.concatenate(labels)
    print(f"pass 1 (sketch): {time.time()-t0:.1f}s — stored "
          f"{(vals.size*4 + idxs.size*4)/2**20:.0f} MB vs {n*p*4/2**20:.0f} MB dense")

    t0 = time.time()
    mu_pre, assign, obj, iters = km.sparse_kmeans_core(
        vals, idxs, spec.p_pad, k, spec.signs_key(), n_init=2, max_iter=40)
    acc = km.clustering_accuracy(assign, labels, k)
    print(f"cluster ({int(iters)} iters): {time.time()-t0:.1f}s — accuracy {acc:.3f}")

    # centers come back to the original domain WITHOUT another pass (paper §VII-B)
    centers_hat = sketch.unmix_dense(mu_pre, spec)
    d = jnp.linalg.norm(centers_hat[:, None] - centers[None], axis=-1)
    print("center error (min-matched):", float(jnp.min(d, axis=1).mean()))


if __name__ == "__main__":
    main()
