"""One-off coverage-baseline probe (stdlib only — the container has no
pytest-cov). Runs the fast lane under a sys.settrace line collector scoped to
src/repro and reports percent covered, approximating coverage.py's statement
count from code-object line tables. Used to pick the --cov-fail-under floor
committed in .github/workflows/ci.yml; CI itself uses real pytest-cov.

Usage: PYTHONPATH=src python tools/cov_baseline.py
"""
from __future__ import annotations

import collections
import dis
import os
import sys
import threading

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src", "repro")
HIT: dict[str, set] = collections.defaultdict(set)


def _local(frame, event, arg):
    if event == "line":
        HIT[frame.f_code.co_filename].add(frame.f_lineno)
    return _local


def _tracer(frame, event, arg):
    if event != "call":
        return None
    fn = frame.f_code.co_filename
    if not fn.startswith(SRC):
        return None
    HIT[fn].add(frame.f_lineno)
    return _local


def executable_lines(path: str) -> set:
    """Approximate coverage.py statements: every line owning bytecode, from the
    compiled code-object tree (docstring-only lines carry no bytecode)."""
    with open(path) as f:
        code = compile(f.read(), path, "exec")
    lines, todo = set(), [code]
    while todo:
        co = todo.pop()
        lines.update(ln for _, ln in dis.findlinestarts(co) if ln is not None)
        todo.extend(c for c in co.co_consts if hasattr(c, "co_code"))
    return lines


def main() -> None:
    import pytest

    # pytest.main from a script leaves tools/ at sys.path[0]; the test modules
    # import `tests.conftest`, which resolves from the repo root
    sys.path.insert(0, ROOT)
    os.chdir(ROOT)
    sys.settrace(_tracer)
    threading.settrace(_tracer)
    rc = pytest.main(["-q", "-m", "not slow", "-p", "no:cacheprovider"])
    sys.settrace(None)
    threading.settrace(None)

    total = covered = 0
    rows = []
    for root, _, files in os.walk(SRC):
        for name in sorted(files):
            if not name.endswith(".py"):
                continue
            path = os.path.join(root, name)
            exe = executable_lines(path)
            hit = HIT.get(path, set()) & exe
            total += len(exe)
            covered += len(hit)
            pct = 100.0 * len(hit) / len(exe) if exe else 100.0
            rows.append((os.path.relpath(path, SRC), len(exe), len(hit), pct))
    for rel, n_exe, n_hit, pct in rows:
        print(f"{rel:<40s} {n_hit:>5d}/{n_exe:<5d} {pct:6.1f}%")
    print(f"\nTOTAL {covered}/{total} = {100.0 * covered / total:.2f}% "
          f"(pytest exit {rc})")


if __name__ == "__main__":
    main()
