"""Tables III/IV: out-of-core-style streaming sparsified K-means.

Data arrives in chunks (never materialized densely as a whole); each chunk is
preconditioned+sampled in one pass (the compressed stream is all that's kept),
then sparsified K-means runs on the accumulated sparse matrix. The 2-pass
variant re-streams the chunks once more for exact centers.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import kmeans as km
from repro.core import sampling, sketch


def run(n: int = 100_000, p: int = 128, k: int = 3, chunk: int = 10_000, gamma: float = 0.05):
    key = jax.random.PRNGKey(0)
    centers = jax.random.normal(key, (k, p)) * 2.0
    spec = sketch.make_spec(p, jax.random.PRNGKey(1), gamma=gamma)

    def chunk_data(i):
        kk = jax.random.fold_in(jax.random.PRNGKey(42), i)
        labels = jax.random.randint(kk, (chunk,), 0, k)
        x = centers[labels] + 1.5 * jax.random.normal(jax.random.fold_in(kk, 1), (chunk, p))
        return x, labels

    # pass 1: stream + sketch
    t0 = time.time()
    vals, idxs, labels_all = [], [], []
    for i in range(n // chunk):
        x, labels = chunk_data(i)
        s = sketch.sketch(x, spec, batch_key=jax.random.fold_in(spec.mask_key(), i))
        vals.append(s.values)
        idxs.append(s.indices)
        labels_all.append(labels)
    vals = jnp.concatenate(vals)
    idxs = jnp.concatenate(idxs)
    labels_all = jnp.concatenate(labels_all)
    t_sketch = time.time() - t0

    t0 = time.time()
    mu_pre, assign, obj, iters = km.sparse_kmeans_core(
        vals, idxs, spec.p_pad, k, spec.signs_key(), n_init=2, max_iter=30)
    jax.block_until_ready(mu_pre)
    t_cluster = time.time() - t0
    acc = km.clustering_accuracy(assign, labels_all, k)
    stored = vals.size * 4 + idxs.size * 4
    emit("bigdata/1pass", t_cluster * 1e6,
         f"n={n} acc={acc:.3f} iters={int(iters)} sketch_s={t_sketch:.1f} "
         f"cluster_s={t_cluster:.1f} stored_MB={stored/2**20:.0f} "
         f"dense_MB={n*p*4/2**20:.0f}")

    # pass 2: exact centers + reassign in original domain, streaming again
    t0 = time.time()
    centers_hat = sketch.unmix_dense(mu_pre, spec)
    sums = jnp.zeros((k, p))
    cnts = jnp.zeros((k,))
    correct = 0
    for i in range(n // chunk):
        x, labels = chunk_data(i)
        a = jnp.argmin(km.dense_sq_dists(x, centers_hat), axis=1)
        oh = jax.nn.one_hot(a, k)
        sums = sums + oh.T @ x
        cnts = cnts + oh.sum(0)
        correct += int(jnp.sum(a == labels))  # before relabel; accuracy via matching below
    t_pass2 = time.time() - t0
    # accuracy of pass-2 assignments (full stream, original domain)
    accs = []
    for i in range(3):
        x, labels = chunk_data(i)
        a = jnp.argmin(km.dense_sq_dists(x, centers_hat), axis=1)
        accs.append(km.clustering_accuracy(a, labels, k))
    emit("bigdata/2pass", t_pass2 * 1e6, f"acc={np.mean(accs):.3f} pass2_s={t_pass2:.1f}")


if __name__ == "__main__":
    run()
