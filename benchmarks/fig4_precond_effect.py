"""Fig. 4 + Table I: effect of ROS preconditioning on spiky data.

Data has canonical-basis principal components (all energy on single
coordinates). Paper's claim: preconditioning halves covariance error and
dramatically improves #recovered PCs at small γ, with near-zero variance.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import estimators, pca, ros, sampling, sketch


def run(p: int = 512, n: int = 1024, k: int = 10, runs: int = 8):
    # paper-exact Table I dimensions (p=512, n=1024, λ = 10…1, canonical PCs)
    lam = jnp.asarray(np.linspace(10, 1, k), jnp.float32)
    u = jnp.eye(p)[:k]                                       # spiky PCs
    key = jax.random.PRNGKey(0)
    kappa = jax.random.normal(key, (n, k))
    x = (kappa * lam[None, :]) @ u

    for gamma in (0.1, 0.2, 0.3, 0.5):
        m = int(gamma * p)
        err_pre, err_raw, rec_pre, rec_raw = [], [], [], []
        for r in range(runs):
            kk = jax.random.PRNGKey(r)
            spec = sketch.make_spec(p, kk, m=m)
            # with preconditioning — error vs C_emp of the preconditioned data
            y = ros.precondition(x, spec.signs_key(), "hadamard")
            s = sampling.subsample(y, spec.mask_key(), m)
            c_hat = estimators.cov_estimator(s)
            err_pre.append(float(jnp.linalg.norm(c_hat - estimators.empirical_cov(y), ord=2)))
            res = pca.sparsified_pca(s, spec, k)
            rec_pre.append(int(pca.recovered_components(res.components, u, 0.95)))
            # without preconditioning
            s0 = sampling.subsample(x, jax.random.fold_in(kk, 9), m)
            c0 = estimators.cov_estimator(s0)
            err_raw.append(float(jnp.linalg.norm(c0 - estimators.empirical_cov(x), ord=2)))
            res0 = pca.sparsified_pca(s0, spec, k, preconditioned=False)
            rec_raw.append(int(pca.recovered_components(res0.components, u, 0.95)))
        emit(f"fig4/gamma={gamma}", 0.0,
             f"err_precond={np.mean(err_pre):.3f} err_raw={np.mean(err_raw):.3f} "
             f"gain={np.mean(err_raw)/max(np.mean(err_pre),1e-9):.2f}x")
        emit(f"table1/gamma={gamma}", 0.0,
             f"recovered_precond={np.mean(rec_pre):.2f}±{np.std(rec_pre):.2f} "
             f"recovered_raw={np.mean(rec_raw):.2f}±{np.std(rec_raw):.2f}")


if __name__ == "__main__":
    run()
