"""Shared benchmark helpers. Output convention: ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import time
from typing import Callable

import jax


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (JAX arrays blocked)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)
