"""Shared benchmark helpers. Output convention: ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import time
from typing import Callable, Iterable

import jax
import numpy as np

from repro.obs import quantiles


def latency_ms(lat_s: Iterable[float],
               qs: tuple[float, ...] = (0.5, 0.99)) -> tuple[float, ...]:
    """Latency quantiles in milliseconds from a sequence of seconds — thin
    shim over :func:`repro.obs.quantiles`, the repo's ONE quantile
    implementation (the launch drivers use it directly)."""
    return quantiles((v * 1e3 for v in lat_s), qs)


def spiked(key, n: int, p: int, k: int, noise: float = 1e-2,
           lam_hi: float = 10.0, lam_lo: float = 7.0):
    """Spiked covariance model: k planted directions over a small iso floor
    (the benchmark twin of tests/conftest.spiked — tests must not import
    benchmarks, so each side keeps one canonical copy)."""
    import jax.numpy as jnp

    u, _ = jnp.linalg.qr(jax.random.normal(key, (p, k)))
    lam = jnp.linspace(lam_hi, lam_lo, k)
    z = jax.random.normal(jax.random.fold_in(key, 1), (n, k)) * lam
    return z @ u.T + noise * jax.random.normal(jax.random.fold_in(key, 2), (n, p))


def max_angle_sin(a, b) -> float:
    """Largest principal-angle sine between the row spaces of a and b (f64)."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    a /= np.linalg.norm(a, axis=1, keepdims=True)
    b /= np.linalg.norm(b, axis=1, keepdims=True)
    s = np.linalg.svd(a @ b.T, compute_uv=False)
    return float(np.sqrt(np.maximum(0.0, 1.0 - s**2)).max())


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (JAX arrays blocked)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)
