"""Sketch-serving subsystem benchmark → ``BENCH_serve.json``.

Six claims of the serving layer, each measured and gated:

1. **Multi-tenant scale** — a sweep up to ≥1000 concurrently live tenants
   (stream backend, lowrank cov path) recording create+ingest+query
   requests/sec and query latency p50/p99. Per-tenant resident state must be
   sketch-sized: asserted ≪ the (p, p) accumulator's p²·4 bytes, and
   *constant* in rows ingested (fold state is fixed-size, so total memory is
   O(tenants), never O(tenants · rows) — the sub-linear growth claim).
2. **Micro-batched ingest** — many tiny ingest requests drained through the
   coalescing worker loop (``max_batch=64``, one jitted sketch+fold per
   drained run) vs the same requests folded one-per-request
   (``max_batch=1``). Gated at ≥2× rows/sec.
3. **Snapshot/restore** — a live service checkpoints, restores, and answers
   queries BIT-identically; ingesting identical further rows into original
   and restored keeps them bit-identical (the cursor resumes at the same
   (step, shard) mask keys).
4. **Multi-worker ingest** — the same 64-group workload through 1 vs 4
   workers: per-group results asserted bit-identical (the partition keeps one
   producer per cursor), and ≥2× rows/sec gated whenever the machine has the
   cores to show it (``os.cpu_count() >= 4`` — jax CPU folds release the GIL,
   so the pool parallelizes on real runners; on smaller boxes the speedup is
   recorded but not gated).
5. **Crash/restore continuation** — a service with an armed
   ``SnapshotPolicy`` is abandoned mid-workload (no orderly stop), restored
   from its last auto-snapshot, and fed the remainder; final state asserted
   bit-identical to an uninterrupted twin.
6. **HTTP frontend** — create/ingest/query over localhost round-trip, and
   admission-control backpressure surfaces as a 429 (+Retry-After): the gate
   that `status="rejected"` survives the wire.

CI uploads the JSON as an artifact so the serving perf trajectory accumulates
across commits (same convention as ``BENCH_api.json``).
"""
from __future__ import annotations

import json
import os
import sys
import time
import urllib.error
import urllib.request

import numpy as np

from benchmarks.common import emit, latency_ms
from repro.api import Plan
from repro.sketchserve import (SketchService, SnapshotPolicy, restore_service,
                               serve_http)

RECORDS: list[dict] = []

P_DIM = 128
RANK = 8


def record(name: str, us: float, **extra):
    rec = {"name": name, "us_per_call": round(us, 1), **extra}
    RECORDS.append(rec)
    derived = " ".join(f"{k}={v}" for k, v in extra.items()
                       if isinstance(v, (int, float, str)))
    emit(name, us, derived)


def _plan() -> Plan:
    return Plan(backend="stream", gamma=0.25, batch_size=128,
                cov_path="lowrank", rank=RANK)


# ---------------------------------------------------------- 1. tenant sweep --


def tenant_sweep(n_tenants: int, rng) -> None:
    plan = _plan()
    rows = rng.normal(size=(64, P_DIM)).astype(np.float32)
    extra_rows = rng.normal(size=(64, P_DIM)).astype(np.float32)
    with SketchService(max_queue=4 * n_tenants + 64,
                       max_batch=128) as svc:
        t0 = time.perf_counter()
        for i in range(n_tenants):
            svc.create_tenant(f"t{i}", "pca", plan=plan, key=1, n_components=4)
        t_create = time.perf_counter() - t0

        t0 = time.perf_counter()
        futs = [svc.ingest(f"t{i}", rows) for i in range(n_tenants)]
        assert all(f.result().ok for f in futs)
        t_ingest = time.perf_counter() - t0

        # query latency over a fixed-size sample (finalize is lazy — these
        # first queries pay it; the sample keeps the sweep O(tenants) overall)
        sample = list(range(0, n_tenants, max(1, n_tenants // 32)))[:32]
        lat = []
        for i in sample:
            tq = time.perf_counter()
            svc.query(f"t{i}", "components").unwrap()
            lat.append(time.perf_counter() - tq)
        p50, p99 = latency_ms(lat)

        # per-tenant resident fold state: sketch-sized, NEVER the (p, p)
        # accumulator — and constant in rows ingested (sub-linear total memory)
        sb0 = [svc.query(f"t{i}", "stats").unwrap()["state_bytes"]
               for i in sample]
        dense_bytes = P_DIM * P_DIM * 4
        assert max(sb0) < dense_bytes / 4, (
            f"per-tenant state {max(sb0)}B is not sketch-sized "
            f"(dense (p,p) would be {dense_bytes}B)")
        for i in sample[:8]:
            for _ in range(4):
                svc.ingest(f"t{i}", extra_rows).result()
        sb1 = [svc.query(f"t{i}", "stats").unwrap()["state_bytes"]
               for i in sample[:8]]
        assert sb1 == sb0[:8], (
            "per-tenant state grew with rows ingested — fold state must be "
            f"fixed-size ({sb0[:8]} -> {sb1})")

    reqs = 2 * n_tenants + len(sample)
    dt = t_create + t_ingest + sum(lat)
    record(f"serve/tenants/{n_tenants}", dt / reqs * 1e6,
           tenants=n_tenants, requests_per_sec=round(reqs / dt),
           create_s=round(t_create, 2), ingest_s=round(t_ingest, 2),
           query_p50_ms=round(float(p50), 2), query_p99_ms=round(float(p99), 2),
           state_bytes_per_tenant=int(max(sb0)), dense_state_bytes=dense_bytes)


# ------------------------------------------------- 2. micro-batched ingest --


def _drain_ingest(chunks: list[np.ndarray], max_batch: int) -> float:
    """Queue every request up front, then start the worker — block sizes are
    exactly max_batch, so both arms measure a steady-state drain."""
    svc = SketchService(max_queue=len(chunks) + 8, max_batch=max_batch)
    svc.create_tenant("t", "pca", plan=_plan(), key=1, n_components=4)
    futs = [svc.ingest("t", c) for c in chunks]
    t0 = time.perf_counter()
    with svc:                      # start() drains; stop() waits for it all
        for f in futs:
            assert f.result(120).ok
        dt = time.perf_counter() - t0
    return dt


def microbatch_bench(rng) -> None:
    n_req, req_rows = 256, 16     # tiny requests: the coalescing regime
    chunks = [rng.normal(size=(req_rows, P_DIM)).astype(np.float32)
              for _ in range(n_req)]
    total = n_req * req_rows
    # two runs per arm: the first pays jit compilation of its fold shapes
    # (process-global cache), the second is the measurement
    for mb in (64, 1):
        _drain_ingest(chunks, mb)
    dt_batched = _drain_ingest(chunks, 64)
    dt_unbatched = _drain_ingest(chunks, 1)
    speedup = dt_unbatched / dt_batched
    record("serve/ingest/unbatched", dt_unbatched / n_req * 1e6,
           rows_per_sec=round(total / dt_unbatched), max_batch=1)
    record("serve/ingest/microbatched", dt_batched / n_req * 1e6,
           rows_per_sec=round(total / dt_batched), max_batch=64,
           speedup_vs_unbatched=round(speedup, 2))
    assert speedup >= 2.0, (
        f"micro-batched ingest only {speedup:.2f}x over one-fold-per-request "
        "— coalescing has regressed")


# ------------------------------------------------------ 3. snapshot/restore --


def snapshot_bench(rng, ckpt_dir: str) -> None:
    plan = _plan()
    x = rng.normal(size=(512, P_DIM)).astype(np.float32)
    more = rng.normal(size=(256, P_DIM)).astype(np.float32)
    with SketchService() as svc:
        svc.create_tenant("p", "pca", plan=plan, key=7, n_components=4,
                          group="g")
        svc.create_tenant("k", "kmeans", plan=plan, key=7, k=4, group="g",
                          algorithm="minibatch")
        svc.ingest("g", x).result()
        comps = svc.query("p", "components").unwrap()
        centers = svc.query("k", "centers").unwrap()
        t0 = time.perf_counter()
        svc.snapshot(ckpt_dir)
        t_save = time.perf_counter() - t0
        # original continues ingesting after the snapshot
        svc.ingest("g", more).result()
        comps_cont = svc.query("p", "components").unwrap()

    t0 = time.perf_counter()
    svc2 = restore_service(ckpt_dir)
    t_load = time.perf_counter() - t0
    with svc2:
        comps2 = svc2.query("p", "components").unwrap()
        centers2 = svc2.query("k", "centers").unwrap()
        assert np.array_equal(comps["components"], comps2["components"]), (
            "snapshot/restore round-trip is not bit-identical (PCA)")
        assert np.array_equal(centers, centers2), (
            "snapshot/restore round-trip is not bit-identical (K-means)")
        # resume: identical further ingest stays bit-identical (the restored
        # cursor continues at the same (step, shard) mask keys)
        svc2.ingest("g", more).result()
        comps2_cont = svc2.query("p", "components").unwrap()
        assert np.array_equal(comps_cont["components"],
                              comps2_cont["components"]), (
            "post-restore ingest diverged from the original process")
    record("serve/snapshot/roundtrip", (t_save + t_load) * 1e6,
           save_ms=round(t_save * 1e3, 1), restore_ms=round(t_load * 1e3, 1),
           bit_identical=True)


# ------------------------------------------------- 4. multi-worker ingest --


def _drain_multiworker(chunks: list[tuple[str, np.ndarray]], n_groups: int,
                       workers: int) -> tuple[float, dict]:
    """64 disjoint single-tenant groups, requests queued up front, drain
    timed from start() to last resolution — the multi-worker analogue of
    ``_drain_ingest``. scan='never' + batch_size-multiple blocks pin every
    fold to the host loop so the parity check below is exact."""
    svc = SketchService(max_queue=len(chunks) + 8, max_batch=64,
                        workers=workers, scan="never")
    plan = _plan()
    for g in range(n_groups):
        svc.create_tenant(f"t{g}", "pca", plan=plan, key=1, n_components=4,
                          group=f"g{g}")
    futs = [svc.ingest(gid, c) for gid, c in chunks]
    t0 = time.perf_counter()
    with svc:
        for f in futs:
            assert f.result(240).ok
        dt = time.perf_counter() - t0
        out = {f"g{g}": np.asarray(
                   svc.query(f"t{g}", "components").unwrap()["components"])
               for g in range(n_groups)}
    return dt, out


def multiworker_bench(rng) -> None:
    n_groups, blocks_per_group = 64, 4
    bs = _plan().batch_size
    chunks = [(f"g{r % n_groups}",
               rng.normal(size=(bs, P_DIM)).astype(np.float32))
              for r in range(n_groups * blocks_per_group)]
    total = sum(c.shape[0] for _, c in chunks)
    for w in (1, 4):       # first runs pay jit compilation; then measure
        _drain_multiworker(chunks, n_groups, w)
    dt1, out1 = _drain_multiworker(chunks, n_groups, 1)
    dt4, out4 = _drain_multiworker(chunks, n_groups, 4)
    for g in range(n_groups):
        assert np.array_equal(out1[f"g{g}"], out4[f"g{g}"]), (
            f"group g{g}: 4-worker result diverged from single-worker — the "
            "disjoint-partition ordering guarantee is broken")
    speedup = dt1 / dt4
    cores = os.cpu_count() or 1
    record("serve/multiworker/1", dt1 / len(chunks) * 1e6,
           rows_per_sec=round(total / dt1), workers=1, groups=n_groups)
    record("serve/multiworker/4", dt4 / len(chunks) * 1e6,
           rows_per_sec=round(total / dt4), workers=4, groups=n_groups,
           speedup_vs_1=round(speedup, 2), cpu_cores=cores,
           per_group_bit_identical=True, speedup_gated=cores >= 4)
    if cores >= 4:
        assert speedup >= 2.0, (
            f"4 workers over 64 groups only {speedup:.2f}x single-worker on a "
            f"{cores}-core machine — the worker pool has regressed")
    else:
        print(f"serve_bench: {cores} core(s) — recording {speedup:.2f}x but "
              "not gating the 4-worker speedup", file=sys.stderr)


# ------------------------------------------- 5. crash/restore continuation --


def crash_restore_bench(rng, base_dir: str) -> None:
    """Auto-snapshot mid-workload, abandon the service without stop(), restore
    from the latest snapshot and feed the rest — bit-identical to a twin that
    never crashed. Blocks are batch_size-sized and folds serialized, so the
    snapshot's row count is always a block boundary and the continuation
    refolds exactly the suffix."""
    plan = _plan()
    bs = plan.batch_size
    blocks = [rng.normal(size=(bs, P_DIM)).astype(np.float32)
              for _ in range(12)]
    ckpt = os.path.join(base_dir, "auto")

    svc = SketchService(scan="never",
                        snapshot_policy=SnapshotPolicy(every_rows=2 * bs),
                        snapshot_dir=ckpt)
    svc.start()
    svc.create_tenant("p", "pca", plan=plan, key=7, n_components=4, group="g")
    for b in blocks[:8]:
        svc.ingest("g", b).result(120).unwrap()
    # wait until the policy has caught up to every folded row — after that the
    # abandoned worker writes nothing more, so the restore below reads a
    # stable "latest" (save_arrays' atomic rename would keep a concurrent
    # write safe, but the resume point would be nondeterministic)
    deadline = time.perf_counter() + 60
    while svc._last_snap_rows < 8 * bs:
        assert time.perf_counter() < deadline, "auto-snapshot never caught up"
        time.sleep(0.02)
    n_snaps = svc.stats["snapshots"]
    # crash: abandon the service (daemon workers) — no stop(), no final write

    t0 = time.perf_counter()
    svc2 = restore_service(ckpt, scan="never")
    t_restore = time.perf_counter() - t0
    with svc2:
        done = svc2.query("p", "stats").unwrap()["rows"] // bs
        for b in blocks[done:]:
            svc2.ingest("g", b).result(120).unwrap()
        got = np.asarray(svc2.query("p", "components").unwrap()["components"])

    with SketchService(scan="never") as twin:
        twin.create_tenant("p", "pca", plan=plan, key=7, n_components=4,
                           group="g")
        for b in blocks:
            twin.ingest("g", b).result(120).unwrap()
        want = np.asarray(twin.query("p", "components").unwrap()["components"])
    assert np.array_equal(got, want), (
        "crash → restore → continue diverged from the uninterrupted run")
    record("serve/crash_restore/continue", t_restore * 1e6,
           restore_ms=round(t_restore * 1e3, 1), auto_snapshots=int(n_snaps),
           resumed_at_block=int(done), total_blocks=len(blocks),
           bit_identical=True)


# --------------------------------------------------------- 6. HTTP frontend --


def _http_post(url: str, body: dict):
    req = urllib.request.Request(url, json.dumps(body).encode(),
                                 {"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=60) as r:
            return r.status, json.loads(r.read()), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read()), dict(e.headers)


def http_bench(rng) -> None:
    rows = rng.normal(size=(64, P_DIM)).astype(np.float32)
    with SketchService(max_pending_rows=256) as svc, serve_http(svc) as fe:
        from repro.sketchserve.snapshot import plan_to_json
        code, body, _ = _http_post(fe.url + "/admin", {
            "op": "create_tenant",
            "params": {"tid": "h", "kind": "pca", "key": 1,
                       "plan": plan_to_json(_plan()),
                       "params": {"n_components": 4}}})
        assert code == 200, f"create over HTTP failed: {code} {body}"
        t0 = time.perf_counter()
        n_req = 16
        for _ in range(n_req):
            code, body, _ = _http_post(fe.url + "/ingest",
                                       {"target": "h", "rows": rows.tolist()})
            assert code == 200, f"ingest over HTTP failed: {code} {body}"
        dt = time.perf_counter() - t0
        with urllib.request.urlopen(fe.url + "/query?tenant=h&op=components",
                                    timeout=60) as r:
            assert r.status == 200
            comps = np.asarray(json.loads(r.read())["result"]["components"])
        want = np.asarray(svc.query("h", "components").unwrap()["components"])
        assert np.allclose(comps, want), "HTTP query diverged from in-process"
        # backpressure round-trip: one request over max_pending_rows must come
        # back as 429 + Retry-After, and the tenant must keep serving after
        big = np.zeros((257, P_DIM), np.float32)
        code, body, hdrs = _http_post(fe.url + "/ingest",
                                      {"target": "h", "rows": big.tolist()})
        assert code == 429, f"oversized ingest answered {code}, wanted 429"
        assert body["status"] == "rejected" and "Retry-After" in hdrs, (
            f"429 body/headers malformed: {body} {hdrs}")
        code, _, _ = _http_post(fe.url + "/ingest",
                                {"target": "h", "rows": rows[:8].tolist()})
        assert code == 200, "service did not keep serving after a 429"
    record("serve/http/ingest", dt / n_req * 1e6,
           rows_per_sec=round(n_req * rows.shape[0] / dt),
           backpressure_429=True, retry_after=True)


def run(json_path: str = "BENCH_serve.json"):
    RECORDS.clear()
    rng = np.random.default_rng(0)
    for n in (64, 256, 1024):
        tenant_sweep(n, rng)
    microbatch_bench(rng)
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        snapshot_bench(rng, os.path.join(d, "snap"))
    multiworker_bench(rng)
    with tempfile.TemporaryDirectory() as d:
        crash_restore_bench(rng, d)
    http_bench(rng)
    out = os.environ.get("BENCH_SERVE_JSON", json_path)
    with open(out, "w") as f:
        json.dump({"records": RECORDS}, f, indent=2)
    print(f"serve_bench: wrote {out} ({len(RECORDS)} records)", file=sys.stderr)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
