"""Sketch-serving subsystem benchmark → ``BENCH_serve.json``.

Three claims of the serving layer, each measured and gated:

1. **Multi-tenant scale** — a sweep up to ≥1000 concurrently live tenants
   (stream backend, lowrank cov path) recording create+ingest+query
   requests/sec and query latency p50/p99. Per-tenant resident state must be
   sketch-sized: asserted ≪ the (p, p) accumulator's p²·4 bytes, and
   *constant* in rows ingested (fold state is fixed-size, so total memory is
   O(tenants), never O(tenants · rows) — the sub-linear growth claim).
2. **Micro-batched ingest** — many tiny ingest requests drained through the
   coalescing worker loop (``max_batch=64``, one jitted sketch+fold per
   drained run) vs the same requests folded one-per-request
   (``max_batch=1``). Gated at ≥2× rows/sec.
3. **Snapshot/restore** — a live service checkpoints, restores, and answers
   queries BIT-identically; ingesting identical further rows into original
   and restored keeps them bit-identical (the cursor resumes at the same
   (step, shard) mask keys).

CI uploads the JSON as an artifact so the serving perf trajectory accumulates
across commits (same convention as ``BENCH_api.json``).
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import emit, latency_ms
from repro.api import Plan
from repro.sketchserve import SketchService, restore_service

RECORDS: list[dict] = []

P_DIM = 128
RANK = 8


def record(name: str, us: float, **extra):
    rec = {"name": name, "us_per_call": round(us, 1), **extra}
    RECORDS.append(rec)
    derived = " ".join(f"{k}={v}" for k, v in extra.items()
                       if isinstance(v, (int, float, str)))
    emit(name, us, derived)


def _plan() -> Plan:
    return Plan(backend="stream", gamma=0.25, batch_size=128,
                cov_path="lowrank", rank=RANK)


# ---------------------------------------------------------- 1. tenant sweep --


def tenant_sweep(n_tenants: int, rng) -> None:
    plan = _plan()
    rows = rng.normal(size=(64, P_DIM)).astype(np.float32)
    extra_rows = rng.normal(size=(64, P_DIM)).astype(np.float32)
    with SketchService(max_queue=4 * n_tenants + 64,
                       max_batch=128) as svc:
        t0 = time.perf_counter()
        for i in range(n_tenants):
            svc.create_tenant(f"t{i}", "pca", plan=plan, key=1, n_components=4)
        t_create = time.perf_counter() - t0

        t0 = time.perf_counter()
        futs = [svc.ingest(f"t{i}", rows) for i in range(n_tenants)]
        assert all(f.result().ok for f in futs)
        t_ingest = time.perf_counter() - t0

        # query latency over a fixed-size sample (finalize is lazy — these
        # first queries pay it; the sample keeps the sweep O(tenants) overall)
        sample = list(range(0, n_tenants, max(1, n_tenants // 32)))[:32]
        lat = []
        for i in sample:
            tq = time.perf_counter()
            svc.query(f"t{i}", "components").unwrap()
            lat.append(time.perf_counter() - tq)
        p50, p99 = latency_ms(lat)

        # per-tenant resident fold state: sketch-sized, NEVER the (p, p)
        # accumulator — and constant in rows ingested (sub-linear total memory)
        sb0 = [svc.query(f"t{i}", "stats").unwrap()["state_bytes"]
               for i in sample]
        dense_bytes = P_DIM * P_DIM * 4
        assert max(sb0) < dense_bytes / 4, (
            f"per-tenant state {max(sb0)}B is not sketch-sized "
            f"(dense (p,p) would be {dense_bytes}B)")
        for i in sample[:8]:
            for _ in range(4):
                svc.ingest(f"t{i}", extra_rows).result()
        sb1 = [svc.query(f"t{i}", "stats").unwrap()["state_bytes"]
               for i in sample[:8]]
        assert sb1 == sb0[:8], (
            "per-tenant state grew with rows ingested — fold state must be "
            f"fixed-size ({sb0[:8]} -> {sb1})")

    reqs = 2 * n_tenants + len(sample)
    dt = t_create + t_ingest + sum(lat)
    record(f"serve/tenants/{n_tenants}", dt / reqs * 1e6,
           tenants=n_tenants, requests_per_sec=round(reqs / dt),
           create_s=round(t_create, 2), ingest_s=round(t_ingest, 2),
           query_p50_ms=round(float(p50), 2), query_p99_ms=round(float(p99), 2),
           state_bytes_per_tenant=int(max(sb0)), dense_state_bytes=dense_bytes)


# ------------------------------------------------- 2. micro-batched ingest --


def _drain_ingest(chunks: list[np.ndarray], max_batch: int) -> float:
    """Queue every request up front, then start the worker — block sizes are
    exactly max_batch, so both arms measure a steady-state drain."""
    svc = SketchService(max_queue=len(chunks) + 8, max_batch=max_batch)
    svc.create_tenant("t", "pca", plan=_plan(), key=1, n_components=4)
    futs = [svc.ingest("t", c) for c in chunks]
    t0 = time.perf_counter()
    with svc:                      # start() drains; stop() waits for it all
        for f in futs:
            assert f.result(120).ok
        dt = time.perf_counter() - t0
    return dt


def microbatch_bench(rng) -> None:
    n_req, req_rows = 256, 16     # tiny requests: the coalescing regime
    chunks = [rng.normal(size=(req_rows, P_DIM)).astype(np.float32)
              for _ in range(n_req)]
    total = n_req * req_rows
    # two runs per arm: the first pays jit compilation of its fold shapes
    # (process-global cache), the second is the measurement
    for mb in (64, 1):
        _drain_ingest(chunks, mb)
    dt_batched = _drain_ingest(chunks, 64)
    dt_unbatched = _drain_ingest(chunks, 1)
    speedup = dt_unbatched / dt_batched
    record("serve/ingest/unbatched", dt_unbatched / n_req * 1e6,
           rows_per_sec=round(total / dt_unbatched), max_batch=1)
    record("serve/ingest/microbatched", dt_batched / n_req * 1e6,
           rows_per_sec=round(total / dt_batched), max_batch=64,
           speedup_vs_unbatched=round(speedup, 2))
    assert speedup >= 2.0, (
        f"micro-batched ingest only {speedup:.2f}x over one-fold-per-request "
        "— coalescing has regressed")


# ------------------------------------------------------ 3. snapshot/restore --


def snapshot_bench(rng, ckpt_dir: str) -> None:
    plan = _plan()
    x = rng.normal(size=(512, P_DIM)).astype(np.float32)
    more = rng.normal(size=(256, P_DIM)).astype(np.float32)
    with SketchService() as svc:
        svc.create_tenant("p", "pca", plan=plan, key=7, n_components=4,
                          group="g")
        svc.create_tenant("k", "kmeans", plan=plan, key=7, k=4, group="g",
                          algorithm="minibatch")
        svc.ingest("g", x).result()
        comps = svc.query("p", "components").unwrap()
        centers = svc.query("k", "centers").unwrap()
        t0 = time.perf_counter()
        svc.snapshot(ckpt_dir)
        t_save = time.perf_counter() - t0
        # original continues ingesting after the snapshot
        svc.ingest("g", more).result()
        comps_cont = svc.query("p", "components").unwrap()

    t0 = time.perf_counter()
    svc2 = restore_service(ckpt_dir)
    t_load = time.perf_counter() - t0
    with svc2:
        comps2 = svc2.query("p", "components").unwrap()
        centers2 = svc2.query("k", "centers").unwrap()
        assert np.array_equal(comps["components"], comps2["components"]), (
            "snapshot/restore round-trip is not bit-identical (PCA)")
        assert np.array_equal(centers, centers2), (
            "snapshot/restore round-trip is not bit-identical (K-means)")
        # resume: identical further ingest stays bit-identical (the restored
        # cursor continues at the same (step, shard) mask keys)
        svc2.ingest("g", more).result()
        comps2_cont = svc2.query("p", "components").unwrap()
        assert np.array_equal(comps_cont["components"],
                              comps2_cont["components"]), (
            "post-restore ingest diverged from the original process")
    record("serve/snapshot/roundtrip", (t_save + t_load) * 1e6,
           save_ms=round(t_save * 1e3, 1), restore_ms=round(t_load * 1e3, 1),
           bit_identical=True)


def run(json_path: str = "BENCH_serve.json"):
    RECORDS.clear()
    rng = np.random.default_rng(0)
    for n in (64, 256, 1024):
        tenant_sweep(n, rng)
    microbatch_bench(rng)
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        snapshot_bench(rng, os.path.join(d, "snap"))
    out = os.environ.get("BENCH_SERVE_JSON", json_path)
    with open(out, "w") as f:
        json.dump({"records": RECORDS}, f, indent=2)
    print(f"serve_bench: wrote {out} ({len(RECORDS)} records)", file=sys.stderr)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
