"""Fig. 5: concentration of H_k (Eq. 41) around I — Thm 7 bound tightness."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import bounds, sampling


def run(p: int = 100, gamma: float = 0.3, runs: int = 200):
    m = int(gamma * p)
    for n in (500, 2000, 8000):
        def one(k):
            idx = sampling.sample_indices(k, n, p, m)
            counts = jnp.zeros((p,)).at[idx.reshape(-1)].add(1.0)
            hk_diag = counts * (p / (m * n))                  # H_k is diagonal
            return jnp.max(jnp.abs(hk_diag - 1.0))

        errs = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(n), runs))
        t = bounds.hk_error_bound(0.001, n, m, p)
        emit(f"fig5/n={n}", 0.0,
             f"err_avg={float(jnp.mean(errs)):.4f} err_max={float(jnp.max(errs)):.4f} "
             f"bound={t:.4f} tightness={t/float(jnp.max(errs)):.2f}x")


if __name__ == "__main__":
    run()
