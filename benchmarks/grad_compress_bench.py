"""Beyond-paper: sketched gradient compression — estimator quality + wire bytes.

Validates the Thm-4 transfer: relative error of the reconstructed gradient vs γ
(with/without ROS preconditioning on a spiky gradient), plus the wire-byte
accounting used in §Perf.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import ros
from repro.core.grad_compress import CompressConfig, compress_decompress, wire_bytes


def run(p_total: int = 1 << 20):
    key = jax.random.PRNGKey(0)
    # spiky gradient: heavy tail (the case preconditioning exists for)
    g = jax.random.normal(key, (p_total,))
    spikes = jax.random.choice(jax.random.fold_in(key, 1), p_total, (p_total // 1000,), replace=False)
    g = g.at[spikes].mul(100.0)

    for gamma in (0.01, 0.05, 0.2):
        cfg = CompressConfig(gamma=gamma, chunk_p=1 << 14, error_feedback=False)
        g_hat, _ = compress_decompress(g, key, jnp.int32(0), cfg)
        rel = float(jnp.linalg.norm(g_hat - g) / jnp.linalg.norm(g))
        # ablation: no preconditioning (mask applied to raw chunks)
        chunks = g.reshape(-1, cfg.chunk_p)
        u = jax.random.uniform(jax.random.fold_in(key, 2), chunks.shape)
        idx = jnp.sort(jax.lax.top_k(u, cfg.m)[1], -1)
        vals = jnp.take_along_axis(chunks, idx, -1)
        raw = jnp.zeros_like(chunks).at[jnp.arange(chunks.shape[0])[:, None], idx].set(vals)
        raw = raw * (cfg.chunk_p / cfg.m)
        rel_raw = float(jnp.linalg.norm(raw.reshape(-1) - g) / jnp.linalg.norm(g))
        wb = wire_bytes(p_total, cfg, n_workers=32)
        emit(f"gradcomp/gamma={gamma}", 0.0,
             f"rel_err={rel:.3f} rel_err_no_precond={rel_raw:.3f} "
             f"wire_ratio={wb['ratio']:.3f}")

    # error feedback: residual saturates at ~((1−γ)/γ)·‖g‖ and the running
    # mean of the transmitted updates converges to g at rate ~1/(γT)
    cfg = CompressConfig(gamma=0.05, chunk_p=1 << 14, error_feedback=True)
    res = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    gn = float(jnp.linalg.norm(g))
    T = 32
    for step in range(T):
        g_hat, _ = compress_decompress(g + res, key, jnp.int32(step), cfg)
        res = (g + res) - g_hat
        acc = acc + g_hat
    rel = float(jnp.linalg.norm(acc / T - g)) / gn
    sat = float(jnp.linalg.norm(res)) / gn
    emit("gradcomp/error_feedback", 0.0,
         f"T={T} rel_err_of_mean={rel:.3f} residual_sat={sat:.1f} "
         f"theory_sat={(1-cfg.gamma)/cfg.gamma:.1f}")


if __name__ == "__main__":
    run()
