"""Benchmark runner — one module per paper table/figure (+ framework benches).

Prints ``name,us_per_call,derived`` CSV. See DESIGN.md §6 for the experiment
index; EXPERIMENTS.md records the reference outputs and their interpretation.

The api_bench suite additionally writes ``BENCH_api.json`` (rows/sec, backend,
γ per measurement, including the fused fit_many ingest speedup) — CI uploads
it as an artifact so the perf trajectory accumulates across commits.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        api_bench,
        bigdata_kmeans,
        cluster_bench,
        fig1_explained_variance,
        fig2_mean_bound,
        fig3_cov_bound,
        fig4_precond_effect,
        fig5_hk_concentration,
        fig7_kmeans_accuracy,
        fig8_kmeans_timing,
        grad_compress_bench,
        kernel_bench,
        lowrank_bench,
        obs_bench,
        refine_bench,
        serve_bench,
        stream_bench,
    )

    suites = [
        ("fig1_explained_variance", fig1_explained_variance.run),
        ("fig2_mean_bound", fig2_mean_bound.run),
        ("fig3_cov_bound", fig3_cov_bound.run),
        ("fig4_precond_effect", fig4_precond_effect.run),
        ("fig5_hk_concentration", fig5_hk_concentration.run),
        ("fig7_kmeans_accuracy", fig7_kmeans_accuracy.run),
        ("fig8_kmeans_timing", fig8_kmeans_timing.run),
        ("bigdata_kmeans", bigdata_kmeans.run),
        ("kernel_bench", kernel_bench.run),
        ("grad_compress_bench", grad_compress_bench.run),
        ("stream_bench", stream_bench.run),
        ("api_bench", api_bench.run),
        ("lowrank_bench", lowrank_bench.run),
        ("refine_bench", refine_bench.run),
        ("serve_bench", serve_bench.run),
        ("cluster_bench", cluster_bench.run),
        ("obs_bench", obs_bench.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"suite/{name},{(time.time()-t0)*1e6:.0f},ok", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"suite/{name},{(time.time()-t0)*1e6:.0f},FAILED", flush=True)
    if failures:
        raise SystemExit(failures)


if __name__ == "__main__":
    main()
