"""Kernel micro-benchmarks: spmm / spmm_t / FWHT / sketch_fused / sparse_assign.

On this CPU container the Pallas kernels run via the interpreter (correctness
path, far too slow to time); timings below benchmark the jnp reference
lowering of each kernel's math, while the TPU expectation comes from the
per-kernel analytic models in ``repro.roofline.kernels`` — which mirror the
ACTUAL tiled schedules (the spmm pair calls the same tile planner the kernels
use). Every measurement lands in ``BENCH_kernels.json`` with rows/sec and the
achieved-vs-roofline fraction so CI archives the per-kernel trajectory; the
p = 2^16 spmm entries double as the acceptance gate that the tiled kernels
(not the jnp fallback) are what ``ops._sparse_mode`` selects there.
"""
from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import ros
from repro.kernels import fwht as kfwht
from repro.kernels import ops, ref
from repro.kernels import spmm as kspmm
from repro.roofline import kernels as rl

RECORDS: list[dict] = []


def record(name: str, us: float, model: rl.KernelRoofline, **extra):
    rec = {"name": name, "us_per_call": round(us, 1),
           "rows_per_sec": round(model.n / (us / 1e6)),
           "tpu_roofline_us": round(model.us, 2),
           "tpu_roofline_rows_per_sec": round(model.rows_per_sec),
           "roofline_fraction": round(model.us / us, 6),
           "bound": model.bound, "hbm_bytes": model.hbm_bytes,
           "flops": model.flops, **extra}
    RECORDS.append(rec)
    emit(name, us,
         f"rows_per_sec={rec['rows_per_sec']:,} "
         f"tpu_roofline_us={model.us:.1f} frac={rec['roofline_fraction']:.2e}")


def _sparse_rows(key, n: int, m: int, p: int):
    vals = jax.random.normal(key, (n, m), jnp.float32)
    idx = jnp.sort(jax.lax.top_k(
        jax.random.uniform(jax.random.fold_in(key, 1), (n, p)), m)[1]
        .astype(jnp.int32), -1)
    return vals, idx


def run(json_path: str = "BENCH_kernels.json"):
    RECORDS.clear()
    key = jax.random.PRNGKey(0)

    # ---- FWHT preconditioning (single-tile and chunked-3-pass regimes) ------
    for p in (1024, 8192, 1 << 16):
        n = min(2048, (1 << 25) // p)
        x = jax.random.normal(key, (n, p), jnp.float32)
        s = jax.random.rademacher(jax.random.fold_in(key, 1), (p,), jnp.float32)
        fn = jax.jit(lambda x, s: ref.ref_hd_precondition(x, s))
        us = timeit(fn, x, s)
        record(f"kernel/fwht/p={p}", us, rl.fwht_roofline(n, p),
               n=n, p=p)

    # ---- tiled spmm / spmm_t (the low-rank projection pair) -----------------
    # p = 2^16 at l = 128 is the acceptance shape: the tiled kernels must be
    # what the VMEM gate selects there (pre-tiling it fell back to jnp)
    ell, m = 128, 64
    for p in (4096, 1 << 16):
        n = 512
        vals, idx = _sparse_rows(key, n, m, p)
        dense = jax.random.normal(jax.random.fold_in(key, 2), (p, ell), jnp.float32)
        t = jax.random.normal(jax.random.fold_in(key, 3), (n, ell), jnp.float32)

        selected = ops._sparse_mode("kernel", p, ell)
        assert selected == "kernel", (
            f"_sparse_mode demoted p={p}, l={ell} to {selected!r} — the tiled "
            "spmm schedule should fit the VMEM budget at any p")
        br, pb = kspmm.plan_tiles(p, ell, jnp.float32, jnp.float32)

        us = timeit(jax.jit(ref.ref_spmm), vals, idx, dense)
        record(f"kernel/spmm/p={p}", us, rl.spmm_roofline(n, m, p, ell),
               n=n, m=m, p=p, ell=ell, block_rows=br, block_cols=pb)

        us = timeit(jax.jit(lambda v, i, t: ref.ref_spmm_t(v, i, t, p)),
                    vals, idx, t)
        record(f"kernel/spmm_t/p={p}", us, rl.spmm_t_roofline(n, m, p, ell),
               n=n, m=m, p=p, ell=ell, block_rows=br, block_cols=pb)

    # ---- fused sketch (the streaming-ingest fast path) ----------------------
    # fused regime (p ≤ 2^15) and the composed chunked-FWHT + gather fallback
    for p in (4096, 1 << 16):
        n = min(1024, (1 << 24) // p)
        m_s = max(8, p // 20)  # γ = 0.05, the paper's Tables III/IV setting
        x = jax.random.normal(key, (n, p), jnp.float32)
        s = jax.random.rademacher(jax.random.fold_in(key, 1), (p,), jnp.float32)
        _, idx = _sparse_rows(jax.random.fold_in(key, 4), n, m_s, p)
        fn = jax.jit(lambda x, s, i: ref.ref_sketch_fused(x, s, i))
        us = timeit(fn, x, s, idx)
        record(f"kernel/sketch_fused/p={p}", us,
               rl.sketch_fused_roofline(n, p, m_s),
               n=n, p=p, m=m_s,
               regime="fused" if p <= kfwht.MAX_P_SINGLE else "composed")

    # ---- sparse assignment: compact (values, indices) vs dense distances ----
    n, p, k = 8192, 1024, 16
    for gamma in (0.05, 0.2):
        m_a = int(gamma * p)
        vals, idx = _sparse_rows(key, n, m_a, p)
        ctr = jax.random.normal(key, (k, p), jnp.float32)
        fn = jax.jit(lambda v, i, c: ref.ref_sparse_assign(v, i, c)[0])
        us = timeit(fn, vals, idx, ctr)
        hbm = n * m_a * 8 + k * p * 4
        model = rl.KernelRoofline("sparse_assign", n, hbm, 2 * n * p * k * 2)
        record(f"kernel/sparse_assign/gamma={gamma}", us, model,
               n=n, p=p, k=k, gamma=gamma)

    out = os.environ.get("BENCH_KERNELS_JSON", json_path)
    with open(out, "w") as f:
        json.dump({"records": RECORDS}, f, indent=2)
    print(f"kernel_bench: wrote {out} ({len(RECORDS)} records)", file=sys.stderr)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
