"""Kernel micro-benchmarks: FWHT preconditioning + sparse assignment.

On this CPU container the Pallas kernels run via the interpreter (correctness
path); timings below benchmark the jnp reference lowering — the TPU roofline
expectations (MXU-resident Kronecker matmuls) are derived analytically and
reported as `derived`.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import ros
from repro.kernels import fwht as kfwht
from repro.kernels import ref


def run():
    key = jax.random.PRNGKey(0)
    for p in (1024, 4096, 8192):
        n = 2048
        x = jax.random.normal(key, (n, p), jnp.float32)
        s = jax.random.rademacher(jax.random.fold_in(key, 1), (p,), jnp.float32)
        fn = jax.jit(lambda x, s: ref.ref_hd_precondition(x, s))
        us = timeit(fn, x, s)
        bytes_moved = 2 * n * p * 4
        a, b = kfwht.factor_p(p)
        macs = n * p * (a + b)
        tpu_us = max(bytes_moved / 819e9, macs * 2 / 197e12) * 1e6
        emit(f"kernel/fwht/p={p}", us,
             f"cpu_GBps={bytes_moved/us*1e6/1e9:.1f} kronecker=({a}x{b}) "
             f"tpu_roofline_us={tpu_us:.1f}")

    # sparse assignment: compact (values, indices) vs dense distances
    n, p, k = 8192, 1024, 16
    for gamma in (0.05, 0.2):
        m = int(gamma * p)
        vals = jax.random.normal(key, (n, m), jnp.float32)
        idx = jnp.sort(jax.lax.top_k(jax.random.uniform(key, (n, p)), m)[1].astype(jnp.int32), -1)
        ctr = jax.random.normal(key, (k, p), jnp.float32)
        fn = jax.jit(lambda v, i, c: ref.ref_sparse_assign(v, i, c)[0])
        us = timeit(fn, vals, idx, ctr)
        hbm = n * m * 8 + k * p * 4
        tpu_us = max(hbm / 819e9, 2 * n * p * k * 2 / 197e12) * 1e6
        emit(f"kernel/sparse_assign/gamma={gamma}", us,
             f"compact_bytes={n*m*8>>20}MB dense_bytes={n*p*4>>20}MB tpu_roofline_us={tpu_us:.1f}")


if __name__ == "__main__":
    run()
