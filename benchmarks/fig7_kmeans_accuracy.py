"""Figs. 6/7: clustering accuracy vs γ for all K-means variants.

Synthetic well-separated clusters stand in for MNIST (no offline dataset);
the orderings the paper reports are what we validate:
  2-pass ≥ sparsified ≥ feature-extraction ≳ no-precond ≥ feature-selection,
with sampling-based variants showing much smaller variance.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import kmeans as km


def make_data(key, n, p, k, noise=1.8, spiky_frac=0.06):
    """Spiky clusters (MNIST-like coherence): centers live on few coordinates,
    so raw uniform sampling misses them — the regime preconditioning fixes."""
    ck, mk, lk, nk = jax.random.split(key, 4)
    centers = jax.random.normal(ck, (k, p)) * 4.0
    mask = jax.random.uniform(mk, (k, p)) < spiky_frac
    centers = jnp.where(mask, centers / jnp.sqrt(spiky_frac), 0.0)
    labels = jax.random.randint(lk, (n,), 0, k)
    x = centers[labels] + noise * jax.random.normal(nk, (n, p))
    return x, labels


def run(n: int = 4000, p: int = 256, k: int = 5, trials: int = 3):
    x, labels = make_data(jax.random.PRNGKey(0), n, p, k)
    res = km.kmeans(x, k, jax.random.PRNGKey(99), n_init=3, max_iter=60)
    acc_full = km.clustering_accuracy(res.assignments, labels, k)
    emit("fig7/standard", 0.0, f"acc={acc_full:.3f}")

    for gamma in (0.05, 0.1, 0.3):
        m = max(2, int(gamma * p))
        rows = {"sparsified": [], "sparsified_2pass": [], "no_precond": [],
                "feat_extract": [], "feat_select": []}
        for t in range(trials):
            kk = jax.random.PRNGKey(1000 + t)
            r = km.sparsified_kmeans(x, k, kk, gamma=gamma, n_init=3, max_iter=60)
            rows["sparsified"].append(km.clustering_accuracy(r.assignments, labels, k))
            r = km.sparsified_kmeans(x, k, kk, gamma=gamma, two_pass=True, n_init=3, max_iter=60)
            rows["sparsified_2pass"].append(km.clustering_accuracy(r.assignments, labels, k))
            r = km.sparsified_kmeans(x, k, kk, gamma=gamma, precondition=False, n_init=3, max_iter=60)
            rows["no_precond"].append(km.clustering_accuracy(r.assignments, labels, k))
            r = km.feature_extraction_kmeans(x, k, m, kk, n_init=3, max_iter=60)
            rows["feat_extract"].append(km.clustering_accuracy(r.assignments, labels, k))
            r = km.feature_selection_kmeans(x, k, m, kk, n_init=3, max_iter=60)
            rows["feat_select"].append(km.clustering_accuracy(r.assignments, labels, k))
        for name, accs in rows.items():
            emit(f"fig7/{name}/gamma={gamma}", 0.0,
                 f"acc={np.mean(accs):.3f}±{np.std(accs):.3f}")


if __name__ == "__main__":
    run()
