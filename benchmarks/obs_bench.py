"""Telemetry overhead + reconciliation benchmark → ``BENCH_obs.json``.

Three claims of the observability layer (repro.obs), each measured and gated:

1. **Zero-cost disabled** — an engine run with ``telemetry=None`` (the
   default) vs the pre-obs loop shape: the telemetry branch is one
   ``if tel is None`` per step, so the run must sit within noise of itself
   across repeats (gated loosely at ≤5% spread — pure run-to-run noise).
2. **≤3% enabled** — the SAME run with a full :class:`EngineTelemetry`
   (registry + spans + per-step records into a JSONL StepLogger) must cost
   ≤3% wall time over the telemetry-off median. The JSONL goes to
   ``obs_smoke.jsonl`` and is uploaded as a CI artifact next to the JSON.
3. **Exact reconciliation at 256 tenants** — a 256-tenant SketchService run
   where every registry metric the serving layer exposes (request counters,
   coalesce histogram, queue-depth/pending gauges, submit→resolve latency
   count) reconciles EXACTLY with the known request totals — metrics that
   drift from the truth are worse than no metrics.

CI runs this as the ``obs-bench`` job and uploads both artifacts so the
overhead trajectory accumulates across commits.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro import obs
from repro.core import sketch
from repro.stream import EngineTelemetry, StreamEngine

RECORDS: list[dict] = []

P_DIM = 512
BATCH = 256
STEPS = 60
REPEATS = 5


def record(name: str, us: float, **extra):
    rec = {"name": name, "us_per_call": round(us, 1), **extra}
    RECORDS.append(rec)
    derived = " ".join(f"{k}={v}" for k, v in extra.items()
                       if isinstance(v, (int, float, str)))
    emit(name, us, derived)


# ------------------------------------------------------- engine overhead ----


def _make_engine():
    spec = sketch.make_spec(P_DIM, jax.random.PRNGKey(1), gamma=0.1)
    data = np.asarray(jax.random.normal(jax.random.PRNGKey(0),
                                        (8, BATCH, P_DIM)))
    return StreamEngine(spec, lambda seed, step, shard: data[step % 8],
                        track_cov=True)


def _run_once(engine, telemetry=None) -> float:
    t0 = time.perf_counter()
    res = engine.run(STEPS, telemetry=telemetry)
    jax.block_until_ready(res.mean)
    return time.perf_counter() - t0


def engine_overhead(jsonl_path: str) -> None:
    engine = _make_engine()
    _run_once(engine)   # compile once; every arm below is steady-state

    off = sorted(_run_once(engine) for _ in range(REPEATS))
    t_off = off[len(off) // 2]

    def _tel(logger):
        return EngineTelemetry(registry=obs.MetricsRegistry(),
                               step_logger=logger)

    with open(jsonl_path, "w") as f:
        on = sorted(_run_once(engine, _tel(obs.StepLogger(stream=f)))
                    for _ in range(REPEATS))
    t_on = on[len(on) // 2]

    noise = (off[-1] - off[0]) / t_off
    overhead = t_on / t_off - 1.0
    rows = STEPS * BATCH
    record("obs/engine/telemetry_off", t_off / STEPS * 1e6,
           rows_per_sec=round(rows / t_off), repeats=REPEATS,
           noise_spread=round(noise, 4))
    record("obs/engine/telemetry_on", t_on / STEPS * 1e6,
           rows_per_sec=round(rows / t_on),
           overhead_frac=round(overhead, 4))

    smoke = obs.read_jsonl(jsonl_path)
    assert len(smoke) == STEPS * REPEATS, (
        f"telemetry JSONL has {len(smoke)} records, expected "
        f"{STEPS} steps x {REPEATS} repeats")
    assert smoke[-1]["rows_total"] == rows, (
        "telemetry JSONL does not cover the run")
    assert overhead <= 0.03, (
        f"enabled telemetry costs {overhead * 100:.1f}% (> 3% gate) — "
        f"off={t_off:.4f}s on={t_on:.4f}s")


# ------------------------------------------- 256-tenant exact reconcile -----


def serve_reconcile(n_tenants: int = 256) -> None:
    from repro.api import Plan
    from repro.sketchserve import SketchService

    rng = np.random.default_rng(0)
    plan = Plan(backend="stream", gamma=0.25, batch_size=128,
                cov_path="lowrank", rank=4)
    groups = 32
    rows_per, n_queries, n_rejected = 16, 32, 4
    rows = rng.normal(size=(rows_per, 64)).astype(np.float32)
    # sized so the workload's own ingest always admits, while one deliberately
    # oversized request per rejection deterministically trips the per-group cap
    cap = (2 * n_tenants // groups) * rows_per + rows_per
    too_big = np.zeros((cap + 1, 64), np.float32)

    t0 = time.perf_counter()
    with SketchService(max_queue=8 * n_tenants, max_batch=64,
                       max_pending_rows=cap) as svc:
        for i in range(n_tenants):
            svc.create_tenant(f"t{i}", "pca" if i % 2 else "mean", plan=plan,
                              key=1, group=f"g{i % groups}",
                              **({"n_components": 2} if i % 2 else {}))
        futs = [svc.ingest(f"g{i % groups}", rows)
                for i in range(2 * n_tenants)]
        assert all(f.result(120).ok for f in futs)
        # deterministic backpressure: a single request larger than the cap is
        # rejected at submit — and MUST still be latency-accounted below
        for i in range(n_rejected):
            r = svc.ingest(f"g{i}", too_big).result(120)
            assert r.status == "rejected", r
        for i in range(n_queries):
            svc.query(f"t{2 * i + 1}", "components").unwrap()
        stats = svc.stats
        reg = svc.registry
        dt = time.perf_counter() - t0

        n_ingest = 2 * n_tenants
        assert stats["ingest_requests"] == n_ingest
        assert stats["ingest_rows"] == n_ingest * rows_per
        assert stats["queries"] == n_queries
        assert stats["rejected"] == n_rejected
        assert stats["requests"] == n_ingest + n_queries + n_tenants
        # every ingest request is accounted to exactly one coalesced fold
        h_coal = reg.histogram("serve.coalesced_requests")
        assert h_coal.sum == n_ingest and h_coal.count == stats["ingest_folds"]
        # everything admitted was folded; the backlog gauges settled to zero
        assert reg.gauge("serve.pending_rows").value == 0
        assert reg.gauge("serve.queue_depth").value == 0
        # every request's submit→resolve latency was observed — INCLUDING the
        # rejected ones (the submit fast path must route through _resolve_fut,
        # not bare set_result; rejections invisible to the latency histogram
        # would understate tail latency exactly when the service is saturated)
        h_lat = reg.histogram("serve.request_seconds")
        assert h_lat.count == n_ingest + n_queries + n_tenants + n_rejected
        # the exposition renders every serving series (scrape-ready)
        text = obs.render_exposition(reg)
        for needle in ("serve_queue_depth", "serve_pending_rows",
                       "serve_request_seconds_count",
                       "serve_coalesced_requests_count"):
            assert needle in text, f"exposition is missing {needle}"
        lat_p50, lat_p99 = h_lat.quantile(0.5, 0.99)

    coalesce = n_ingest / max(stats["ingest_folds"], 1)
    record(f"obs/serve/reconcile/{n_tenants}", dt / n_ingest * 1e6,
           tenants=n_tenants, ingest_requests=n_ingest,
           requests_per_fold=round(coalesce, 2),
           latency_p50_ms=round(lat_p50 * 1e3, 2),
           latency_p99_ms=round(lat_p99 * 1e3, 2),
           reconciled=True)


def run(json_path: str = "BENCH_obs.json"):
    RECORDS.clear()
    jsonl = os.environ.get("OBS_SMOKE_JSONL", "obs_smoke.jsonl")
    engine_overhead(jsonl)
    serve_reconcile()
    out = os.environ.get("BENCH_OBS_JSON", json_path)
    with open(out, "w") as f:
        json.dump({"records": RECORDS}, f, indent=2)
    print(f"obs_bench: wrote {out} ({len(RECORDS)} records)", file=sys.stderr)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
