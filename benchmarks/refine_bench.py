"""Second-pass refinement: passes-vs-accuracy and replay throughput.

Sweeps ``fit_refine(passes=q)`` for PCA on the spiked model (dense-vs-lowrank
max principal-angle sine per pass count — the headline: one replay pass buys
≥ 10× subspace accuracy at a narrow rank, for zero stored data) and two-pass
K-means (refined-center distance to the planted truth + the per-rebuild
reassignment counts decaying to a Lloyd fixed point). Records rows/sec for the
forward ingest and for each replay pass — replay regenerates sketches, so a
pass should cost about one ingest, and a regression that re-sketches per
consumer or per refiner shows up here.

Writes ``BENCH_refine.json`` (name, us_per_call, rows/sec, angle / truth-dist
per pass count) — uploaded as a CI artifact by the refine-bench job. The
passes-vs-accuracy gates are asserted so CI fails if refinement stops
refining.
"""
from __future__ import annotations

import json
import os
import sys

import jax
import numpy as np

from benchmarks.common import emit, max_angle_sin as _max_angle_sin, spiked, timeit
from repro.api import Plan, SparsifiedKMeans, SparsifiedPCA

RECORDS: list[dict] = []


def _spiked(n, p, k):
    return spiked(jax.random.PRNGKey(0), n, p, k)


def _clusters(n, p, k, sep=3.0, noise=1.0):
    key = jax.random.PRNGKey(7)
    ck, lk, nk = jax.random.split(key, 3)
    centers = jax.random.normal(ck, (k, p)) * sep
    labels = jax.random.randint(lk, (n,), 0, k)
    return centers[labels] + noise * jax.random.normal(nk, (n, p)), centers


def record(name, us, rows, **extra):
    rec = {"name": name, "us_per_call": round(us, 1),
           "rows_per_sec": round(rows / (us / 1e6)), **extra}
    RECORDS.append(rec)
    emit(name, us, " ".join(f"{k}={v}" for k, v in
                            [("rows_per_sec", f"{rec['rows_per_sec']:,}")]
                            + sorted(extra.items())))


def run(json_path: str = "BENCH_refine.json"):
    RECORDS.clear()
    # ---- PCA: passes vs accuracy (and replay throughput) -------------------
    n, p, k, ell = 8192, 256, 4, 12          # rank = 3k: the one-pass gap shows
    x = _spiked(n, p, k)
    dense = SparsifiedPCA(k, Plan(gamma=0.5, batch_size=2048), key=1).fit(x)
    plan = Plan(backend="stream", gamma=0.5, batch_size=2048,
                cov_path="lowrank", rank=ell)
    angles = {}
    for passes in (0, 1, 2):
        def fit(passes=passes):
            est = SparsifiedPCA(k, plan, key=1)
            return (est.fit(x) if passes == 0
                    else est.fit_refine(x, passes=passes)).components_

        comps = fit()
        angles[passes] = _max_angle_sin(comps, dense.components_)
        us = timeit(fit, warmup=1, iters=3)
        # each replay pass re-ingests all n rows: normalize throughput to the
        # total rows the call actually streamed
        record(f"refine/pca/passes{passes}", us, n * (1 + passes),
               max_angle_sin_vs_dense=round(angles[passes], 6), passes=passes)

    # the acceptance gate: ONE pass buys >= 10x subspace accuracy
    assert angles[1] * 10 <= angles[0], (
        f"refinement stopped refining: one-pass angle {angles[0]:.2e}, "
        f"refined {angles[1]:.2e}")
    assert angles[2] <= angles[1] * 2, (
        "second pass regressed the subspace noticeably: "
        f"{angles[1]:.2e} -> {angles[2]:.2e}")

    # ---- K-means: two-pass center error + reassignment decay ---------------
    nk_, pk_, kk_ = 16384, 64, 6
    xc, truth = _clusters(nk_, pk_, kk_)
    planc = Plan(backend="stream", gamma=0.25, batch_size=2048)

    def truth_dist(centers):
        from scipy.optimize import linear_sum_assignment

        d = np.linalg.norm(np.asarray(centers)[:, None]
                           - np.asarray(truth)[None], axis=-1)
        ri, ci = linear_sum_assignment(d)
        return float(d[ri, ci].mean())

    one = SparsifiedKMeans(kk_, planc, key=2, algorithm="minibatch").fit(xc)
    d_one = truth_dist(one.centers_)
    us = timeit(lambda: SparsifiedKMeans(kk_, planc, key=2,
                                         algorithm="minibatch").fit(xc).centers_,
                warmup=0, iters=1)
    record("refine/kmeans/passes0", us, nk_, dist_to_truth=round(d_one, 4))

    ref = SparsifiedKMeans(kk_, planc, key=2,
                           algorithm="minibatch").fit_refine(xc, passes=2)
    d_ref = truth_dist(ref.centers_)
    us = timeit(lambda: SparsifiedKMeans(kk_, planc, key=2, algorithm="minibatch")
                .fit_refine(xc, passes=2).centers_, warmup=0, iters=1)
    # forward + 2 rebuild passes + 1 measurement replay = 4 ingests
    record("refine/kmeans/passes2", us, nk_ * 4, dist_to_truth=round(d_ref, 4),
           reassigned=[int(c) for c in ref.refine_reassign_counts_])
    assert d_ref <= d_one * 1.05, (
        f"two-pass centers drifted from truth: {d_one:.4f} -> {d_ref:.4f}")
    cnts = ref.refine_reassign_counts_
    assert cnts[-1] <= max(cnts[0], 1), (
        f"reassignment counts did not decay across rebuilds: {cnts}")

    out = os.environ.get("BENCH_REFINE_JSON", json_path)
    with open(out, "w") as f:
        json.dump({"records": RECORDS, "p": p, "rank": ell,
                   "pca_angles_by_passes": {str(q): a for q, a in angles.items()},
                   "kmeans_dist_to_truth": {"passes0": d_one, "passes2": d_ref}},
                  f, indent=2)
    print(f"refine_bench: wrote {out} ({len(RECORDS)} records)", file=sys.stderr)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
