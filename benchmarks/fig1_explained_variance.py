"""Fig. 1: explained variance of estimated PCs — precondition+sparsify vs
uniform column sampling, heavy-tailed data (multivariate t, df=1).

Paper's claim: comparable mean accuracy but ~10× smaller std for our approach.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import estimators, pca, sampling, sketch


def t_dist_data(rng, n, p):
    """Multivariate t (df=1) with C_ij = 2·0.5^|i-j| (paper §II-A)."""
    idx = np.arange(p)
    c = 2.0 * 0.5 ** np.abs(idx[:, None] - idx[None, :])
    lchol = np.linalg.cholesky(c + 1e-9 * np.eye(p))
    g = rng.normal(size=(n, p)) @ lchol.T
    chi = rng.chisquare(df=1, size=(n, 1))
    return (g / np.sqrt(chi)).astype(np.float32)


def run(n_runs: int = 20, p: int = 256, n: int = 512, k: int = 10):
    rng = np.random.default_rng(0)
    for gamma in (0.1, 0.2, 0.3, 0.5):
        ours, cols = [], []
        for r in range(n_runs):
            x = jnp.asarray(t_dist_data(rng, n, p))
            key = jax.random.PRNGKey(r)
            spec = sketch.make_spec(p, key, gamma=gamma)
            s = sketch.sketch(x, spec)
            res = pca.sparsified_pca(s, spec, k)
            ours.append(float(pca.explained_variance(res.components, x)))
            # matched storage: n_cols·p nonzeros == n·m kept entries
            n_cols = min(n, int(round(n * spec.m / p)))
            sel = rng.choice(n, n_cols, replace=False)
            res_c = pca.pca(x[sel], k)
            cols.append(float(pca.explained_variance(res_c.components, x)))
        emit(f"fig1/ours/gamma={gamma}", 0.0,
             f"ev_mean={np.mean(ours):.4f} ev_std={np.std(ours):.4f}")
        emit(f"fig1/colsample/gamma={gamma}", 0.0,
             f"ev_mean={np.mean(cols):.4f} ev_std={np.std(cols):.4f}")


if __name__ == "__main__":
    run()
