"""Front-door overhead: the same PCA/mean job through every Plan backend.

Times ``repro.api`` estimators fitting identical data on backend = batch /
stream / sharded (1-device mesh on this container — the collectives still run,
over an axis of size one), plus the compact vs dense covariance delta path.
The point of the measurement: the unified layer's dispatch + chunked key
discipline must cost ~nothing over calling the core functions directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.api import Plan, SparsifiedCov, SparsifiedPCA


def run():
    n, p = 8192, 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (n, p), jnp.float32)
    plan = Plan(backend="batch", gamma=0.05, batch_size=2048)

    for backend in ("batch", "stream", "sharded"):
        pl = plan.replace(backend=backend)

        def fit():
            est = SparsifiedPCA(8, pl, key=1).fit(x)
            return est.components_

        us = timeit(fit, warmup=1, iters=3)
        emit(f"api/pca/{backend}", us, f"rows_per_sec={n / (us / 1e6):,.0f}")

    for path in ("dense", "compact"):
        pl = plan.replace(backend="stream", cov_path=path, gamma=0.02)

        def fit_cov():
            return SparsifiedCov(pl, key=1).fit(x).cov_

        us = timeit(fit_cov, warmup=1, iters=3)
        emit(f"api/cov/{path}", us, f"rows_per_sec={n / (us / 1e6):,.0f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
