"""Front-door overhead + the fused fit_many ingest win.

Times ``repro.api`` estimators fitting identical data on backend = batch /
stream / sharded (1-device mesh on this container — the collectives still run,
over an axis of size one), the compact vs dense covariance delta path, and the
headline measurement: ingest throughput of the PCA+K-means pair FUSED through
``fit_many`` (one sketch pass feeds both) vs sequential fits (each consumer
sketches the data itself). The fused pass does half the compression work, so
it should land near 2× — the acceptance bar is ≥1.5×.

Every measurement is also recorded to ``BENCH_api.json``
(name, us_per_call, rows/sec, backend, γ) so CI can archive the perf
trajectory as an artifact.
"""
from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.api import Plan, SparsifiedCov, SparsifiedKMeans, SparsifiedPCA, fit_many

RECORDS: list[dict] = []


def record(name: str, us: float, rows: int, backend: str, gamma: float, **extra):
    rec = {"name": name, "us_per_call": round(us, 1),
           "rows_per_sec": round(rows / (us / 1e6)), "backend": backend,
           "gamma": gamma, **extra}
    RECORDS.append(rec)
    derived = f"rows_per_sec={rec['rows_per_sec']:,}"
    if "speedup_vs_sequential" in extra:
        derived += f" speedup={extra['speedup_vs_sequential']:.2f}x"
    emit(name, us, derived)


def run(json_path: str = "BENCH_api.json"):
    RECORDS.clear()
    n, p = 8192, 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (n, p), jnp.float32)
    plan = Plan(backend="batch", gamma=0.05, batch_size=2048)

    for backend in ("batch", "stream", "sharded"):
        pl = plan.replace(backend=backend)

        def fit():
            est = SparsifiedPCA(8, pl, key=1).fit(x)
            return est.components_

        us = timeit(fit, warmup=1, iters=3)
        record(f"api/pca/{backend}", us, n, backend, pl.gamma)

    for path in ("dense", "compact"):
        pl = plan.replace(backend="stream", cov_path=path, gamma=0.02)

        def fit_cov():
            return SparsifiedCov(pl, key=1).fit(x).cov_

        us = timeit(fit_cov, warmup=1, iters=3)
        record(f"api/cov/{path}", us, n, "stream", pl.gamma)

    # ---- the tentpole measurement: shared-sketch ingest for PCA + K-means --
    # Ingest only (finalize is identical work in both arms): sequential fits
    # sketch the data once PER consumer; fit_many sketches once TOTAL.

    def seq_ingest():
        SparsifiedPCA(8, plan, key=1).partial_fit(x).sync()
        SparsifiedKMeans(8, plan, key=1).partial_fit(x).sync()

    def fused_ingest():
        fit_many(plan, [SparsifiedPCA(8, plan, key=1),
                        SparsifiedKMeans(8, plan, key=1)], x,
                 finalize=False).sync()

    us_seq = timeit(seq_ingest, warmup=1, iters=3)
    us_fused = timeit(fused_ingest, warmup=1, iters=3)
    speedup = us_seq / us_fused
    record("api/fused_ingest/pca+kmeans/sequential", us_seq, n, "batch", plan.gamma)
    record("api/fused_ingest/pca+kmeans/fit_many", us_fused, n, "batch", plan.gamma,
           speedup_vs_sequential=speedup)
    # gate the shared-sketch win so CI catches a re-sketch-per-consumer
    # regression (~2× in practice; 1.3 floor leaves timer-noise headroom
    # under the 1.5× acceptance bar)
    assert speedup >= 1.3, (
        f"fused fit_many ingest only {speedup:.2f}x over sequential fits — "
        "the shared sketch pass has regressed")

    # ---- scanned ingest: fit_many(scan=True)'s lax.scan hot loop vs the ----
    # per-chunk host loop, on the stream backend (PCA moments + minibatch
    # K-means — both scan-eligible folds). The compiled scan is lru-cached,
    # so the timed iterations measure the hot loop, not compilation. Small
    # chunks (batch_size=256 → 32 steps) are the regime the scan exists for:
    # per-chunk Python dispatch dominates the host loop there.
    spl = plan.replace(backend="stream", batch_size=256)

    def host_ingest():
        fit_many(spl, [SparsifiedPCA(8, spl, key=1),
                       SparsifiedKMeans(8, spl, key=1, algorithm="minibatch")],
                 x, finalize=False).sync()

    def scan_ingest():
        fit_many(spl, [SparsifiedPCA(8, spl, key=1),
                       SparsifiedKMeans(8, spl, key=1, algorithm="minibatch")],
                 x, finalize=False, scan=True).sync()

    us_host = timeit(host_ingest, warmup=1, iters=3)
    us_scan = timeit(scan_ingest, warmup=1, iters=3)
    record("api/scan_ingest/pca+kmeans/host_loop", us_host, n, "stream", plan.gamma)
    record("api/scan_ingest/pca+kmeans/lax_scan", us_scan, n, "stream", plan.gamma,
           speedup_vs_sequential=us_host / us_scan)

    out = os.environ.get("BENCH_API_JSON", json_path)
    with open(out, "w") as f:
        json.dump({"records": RECORDS}, f, indent=2)
    print(f"api_bench: wrote {out} ({len(RECORDS)} records)", file=sys.stderr)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
