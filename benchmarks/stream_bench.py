"""Streaming sketch engine throughput: rows/sec of source → sketch → accumulate.

Times the engine's fully-jitted lax.scan hot loop (StreamEngine.run_scanned)
over a pre-staged stream, sweeping batch size, γ = m/p, and p. The covariance
accumulator is tracked where the (p, p) state fits comfortably and dropped for
the large-p mean-only row, mirroring how the engine is deployed at scale.

On this CPU container the preconditioner is the jnp butterfly; on TPU the same
engine runs the Pallas Kronecker kernels (chunked three-pass above p = 2^15),
so the rows/sec printed here is the portable lower bound of the hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.api import Plan, make_engine


def _bench_one(p: int, gamma: float, batch: int, steps: int, track_cov: bool):
    key = jax.random.PRNGKey(p + batch)
    plan = Plan(backend="stream", gamma=gamma, batch_size=batch)
    xs = jax.random.normal(key, (steps, 1, batch, p), jnp.float32)
    eng = make_engine(plan, p, jax.random.fold_in(key, 1),
                      lambda seed, step, shard: None, track_cov=track_cov)
    spec = eng.spec

    def fold(xs):
        res = eng.run_scanned(xs)
        return res.cov if track_cov else res.mean

    us = timeit(fold, xs, warmup=1, iters=3)
    rows = steps * batch
    rows_per_sec = rows / (us / 1e6)
    emit(f"stream/p={p}/g={gamma}/b={batch}", us,
         f"rows_per_sec={rows_per_sec:,.0f} m={spec.m} cov={int(track_cov)}")


def run():
    # batch-size sweep at fixed (p, γ)
    for batch in (128, 512):
        _bench_one(p=4096, gamma=0.05, batch=batch, steps=8, track_cov=True)
    # γ sweep
    _bench_one(p=4096, gamma=0.2, batch=512, steps=8, track_cov=True)
    # large-p regime (mean-only accumulator; preconditioner chunked on TPU)
    _bench_one(p=1 << 16, gamma=0.01, batch=64, steps=4, track_cov=False)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
