"""Fig. 2: tightness of the Thm-4 mean-estimator bound (ℓ∞, δ₁=0.001).

Paper's claim: the bound tracks the max over runs closely and decays with n.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import bounds, estimators, sampling


def run(p: int = 100, gamma: float = 0.3, runs: int = 200):
    m = int(gamma * p)
    base = jax.random.PRNGKey(7)
    xbar = jax.random.normal(base, (p,))
    for n in (1000, 4000, 16000):
        x = xbar[None, :] + jax.random.normal(jax.random.fold_in(base, n), (n, p))

        def one(k):
            s = sampling.subsample(x, k, m)
            return jnp.max(jnp.abs(estimators.mean_estimator(s) - estimators.empirical_mean(x)))

        errs = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(1), runs))
        t = bounds.mean_error_bound(
            0.001, n, m, p, float(bounds.max_abs(x)), float(bounds.max_coord_norm(x))
        )
        emit(f"fig2/n={n}", 0.0,
             f"err_avg={float(jnp.mean(errs)):.5f} err_max={float(jnp.max(errs)):.5f} "
             f"bound={t:.5f} tightness={t/float(jnp.max(errs)):.2f}x")


if __name__ == "__main__":
    run()
