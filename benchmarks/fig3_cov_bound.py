"""Fig. 3: covariance-estimator error vs (n, γ) against the Thm-6 bound.

Paper's claim: bound within ~an order of magnitude (they plot bound/10), error
decays with n at fixed γ and with γ at fixed n.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import bounds, estimators, sampling


def gen(key, n, p, k=5):
    lam = jnp.asarray([10.0, 8.0, 6.0, 4.0, 2.0])
    u, _ = jnp.linalg.qr(jax.random.normal(key, (p, k)))
    kappa = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
    return (kappa * lam[None, :]) @ u.T


def run(p: int = 300, runs: int = 20):
    key = jax.random.PRNGKey(3)
    gamma = 0.3
    for n in (p, 3 * p, 10 * p):
        x = gen(key, n, p)
        m = int(gamma * p)
        errs = []
        for r in range(runs):
            s = sampling.subsample(x, jax.random.PRNGKey(r), m)
            errs.append(float(jnp.linalg.norm(
                estimators.cov_estimator(s) - estimators.empirical_cov(x), ord=2)))
        terms = bounds.cov_bound_from_data(x, m, rho=1.0)
        t = terms.error_bound(0.01)
        emit(f"fig3a/n={n}", 0.0,
             f"err_avg={np.mean(errs):.3f} err_max={np.max(errs):.3f} "
             f"bound_div10={t/10:.3f} bound={t:.3f}")
    n = 10 * p
    x = gen(key, n, p)
    for gamma in (0.1, 0.3, 0.5):
        m = int(gamma * p)
        errs = []
        for r in range(runs):
            s = sampling.subsample(x, jax.random.PRNGKey(100 + r), m)
            errs.append(float(jnp.linalg.norm(
                estimators.cov_estimator(s) - estimators.empirical_cov(x), ord=2)))
        terms = bounds.cov_bound_from_data(x, m, rho=1.0)
        t = terms.error_bound(0.01)
        emit(f"fig3b/gamma={gamma}", 0.0,
             f"err_avg={np.mean(errs):.3f} err_max={np.max(errs):.3f} bound_div10={t/10:.3f}")


if __name__ == "__main__":
    run()
