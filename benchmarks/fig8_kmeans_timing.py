"""Fig. 8 + Table V: per-iteration speedup of sparsified vs dense K-means.

Times the two Lloyd kernels (assignment + center update) on identical data.
CPU wall-clock (the container target); the γ-proportional flop reduction is the
paper's claim — on TPU the win is realized as bandwidth (DESIGN.md §3.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import kmeans as km
from repro.core import sketch


def run(n: int = 20000, p: int = 512, k: int = 10):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, p))
    centers = jax.random.normal(jax.random.fold_in(key, 1), (k, p))

    @jax.jit
    def dense_assign(x, c):
        return jnp.argmin(km.dense_sq_dists(x, c), axis=1)

    us_dense = timeit(dense_assign, x, centers)
    emit("tableV/assign/dense", us_dense, f"n={n} p={p} K={k}")

    for gamma in (0.05, 0.1, 0.3):
        spec = sketch.make_spec(p, key, gamma=gamma)
        s = sketch.sketch(x, spec)

        @jax.jit
        def sparse_assign(v, i, c):
            return jnp.argmin(km.sparse_sq_dists(v, i, c), axis=1)

        us = timeit(sparse_assign, s.values, s.indices, centers)
        emit(f"tableV/assign/gamma={gamma}", us,
             f"speedup={us_dense/us:.1f}x ideal={1/spec.gamma:.1f}x")

    # center update
    a = jax.random.randint(key, (n,), 0, k)

    @jax.jit
    def dense_update(x, a):
        oh = jax.nn.one_hot(a, k, dtype=x.dtype)
        return oh.T @ x / jnp.maximum(oh.sum(0)[:, None], 1.0)

    us_dense_u = timeit(dense_update, x, a)
    emit("tableV/update/dense", us_dense_u, "")
    spec = sketch.make_spec(p, key, gamma=0.05)
    s = sketch.sketch(x, spec)

    @jax.jit
    def sparse_update(v, i, a):
        rows = jnp.broadcast_to(a[:, None], i.shape)
        sums = jnp.zeros((k, spec.p_pad), v.dtype).at[rows, i].add(v)
        cnts = jnp.zeros((k, spec.p_pad), v.dtype).at[rows, i].add(1.0)
        return sums / jnp.maximum(cnts, 1.0)

    us_u = timeit(sparse_update, s.values, s.indices, a)
    emit("tableV/update/gamma=0.05", us_u, f"speedup={us_dense_u/us_u:.1f}x")
    emit("tableV/combined/gamma=0.05", 0.0,
         f"speedup={(us_dense+us_dense_u)/(us_u+timeit(jax.jit(lambda v,i,c: jnp.argmin(km.sparse_sq_dists(v,i,c),axis=1)), s.values, s.indices, centers)):.1f}x")


if __name__ == "__main__":
    run()
