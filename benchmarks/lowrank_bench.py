"""Low-rank spectral path: ingest throughput + peak accumulator bytes vs the
dense / compact (p, p) covariance paths.

Fits ``SparsifiedPCA`` on a spiked stream with ``cov_path`` = dense, compact,
lowrank(range), lowrank(fd) and records rows/sec per path plus the byte size of
each path's covariance accumulator — the headline: the (p, p) accumulator
(p²·4 bytes) shrinks to the O(l·p) lowrank state, asserted here so a
regression that silently re-materializes (p, p) fails CI. A subspace sanity
check (principal angle vs the dense path) guards against winning the memory
game by returning garbage.

Writes ``BENCH_lowrank.json`` (name, us_per_call, rows/sec, accumulator_bytes,
max angle) — uploaded as a CI artifact by the lowrank-bench job.
"""
from __future__ import annotations

import json
import os
import sys

import jax

from benchmarks.common import emit, max_angle_sin as _max_angle_sin, spiked, timeit
from repro.api import Plan, SparsifiedPCA

RECORDS: list[dict] = []


def _spiked(n, p, k):
    return spiked(jax.random.PRNGKey(0), n, p, k)


def _state_bytes(est: SparsifiedPCA) -> int:
    st = est._reducer.state
    if st is None:  # batch dense/compact: the retained sketch IS the state
        return sum(s.nbytes() for s in est._reducer.parts)
    return sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(st))


def record(name, us, rows, acc_bytes, angle=None):
    rec = {"name": name, "us_per_call": round(us, 1),
           "rows_per_sec": round(rows / (us / 1e6)),
           "accumulator_bytes": int(acc_bytes)}
    if angle is not None:
        rec["max_angle_sin_vs_dense"] = round(angle, 6)
    RECORDS.append(rec)
    extra = f"rows_per_sec={rec['rows_per_sec']:,} acc_bytes={acc_bytes:,}"
    if angle is not None:
        extra += f" angle={angle:.1e}"
    emit(name, us, extra)


def run(json_path: str = "BENCH_lowrank.json"):
    RECORDS.clear()
    n, p, k, ell = 8192, 1024, 8, 64
    x = _spiked(n, p, k)
    base = Plan(backend="stream", gamma=0.05, batch_size=2048)

    paths = {
        "dense": base,
        "compact": base.replace(cov_path="compact"),
        "lowrank_range": base.replace(cov_path="lowrank", rank=ell),
        "lowrank_fd": base.replace(cov_path="lowrank", rank=ell, lowrank_method="fd"),
    }
    fitted, acc_bytes = {}, {}
    for name, plan in paths.items():
        def fit(plan=plan):
            est = SparsifiedPCA(k, plan, key=1).fit(x)
            return est

        est = fit()  # measured separately so the bytes probe isn't timed
        fitted[name], acc_bytes[name] = est, _state_bytes(est)
        us = timeit(lambda: fit().components_, warmup=1, iters=3)
        angle = (None if name == "dense"
                 else _max_angle_sin(est.components_, fitted["dense"].components_))
        record(f"lowrank/pca/{name}", us, n, acc_bytes[name], angle)

    # ---- the acceptance assertions -----------------------------------------
    pp_bytes = p * p * 4
    for name in ("lowrank_range", "lowrank_fd"):
        st = fitted[name]._reducer.state
        leaves = jax.tree.leaves(st)
        # O(l·p), and no leaf anywhere near a (p, p) materialization
        assert max(leaf.size for leaf in leaves) <= ell * p, (
            f"{name}: accumulator leaf larger than l·p")
        assert acc_bytes[name] <= 3 * ell * p * 4, (
            f"{name}: accumulator {acc_bytes[name]} bytes exceeds O(l·p)")
        assert acc_bytes[name] < pp_bytes / 4, (
            f"{name}: no memory win over the (p, p) accumulator")
    assert acc_bytes["dense"] >= pp_bytes  # what the lowrank path replaces

    # the memory win must not come from a garbage subspace. At the throughput
    # config's γ=0.05 the DENSE estimate is itself noise-dominated (the angle
    # is recorded above, not asserted); fidelity is asserted in the estimator-
    # noise-benign regime (γ=0.5 — the slow-lane acceptance test pins 1e-3 at
    # its full n; this is the cheap CI-bench guard).
    pf, kf, ellf, nf = 128, 4, 64, 8192
    xf = _spiked(nf, pf, kf)
    planf = Plan(backend="stream", gamma=0.5, batch_size=2048)
    df = SparsifiedPCA(kf, planf, key=1).fit(xf)
    planl = planf.replace(cov_path="lowrank", rank=ellf)
    lf = SparsifiedPCA(kf, planl, key=1).fit(xf)
    us = timeit(lambda: SparsifiedPCA(kf, planl, key=1).fit(xf).components_,
                warmup=0, iters=1)
    angle = _max_angle_sin(lf.components_, df.components_)
    record("lowrank/fidelity/gamma0.5", us, nf, _state_bytes(lf), angle)
    assert angle < 0.1, f"lowrank subspace drifted from the dense path: {angle}"

    out = os.environ.get("BENCH_LOWRANK_JSON", json_path)
    with open(out, "w") as f:
        json.dump({"records": RECORDS, "p": p, "rank": ell,
                   "pp_accumulator_bytes": pp_bytes}, f, indent=2)
    print(f"lowrank_bench: wrote {out} ({len(RECORDS)} records)", file=sys.stderr)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
