"""EngineState lifecycle benchmark → ``BENCH_cluster.json``.

Three costs of the fault-tolerance / multi-host story, each measured and the
correctness condition behind it asserted:

1. **Checkpoint/restore overhead** — a run with ``checkpoint_every`` vs the
   uninterrupted run (overhead fraction), plus save/restore wall time for the
   fixed-size EngineState. Restore-and-continue must be BIT-identical to the
   uninterrupted run (the (seed, step, shard) contract regenerates the rest).
2. **Elastic re-shard replay cost** — finishing a restored 8-shard run under
   4 and 2 simulated workers (``cluster.continue_elastic``): per-step wall
   time vs the engine's own per-step time. Final moments must match the
   uninterrupted run at 1e-5 (delta merge = float-sum reordering only).
3. **Multi-process vs single-process rows/sec** — the same sharded fit run by
   2 REAL processes (gloo CPU collectives, ``jax.distributed``) vs one
   process with 2 forced host devices. On CPU gloo adds transport cost; the
   row records the achieved fraction so the trajectory is visible across
   commits. Results must agree at 1e-5.

CI uploads the JSON as an artifact (same convention as ``BENCH_api.json``).
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import tempfile
import textwrap
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.cluster import continue_elastic
from repro.core import sketch as sketch_mod
from repro.stream.engine import StreamEngine, StreamKMeansConfig

RECORDS: list[dict] = []

P_DIM = 256
B = 512
STEPS = 12
CKPT_EVERY = 4


def record(name: str, us: float, **extra):
    rec = {"name": name, "us_per_call": round(us, 1), **extra}
    RECORDS.append(rec)
    derived = " ".join(f"{k}={v}" for k, v in extra.items()
                       if isinstance(v, (int, float, str)))
    emit(name, us, derived)


def _source(seed, step, shard):
    k = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed or 0), step), shard)
    return jax.random.normal(k, (B, P_DIM))


def _engine(n_shards: int) -> StreamEngine:
    spec = sketch_mod.make_spec(P_DIM, jax.random.PRNGKey(3), gamma=0.1)
    return StreamEngine(spec, _source, n_shards=n_shards,
                        kmeans=StreamKMeansConfig(4, n_init=2))


def checkpoint_restore_bench(ckpt_dir: str):
    eng = _engine(4)
    eng.run(1, seed=0)  # compile outside the timed region
    t0 = time.perf_counter()
    full = eng.run(STEPS, seed=0)
    t_plain = time.perf_counter() - t0

    eng2 = _engine(4)
    eng2.run(1, seed=0)
    t0 = time.perf_counter()
    eng2.run(STEPS, seed=0, checkpoint_dir=ckpt_dir,
             checkpoint_every=CKPT_EVERY)
    t_ckpt = time.perf_counter() - t0

    eng3 = _engine(4)
    eng3.run(1, seed=0)
    t0 = time.perf_counter()
    state, next_step = eng3.restore_state(ckpt_dir)
    t_restore = time.perf_counter() - t0
    res = eng3.run(STEPS, seed=0, state=state, start_step=next_step)
    assert np.array_equal(np.asarray(res.mean), np.asarray(full.mean)), (
        "restore-and-continue is not bit-identical to the uninterrupted run")
    assert np.array_equal(np.asarray(res.centers), np.asarray(full.centers))

    n_ckpts = STEPS // CKPT_EVERY
    rows = STEPS * 4 * B
    record("cluster/checkpoint/overhead", (t_ckpt - t_plain) * 1e6 / n_ckpts,
           overhead_frac=round(max(0.0, t_ckpt / t_plain - 1.0), 4),
           rows_per_sec=round(rows / t_ckpt),
           checkpoints=n_ckpts, bit_identical=True)
    record("cluster/checkpoint/restore", t_restore * 1e6,
           restore_ms=round(t_restore * 1e3, 2), resumed_at=next_step)


def elastic_reshard_bench(ckpt_dir: str):
    eng = _engine(8)
    full = eng.run(STEPS, seed=1)
    eng2 = _engine(8)
    eng2.run(STEPS // 2, seed=1)
    eng2.save_state(ckpt_dir, STEPS // 2, seed=1)

    # baseline: the engine's own per-step cost over the back half
    eng3 = _engine(8)
    eng3.run(1, seed=1)
    state, start = eng3.restore_state(ckpt_dir)
    t0 = time.perf_counter()
    eng3.run(STEPS, seed=1, state=state, start_step=start)
    t_engine = (time.perf_counter() - t0) / (STEPS - start)

    for n_workers in (4, 2):
        eng4 = _engine(8)
        eng4.run(1, seed=1)
        state, start = eng4.restore_state(ckpt_dir)
        t0 = time.perf_counter()
        continue_elastic(eng4, STEPS, state=state, start_step=start,
                         n_workers=n_workers, seed=1)
        t_step = (time.perf_counter() - t0) / (STEPS - start)
        res = eng4.finalize()
        np.testing.assert_allclose(np.asarray(res.mean), np.asarray(full.mean),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.cov), np.asarray(full.cov),
                                   atol=1e-5)
        record(f"cluster/elastic/8_to_{n_workers}", t_step * 1e6,
               vs_engine_step=round(t_step / t_engine, 2),
               rows_per_sec=round(8 * B / t_step), parity_atol=1e-5)


_MP_FIT = """
import sys, time, json
import numpy as np

MODE = sys.argv[1]
if MODE == "worker":
    pid, nproc, port = int(sys.argv[2]), int(sys.argv[3]), sys.argv[4]
    from repro import cluster
    cluster.initialize(f"127.0.0.1:{port}", nproc, pid)
import jax
from repro.api import Plan, SparsifiedCov, fit_many

B, P, STEPS = 512, 256, 10

def source(seed, step, shard):
    k = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed or 0), step), shard)
    return jax.random.normal(k, (B, P))

plan = Plan(backend="sharded", gamma=0.1, batch_size=B, n_shards=2)
cov = SparsifiedCov(plan, key=3)
fit_many(plan, [cov], source=source, steps=1, seed=5)  # compile
cov2 = SparsifiedCov(plan, key=3)
t0 = time.perf_counter()
fit_many(plan, [cov2], source=source, steps=STEPS, seed=5)
dt = time.perf_counter() - t0
if MODE != "worker" or int(sys.argv[2]) == 0:
    print("RESULT" + json.dumps({
        "rows_per_sec": STEPS * 2 * B / dt,
        "mean": np.asarray(cov2.mean_).tolist(),
        "cov_tr": float(np.trace(np.asarray(cov2.cov_)))}))
"""


def multiprocess_bench():
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env.update(PYTHONPATH="src", JAX_PLATFORMS="cpu")
    script = textwrap.dedent(_MP_FIT)

    ref_env = dict(env, XLA_FLAGS="--xla_force_host_platform_device_count=2")
    t0 = time.perf_counter()
    ref_out = subprocess.run([sys.executable, "-c", script, "single"],
                             env=ref_env, capture_output=True, text=True,
                             timeout=600)
    assert ref_out.returncode == 0, ref_out.stderr[-4000:]
    ref = json.loads(ref_out.stdout.split("RESULT", 1)[1])

    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    with tempfile.TemporaryDirectory() as d:
        wpath = os.path.join(d, "w.py")
        with open(wpath, "w") as f:
            f.write(script)
        procs = [subprocess.Popen(
            [sys.executable, wpath, "worker", str(pid), "2", str(port)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
            for pid in range(2)]
        outs = [p.communicate(timeout=600) for p in procs]
    for p, (o, e) in zip(procs, outs):
        assert p.returncode == 0, f"worker failed:\n{e[-4000:]}"
    got = json.loads(outs[0][0].split("RESULT", 1)[1])

    np.testing.assert_allclose(got["mean"], ref["mean"], atol=1e-5)
    np.testing.assert_allclose(got["cov_tr"], ref["cov_tr"], rtol=1e-5)
    record("cluster/multiprocess/2proc_vs_1proc",
           (time.perf_counter() - t0) * 1e6,
           rows_per_sec_2proc=round(got["rows_per_sec"]),
           rows_per_sec_1proc=round(ref["rows_per_sec"]),
           fraction=round(got["rows_per_sec"] / ref["rows_per_sec"], 3),
           parity_atol=1e-5)


def run(json_path: str = "BENCH_cluster.json"):
    RECORDS.clear()
    with tempfile.TemporaryDirectory() as d:
        checkpoint_restore_bench(os.path.join(d, "ck"))
        elastic_reshard_bench(os.path.join(d, "el"))
    multiprocess_bench()
    out = os.environ.get("BENCH_CLUSTER_JSON", json_path)
    with open(out, "w") as f:
        json.dump({"records": RECORDS}, f, indent=2)
    print(f"cluster_bench: wrote {out} ({len(RECORDS)} records)",
          file=sys.stderr)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
