import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver: lower one cell under a named strategy variant and
print the three roofline terms + memory (used to produce EXPERIMENTS.md §Perf).

    PYTHONPATH=src python experiments/perf_iterate.py <arch> <shape> <variant>

Variants are defined in VARIANTS below; 'baseline' is the dry-run default.
"""
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402


def main(arch: str, shape_name: str, variant: str):
    from repro.configs.registry import get_arch, get_shape
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import probe_cell, roofline_terms
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import TrainerConfig, lower_cell
    from repro.launch.dryrun import arch_trainer_config

    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=False)
    base_t = arch_trainer_config(arch, shape.kind)

    VARIANTS = {
        "baseline": (cfg, base_t),
        # dense cells: drop TP, fold model axis into FSDP/batch
        "dp_only": (cfg, dataclasses.replace(base_t, dp_only=True, sp=False)),
        # microbatching: 4 accumulation steps
        "accum4": (cfg, dataclasses.replace(base_t, accum_steps=4)),
        "dp_only_accum4": (cfg, dataclasses.replace(base_t, dp_only=True, sp=False, accum_steps=4)),
        # bigger flash tiles (fewer scan steps, more VMEM)
        "chunk2k": (cfg, dataclasses.replace(base_t, q_chunk=2048, kv_chunk=2048)),
        # MoE: tighter capacity
        "cap1.0": (dataclasses.replace(cfg, capacity_factor=1.0), base_t),
        # MoE: EP off (pjit-partitioned local dispatch)
        "no_ep": (cfg, dataclasses.replace(base_t, use_ep=False)),
        # no sequence parallelism
        "no_sp": (cfg, dataclasses.replace(base_t, sp=False)),
        # SSD chunk sweep (ssm archs)
        "ssd_q128": (dataclasses.replace(cfg, ssm_chunk=128), base_t),
        "ssd_q32": (dataclasses.replace(cfg, ssm_chunk=32), base_t),
        # no activation remat (trade memory for recompute bytes/flops)
        "noremat": (dataclasses.replace(cfg, remat=False), base_t),
        "dp_only_noremat": (dataclasses.replace(cfg, remat=False),
                            dataclasses.replace(base_t, dp_only=True, sp=False)),
        # selective remat: keep flash-attention outputs (skip its recompute)
        "dp_only_saveattn": (dataclasses.replace(cfg, remat_policy="save_attn"),
                             dataclasses.replace(base_t, dp_only=True, sp=False)),
        "dp_only_ssdq128": (dataclasses.replace(cfg, ssm_chunk=128),
                            dataclasses.replace(base_t, dp_only=True, sp=False)),
        # paper technique: sketched gradient compression γ=0.05 + error feedback
        "compress05": (cfg, dataclasses.replace(
            base_t, dp_only=True, sp=False,
            compress=__import__("repro.core.grad_compress", fromlist=["CompressConfig"]).CompressConfig(
                gamma=0.05, chunk_p=1 << 14, error_feedback=True))),
    }
    cfg_v, tcfg_v = VARIANTS[variant]

    t0 = time.time()
    lowered, meta = lower_cell(cfg_v, shape, mesh, tcfg_v)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    peak = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes + ma.output_size_in_bytes)
    del compiled, lowered
    probe = probe_cell(cfg_v, shape, mesh, tcfg_v)
    terms = roofline_terms(probe["per_device"], mesh.size, cfg_v, shape)
    rec = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "peak_GB": round(peak / 2**30, 2),
        "terms": {k: (round(v, 4) if isinstance(v, float) else v) for k, v in terms.items()},
        "wire_by_kind_GB": {k: round(v / 2**30, 2)
                            for k, v in probe["per_device"]["wire_by_kind"].items()},
        "wall_s": round(time.time() - t0, 1),
    }
    out = f"experiments/perf/{arch}__{shape_name}__{variant}.json"
    os.makedirs("experiments/perf", exist_ok=True)
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2], sys.argv[3])
