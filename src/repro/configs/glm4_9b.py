"""GLM-4 9B — RoPE, GQA [hf:THUDM/glm-4-9b; hf].

Assignment: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=1e4,
)
