"""Qwen2-VL 2B — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Assignment: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
The vision tower is a STUB: input_specs() supplies precomputed patch embeddings
merged into the token stream; the backbone applies multimodal RoPE with
(t, h, w) sections (16, 24, 24) over head_dim 128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    n_vision_tokens=256,
)
