"""Model/shape configuration dataclasses shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "vlm", "audio", "hybrid", "ssm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 → d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                 # per-expert hidden size
    n_shared_experts: int = 0
    first_k_dense: int = 0            # leading dense layers (DeepSeek/Kimi style)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # --- attention patterns ---
    sliding_window: int = 0           # >0 → local layers use this window
    local_global_ratio: int = 0       # gemma3: 5 local per 1 global
    rope_theta: float = 1e4
    rope_theta_global: float = 0.0    # gemma3 global layers use 1e6
    mrope_sections: tuple[int, int, int] | None = None   # qwen2-vl
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_width: int = 4
    attn_every: int = 0               # zamba2: shared attn block period
    # --- encoder-decoder ---
    n_enc_layers: int = 0
    # --- misc ---
    n_vision_tokens: int = 64         # vlm stub: precomputed patch embeddings
    rms_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"        # "full" | "save_attn" (keep flash outputs)
    scan_unroll: int = 1              # >1 only for roofline depth probes

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self) -> "ModelConfig":
        """Smoke-test config: same family/topology, tiny sizes, CPU-friendly."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.attn_every or self.local_global_ratio else 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 2,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 8),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=64 if self.moe_d_ff else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            first_k_dense=min(self.first_k_dense, 1),
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            mrope_sections=(4, 2, 2) if self.mrope_sections else None,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8 if self.ssm_state else 128,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2) if self.n_enc_layers else 0,
            n_vision_tokens=8,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    def reduced(self) -> "ShapeConfig":
        return dataclasses.replace(self, seq_len=min(self.seq_len, 32), global_batch=min(self.global_batch, 2))


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic sequence handling: run for SSM/hybrid and the
# 5:1-local gemma3; skip for pure full-attention archs (see DESIGN.md §4).
LONG_CONTEXT_ARCHS = {"mamba2-1.3b", "zamba2-1.2b", "gemma3-1b"}


def cell_is_runnable(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "skip: pure full-attention arch at 500k decode (DESIGN.md §4)"
    return True, ""
