"""Kimi K2 — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2; unverified].

Assignment: 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384e top-8.
d_ff=2048 is the per-expert hidden size (DeepSeek-V3-style); we keep Kimi's one
shared expert and one leading dense layer (dense-layer FFN = 8 experts' width).
The paper-exact MLA attention is approximated by GQA kv=8 per the assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,                 # 7168 / 64
    d_ff=16384,                   # dense (first_k_dense) layers' FFN
    moe_d_ff=2048,
    n_experts=384,
    experts_per_token=8,
    n_shared_experts=1,
    first_k_dense=1,
    vocab_size=163840,
    rope_theta=5e4,
)
