from repro.configs.base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401
