"""Mamba2 1.3B — SSD (state-space duality), attention-free [arXiv:2405.21060; unverified].

Assignment: 48L d_model=2048 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
d_inner = 2·d_model = 4096, head_dim 64 → 64 SSD heads, ngroups=1, conv width 4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
)
