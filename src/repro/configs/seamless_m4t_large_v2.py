"""SeamlessM4T large v2 — enc-dec, multimodal [arXiv:2308.11596; hf].

Assignment: 24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
Interpreted as 24 encoder + 24 decoder layers (the seamless large text stacks).
The audio frontend is a STUB: input_specs() supplies precomputed frame
embeddings (B, S, d) for the encoder; the decoder consumes text tokens with
cross-attention into the encoder output.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,                  # decoder layers
    n_enc_layers=24,              # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    rope_theta=1e4,
)
