"""Architecture registry: ``--arch <id>`` resolution for every assigned config."""
from __future__ import annotations

from repro.configs import (
    deepseek_coder_33b,
    gemma3_1b,
    glm4_9b,
    kimi_k2_1t_a32b,
    mamba2_1p3b,
    phi3_medium_14b,
    qwen2_vl_2b,
    qwen3_moe_235b_a22b,
    seamless_m4t_large_v2,
    zamba2_1p2b,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, cell_is_runnable  # noqa: F401

ARCHS: dict[str, ModelConfig] = {
    c.CONFIG.name: c.CONFIG
    for c in (
        kimi_k2_1t_a32b,
        qwen3_moe_235b_a22b,
        qwen2_vl_2b,
        deepseek_coder_33b,
        glm4_9b,
        gemma3_1b,
        phi3_medium_14b,
        seamless_m4t_large_v2,
        zamba2_1p2b,
        mamba2_1p3b,
    )
}


def get_arch(name: str, reduced: bool = False) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    cfg = ARCHS[name]
    return cfg.reduced() if reduced else cfg


def get_shape(name: str, reduced: bool = False) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    s = SHAPES[name]
    return s.reduced() if reduced else s


def all_cells():
    """Every (arch, shape) pair with its runnability verdict — 40 cells."""
    for a in ARCHS:
        for s in SHAPES:
            ok, why = cell_is_runnable(a, s)
            yield a, s, ok, why
