"""Qwen3-MoE 235B-A22B — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family; hf].

Assignment: 94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936, MoE 128e top-8.
All layers are MoE (no shared experts), per Qwen3-MoE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=12288,                   # unused (no dense layers); kept for completeness
    moe_d_ff=1536,
    n_experts=128,
    experts_per_token=8,
    n_shared_experts=0,
    first_k_dense=0,
    vocab_size=151936,
    rope_theta=1e6,
)
