"""Gemma 3 1B — 5:1 local:global attention, 128k ctx [hf:google/gemma-3-1b-pt; unverified].

Assignment: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.
Local layers use a 512-token sliding window with rope θ=1e4; every 6th layer is
global with θ=1e6 (the 5:1 pattern). head_dim=256 (decoupled from d_model/H).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    sliding_window=512,
    local_global_ratio=5,
    rope_theta=1e4,
    rope_theta_global=1e6,
)
