"""Zamba2 1.2B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf].

Assignment: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
38 Mamba2 layers; a single weight-tied transformer block (MHA 32 heads +
FFN 8192) is applied after every 6th Mamba layer (Zamba2's shared-block design,
simplified: no LoRA adapters per call site — noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
    attn_every=6,
    rope_theta=1e4,
)
