"""DeepSeek-Coder 33B — llama-arch dense [arXiv:2401.14196; hf].

Assignment: 62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=1e5,
)
