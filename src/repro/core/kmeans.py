"""Sparsified K-means (paper §VI, Algs. 1–2) plus the comparison baselines of §VII.

All cluster solvers share the same shape conventions:
  data rows = samples; centers (K, p); assignments (n,) int32.

Solvers
-------
- :func:`kmeans`                    — standard Lloyd + K-means++ (the reference).
- :func:`sparsified_kmeans`         — Alg. 1: one pass (precondition→sample→cluster
                                      on the sparse matrix), optional Alg. 2 second pass.
- :func:`feature_extraction_kmeans` — Boutsidis et al. [36]: Z = XΩᵀ, Ω random signs.
- :func:`feature_selection_kmeans`  — [36]: leverage-score row (feature) sampling.

The sparse assignment step is the compute hot-spot; the reference here is
gather-based, and ``repro.kernels.sparse_assign`` provides the TPU Pallas kernel
(one-hot MXU form) behind the same signature.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ros, sketch
from repro.core.sampling import SparseRows, subsample


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class KMeansResult:
    assignments: jax.Array          # (n,) int32
    centers: jax.Array              # (K, p) in the ORIGINAL domain
    objective: jax.Array            # final value of the solver's objective
    n_iter: jax.Array               # iterations of the final (best) run
    centers_pre: jax.Array | None = None  # (K, p_pad) preconditioned domain (sparsified only)

    def tree_flatten(self):
        return (self.assignments, self.centers, self.objective, self.n_iter, self.centers_pre), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


# ------------------------------------------------------------ distances -----

def dense_sq_dists(x: jax.Array, centers: jax.Array) -> jax.Array:
    """(n, K) squared Euclidean distances."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(centers * centers, axis=1)
    return x2 - 2.0 * x @ centers.T + c2[None, :]


def sparse_sq_dists(values: jax.Array, indices: jax.Array, centers: jax.Array) -> jax.Array:
    """(n, K) sparsified distances ‖z_i − R_iᵀ μ_k‖² (Eq. 35), gather reference.

    Only the sampled coordinates of each row participate — this is what realizes
    the γ = m/p flop reduction (O(nmK) instead of O(npK)).
    """
    g = centers.T[indices]                                   # (n, m, K)
    return jnp.sum((values[..., None] - g) ** 2, axis=1)


# ----------------------------------------------------------- K-means++ ------

def _kpp_init(key: jax.Array, dist_to_center: Callable[[int], jax.Array], n: int, k: int,
              gather_row: Callable[[jax.Array], jax.Array], p: int, dtype) -> jax.Array:
    """Greedy K-means++ D²-seeding (kmeans++ with ``n_cand`` trial centers per
    step, keeping the one that most reduces the potential — as in sklearn).

    dist_to_center(row_dense) -> (n,) squared distances of every sample to a
    candidate center given as a dense p-vector. gather_row(i) -> dense p-vector
    for sample i.
    """
    n_cand = 2 + int(np.ceil(np.log(max(k, 2))))
    k0, key = jax.random.split(key)
    first = gather_row(jax.random.randint(k0, (), 0, n))
    centers = jnp.zeros((k, p), dtype).at[0].set(first)
    min_d = dist_to_center(first)

    def body(j, carry):
        centers, min_d, key = carry
        key, kc = jax.random.split(key)
        # D² sampling of n_cand candidates (guard all-zero with the floor)
        logits = jnp.log(jnp.maximum(min_d, 1e-30))
        idxs = jax.random.categorical(kc, logits, shape=(n_cand,))
        cands = jax.vmap(gather_row)(idxs)                   # (n_cand, p)
        new_ds = jax.vmap(dist_to_center)(cands)             # (n_cand, n)
        pots = jnp.sum(jnp.minimum(min_d[None, :], new_ds), axis=1)
        best = jnp.argmin(pots)
        centers = centers.at[j].set(cands[best])
        min_d = jnp.minimum(min_d, new_ds[best])
        return centers, min_d, key

    centers, _, _ = jax.lax.fori_loop(1, k, body, (centers, min_d, key))
    return centers


def kpp_init_dense(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    n, p = x.shape

    def dist(c):
        return jnp.sum((x - c[None, :]) ** 2, axis=1)

    return _kpp_init(key, dist, n, k, lambda i: x[i], p, x.dtype)


def kpp_init_sparse(key: jax.Array, values: jax.Array, indices: jax.Array, p: int, k: int) -> jax.Array:
    """K-means++ under the sparsified metric: candidate centers are scattered
    sparse rows; distances use only each row's sampled coordinates (Eq. 35)."""
    n, m = values.shape

    def gather_row(i):
        return jnp.zeros((p,), values.dtype).at[indices[i]].set(values[i])

    def dist(c):
        g = c[indices]                                       # (n, m)
        return jnp.sum((values - g) ** 2, axis=1)

    return _kpp_init(key, dist, n, k, gather_row, p, values.dtype)


# ------------------------------------------------------------ Lloyd loops ---

def _lloyd_dense(x: jax.Array, mu0: jax.Array, max_iter: int, tol: float):
    n, p = x.shape
    k = mu0.shape[0]

    def cond(c):
        it, _, shift = c[0], c[1], c[2]
        return (it < max_iter) & (shift > tol)

    def body(c):
        it, mu, _ = c
        d = dense_sq_dists(x, mu)
        a = jnp.argmin(d, axis=1)
        oh = jax.nn.one_hot(a, k, dtype=x.dtype)             # (n, K)
        sums = oh.T @ x                                      # (K, p)
        counts = jnp.sum(oh, axis=0)
        new_mu = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], mu)
        shift = jnp.max(jnp.abs(new_mu - mu))
        return it + 1, new_mu, shift

    it, mu, _ = jax.lax.while_loop(cond, body, (jnp.zeros((), jnp.int32), mu0, jnp.full((), jnp.inf, x.dtype)))
    d = dense_sq_dists(x, mu)
    a = jnp.argmin(d, axis=1).astype(jnp.int32)
    obj = jnp.sum(jnp.min(d, axis=1))
    return mu, a, obj, it


def _lloyd_sparse(values: jax.Array, indices: jax.Array, p: int, mu0: jax.Array,
                  max_iter: int, tol: float, assign_fn=None):
    """Lloyd on compact sparse rows: Eq. (36) assignment + Eq. (39) update."""
    n, m = values.shape
    k = mu0.shape[0]
    assign_fn = assign_fn or sparse_sq_dists

    def cond(c):
        it, _, shift = c[0], c[1], c[2]
        return (it < max_iter) & (shift > tol)

    def body(c):
        it, mu, _ = c
        d = assign_fn(values, indices, mu)
        a = jnp.argmin(d, axis=1)
        rows = jnp.broadcast_to(a[:, None], indices.shape)
        sums = jnp.zeros((k, p), values.dtype).at[rows, indices].add(values)
        counts = jnp.zeros((k, p), values.dtype).at[rows, indices].add(1.0)
        # coordinates never sampled in a cluster keep their previous value
        new_mu = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), mu)
        shift = jnp.max(jnp.abs(new_mu - mu))
        return it + 1, new_mu, shift

    it, mu, _ = jax.lax.while_loop(cond, body, (jnp.zeros((), jnp.int32), mu0, jnp.full((), jnp.inf, values.dtype)))
    d = assign_fn(values, indices, mu)
    a = jnp.argmin(d, axis=1).astype(jnp.int32)
    obj = jnp.sum(jnp.min(d, axis=1))
    return mu, a, obj, it


# ------------------------------------------------------------- solvers ------

@functools.partial(jax.jit, static_argnames=("k", "n_init", "max_iter"))
def kmeans(x: jax.Array, k: int, key: jax.Array, n_init: int = 5,
           max_iter: int = 100, tol: float = 1e-6) -> KMeansResult:
    """Standard K-means (Lloyd) with K-means++ seeding, best of ``n_init`` runs."""

    def one_run(rkey):
        mu0 = kpp_init_dense(rkey, x, k)
        return _lloyd_dense(x, mu0, max_iter, tol)

    mus, assigns, objs, iters = jax.lax.map(one_run, jax.random.split(key, n_init))
    best = jnp.argmin(objs)
    return KMeansResult(assigns[best], mus[best], objs[best], iters[best])


@functools.partial(jax.jit, static_argnames=("k", "p", "n_init", "max_iter", "assign_fn"))
def sparse_kmeans_core(values: jax.Array, indices: jax.Array, p: int, k: int, key: jax.Array,
                       n_init: int = 5, max_iter: int = 100, tol: float = 1e-6,
                       assign_fn=None):
    """Lloyd on an already-sketched matrix (domain-agnostic); best of n_init."""

    def one_run(rkey):
        mu0 = kpp_init_sparse(rkey, values, indices, p, k)
        return _lloyd_sparse(values, indices, p, mu0, max_iter, tol, assign_fn)

    mus, assigns, objs, iters = jax.lax.map(one_run, jax.random.split(key, n_init))
    best = jnp.argmin(objs)
    return mus[best], assigns[best], objs[best], iters[best]


def sparsified_kmeans(x: jax.Array, k: int, key: jax.Array, gamma: float | None = None,
                      m: int | None = None, transform: ros.Transform = "hadamard",
                      precondition: bool = True, two_pass: bool = False,
                      n_init: int = 5, max_iter: int = 100, tol: float = 1e-6,
                      assign_fn=None) -> KMeansResult:
    """Alg. 1 (one-pass) / Alg. 2 (``two_pass=True``) sparsified K-means.

    ``precondition=False`` gives the paper's no-ROS ablation baseline.
    """
    n, p = x.shape
    spec = sketch.make_spec(p, key, gamma=gamma, m=m,
                            transform=transform if precondition else "dct")
    if precondition:
        s = sketch.sketch(x, spec)
        pp = spec.p_pad
    else:
        s = subsample(x, spec.mask_key(), spec.m)
        pp = p

    mu_pre, a, obj, it = sparse_kmeans_core(
        s.values, s.indices, pp, k, spec.signs_key(), n_init, max_iter, tol, assign_fn
    )
    centers = sketch.unmix_dense(mu_pre, spec) if precondition else mu_pre

    if two_pass:
        # Alg. 2: one more pass over the ORIGINAL data — recompute centers as
        # true sample means of assigned points, and reassign in the original domain.
        oh = jax.nn.one_hot(a, k, dtype=jnp.float32)
        sums = oh.T @ x.astype(jnp.float32)
        counts = jnp.sum(oh, axis=0)
        centers2 = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], centers)
        d = dense_sq_dists(x.astype(jnp.float32), centers)   # reassign w/ 1-pass centers
        a = jnp.argmin(d, axis=1).astype(jnp.int32)
        obj = jnp.sum(jnp.min(d, axis=1))
        centers = centers2

    return KMeansResult(a, centers, obj, it, centers_pre=mu_pre)


def feature_extraction_kmeans(x: jax.Array, k: int, m: int, key: jax.Array,
                              two_pass: bool = False, n_init: int = 5,
                              max_iter: int = 100, tol: float = 1e-6) -> KMeansResult:
    """Boutsidis et al. feature extraction: cluster Z = XΩᵀ/√m, Ω ∈ {±1}^{m×p}.

    One-pass center estimates use the pseudo-inverse lift Ω⁺ (the paper's Fig. 9
    shows these are poor — kept faithful); ``two_pass`` recomputes them from X.
    """
    n, p = x.shape
    komega, krun = jax.random.split(key)
    omega = jax.random.rademacher(komega, (m, p), dtype=jnp.float32) / np.sqrt(m)
    z = x.astype(jnp.float32) @ omega.T
    res = kmeans(z, k, krun, n_init=n_init, max_iter=max_iter, tol=tol)
    # lift centers with the pseudo-inverse (rank-m, inconsistent — see §VII-B)
    centers = res.centers @ jnp.linalg.pinv(omega).T
    a, obj = res.assignments, res.objective
    if two_pass:
        oh = jax.nn.one_hot(a, k, dtype=jnp.float32)
        counts = jnp.sum(oh, axis=0)
        centers = jnp.where(counts[:, None] > 0,
                            (oh.T @ x.astype(jnp.float32)) / jnp.maximum(counts, 1.0)[:, None],
                            centers)
    return KMeansResult(a, centers, obj, res.n_iter)


def leverage_scores(x: jax.Array, rank: int, key: jax.Array, oversample: int = 10) -> jax.Array:
    """Approximate row (feature) leverage scores via a randomized range finder [7].

    Returns (p,) scores of Xᵀ's rows = feature importances for feature selection.
    """
    n, p = x.shape
    xt = x.astype(jnp.float32).T                             # (p, n) features-as-rows
    g = jax.random.normal(key, (n, rank + oversample), jnp.float32)
    ys = xt @ g                                              # (p, r+o)
    q, _ = jnp.linalg.qr(ys)                                 # (p, r+o) orthonormal
    scores = jnp.sum(q[:, :rank] ** 2, axis=1)
    return scores / jnp.sum(scores)


def feature_selection_kmeans(x: jax.Array, k: int, m: int, key: jax.Array,
                             two_pass: bool = False, n_init: int = 5,
                             max_iter: int = 100, tol: float = 1e-6) -> KMeansResult:
    """[36] feature selection: sample m features by leverage scores, cluster there.

    Requires ≥3 passes over the data (score pass, sampling pass, clustering) —
    included as the paper's multi-pass baseline.
    """
    n, p = x.shape
    kscore, ksel, krun = jax.random.split(key, 3)
    scores = leverage_scores(x, rank=k, key=kscore)
    sel = jax.random.choice(ksel, p, (m,), replace=False, p=scores)
    # rescale by 1/sqrt(m q_j) as in [36]
    z = x[:, sel].astype(jnp.float32) / jnp.sqrt(m * scores[sel])[None, :]
    res = kmeans(z, k, krun, n_init=n_init, max_iter=max_iter, tol=tol)
    centers = jnp.zeros((k, p), jnp.float32).at[:, sel].set(res.centers * jnp.sqrt(m * scores[sel])[None, :])
    a = res.assignments
    if two_pass:
        oh = jax.nn.one_hot(a, k, dtype=jnp.float32)
        counts = jnp.sum(oh, axis=0)
        centers = jnp.where(counts[:, None] > 0,
                            (oh.T @ x.astype(jnp.float32)) / jnp.maximum(counts, 1.0)[:, None],
                            centers)
    return KMeansResult(a, centers, res.objective, res.n_iter)


# -------------------------------------------------------------- metrics -----

def clustering_accuracy(pred: jax.Array, true: jax.Array, k: int) -> float:
    """Best-permutation label accuracy (Hungarian matching), as in §VII-B."""
    from scipy.optimize import linear_sum_assignment

    pred = np.asarray(pred)
    true = np.asarray(true)
    conf = np.zeros((k, k))
    for i in range(k):
        for j in range(k):
            conf[i, j] = np.sum((pred == i) & (true == j))
    ri, ci = linear_sum_assignment(-conf)
    return float(conf[ri, ci].sum() / len(true))
