"""Element-wise uniform sampling without replacement (the R_i R_iᵀ step).

Each sample keeps exactly ``m`` of ``p`` coordinates, chosen uniformly at random
without replacement, **with an independent draw per sample** — the property the
paper's consistency results hinge on (§VII-B discussion).

Sparse data is stored as a *compact dense pair* ``(values (n, m), indices (n, m))``
rather than CSR/CSC: TPUs have no sparse memory path, and the compact pair keeps
the γ = m/p compute win as a reduced contraction dimension on the MXU (see
DESIGN.md §3.2). Indices are sorted ascending per row for locality.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparseRows:
    """Exactly-m-sparse rows of an (n, p) matrix in compact form.

    values:  (n, m) — the kept entries.
    indices: (n, m) int32 — their column positions, sorted ascending per row.
    p:       full dimensionality (static).
    """

    values: jax.Array
    indices: jax.Array
    p: int

    # -- pytree plumbing (p is static aux data) --
    def tree_flatten(self):
        return (self.values, self.indices), self.p

    @classmethod
    def tree_unflatten(cls, p, children):
        return cls(children[0], children[1], p)

    @property
    def n(self) -> int:
        return self.values.shape[0]

    @property
    def m(self) -> int:
        return self.values.shape[1]

    @property
    def gamma(self) -> float:
        """Deprecated: use ``SketchSpec.gamma`` (canonically ``m / p_pad``).

        For rows produced by ``sketch.sketch`` the two coincide (``self.p`` IS
        the padded dimensionality), but for raw unpadded subsamples at a
        non-power-of-two p this ``m / self.p`` disagrees with the spec the
        sketch was configured from (e.g. p=1000 → p_pad=1024) — so the spec's
        definition is the one the repo standardizes on.
        """
        import warnings

        warnings.warn(
            "SparseRows.gamma is deprecated: γ is canonically m / p_pad — read "
            "it from the SketchSpec (spec.gamma) that produced this sketch",
            DeprecationWarning, stacklevel=2)
        return self.m / self.p

    def to_dense(self) -> jax.Array:
        """Dense (n, p) with zeros at unsampled coordinates: R_i R_iᵀ y_i."""
        n, m = self.values.shape
        out = jnp.zeros((n, self.p), self.values.dtype)
        rows = jnp.arange(n)[:, None]
        return out.at[rows, self.indices].add(self.values)

    def nbytes(self) -> int:
        return self.values.size * self.values.dtype.itemsize + self.indices.size * self.indices.dtype.itemsize


def sample_indices(key: jax.Array, n: int, p: int, m: int) -> jax.Array:
    """(n, m) int32 — m distinct columns per row, uniform without replacement.

    top-k of i.i.d. uniforms is a uniformly random m-subset; we sort for locality.
    """
    if not (0 < m <= p):
        raise ValueError(f"need 0 < m <= p, got m={m}, p={p}")
    u = jax.random.uniform(key, (n, p))
    _, idx = jax.lax.top_k(u, m)
    return jnp.sort(idx.astype(jnp.int32), axis=-1)


@functools.partial(jax.jit, static_argnames=("m",))
def subsample(y: jax.Array, key: jax.Array, m: int) -> SparseRows:
    """Keep m of p entries of each row of ``y`` (n, p), independent per row."""
    n, p = y.shape
    idx = sample_indices(key, n, p, m)
    vals = jnp.take_along_axis(y, idx, axis=-1)
    return SparseRows(vals, idx, p)


def scatter_to_dense(values: jax.Array, indices: jax.Array, p: int) -> jax.Array:
    """Functional form of SparseRows.to_dense for raw (values, indices)."""
    return SparseRows(values, indices, p).to_dense()


def counts_per_coordinate(indices: jax.Array, p: int, dtype=jnp.int32) -> jax.Array:
    """(p,) — how many rows sampled each coordinate (the n_k^{(j)} of Eq. 39).

    Accumulates in int32 (exact to 2^31): a float32 scatter-add silently stops
    counting once a coordinate passes 2^24, which turns any downstream running
    mean into a fixed-rate EMA on long streams (the same fix as
    ``KMeansState.counts``). Callers that need float weights cast the returned
    exact counts at the call site — that is what the ``dtype`` parameter does.
    """
    counts = jnp.zeros((p,), jnp.int32).at[indices.reshape(-1)].add(1)
    return counts if dtype == jnp.int32 else counts.astype(dtype)


def row_sampled_gather(dense_vecs: jax.Array, indices: jax.Array) -> jax.Array:
    """R_iᵀ v for a batch: gather ``dense_vecs`` (n, p) or (p,) at (n, m) indices."""
    if dense_vecs.ndim == 1:
        return dense_vecs[indices]
    return jnp.take_along_axis(dense_vecs, indices, axis=-1)
