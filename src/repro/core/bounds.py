"""The paper's finite-sample bounds (Thms 4, 6, 7; Cors 2, 3, 5; Thm D6).

Used by the benchmark suite to reproduce Figs. 2, 3, 5 (bound-tightness plots)
and by users to size m for a target accuracy.

NOTE on conventions: the paper's data matrix is (p, n) with samples as columns;
this codebase stores (n, p) with samples as rows. The norm helpers below are
named by *meaning*, matched to the paper's symbols:

- ``max_abs``          = ‖X‖_max            (max |entry|)
- ``max_coord_norm``   = ‖X‖_max-row        (max over coordinates of ℓ2 across samples)
- ``max_sample_norm``  = ‖X‖_max-col        (max ℓ2 norm of a sample)
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.ros import ETA


# --------------------------------------------------------- norm helpers -----

def max_abs(x) -> jnp.ndarray:
    return jnp.max(jnp.abs(x))


def max_coord_norm(x) -> jnp.ndarray:
    """Paper's ‖X‖_max-row: x is (n, p), norm taken down each column."""
    return jnp.max(jnp.linalg.norm(x, axis=0))


def max_sample_norm(x) -> jnp.ndarray:
    """Paper's ‖X‖_max-col: max ℓ2 norm over samples (rows here)."""
    return jnp.max(jnp.linalg.norm(x, axis=1))


def max_fourth_moment(x) -> jnp.ndarray:
    """max_j Σ_i X_{j,i}^4 of Eq. (26) — per-coordinate quartic sum."""
    return jnp.max(jnp.sum(x.astype(jnp.float32) ** 4, axis=0))


def tau(m: int, p: int) -> float:
    """Eq. (9)."""
    return max(p / m - 1.0, 1.0)


# --------------------------------------------------------------- Thm 4 ------

def mean_failure_prob(t: float, n: int, m: int, p: int, x_max: float, x_maxrow: float) -> float:
    """δ₁ of Eq. (10): P{‖x̄̂ − x̄‖∞ > t} ≤ δ₁."""
    num = -n * t**2 / 2.0
    den = (p / m - 1.0) * x_maxrow**2 / n + tau(m, p) * x_max * t / 3.0
    return float(2 * p * np.exp(num / den))


def mean_error_bound(delta1: float, n: int, m: int, p: int, x_max: float, x_maxrow: float) -> float:
    """t(δ₁) of Eq. (16) — the ℓ∞ error bound at failure probability δ₁."""
    L = np.log(2 * p / delta1)
    a = tau(m, p) / 3.0 * x_max * L
    return float((a + np.sqrt(a**2 + 2.0 * (p / m - 1.0) * L * x_maxrow**2)) / n)


# --------------------------------------------------------------- Cor 2/3 ----

def ros_max_entry_bound(n: int, p: int, alpha: float, transform: str = "hadamard") -> float:
    """Cor. 2 Eq. (3): w.p. ≥ 1−α, ‖Y‖_max ≤ this (for unit-norm samples)."""
    eta = ETA[transform]
    return float(np.sqrt(2.0 / eta * np.log(2 * n * p / alpha)) / np.sqrt(p))


def ros_max_coord_norm_bound(n: int, p: int, alpha: float, transform: str = "hadamard") -> float:
    """Cor. 2 Eq. (4) (for unit-norm samples)."""
    eta = ETA[transform]
    return float(np.sqrt(n / p) * np.sqrt(2.0 / eta * np.log(2 * n * p / alpha)))


def rho_bound(n: int, p: int, m: int, alpha: float = 0.01, transform: str = "hadamard") -> float:
    """Cor. 3 Eq. (7): w.p. ≥ 1−α, ‖w_i‖² ≤ ρ‖x_i‖² with ρ = (m/p)(2/η)log(2np/α).

    Clipped at 1 since ρ ≤ 1 always holds deterministically.
    """
    eta = ETA[transform]
    return float(min(1.0, m / p * 2.0 / eta * np.log(2 * n * p / alpha)))


def cor5_min_m(n: int, p: int, t: float, transform: str = "hadamard") -> float:
    """Eq. (18): m needed for δ₁ ≤ 0.001 after preconditioning (γ ≤ 0.5)."""
    eta = ETA[transform]
    return float(
        1.0 / n * 4.0 / eta * np.log(200 * n * p) * np.log(2000 * p) * (t**-2 + np.sqrt(p) / (3.0 * t))
    )


# --------------------------------------------------------------- Thm 6 ------

@dataclasses.dataclass(frozen=True)
class CovBoundTerms:
    """L (25) and σ² (26) for the matrix-Bernstein covariance bound."""

    L: float
    sigma_sq: float
    p: int

    def failure_prob(self, t: float) -> float:
        """δ₂ of Eq. (24)."""
        return float(self.p * np.exp(-(t**2) / 2.0 / (self.sigma_sq + self.L * t / 3.0)))

    def error_bound(self, delta2: float) -> float:
        """t(δ₂) — spectral-norm error bound at failure probability δ₂."""
        lg = np.log(self.p / delta2)
        a = self.L / 3.0 * lg
        return float(a + np.sqrt(a**2 + 2.0 * self.sigma_sq * lg))


def cov_bound_terms(
    n: int,
    m: int,
    p: int,
    rho: float,
    x_max: float,
    x_maxcol: float,
    x_fro_sq: float,
    cov_norm: float,
    diag_cov_norm: float,
    max_fourth: float,
) -> CovBoundTerms:
    """Compute L (25) and the σ² upper bound (26) from data statistics."""
    c1 = p * (p - 1.0) / (m * (m - 1.0))
    L = (c1 * rho + 1.0) * x_maxcol**2 + p * (p - m) / (m * (m - 1.0)) * x_max**2
    L /= n
    sigma_sq = (
        (c1 * rho - 1.0) * x_maxcol**2 * cov_norm
        + p * (p - 1.0) * (p - m) / (m * (m - 1.0) ** 2) * rho * x_maxcol**2 * diag_cov_norm
        + 2.0 * p * (p - 1.0) * (p - m) / (m * (m - 1.0) ** 2) * x_max**2 * x_fro_sq / n
        + p * (p - m) ** 2 / (m * (m - 1.0) ** 2) * max_fourth / n
    ) / n
    return CovBoundTerms(L=float(L), sigma_sq=float(sigma_sq), p=p)


def cov_bound_from_data(x, m: int, rho: float | None = None, alpha: float = 0.01,
                        transform: str = "hadamard", preconditioned: bool = True) -> CovBoundTerms:
    """Convenience: measure the data statistics of (n, p) ``x`` and build the bound."""
    from repro.core.estimators import empirical_cov

    n, p = x.shape
    if rho is None:
        rho = rho_bound(n, p, m, alpha, transform) if preconditioned else 1.0
    c = empirical_cov(x)
    return cov_bound_terms(
        n=n,
        m=m,
        p=p,
        rho=rho,
        x_max=float(max_abs(x)),
        x_maxcol=float(max_sample_norm(x)),
        x_fro_sq=float(jnp.sum(x.astype(jnp.float32) ** 2)),
        cov_norm=float(jnp.linalg.norm(c, ord=2)),
        diag_cov_norm=float(jnp.max(jnp.abs(jnp.diagonal(c)))),
        max_fourth=float(max_fourth_moment(x)),
    )


# --------------------------------------------------------------- Thm 7 ------

def hk_failure_prob(t: float, n_k: int, m: int, p: int) -> float:
    """δ₃ of Eq. (43): P{‖H_k − I‖₂ > t} ≤ δ₃."""
    num = -n_k * t**2 / 2.0
    den = (p / m - 1.0) + (p / m + 1.0) * t / 3.0
    return float(p * np.exp(num / den))


def hk_error_bound(delta3: float, n_k: int, m: int, p: int) -> float:
    """t(δ₃) for Thm 7 — inverts Eq. (43)."""
    lg = np.log(p / delta3)
    a = (p / m + 1.0) * lg / (3.0 * n_k)
    return float(a + np.sqrt(a**2 + 2.0 * (p / m - 1.0) * lg / n_k))


# --------------------------------------------------------------- Thm D6 -----

def distance_preservation_min_m(beta: float, p: int) -> float:
    """Thm D6 sampling budget: m ≥ 4(√β + √(8 log(βp)))² log β keeps pairwise
    distances within [0.40, 1.48] w.p. ≥ 1 − 3/β."""
    return float(4.0 * (np.sqrt(beta) + np.sqrt(8.0 * np.log(beta * p))) ** 2 * np.log(beta))
