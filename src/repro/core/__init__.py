"""Core library: the paper's contribution as composable JAX modules.

- ros:         HD preconditioning (Eq. 1)
- sampling:    m-of-p uniform sampling without replacement, compact sparse rows
- sketch:      fused one-pass precondition+sample operator
- estimators:  unbiased mean / covariance estimators (Thms 4, 6)
- bounds:      the paper's finite-sample guarantees
- pca:         sparsified PCA
- kmeans:      sparsified K-means (Alg. 1/2) + baselines
- distributed: shard_map one-pass estimators
- grad_compress: sketched gradient all-reduce (beyond-paper integration)
"""
from repro.core import bounds, estimators, ros, sampling, sketch  # noqa: F401
from repro.core.sampling import SparseRows  # noqa: F401
from repro.core.sketch import SketchSpec, make_spec  # noqa: F401
