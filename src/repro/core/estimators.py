"""Unbiased estimators recovered from sparsified data (paper §IV–V).

Mean (Thm 4):      x̄̂ = (p/m)·(1/n) Σ_i R_iR_iᵀ x_i
Covariance (Thm 6): Ĉ_emp = p(p−1)/(m(m−1))·(1/n) Σ_i w_i w_iᵀ,
                   Ĉ_n = Ĉ_emp − (p−m)/(p−1)·diag(Ĉ_emp)   (unbiased)

Both have a *streaming* form (constant-memory accumulators, one pass) and a
*batch* form. The batch covariance offers two equivalent computation paths:

- ``dense``: scatter to (n, p) then one MXU matmul WᵀW — the right choice on TPU
  for n·p activations that fit;
- ``compact``: scatter n·m² outer-product entries — the right choice when γ ≪ 1
  and p is large (CPU / host aggregation).

Estimates live in the *preconditioned* domain when the data was sketched with a
ROS; PCA consumers either unmix eigenvectors (U = (HD)ᵀ Û) or work directly in
the preconditioned domain (the spectrum is unchanged — HD is orthonormal).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.sampling import SparseRows


# ---------------------------------------------------------------- mean ------

def mean_estimator(s: SparseRows) -> jax.Array:
    """Unbiased estimate of the sample mean (length p), Thm 4."""
    n, m = s.values.shape
    acc = jnp.zeros((s.p,), jnp.promote_types(s.values.dtype, jnp.float32))
    acc = acc.at[s.indices.reshape(-1)].add(s.values.reshape(-1).astype(acc.dtype))
    return acc * (s.p / (m * n))


# ---------------------------------------------------------- covariance ------

def _cov_scale(p: int, m: int) -> float:
    if m < 2:
        raise ValueError("covariance estimator needs m >= 2 (Thm B4, Eq. 50)")
    return (p * (p - 1)) / (m * (m - 1))


def _debias(c_emp_hat: jax.Array, p: int, m: int) -> jax.Array:
    corr = (p - m) / (p - 1)
    d = jnp.diagonal(c_emp_hat)
    return c_emp_hat - corr * jnp.diag(d)


def _scatter_outer(values: jax.Array, indices: jax.Array, p: int) -> jax.Array:
    """Σ_i w_i w_iᵀ via n·m² outer-product scatter-adds — the compact path's
    (p, p) accumulation with no dense (n, p) intermediate."""
    v = values.astype(jnp.float32)
    outer = v[:, :, None] * v[:, None, :]                     # (n, m, m)
    rows = jnp.broadcast_to(indices[:, :, None], outer.shape)
    cols = jnp.broadcast_to(indices[:, None, :], outer.shape)
    return jnp.zeros((p, p), jnp.float32).at[
        rows.reshape(-1), cols.reshape(-1)].add(outer.reshape(-1))


@functools.partial(jax.jit, static_argnames=("path",))
def cov_estimator(s: SparseRows, path: Literal["dense", "compact"] = "dense") -> jax.Array:
    """Unbiased estimate Ĉ_n (p×p) of the empirical covariance (1/n)·XᵀX, Thm 6."""
    n, m = s.values.shape
    scale = _cov_scale(s.p, m)
    if path == "dense":
        w = s.to_dense().astype(jnp.float32)
        c_emp_hat = scale / n * (w.T @ w)
    else:
        c_emp_hat = scale / n * _scatter_outer(s.values, s.indices, s.p)
    return _debias(c_emp_hat, s.p, m)


# ----------------------------------------------------------- streaming ------
# Minimal fold-a-batch accumulator, kept for small scripts and examples. The
# full streaming subsystem — donated accumulators, shard_map distribution,
# per-(step, shard) mask keys, streaming K-means — is repro.stream.StreamEngine.

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class StreamState:
    """Constant-memory accumulators for one-pass mean+covariance estimation.

    sum_w:    (p,)   Σ R_iR_iᵀ x_i
    sum_wwt:  (p, p) Σ w_i w_iᵀ       (only if track_cov)
    count:    scalar n so far — int32, exact to 2^31 rows (f32 would silently
              stop counting past 2^24 on the long streams the engine targets)
    """

    sum_w: jax.Array
    sum_wwt: jax.Array | None
    count: jax.Array

    def tree_flatten(self):
        return (self.sum_w, self.sum_wwt, self.count), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def stream_init(p: int, track_cov: bool = True) -> StreamState:
    return StreamState(
        sum_w=jnp.zeros((p,), jnp.float32),
        sum_wwt=jnp.zeros((p, p), jnp.float32) if track_cov else None,
        count=jnp.zeros((), jnp.int32),
    )


def stream_delta(batch: SparseRows, track_cov: bool = True,
                 cov_path: Literal["dense", "compact"] = "dense") -> StreamState:
    """One batch's contribution as a StreamState — local, no collectives, so a
    distributed caller can psum it before :func:`stream_apply`.

    ``cov_path="compact"`` scatters the n·m² outer products straight into the
    (p, p) accumulator instead of materializing the dense (n, p) scatter of the
    batch first — the right choice when γ ≪ 1 and p is large, where the n·p
    intermediate (not the accumulator) dominates the step's memory.
    """
    n = batch.values.shape[0]
    sum_w = jnp.zeros((batch.p,), jnp.float32).at[batch.indices.reshape(-1)].add(
        batch.values.reshape(-1).astype(jnp.float32)
    )
    sum_wwt = None
    if track_cov:
        if cov_path == "compact":
            sum_wwt = _scatter_outer(batch.values, batch.indices, batch.p)
        else:
            w = batch.to_dense().astype(jnp.float32)
            sum_wwt = w.T @ w
    return StreamState(sum_w, sum_wwt, jnp.int32(n))


def stream_apply(state: StreamState, delta: StreamState) -> StreamState:
    """Fold a (possibly psum'd) delta into the accumulator."""
    sum_wwt = state.sum_wwt
    if sum_wwt is not None:
        sum_wwt = sum_wwt + delta.sum_wwt
    return StreamState(state.sum_w + delta.sum_w, sum_wwt, state.count + delta.count)


@functools.partial(jax.jit, static_argnames=("cov_path",))
def stream_update(state: StreamState, batch: SparseRows,
                  cov_path: Literal["dense", "compact"] = "dense") -> StreamState:
    """Fold one sketched batch into the accumulators (pure; jit/scan friendly)."""
    return stream_apply(state, stream_delta(batch, track_cov=state.sum_wwt is not None,
                                            cov_path=cov_path))


def stream_finalize_mean(state: StreamState, m: int) -> jax.Array:
    p = state.sum_w.shape[0]
    # p/m first: keeps the divisor float (m·count could overflow int32)
    return state.sum_w * (p / m / state.count)


def stream_finalize_cov(state: StreamState, m: int) -> jax.Array:
    p = state.sum_w.shape[0]
    c_emp_hat = _cov_scale(p, m) / state.count * state.sum_wwt
    return _debias(c_emp_hat, p, m)


# ------------------------------------------------- reference quantities -----

def empirical_mean(x: jax.Array) -> jax.Array:
    return jnp.mean(x.astype(jnp.float32), axis=0)


def empirical_cov(x: jax.Array) -> jax.Array:
    """(1/n)·XᵀX — the paper's C_emp (uncentered second moment), rows=samples."""
    x = x.astype(jnp.float32)
    return x.T @ x / x.shape[0]
