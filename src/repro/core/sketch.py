"""The one-pass sketch: precondition (HD) then subsample (R_i R_iᵀ), fused.

This is the paper's full compression operator. A :class:`SketchSpec` captures
everything needed to interpret / unmix a sketch later (transform type, D's key,
original p) so that streaming consumers never revisit raw data.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import ros
from repro.core.sampling import SparseRows, sample_indices, subsample
from repro.utils.prng import fold_in_str


@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Static + key state describing a sketch stream."""

    p: int                      # original dimensionality
    m: int                      # kept coordinates per sample
    transform: ros.Transform = "hadamard"
    key: jax.Array | None = None  # root key; D uses fold("signs"), R_i use fold("mask")

    @property
    def p_pad(self) -> int:
        return ros.pad_len(self.p, self.transform)

    @property
    def gamma(self) -> float:
        """THE repo-wide definition of the keep fraction: γ = m / p_pad.

        Sampling happens in the padded (preconditioned) domain, so p_pad — not
        the original p — is the denominator ``make_spec`` rounds γ against.
        (``SparseRows.gamma``, the m / p of a row's own domain, is deprecated:
        at a non-power-of-two p the two disagree, e.g. p=1000 → p_pad=1024.)
        """
        return self.m / self.p_pad

    def signs_key(self) -> jax.Array:
        return fold_in_str(self.key, "ros-signs")

    def mask_key(self) -> jax.Array:
        return fold_in_str(self.key, "sample-mask")


def make_spec(p: int, key: jax.Array, gamma: float | None = None, m: int | None = None,
              transform: ros.Transform = "hadamard") -> SketchSpec:
    pp = ros.pad_len(p, transform)
    if m is None:
        if gamma is None:
            raise ValueError("provide gamma or m")
        if not 0.0 < gamma <= 1.0:
            raise ValueError(f"gamma must be in (0, 1], got {gamma}")
        # clamp: rounding can only reach p_pad at gamma=1, but keep the sampler
        # in range no matter what float lands here
        m = min(pp, max(1, int(round(gamma * pp))))
    m = int(m)
    if not 0 < m <= pp:
        raise ValueError(
            f"m must be in [1, p_pad={pp}] (transform={transform!r}, p={p}), got {m}")
    return SketchSpec(p=p, m=m, transform=transform, key=key)


def batch_key(spec: SketchSpec, step, shard) -> jax.Array:
    """The per-(step, shard) mask key — every batch draws independent R_i.

    This is the repo-wide PRNG discipline: the stream engine, the ``repro.api``
    estimators, and the gradient compressor all derive their per-batch masks by
    folding (step, shard) into the spec's mask key, so any worker can regenerate
    any batch's mask from (root key, step, shard) alone.
    """
    return jax.random.fold_in(jax.random.fold_in(spec.mask_key(), step), shard)


@functools.partial(jax.jit, static_argnames=("p", "m", "transform", "impl"))
def _sketch_impl(x, signs_key, mask_key, p, m, transform, impl):
    if impl in ("kernel", "interpret") and transform == "hadamard":
        # the fused one-pass kernel: precondition → sample without writing the
        # dense (n, p_pad) intermediate back to HBM (~2.5× less traffic at
        # γ=0.05). sample_indices here is bit-identical to subsample's draw
        # (same key, same (n, p_pad) shape), so the sketch is unchanged; above
        # the fused ceiling kernels.ops composes chunked-FWHT + gather.
        from repro.kernels import ops as kops  # deferred: kernels import core

        pp = ros.pad_len(p, transform)
        if x.shape[-1] < pp:
            x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pp - x.shape[-1])])
        d = ros.signs_for(signs_key, pp, dtype=x.dtype)
        idx = sample_indices(mask_key, x.shape[0], pp, m)
        vals = kops.sketch_fused(x, d, idx, mode=impl)
        return SparseRows(vals, idx, pp)
    y = ros.precondition(x, signs_key, transform, p_orig=p, impl=impl)
    return subsample(y, mask_key, m)


def sketch(x: jax.Array, spec: SketchSpec, batch_key: jax.Array | None = None,
           impl: str = "auto") -> SparseRows:
    """Compress a batch of rows (n, p) → SparseRows (n, m) in one fused pass.

    ``batch_key`` distinguishes batches of a stream so every sample gets an
    independent R_i; defaults to the spec's mask key (fine for one-shot use).
    ``impl`` picks the backend (see ros.resolve_impl); the default uses the
    Pallas kernels on TPU and the jnp butterfly elsewhere. Kernel impls take
    the FUSED one-pass path (kernels.sketch_fused) for Hadamard specs up to
    the single-tile ceiling — same sketch, one VMEM round trip.
    """
    impl = ros.resolve_impl(impl)
    mask_key = batch_key if batch_key is not None else spec.mask_key()
    return _sketch_impl(x, spec.signs_key(), mask_key, spec.p, spec.m, spec.transform, impl)


def unmix_dense(w_dense: jax.Array, spec: SketchSpec) -> jax.Array:
    """(HD)ᵀ applied to dense vectors living in the preconditioned domain."""
    return ros.unmix(w_dense, spec.signs_key(), spec.transform, p_orig=spec.p)


def compression_ratio(spec: SketchSpec, value_bytes: int = 4, index_bytes: int = 4) -> float:
    """Stored bytes per sample vs. dense fp32 — the paper's storage story.

    The dense baseline is the ORIGINAL p (what the user actually stores), while
    m was rounded from γ·p_pad — so at a padded p the ratio is slightly larger
    than γ·(value_bytes+index_bytes)/4 (e.g. p=1000, γ=0.25 → m=256 →
    ratio 0.512, not 0.5).
    """
    dense = spec.p * 4
    sketched = spec.m * (value_bytes + index_bytes)
    return sketched / dense
