"""Sketched gradient compression — the paper's estimator applied to DP training.

Two modes (DESIGN.md §2):

**shared-mask** (default, communication-optimal): all workers use the SAME
per-step mask R_t (derived from the step key), so the DP reduction only touches
the m kept coordinates — the all-reduce shrinks from p to m = γ·p floats.
Over steps, masks are independent ⇒ with error feedback this is preconditioned
rand-k: the ROS smoothing (Thm 1) is what makes *uniform* index sampling
competitive with magnitude-aware top-k, with zero index traffic (a seed).

**per-worker** (paper-faithful Thm 4): every worker draws its own R_i and the
averaged estimator (p/m)(1/n_w)ΣR_iR_iᵀ(HD g_i) is exactly the paper's sample
mean — unbiased with the ℓ∞ bound (16). Realized as an all_gather of (values)
+ scatter-accumulate; the traffic is n_w·m per worker, winning when γ < 1/n_w
(Cor. 5's log(n)/n budget as the fleet grows). Used inside shard_map.

Gradients are flattened to one vector and chunked to ``chunk_p`` (power of two);
each chunk gets the block-diagonal ROS — an orthonormal map, so all guarantees
hold per chunk with p → chunk_p.

PRNG discipline: the compressor's keys are the SAME (seed, step, shard) story as
data sketching — a :class:`~repro.core.sketch.SketchSpec` over the chunk length
supplies the signs key, and every per-step (and, in per-worker mode, per-shard)
mask is ``sketch.batch_key(spec, step, shard)``, so DP training and streaming
estimation share one bookkeeping scheme (any worker can regenerate any step's
mask from the root key alone).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ros
from repro.core import sketch as sketch_mod
from repro.core.sampling import sample_indices
from repro.utils.tree import tree_flatten_to_vector


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    gamma: float = 0.1
    chunk_p: int = 1 << 14            # ROS block size (power of two)
    error_feedback: bool = True
    mode: str = "shared-mask"         # or "per-worker"

    @property
    def m(self) -> int:
        return max(1, int(round(self.gamma * self.chunk_p)))


def mask_spec(cfg: CompressConfig, key: jax.Array) -> sketch_mod.SketchSpec:
    """The compressor's sketch over one gradient chunk — the single source of
    its signs key and per-(step, shard) mask keys (``sketch.batch_key``).
    Routed through make_spec so an out-of-range gamma/chunk_p combination
    fails here, not deep inside the sampler."""
    return sketch_mod.make_spec(cfg.chunk_p, key, m=cfg.m, transform="hadamard")


def _to_chunks(vec: jax.Array, chunk_p: int):
    n = vec.shape[0]
    pad = -n % chunk_p
    v = jnp.pad(vec, (0, pad))
    return v.reshape(-1, chunk_p), n


def compress_decompress(vec: jax.Array, key: jax.Array, step: jax.Array,
                        cfg: CompressConfig, unbiased: bool | None = None,
                        shard: int | jax.Array = 0):
    """Shared-mask round trip g → ĝ on one worker's (or the averaged) gradient.

    Returns (g_hat, kept_values) — in a real collective only ``kept_values``
    (m per chunk) crosses the network; the reconstruction is local.

    ``shard`` folds into the mask key exactly as the stream engine's shard id
    does; shared-mask mode keeps the default 0 on every worker (same mask ⇒
    the all-reduce only touches the kept coordinates).

    ``unbiased=True`` applies the paper's (p/m) rescale (Thm 4 estimator).
    With error feedback the compressor must be CONTRACTIVE, so the rescale is
    dropped (rand-k + EF convention) — the residual loop restores the missing
    mass over steps; (p/m)-rescaled EF residuals diverge (‖I − (p/m)RRᵀ‖ ≫ 1).
    """
    if unbiased is None:
        unbiased = not cfg.error_feedback
    spec = mask_spec(cfg, key)
    chunks, n = _to_chunks(vec, cfg.chunk_p)
    nc, cp = chunks.shape
    signs_key = spec.signs_key()
    y = ros.precondition(chunks, signs_key, "hadamard")
    idx = sample_indices(sketch_mod.batch_key(spec, step, shard), nc, cp, cfg.m)
    vals = jnp.take_along_axis(y, idx, axis=-1)               # ← the wire payload
    scale = (cp / cfg.m) if unbiased else 1.0
    y_hat = jnp.zeros_like(y).at[jnp.arange(nc)[:, None], idx].set(vals) * scale
    g_hat = ros.unmix(y_hat, signs_key, "hadamard").reshape(-1)[:n]
    return g_hat, vals


def compress_grads(grads: Any, key: jax.Array, step: jax.Array, cfg: CompressConfig,
                   residual: Any | None = None, shard: int | jax.Array = 0):
    """Apply sketch compression to a gradient pytree (+ error feedback).

    Returns (g_hat pytree, new_residual pytree or None, wire_floats int).
    """
    vec, unflatten = tree_flatten_to_vector(grads)
    if residual is not None:
        rvec, _ = tree_flatten_to_vector(residual)
        vec = vec + rvec
    g_hat_vec, vals = compress_decompress(vec, key, step, cfg, shard=shard)
    new_residual = None
    if cfg.error_feedback:
        new_residual = unflatten(vec - g_hat_vec)
    return unflatten(g_hat_vec), new_residual, int(np.prod(vals.shape))


def perworker_mean_estimate(local_vec: jax.Array, key: jax.Array, step: jax.Array,
                            cfg: CompressConfig, axis_names) -> jax.Array:
    """Paper-faithful Thm-4 estimator across DP workers (call inside shard_map).

    Each worker samples its own mask — its shard id (flattened axis index) folds
    into ``sketch.batch_key`` exactly as a stream shard's does; the mean of the
    scattered, rescaled samples is psum'd — unbiased for the mean gradient.
    """
    spec = mask_spec(cfg, key)
    chunks, n = _to_chunks(local_vec, cfg.chunk_p)
    nc, cp = chunks.shape
    signs_key = spec.signs_key()                              # shared unitary
    y = ros.precondition(chunks, signs_key, "hadamard")
    widx = 0
    for a in axis_names:
        # jax.lax.axis_size is absent in jax 0.4.x; psum of 1 is the portable form.
        widx = widx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    idx = sample_indices(sketch_mod.batch_key(spec, step, widx), nc, cp, cfg.m)
    vals = jnp.take_along_axis(y, idx, axis=-1)
    scat = jnp.zeros_like(y).at[jnp.arange(nc)[:, None], idx].set(vals) * (cp / cfg.m)
    n_w = 1
    for a in axis_names:
        scat = jax.lax.psum(scat, a)
        n_w *= jax.lax.psum(1, a)
    y_mean = scat / n_w
    return ros.unmix(y_mean, signs_key, "hadamard").reshape(-1)[:n]


def wire_bytes(p_total: int, cfg: CompressConfig, n_workers: int) -> dict:
    """Napkin accounting used by EXPERIMENTS.md §Perf."""
    dense = 2 * p_total * 4                                   # ring all-reduce ≈ 2p
    if cfg.mode == "shared-mask":
        comp = 2 * int(p_total * cfg.gamma) * 4
    else:
        comp = n_workers * int(p_total * cfg.gamma) * 8       # values+indices gather
    return {"dense_bytes": dense, "compressed_bytes": comp, "ratio": comp / dense}
