"""Distributed one-pass sketching & estimation (paper §I: distributed-data setting).

.. deprecated::
    These free functions are kept as thin shims for existing callers. The
    front door for new code is ``repro.api`` — the same reductions run via
    ``Plan(backend="sharded")`` on :class:`repro.api.SparsifiedMean` /
    ``SparsifiedCov`` / ``SparsifiedPCA`` / ``SparsifiedKMeans``, sharing one
    key discipline with the batch and streaming backends.

Each data shard sketches its own samples locally (independent R_i per sample),
and the only cross-shard traffic is the psum of the fixed-size accumulators —
(p,) for the mean, (p,p) for the covariance, (K,p)+(K,p) for K-means updates.
The mean/covariance reductions delegate to the explicit shard_map collectives
in ``repro.stream.sharded`` (one psum of the accumulator delta per call);
K-means keeps global-view jit because Lloyd's loop interleaves many small
reductions that XLA already lowers to the same psums. The *streaming* versions
of all three — constant-memory, batch-at-a-time — live in
``repro.stream.StreamEngine``.

tests/test_distributed.py asserts equivalence with the single-device path on a
forced host mesh (for K-means: up to a cluster relabelling — see the test's
docstring for the tie-break diagnosis).

For clusters: run one process per host with the same code; `jax.make_mesh`
over all devices; the data pipeline feeds per-host shards (data/pipeline.py's
(seed, step, shard) contract).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import estimators, kmeans, sketch
from repro.core.sampling import SparseRows
from repro.stream import sharded as _sharded


def shard_rows(x: jax.Array, mesh, axes=("data",)) -> jax.Array:
    """Place (n, …) data row-sharded over the mesh's data axes."""
    spec = P(axes if len(axes) > 1 else axes[0], *([None] * (x.ndim - 1)))
    return jax.device_put(x, NamedSharding(mesh, spec))


def sketch_sharded(x: jax.Array, spec: sketch.SketchSpec, mesh, axes=("data",)) -> SparseRows:
    """One-pass compress of row-sharded data; output stays row-sharded."""
    xs = shard_rows(x, mesh, axes)
    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        return sketch.sketch(xs, spec)


def distributed_mean(s: SparseRows, mesh, axes=("data",)) -> jax.Array:
    """Thm-4 estimator over sharded sketches; psum of a (p,) accumulator."""
    return _sharded.sharded_mean(s, mesh, axes)


def distributed_cov(s: SparseRows, mesh, axes=("data",)) -> jax.Array:
    """Thm-6 estimator; the (p,p) accumulator is the only cross-shard tensor."""
    return _sharded.sharded_cov(s, mesh, axes)


def distributed_kmeans(s: SparseRows, k: int, key, mesh, n_init: int = 3,
                       max_iter: int = 50, tol: float = 1e-6):
    """Sparsified K-means on sharded sketches (assignment stays local; the
    center/count scatter-adds psum over the data axes)."""
    with mesh:
        return kmeans.sparse_kmeans_core(
            s.values, s.indices, s.p, k, key, n_init=n_init, max_iter=max_iter, tol=tol
        )
