"""Sparsified PCA (paper §V application): principal components from sketched data.

The unbiased covariance estimator Ĉ_n is formed in the *preconditioned* domain;
its eigenvectors are unmixed by (HD)ᵀ to give components in the original domain
(HD is orthonormal, so eigenvalues are unchanged — §VI-A).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimators, sketch
from repro.core.sampling import SparseRows


@dataclasses.dataclass(frozen=True)
class PCAResult:
    components: jax.Array     # (k, p) — rows are principal components, original domain
    eigenvalues: jax.Array    # (k,)  — descending
    mean: jax.Array | None    # (p,)  — unbiased mean estimate (original domain)


def _top_eig(c: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    evals, evecs = jnp.linalg.eigh(c)             # ascending
    order = jnp.argsort(evals)[::-1][:k]
    return evecs[:, order].T, evals[order]


def pca(x: jax.Array, k: int) -> PCAResult:
    """Reference dense PCA of (1/n)·XᵀX, rows=samples (uncentered, as the paper)."""
    comps, evals = _top_eig(estimators.empirical_cov(x), k)
    return PCAResult(comps, evals, estimators.empirical_mean(x))


def sparsified_pca(s: SparseRows, spec: sketch.SketchSpec, k: int,
                   preconditioned: bool = True) -> PCAResult:
    """PCA from a one-pass sketch. ``s`` lives in the preconditioned domain."""
    c_hat = estimators.cov_estimator(s, path="dense")
    comps_pre, evals = _top_eig(c_hat, k)
    mean_pre = estimators.mean_estimator(s)
    if preconditioned:
        comps = sketch.unmix_dense(comps_pre, spec)
        mean = sketch.unmix_dense(mean_pre[None, :], spec)[0]
    else:
        comps, mean = comps_pre[:, : spec.p], mean_pre[: spec.p]
    return PCAResult(comps, evals, mean)


def pca_from_stream(state: estimators.StreamState, spec: sketch.SketchSpec, k: int) -> PCAResult:
    """Finalize streaming accumulators into PCs (constant memory, single pass)."""
    c_hat = estimators.stream_finalize_cov(state, spec.m)
    comps_pre, evals = _top_eig(c_hat, k)
    mean_pre = estimators.stream_finalize_mean(state, spec.m)
    comps = sketch.unmix_dense(comps_pre, spec)
    mean = sketch.unmix_dense(mean_pre[None, :], spec)[0]
    return PCAResult(comps, evals, mean)


def explained_variance(components: jax.Array, x: jax.Array) -> jax.Array:
    """Fraction tr(Uᵀ XᵀX U)/tr(XᵀX) (Fig. 1 metric). ``components``: (k, p)."""
    x = x.astype(jnp.float32)
    u = components.astype(jnp.float32)
    proj = x @ u.T                               # (n, k)
    return jnp.sum(proj**2) / jnp.sum(x**2)


def recovered_components(est: jax.Array, true: jax.Array, thresh: float = 0.95) -> int:
    """Table-I metric: #true components recovered under a greedy ONE-TO-ONE match.

    Pairs the globally largest |⟨û_i, u_j⟩| first, then removes both û_i and
    u_j from contention and repeats — so one estimated component can never be
    credited for several true ones (a per-true-component ``max`` over the Gram
    matrix would double-count exactly that way and inflate the metric).
    """
    g = np.abs(np.asarray(est, np.float32) @ np.asarray(true, np.float32).T)  # (ke, kt)
    recovered = 0
    for _ in range(min(g.shape)):
        i, j = np.unravel_index(np.argmax(g), g.shape)
        if g[i, j] <= thresh:
            break
        recovered += 1
        g[i, :] = -1.0  # û_i is spent …
        g[:, j] = -1.0  # … and u_j is matched
    return recovered
