"""Randomized orthonormal system (ROS) preconditioning: x -> y = H D x  (paper Eq. 1).

``H`` is a fast orthonormal transform (normalized Walsh-Hadamard or orthonormal
DCT-II) and ``D`` a random ±1 diagonal. ``HD`` is orthonormal, so the adjoint
``D Hᵀ`` exactly unmixes. Applying H costs O(p log p) and is embarrassingly
parallel across samples.

Data convention: **rows are samples** — ``X`` has shape ``(n, p)`` (the paper
uses columns; everything here is the transpose of the paper's notation).

Hadamard requires p a power of two; :func:`pad_len` gives the padded length and
:func:`precondition` zero-pads internally (zero-padding then applying an
orthonormal transform is itself an isometry on the embedded data, so all the
paper's guarantees hold with p replaced by p_pad).

The TPU-optimized path lives in ``repro.kernels.fwht`` (Kronecker-factored MXU
form); this module is the reference implementation used on CPU and as the
kernels' oracle.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.prng import rademacher

Transform = Literal["hadamard", "dct"]

# η in Thm. 1 / Cor. 2-3: Hadamard has the sharper sub-gaussian constant.
ETA = {"hadamard": 1.0, "dct": 0.5}


def pad_len(p: int, transform: Transform = "hadamard") -> int:
    """Length after padding: next power of two for Hadamard, identity for DCT."""
    if transform == "dct":
        return p
    return 1 << max(0, (p - 1).bit_length())


def fwht(x: jax.Array) -> jax.Array:
    """Normalized fast Walsh-Hadamard transform along the last axis.

    Iterative radix-2 butterfly, O(p log p). Requires p a power of two.
    Self-inverse (H = Hᵀ = H⁻¹ after 1/√p normalization).
    """
    p = x.shape[-1]
    if p & (p - 1):
        raise ValueError(f"FWHT needs a power-of-two length, got {p}")
    orig_shape = x.shape
    x = x.reshape(-1, p)
    h = 1
    while h < p:
        x = x.reshape(-1, p // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    x = x.reshape(orig_shape)
    return x * (1.0 / np.sqrt(p)).astype(x.dtype)


def _dct_ii_ortho(x: jax.Array) -> jax.Array:
    """Orthonormal DCT-II along the last axis via a single length-p FFT.

    Uses the even/odd reordering trick (Makhoul): v = [x_0, x_2, ..., x_3, x_1],
    X_k = Re(e^{-iπk/2p} FFT(v)_k), then orthonormal scaling.
    """
    p = x.shape[-1]
    v = jnp.concatenate([x[..., ::2], x[..., 1::2][..., ::-1]], axis=-1)
    V = jnp.fft.fft(v.astype(jnp.float32), axis=-1)
    k = jnp.arange(p)
    phase = jnp.exp(-1j * jnp.pi * k / (2 * p))
    y = 2.0 * jnp.real(phase * V)
    scale = jnp.full((p,), np.sqrt(1.0 / (2 * p)), dtype=jnp.float32).at[0].set(np.sqrt(1.0 / (4 * p)))
    return (y * scale).astype(x.dtype)


def _dct_iii_ortho(x: jax.Array) -> jax.Array:
    """Orthonormal DCT-III (inverse of orthonormal DCT-II) along the last axis.

    Reconstructs the length-p FFT of the reordered sequence from the DCT
    coefficients using Hermitian symmetry (W_{p−k} = −i·conj(W_k)), then inverts.
    """
    p = x.shape[-1]
    k = jnp.arange(p)
    scale = jnp.full((p,), np.sqrt(1.0 / (2 * p)), dtype=jnp.float32).at[0].set(np.sqrt(1.0 / (4 * p)))
    Y = x.astype(jnp.float32) / (2.0 * scale)                 # Re(e^{-iπk/2p} V_k)
    im = -jnp.concatenate([jnp.zeros_like(Y[..., :1]), Y[..., :0:-1]], axis=-1)
    V = jnp.exp(1j * jnp.pi * k / (2 * p)) * (Y + 1j * im)
    v = jnp.real(jnp.fft.ifft(V, axis=-1))
    # undo the even/odd reordering
    out = jnp.zeros_like(v)
    half = (p + 1) // 2
    out = out.at[..., ::2].set(v[..., :half])
    out = out.at[..., 1::2].set(v[..., half:][..., ::-1])
    return out.astype(x.dtype)


def apply_h(x: jax.Array, transform: Transform = "hadamard", adjoint: bool = False) -> jax.Array:
    """Apply the deterministic orthonormal H (or Hᵀ) along the last axis."""
    if transform == "hadamard":
        return fwht(x)  # symmetric & self-inverse
    if adjoint:
        return _dct_iii_ortho(x)
    return _dct_ii_ortho(x)


def signs_for(key: jax.Array, p_padded: int, dtype=jnp.float32) -> jax.Array:
    """The diagonal of D — derived deterministically from ``key``."""
    return rademacher(key, (p_padded,), dtype=dtype)


def resolve_impl(impl: str) -> str:
    """Resolve the "auto" Hadamard backend: Pallas kernel on TPU, jnp elsewhere.

    The single policy point shared by :func:`precondition` and sketch.sketch.
    """
    if impl == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "jnp"
    return impl


@functools.partial(jax.jit, static_argnames=("transform", "p_orig", "impl"))
def precondition(x: jax.Array, key: jax.Array, transform: Transform = "hadamard",
                 p_orig: int | None = None, impl: str = "jnp") -> jax.Array:
    """y = H D x along the last axis, zero-padding to the transform length.

    ``x``: (..., p). Returns (..., p_pad).

    ``impl`` selects the Hadamard backend: ``"jnp"`` (butterfly reference),
    ``"kernel"`` / ``"interpret"`` (the Pallas MXU kernel, chunked three-pass
    above p = 2^15 — see repro.kernels.fwht), or ``"auto"`` (kernel on TPU,
    jnp elsewhere). Non-Hadamard transforms always use the jnp path.
    """
    p = p_orig if p_orig is not None else x.shape[-1]
    pp = pad_len(p, transform)
    if x.shape[-1] < pp:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, pp - x.shape[-1])]
        x = jnp.pad(x, pad)
    d = signs_for(key, pp, dtype=x.dtype)
    impl = resolve_impl(impl)
    if impl != "jnp" and transform == "hadamard":
        from repro.kernels import fwht as _fwht  # deferred: kernels import this module

        lead = x.shape[:-1]
        y = _fwht.hd_precondition(x.reshape(-1, pp), d, interpret=(impl == "interpret"))
        return y.reshape(*lead, pp)
    return apply_h(x * d, transform)


@functools.partial(jax.jit, static_argnames=("transform", "p_orig"))
def unmix(y: jax.Array, key: jax.Array, transform: Transform = "hadamard", p_orig: int | None = None) -> jax.Array:
    """x = D Hᵀ y — exact inverse of :func:`precondition` (drops any padding)."""
    pp = y.shape[-1]
    d = signs_for(key, pp, dtype=y.dtype)
    x = apply_h(y, transform, adjoint=True) * d
    if p_orig is not None and p_orig < pp:
        x = x[..., :p_orig]
    return x


def hadamard_matrix(p: int, dtype=jnp.float32) -> jax.Array:
    """Dense normalized Hadamard matrix (tests / small-p fallback only)."""
    if p & (p - 1):
        raise ValueError(f"p must be a power of two, got {p}")
    h = np.array([[1.0]])
    while h.shape[0] < p:
        h = np.block([[h, h], [h, -h]])
    return jnp.asarray(h / np.sqrt(p), dtype=dtype)
