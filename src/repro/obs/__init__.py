"""repro.obs — full-stack telemetry: metrics, tracing, structured step logs.

Dependency-free (stdlib + numpy; jax touched only lazily for profiler
annotations). The pieces:

- :class:`MetricsRegistry` — thread-safe counters / gauges / histograms
  (p50/p95/p99 from a bounded reservoir), label-keyed series, an in-process
  ``snapshot()`` API, and a shared no-op mode so disabled telemetry is free.
- :func:`span` / :func:`timed` — nesting wall-time tracing aggregated per
  dotted path, passed through ``jax.profiler.TraceAnnotation`` so the same
  names appear in XLA profiles.
- :class:`StepLogger` / :func:`read_jsonl` — structured JSONL step records.
- :func:`render_exposition` / :class:`MetricsServer` — Prometheus-style text
  exposition and a stdlib scrape endpoint.
- :func:`quantiles` — THE shared percentile helper (benchmarks and launch
  drivers compute latency percentiles through it).

Wired consumers: ``StreamEngine.run(telemetry=)`` (per-step engine metrics),
``SketchService`` (its legacy ``stats`` dict is now a registry snapshot),
``repro.cluster.heartbeat`` (per-host liveness gauges on the EngineState wire
format), and the ``repro.kernels.ops`` dispatch counters
(``kernels.dispatch{op=,path=}`` — watch for silent regressions to the jnp
fallback path).
"""
from repro.obs.registry import (  # noqa: F401
    DEFAULT_QUANTILES,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_REGISTRY,
    default_registry,
    quantiles,
    set_default_registry,
)
from repro.obs.sinks import (  # noqa: F401
    MetricsServer,
    render_exposition,
    serve_metrics,
)
from repro.obs.steplog import StepLogger, read_jsonl  # noqa: F401
from repro.obs.tracing import (  # noqa: F401
    current_path,
    span,
    span_totals,
    timed,
)
