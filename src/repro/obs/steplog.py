"""StepLogger — structured JSONL progress output for step loops.

One JSON object per line, append-only, machine-parseable — the levanter-style
hook-driven step log, minus the wandb dependency. Every record carries the
step index, a wall-clock timestamp, and whatever fields the caller passes;
numpy scalars/arrays coerce to plain JSON so engine metrics log without
ceremony. ``every=N`` downsamples at the logger (callers log every step and
the logger decides), which keeps call sites free of modulo logic.

:func:`read_jsonl` is the inverse — the round-trip the tests pin.
"""
from __future__ import annotations

import io
import json
import os
import time
from typing import Any, IO


def _jsonable(v: Any):
    if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:  # numpy scalar
        return v.item()
    if hasattr(v, "tolist"):                               # numpy array
        return v.tolist()
    return str(v)


class StepLogger:
    """Write structured per-step JSONL records to a path or stream.

    Parameters
    ----------
    path: file to append to (created, parent dirs made). Mutually exclusive
        with ``stream``.
    stream: an open text stream (e.g. ``sys.stderr``) — not closed on exit.
    every: emit only steps where ``step % every == 0`` (step 0 always logs;
        pass force=True to log an off-cadence record, e.g. the final step).
    static: fields stamped into every record (run id, host, config).
    """

    def __init__(self, path: str | None = None, stream: IO | None = None,
                 every: int = 1, static: dict | None = None):
        if (path is None) == (stream is None):
            raise ValueError("StepLogger needs exactly one of path= / stream=")
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.every = int(every)
        self.static = dict(static or {})
        self.path = path
        self._owns = path is not None
        if path is not None:
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            self._f: IO = open(path, "a")
        else:
            self._f = stream
        self.emitted = 0

    def log(self, step: int, force: bool = False, **fields) -> bool:
        """Emit one record (subject to ``every``); returns whether it wrote."""
        if not force and step % self.every != 0:
            return False
        rec = {"step": int(step), "t": round(time.time(), 6), **self.static}
        for k, v in fields.items():
            rec[k] = v if isinstance(v, (int, float, str, bool, type(None),
                                         list, dict)) else _jsonable(v)
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        self.emitted += 1
        return True

    def close(self) -> None:
        if self._owns and not self._f.closed:
            self._f.close()

    def __enter__(self) -> "StepLogger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_jsonl(path_or_stream) -> list[dict]:
    """Parse a JSONL file (or open stream) back into a list of records."""
    if isinstance(path_or_stream, (str, os.PathLike)):
        with open(path_or_stream) as f:
            return [json.loads(line) for line in f if line.strip()]
    if isinstance(path_or_stream, io.StringIO):
        path_or_stream.seek(0)
    return [json.loads(line) for line in path_or_stream if line.strip()]
