"""Metric sinks: Prometheus-style text exposition + a scrape endpoint.

:func:`render_exposition` serializes a registry in the Prometheus text
format — counters and gauges as single samples, histograms as summaries
(``_count`` / ``_sum`` plus ``quantile=`` samples from the reservoir). Metric
names sanitize ``.``/``-`` to ``_``; label values escape per the format spec.
The output is deterministic (sorted by name, then label set) so tests can pin
it as a snapshot.

:class:`MetricsServer` is the stdlib scrape endpoint (daemon-threaded
``ThreadingHTTPServer``): ``GET /metrics`` answers the exposition text,
``GET /metrics.json`` the :meth:`MetricsRegistry.snapshot` JSON. The launch
drivers hang one off ``--metrics-port`` so a long-lived run can be watched
with nothing but curl.
"""
from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs.registry import DEFAULT_QUANTILES, MetricsRegistry

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _prom_labels(labels: dict, extra: dict | None = None) -> str:
    merged = {**labels, **(extra or {})}
    if not merged:
        return ""
    body = ",".join(
        '{}="{}"'.format(_prom_name(str(k)),
                         str(v).replace("\\", r"\\").replace('"', r"\"")
                               .replace("\n", r"\n"))
        for k, v in sorted(merged.items()))
    return "{" + body + "}"


def _fmt(v) -> str:
    if v is None or v != v:  # None / NaN
        return "NaN"
    f = float(v)
    # ±Inf per the Prometheus text format; int(inf) would raise OverflowError
    # below, so one infinite gauge (or histogram sum) must not kill a scrape
    if f == float("inf"):
        return "+Inf"
    if f == float("-inf"):
        return "-Inf"
    return str(int(f)) if f == int(f) else repr(f)


def render_exposition(registry: MetricsRegistry) -> str:
    """The registry as Prometheus text-format exposition (sorted, stable)."""
    lines: list[str] = []
    typed: set[str] = set()
    for m in sorted(registry.metrics(),
                    key=lambda m: (m.name, sorted(m.labels.items()))):
        name = _prom_name(m.name)
        if m.kind == "counter":
            if name not in typed:
                typed.add(name)
                lines.append(f"# TYPE {name} counter")
            lines.append(f"{name}{_prom_labels(m.labels)} {_fmt(m.value)}")
        elif m.kind == "gauge":
            if name not in typed:
                typed.add(name)
                lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name}{_prom_labels(m.labels)} {_fmt(m.value)}")
        elif m.kind == "histogram":
            if name not in typed:
                typed.add(name)
                lines.append(f"# TYPE {name} summary")
            s = m.summary()
            for q, key in zip(DEFAULT_QUANTILES, ("p50", "p95", "p99")):
                lines.append(f"{name}{_prom_labels(m.labels, {'quantile': q})}"
                             f" {_fmt(s[key])}")
            lines.append(f"{name}_count{_prom_labels(m.labels)} "
                         f"{_fmt(s['count'])}")
            lines.append(f"{name}_sum{_prom_labels(m.labels)} "
                         f"{_fmt(s['sum'])}")
    return "\n".join(lines) + ("\n" if lines else "")


class _Handler(BaseHTTPRequestHandler):
    registry: MetricsRegistry  # class attr, bound per-server subclass

    def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler contract
        if self.path.split("?")[0] == "/metrics":
            body = render_exposition(self.registry).encode()
            ctype = "text/plain; version=0.0.4; charset=utf-8"
        elif self.path.split("?")[0] == "/metrics.json":
            body = json.dumps(self.registry.snapshot(), default=str).encode()
            ctype = "application/json"
        else:
            self.send_error(404, "try /metrics or /metrics.json")
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):  # scrapes must not spam the run's stdout
        pass


class MetricsServer:
    """A daemon-threaded scrape endpoint over one registry. ``port=0`` binds
    an ephemeral port (read it back off ``.port``/``.url``)."""

    def __init__(self, registry: MetricsRegistry, port: int = 0,
                 host: str = "127.0.0.1"):
        handler = type("_BoundHandler", (_Handler,), {"registry": registry})
        self._httpd = ThreadingHTTPServer((host, int(port)), handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self.url = f"http://{host}:{self.port}/metrics"
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True, name="obs-metrics")
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join()

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve_metrics(registry: MetricsRegistry, port: int = 0) -> MetricsServer:
    """Start a /metrics endpoint for ``registry``; returns the live server."""
    return MetricsServer(registry, port)
