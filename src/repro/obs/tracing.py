"""span()/timed() — nested wall-time tracing that aggregates per name.

A ``span("engine.update")`` times its block and folds the duration into the
owning registry's ``span`` histogram under the span's *path* — nested spans
dot-join (``engine.step.source``), so one histogram series exists per unique
nesting path and :func:`span_totals` reads back an aggregated
``{path: {count, total_s, ...}}`` view without any tree bookkeeping at
runtime. The nesting stack is thread-local, so worker threads trace
independently.

Spans pass through :class:`jax.profiler.TraceAnnotation` (lazily imported; a
no-op when jax is absent or the profiler is off), so the same names show up
as trace events in XLA profiles — the host-side twin of the
``jax.named_scope`` annotations inside the engine's jitted update.

:func:`timed` wraps a callable in a span per call and additionally records
the *first* call under ``<name>.first`` — for jitted functions that first
call is compile+execute, so the compile cost is separated from the
steady-state distribution instead of polluting its quantiles.
"""
from __future__ import annotations

import functools
import threading
import time
from contextlib import contextmanager

from repro.obs.registry import MetricsRegistry, default_registry

_tls = threading.local()

SPAN_METRIC = "span"


def _stack() -> list:
    s = getattr(_tls, "stack", None)
    if s is None:
        s = _tls.stack = []
    return s


@functools.cache
def _trace_annotation():
    """jax.profiler.TraceAnnotation, or None — resolved once, lazily, so the
    obs package imports without jax."""
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation
    except Exception:  # noqa: BLE001 — any import failure means "no profiler"
        return None


def current_path() -> str | None:
    """The innermost active span path on this thread, if any."""
    s = _stack()
    return s[-1] if s else None


@contextmanager
def span(name: str, registry: MetricsRegistry | None = None,
         annotate: bool = True):
    """Time a block; record seconds into ``registry.histogram("span",
    name=<dotted path>)``. Yields the path."""
    reg = registry if registry is not None else default_registry()
    stack = _stack()
    path = f"{stack[-1]}.{name}" if stack else name
    stack.append(path)
    ann_cls = _trace_annotation() if annotate else None
    ann = ann_cls(path) if ann_cls is not None else None
    if ann is not None:
        ann.__enter__()
    t0 = time.perf_counter()
    try:
        yield path
    finally:
        dt = time.perf_counter() - t0
        if ann is not None:
            ann.__exit__(None, None, None)
        stack.pop()
        reg.histogram(SPAN_METRIC, path=path).observe(dt)


def timed(name: str, registry: MetricsRegistry | None = None):
    """Decorator form of :func:`span`; splits the first call (compile, for
    jitted fns) out under ``<name>.first``."""

    def deco(fn):
        first_done = [False]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            reg = registry if registry is not None else default_registry()
            t0 = time.perf_counter()
            with span(name, reg):
                out = fn(*args, **kwargs)
            if not first_done[0]:
                first_done[0] = True
                reg.histogram(SPAN_METRIC, path=f"{name}.first").observe(
                    time.perf_counter() - t0)
            return out

        return wrapper

    return deco


def span_totals(registry: MetricsRegistry | None = None) -> dict[str, dict]:
    """Aggregated per-path span view: ``{path: {count, total_s, p50, p95,
    p99, max}}`` — the read side of :func:`span`."""
    reg = registry if registry is not None else default_registry()
    out: dict[str, dict] = {}
    for m in reg.metrics():
        if m.name == SPAN_METRIC and m.kind == "histogram":
            s = m.summary()
            out[m.labels.get("path", "")] = {
                "count": s["count"], "total_s": s["sum"], "p50": s["p50"],
                "p95": s["p95"], "p99": s["p99"], "max": s["max"]}
    return out
