"""MetricsRegistry — the repo's one metrics vocabulary: counters, gauges,
histograms.

Dependency-free (stdlib + numpy), thread-safe, and zero-cost when disabled: a
``MetricsRegistry(enabled=False)`` hands every caller the same shared no-op
metric objects, so instrumented hot paths pay one attribute call on a
do-nothing method and nothing else — no allocation, no locking, no retention.

Metric identity is ``(name, labels)``: ``registry.counter("serve.folds",
group="g0")`` and ``group="g1"`` are independent series, the way a Prometheus
label set works. Lookups cache the metric object, so call sites that keep a
reference (the engine's per-step loop, the serving worker) pay only the
increment; call sites that re-look-up per event pay one dict get under the
registry lock.

Histograms keep exact ``count``/``sum``/``min``/``max`` plus a bounded
ring-buffer reservoir of the most recent observations for quantile estimation
(:meth:`Histogram.quantile`, p50/p95/p99 in :meth:`Histogram.summary`). The
reservoir bounds memory on unbounded streams; totals stay exact forever.

:func:`quantiles` is THE repo-wide quantile helper — the launch drivers and
benchmarks compute their latency percentiles through it rather than keeping
per-file copies.
"""
from __future__ import annotations

import threading
from typing import Iterable

import numpy as np

DEFAULT_QUANTILES = (0.5, 0.95, 0.99)


def quantiles(values: Iterable[float],
              qs: tuple[float, ...] = DEFAULT_QUANTILES) -> tuple[float, ...]:
    """Empirical quantiles of a sequence, as plain floats (NaN when empty).

    The one shared implementation behind ``Histogram.summary``, the launch
    drivers' latency p50/p99 lines, and the benchmark gates.
    """
    arr = np.asarray(tuple(values), dtype=np.float64)
    if arr.size == 0:
        return tuple(float("nan") for _ in qs)
    return tuple(float(v) for v in np.quantile(arr, qs))


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class Counter:
    """Monotonic counter. ``inc`` is atomic (per-metric lock), so concurrent
    writers sum exactly — tests hammer this from 8 threads."""

    kind = "counter"
    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: dict):
        self.name, self.labels = name, dict(labels)
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value

    def read(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value (queue depth, rows/sec, bytes)."""

    kind = "gauge"
    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: dict):
        self.name, self.labels = name, dict(labels)
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def read(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Histogram:
    """Exact count/sum/min/max + a bounded reservoir of the most recent
    ``window`` observations for quantiles. ``observe`` is atomic."""

    kind = "histogram"
    __slots__ = ("name", "labels", "window", "_lock", "_count", "_sum",
                 "_min", "_max", "_buf", "_pos")

    def __init__(self, name: str, labels: dict, window: int = 4096):
        self.name, self.labels = name, dict(labels)
        self.window = int(window)
        self._lock = threading.Lock()
        self._count = 0
        self._sum = 0.0
        self._min = self._max = None
        self._buf: list[float] = []
        self._pos = 0   # ring-buffer write head once the window is full

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            self._min = v if self._min is None else min(self._min, v)
            self._max = v if self._max is None else max(self._max, v)
            if len(self._buf) < self.window:
                self._buf.append(v)
            else:
                self._buf[self._pos] = v
                self._pos = (self._pos + 1) % self.window

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, *qs: float) -> tuple[float, ...]:
        with self._lock:
            buf = tuple(self._buf)
        return quantiles(buf, qs or DEFAULT_QUANTILES)

    def summary(self) -> dict:
        with self._lock:
            buf, count, total = tuple(self._buf), self._count, self._sum
            lo, hi = self._min, self._max
        p50, p95, p99 = quantiles(buf, DEFAULT_QUANTILES)
        return {"count": count, "sum": total, "min": lo, "max": hi,
                "p50": p50, "p95": p95, "p99": p99}

    def read(self) -> dict:
        return {"type": self.kind, **self.summary()}


class _NullMetric:
    """Shared do-nothing stand-in handed out by a disabled registry."""

    kind = "null"
    name, labels = "", {}

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    value = 0
    count = 0
    sum = 0.0

    def quantile(self, *qs):
        return tuple(float("nan") for _ in (qs or DEFAULT_QUANTILES))

    def summary(self):
        return {}

    def read(self):
        return {}


_NULL = _NullMetric()


class MetricsRegistry:
    """Thread-safe home for a process's metrics.

    ``enabled=False`` makes every accessor return the shared no-op metric —
    the zero-cost-when-disabled contract instrumented code relies on instead
    of guarding each call site.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._metrics: dict[tuple, Counter | Gauge | Histogram] = {}

    # ------------------------------------------------------------ accessors --

    def _get(self, cls, name: str, labels: dict, **kw):
        if not self.enabled:
            return _NULL
        key = (cls.kind, name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(name, labels, **kw)
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, window: int = 4096, **labels) -> Histogram:
        return self._get(Histogram, name, labels, window=window)

    # ------------------------------------------------------------- reading --

    def metrics(self) -> list:
        """The live metric objects (stable snapshot of the collection)."""
        with self._lock:
            return list(self._metrics.values())

    def snapshot(self) -> dict[str, dict]:
        """In-process snapshot API: ``{name{labels}: reading}`` for every
        metric. Per-metric readings are atomic; the collection is the set of
        metrics registered at call time."""
        out = {}
        for m in self.metrics():
            lbl = ",".join(f"{k}={v}" for k, v in sorted(m.labels.items()))
            out[f"{m.name}{{{lbl}}}" if lbl else m.name] = m.read()
        return out

    def reset(self) -> None:
        """Drop every metric (tests and benchmark arms start clean)."""
        with self._lock:
            self._metrics.clear()


#: registry handed to call sites that don't thread one through explicitly
#: (kernel dispatch counters, bare span() calls).
_default = MetricsRegistry()
#: always-disabled registry for explicit "no telemetry" wiring.
NULL_REGISTRY = MetricsRegistry(enabled=False)


def default_registry() -> MetricsRegistry:
    return _default


def set_default_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-wide default registry; returns the previous one."""
    global _default
    prev, _default = _default, reg
    return prev
