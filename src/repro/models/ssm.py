"""Mamba2 (SSD — state-space duality) layer: chunked training scan + O(1) decode.

Faithful to "Transformers are SSMs" (arXiv:2405.21060) with ngroups=1:
  in_proj → [z | x | B | C | dt], causal depthwise conv on (x,B,C), scalar-A SSD
  with chunked block decomposition (intra-chunk quadratic + inter-chunk state
  recurrence), gated RMSNorm, out_proj.

The chunked form is TPU-friendly: each chunk's intra term is a (Q×Q) masked
matmul on the MXU and the inter-chunk recurrence is a length-S/Q lax.scan over
a small (H, N, P) state — this is the sub-quadratic path that makes the
long_500k cells runnable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import truncated_normal_init


def init_mamba2_params(key, cfg, dtype) -> dict:
    d = cfg.d_model
    din = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_ch = din + 2 * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_proj = 2 * din + 2 * n + h
    return {
        "in_proj": truncated_normal_init(k1, (d, d_proj), 1.0, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_width, conv_ch), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "dt_bias": jnp.full((h,), np.log(np.expm1(0.01)), jnp.float32),   # softplus⁻¹(0.01)
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.zeros((din,), jnp.float32),
        "out_proj": truncated_normal_init(k4, (din, d), 1.0, dtype),
    }


def _split_proj(proj: jax.Array, cfg):
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :din]
    xbc = proj[..., din : 2 * din + 2 * n]
    dt = proj[..., 2 * din + 2 * n :]
    return z, xbc, dt


def causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. xbc (B, S, C); w (W, C)."""
    wdt = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (wdt - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(wdt))
    return jax.nn.silu(out + b.astype(out.dtype))


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b_mat: jax.Array,
                c_mat: jax.Array, chunk: int):
    """SSD scan. x (B,S,H,P), dt (B,S,H), a (H,)<0, b/c (B,S,N). Returns (y, final_state)."""
    B, S, H, P = x.shape
    N = b_mat.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    f32 = jnp.float32

    xr = x.reshape(B, nc, Q, H, P).astype(f32)
    dtr = dt.reshape(B, nc, Q, H).astype(f32)
    br = b_mat.reshape(B, nc, Q, N).astype(f32)
    cr = c_mat.reshape(B, nc, Q, N).astype(f32)

    da = dtr * a[None, None, None, :]                        # (B,nc,Q,H) ≤ 0
    cum = jnp.cumsum(da, axis=2)                             # inclusive
    seg_total = cum[:, :, -1, :]                             # (B,nc,H)

    # --- intra-chunk (quadratic within chunk, MXU matmuls) -------------------
    scores = jnp.einsum("bcin,bcjn->bcij", cr, br)           # (B,nc,Q,Q)
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # cum_i − cum_j (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    w_ij = scores[..., None] * decay                         # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", w_ij, dtr, xr)

    # --- chunk states ---------------------------------------------------------
    dec_end = jnp.exp(seg_total[:, :, None, :] - cum)        # (B,nc,Q,H)
    s_c = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", br, dtr * dec_end, xr)  # (B,nc,H,N,P)

    # --- inter-chunk recurrence ----------------------------------------------
    def step(carry, inp):
        s_chunk, t_chunk = inp                               # (B,H,N,P), (B,H)
        before = carry
        new = before * jnp.exp(t_chunk)[:, :, None, None] + s_chunk
        return new, before

    init = jnp.zeros((B, H, N, P), f32)
    final, before_states = jax.lax.scan(
        step, init, (jnp.moveaxis(s_c, 1, 0), jnp.moveaxis(seg_total, 1, 0))
    )
    before_states = jnp.moveaxis(before_states, 0, 1)        # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcin,bchnp->bcihp", cr, before_states) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y.astype(x.dtype), final


def mamba2_forward(params: dict, u: jax.Array, cfg, return_state: bool = False,
                   dist=None):
    """Full layer: u (B, S, d_model) → (B, S, d_model) [, recurrent state].

    With a Dist context, SSD heads are sharded over the TP axis (H=64 splits
    evenly on 16-way meshes); the sequence/chunk axes stay unsharded so the
    inter-chunk lax.scan never walks a partitioned dimension (which forces
    involuntary replication — dry-run finding).
    """
    from repro.models.common import rms_norm

    B, S, d = u.shape
    din, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = u @ params["in_proj"]
    z, xbc_raw, dt = _split_proj(proj, cfg)
    xbc = causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xs = xbc[..., :din].reshape(B, S, h, pdim)
    b_mat = xbc[..., din : din + n]
    c_mat = xbc[..., din + n :]
    if dist is not None and dist.mesh is not None and dist.tp_axis and h % dist.mesh.shape[dist.tp_axis] == 0:
        xs = dist.constrain(xs, dist.dp_axes, None, dist.tp_axis, None)
        z = dist.constrain(z, dist.dp_axes, None, dist.tp_axis)  # din = H·P aligns
        b_mat = dist.constrain(b_mat, dist.dp_axes, None, None)
        c_mat = dist.constrain(c_mat, dist.dp_axes, None, None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    y, final = ssd_chunked(xs, dt, a, b_mat, c_mat, cfg.ssm_chunk)
    y = y + params["d_skip"][None, None, :, None].astype(y.dtype) * xs
    y = y.reshape(B, S, din)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.rms_eps)
    out = y @ params["out_proj"]
    if return_state:
        wdt = cfg.conv_width
        conv_state = jnp.pad(xbc_raw, ((0, 0), (max(0, wdt - 1 - S), 0), (0, 0)))[:, -(wdt - 1):, :]
        return out, {"ssm": final, "conv": conv_state}
    return out


# ------------------------------------------------------------------ decode ---

def init_mamba2_state(cfg, batch: int, dtype) -> dict:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }


def mamba2_decode_step(params: dict, u: jax.Array, state: dict, cfg):
    """One-token recurrent step. u (B, 1, d) → (y (B,1,d), new_state)."""
    from repro.models.common import rms_norm

    B = u.shape[0]
    din, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = u[:, 0] @ params["in_proj"]                       # (B, d_proj)
    z, xbc, dt = _split_proj(proj, cfg)
    # conv over the rolling window
    win = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # (B, W, C)
    conv_out = jnp.sum(win * params["conv_w"][None].astype(win.dtype), axis=1) + params["conv_b"].astype(win.dtype)
    conv_out = jax.nn.silu(conv_out)
    new_conv = win[:, 1:]
    xs = conv_out[..., :din].reshape(B, h, pdim).astype(jnp.float32)
    b_mat = conv_out[..., din : din + n].astype(jnp.float32)
    c_mat = conv_out[..., din + n :].astype(jnp.float32)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B, H)
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dtv * a[None, :])                           # (B, H)
    new_ssm = state["ssm"] * da[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", b_mat, dtv, xs
    )
    y = jnp.einsum("bn,bhnp->bhp", c_mat, new_ssm)           # (B,H,P)
    y = y + params["d_skip"][None, :, None] * xs
    y = y.reshape(B, din).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.rms_eps)
    out = (y @ params["out_proj"])[:, None, :]
    return out, {"ssm": new_ssm, "conv": new_conv}
