"""Attention: chunked (flash-style) training/prefill path + cached decode path.

Memory-efficient attention is implemented as an online-softmax double loop
(lax.map over query chunks, lax.scan over KV chunks) so peak activation memory
is O(q_chunk × kv_chunk) per head group instead of O(S²) — required for the
32k/500k-token cells on 16 GB chips. GQA is handled by grouping query heads
over KV heads; sliding-window and bidirectional (encoder / cross) variants are
flags. Decode attends over a (possibly sequence-sharded) KV cache with plain
einsums — XLA turns the softmax/contraction over the sharded axis into the
psum-style collectives recorded in the roofline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """(…, q, k) additive bias from position masks."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(ok, 0.0, NEG_INF)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
                    window: int = 0, q_offset: int = 0, q_chunk: int = 512,
                    kv_chunk: int = 1024) -> jax.Array:
    """Online-softmax attention. q (B,Sq,H,hd); k,v (B,Skv,Hkv,hd); GQA by grouping.

    Returns (B, Sq, H, hd). Chunk sizes are clipped to the sequence lengths.
    """
    import math

    B, Sq, H, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    qc = math.gcd(Sq, min(q_chunk, Sq))      # largest chunk dividing the length
    kc = math.gcd(Skv, min(kv_chunk, Skv))
    nq, nk = Sq // qc, Skv // kc
    scale = 1.0 / np.sqrt(hd)
    qg = (q * scale).reshape(B, Sq, Hkv, G, hd)

    def q_block(qi):
        qb = jax.lax.dynamic_slice_in_dim(qg, qi * qc, qc, axis=1)      # (B,qc,Hkv,G,hd)
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def kv_step(carry, ki):
            acc, mx, den = carry
            kb = jax.lax.dynamic_slice_in_dim(k, ki * kc, kc, axis=1)   # (B,kc,Hkv,hd)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * kc, kc, axis=1)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32), kb.astype(jnp.float32))
            k_pos = ki * kc + jnp.arange(kc)
            s = s + _mask_bias(q_pos, k_pos, causal, window)
            new_mx = jnp.maximum(mx, jnp.max(s, axis=-1))
            p = jnp.exp(s - new_mx[..., None])
            corr = jnp.exp(mx - new_mx)
            den = den * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
            return (acc, new_mx, den), None

        acc0 = jnp.zeros((B, Hkv, G, qc, hd), jnp.float32)
        mx0 = jnp.full((B, Hkv, G, qc), NEG_INF, jnp.float32)
        den0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        (acc, _, den), _ = jax.lax.scan(kv_step, (acc0, mx0, den0), jnp.arange(nk))
        out = acc / jnp.maximum(den[..., None], 1e-30)
        # cast per chunk so the stacked (nq, …) buffer is input-dtype, not f32
        return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(q.dtype)      # (B,qc,Hkv,G,hd)

    if nq == 1:
        out = q_block(0)
    else:
        outs = jax.lax.map(q_block, jnp.arange(nq))                     # (nq,B,qc,Hkv,G,hd)
        out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hkv, G, hd)
    return out.reshape(B, Sq, H, hd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_len: jax.Array, *, window: int = 0) -> jax.Array:
    """One-token attention over a KV cache.

    q (B,1,H,hd); caches (B,Smax,Hkv,hd); cur_len: scalar int — tokens valid in
    the cache *including* the current one. Positions ≥ cur_len are masked; with
    a sliding window, positions ≤ cur_len−window are too.
    """
    B, _, H, hd = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    G = H // Hkv
    scale = 1.0 / np.sqrt(hd)
    qg = (q * scale).reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32), k_cache.astype(jnp.float32))
    pos = jnp.arange(Smax)
    ok = pos[None, :] < cur_len
    if window > 0:
        ok &= pos[None, :] > (cur_len - 1 - window)
    s = jnp.where(ok[:, None, None, :] if ok.ndim == 2 else ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def update_cache(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write new (B,1,Hkv,hd) into cache (B,Smax,Hkv,hd) at sequence index pos."""
    return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype), pos, axis=1)


def init_attn_params(key, d: int, n_heads: int, n_kv: int, head_dim: int, dtype) -> dict:
    from repro.models.common import truncated_normal_init

    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": truncated_normal_init(kq, (d, n_heads * head_dim), 1.0, dtype),
        "wk": truncated_normal_init(kk, (d, n_kv * head_dim), 1.0, dtype),
        "wv": truncated_normal_init(kv, (d, n_kv * head_dim), 1.0, dtype),
        "wo": truncated_normal_init(ko, (n_heads * head_dim, d), 1.0, dtype),
    }
