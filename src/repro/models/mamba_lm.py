"""Mamba2 LM (attention-free): embed → scanned Mamba2 layers → head.

Constant-size recurrent state (no KV cache) — the long_500k decode cell costs
the same per token as short contexts; this is the arch where the sub-quadratic
requirement is structural.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.common import (
    cross_entropy_loss,
    embed,
    init_embedding,
    init_rms,
    rms_norm,
    truncated_normal_init,
)
from repro.models.transformer import NO_DIST, Dist


def init_mamba_lm_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ke, km, kh = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: {
        "ln": init_rms(cfg.d_model),
        "mamba": ssm.init_mamba2_params(k, cfg, dtype),
    })(jax.random.split(km, cfg.n_layers))
    return {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": init_rms(cfg.d_model),
        "lm_head": truncated_normal_init(kh, (cfg.d_model, cfg.vocab_size), 1.0, dtype),
    }


def forward(params, tokens: jax.Array, cfg: ModelConfig, dist: Dist = NO_DIST, **_):
    x = embed(params["embed"], tokens)
    x = dist.constrain(x, dist.dp_axes, dist.seq_axis, None)

    def body(x, lp):
        h = rms_norm(x, lp["ln"], cfg.rms_eps)
        x = x + ssm.mamba2_forward(lp["mamba"], h, cfg, dist=dist)
        x = dist.constrain(x, dist.dp_axes, dist.seq_axis, None)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return x @ params["lm_head"]


def mamba_lm_loss(params, batch: dict, cfg: ModelConfig, dist: Dist = NO_DIST, **kw):
    logits = forward(params, batch["tokens"], cfg, dist)
    loss = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss, {"nll": loss}


def init_decode_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, conv_ch), dtype),
    }


def decode_step(params, token: jax.Array, state: dict, cur_len, cfg: ModelConfig,
                dist: Dist = NO_DIST):
    x = embed(params["embed"], token)

    def body(x, layer):
        lp, sst, cst = layer
        h = rms_norm(x, lp["ln"], cfg.rms_eps)
        y, ns = ssm.mamba2_decode_step(lp["mamba"], h, {"ssm": sst, "conv": cst}, cfg)
        return x + y, (ns["ssm"], ns["conv"])

    x, (nssm, nconv) = jax.lax.scan(body, x, (params["layers"], state["ssm"], state["conv"]),
                                    unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return (x @ params["lm_head"])[:, 0], {"ssm": nssm, "conv": nconv}
