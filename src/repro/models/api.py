"""Unified per-architecture API: init / loss / prefill / decode + input specs.

Everything the launcher, trainer, server and dry-run need, keyed by config
family. ``input_specs`` returns jax.ShapeDtypeStruct trees (no allocation) —
the dry-run lowers against these directly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, mamba_lm, ssm
from repro.models import transformer as tr
from repro.models.transformer import NO_DIST, Dist


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init_params: Callable[[jax.Array], Any]
    loss_fn: Callable[..., Any]             # (params, batch, dist) -> (loss, metrics)
    prefill_fn: Callable[..., Any]          # (params, batch, dist) -> (logits, cache)
    decode_fn: Callable[..., Any]           # (params, token, cache, cur_len, dist) -> (logits, cache)
    init_decode_state: Callable[..., Any]   # (batch, max_len) -> cache/state pytree


def _tokens_spec(shape: ShapeConfig, dtype=jnp.int32):
    return jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), dtype)


def get_api(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def loss_fn(params, batch, dist=NO_DIST, **kw):
            return tr.lm_loss(params, batch, cfg, dist, **kw)

        def prefill_fn(params, batch, dist=NO_DIST, **kw):
            return tr.prefill(params, batch["tokens"], cfg, dist,
                              positions=batch.get("positions"),
                              vision_embeds=batch.get("vision_embeds"), **kw)

        def decode_fn(params, token, cache, cur_len, dist=NO_DIST):
            return tr.decode_step(params, token, cache, cur_len, cfg, dist)

        return ModelAPI(
            cfg=cfg,
            init_params=lambda key: tr.init_lm_params(key, cfg),
            loss_fn=loss_fn,
            prefill_fn=prefill_fn,
            decode_fn=decode_fn,
            init_decode_state=lambda batch, max_len: tr.init_kv_cache(cfg, batch, max_len),
        )
    if fam == "ssm":
        def ssm_prefill(params, batch, dist=NO_DIST, **kw):
            # prompt pass returning per-layer recurrent states
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
            x = dist.constrain(x, dist.dp_axes, None, None)
            from repro.models.common import rms_norm

            def body(x, lp):
                h = rms_norm(x, lp["ln"], cfg.rms_eps)
                y, st = ssm.mamba2_forward(lp["mamba"], h, cfg, return_state=True, dist=dist)
                return x + y, st

            x, states = jax.lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
            x = rms_norm(x[:, -1], params["final_norm"], cfg.rms_eps)
            return x @ params["lm_head"], states

        return ModelAPI(
            cfg=cfg,
            init_params=lambda key: mamba_lm.init_mamba_lm_params(key, cfg),
            loss_fn=lambda params, batch, dist=NO_DIST, **kw: mamba_lm.mamba_lm_loss(params, batch, cfg, dist),
            prefill_fn=ssm_prefill,
            decode_fn=lambda params, token, cache, cur_len, dist=NO_DIST: mamba_lm.decode_step(
                params, token, cache, cur_len, cfg, dist),
            init_decode_state=lambda batch, max_len: mamba_lm.init_decode_state(cfg, batch),
        )
    if fam == "hybrid":
        def hyb_prefill(params, batch, dist=NO_DIST, **kw):
            # training-style pass is the prefill compute; decode states are
            # rebuilt via the same scan with state collection
            logits = hybrid.forward(params, batch["tokens"], cfg, dist, **kw)
            return logits[:, -1], None

        return ModelAPI(
            cfg=cfg,
            init_params=lambda key: hybrid.init_hybrid_params(key, cfg),
            loss_fn=lambda params, batch, dist=NO_DIST, **kw: hybrid.hybrid_loss(params, batch, cfg, dist, **kw),
            prefill_fn=hyb_prefill,
            decode_fn=lambda params, token, cache, cur_len, dist=NO_DIST: hybrid.decode_step(
                params, token, cache, cur_len, cfg, dist),
            init_decode_state=lambda batch, max_len: hybrid.init_decode_state(cfg, batch, max_len),
        )
    if fam == "audio":
        def audio_loss(params, batch, dist=NO_DIST, **kw):
            return encdec.encdec_loss(params, batch, cfg, dist, **kw)

        def audio_prefill(params, batch, dist=NO_DIST, max_len: int = 128, **kw):
            cache = encdec.init_decode_cache(params, batch["frames"], cfg, max_len, dist)
            return None, cache

        return ModelAPI(
            cfg=cfg,
            init_params=lambda key: encdec.init_encdec_params(key, cfg),
            loss_fn=audio_loss,
            prefill_fn=audio_prefill,
            decode_fn=lambda params, token, cache, cur_len, dist=NO_DIST: encdec.decode_step(
                params, token, cache, cur_len, cfg, dist),
            init_decode_state=None,  # built by prefill (needs encoder output)
        )
    raise ValueError(f"unknown family {fam}")


# -------------------------------------------------------------- input specs --

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this (arch, shape) cell.

    train  → the kwargs of loss_fn's ``batch``
    prefill→ the kwargs of prefill_fn's ``batch``
    decode → (token, cache/state, cur_len) for decode_fn
    """
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind in ("train", "prefill"):
        batch: dict[str, Any] = {"tokens": tok}
        if shape.kind == "train":
            batch["labels"] = tok
        if cfg.family == "vlm":
            batch["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
            if shape.kind == "prefill":
                batch.pop("tokens")  # prefill = encode; decode budget is static
        return {"batch": batch}
    # decode: one new token against a cache of length S
    api = get_api(cfg)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    if cfg.family == "audio":
        cache = encdec_cache_specs(cfg, B, S)
        return {"token": token, "cache": cache, "cur_len": jax.ShapeDtypeStruct((), jnp.int32)}
    cache = jax.eval_shape(lambda: api.init_decode_state(B, S))
    return {"token": token, "cache": cache, "cur_len": jax.ShapeDtypeStruct((), jnp.int32)}


def encdec_cache_specs(cfg: ModelConfig, B: int, max_len: int) -> dict:
    hd, kv = cfg.hd, cfg.n_kv_heads
    return {
        "k": jax.ShapeDtypeStruct((cfg.n_layers, B, max_len, kv, hd), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((cfg.n_layers, B, max_len, kv, hd), jnp.bfloat16),
        "xk": jax.ShapeDtypeStruct((cfg.n_layers, B, max_len, kv, hd), jnp.bfloat16),
        "xv": jax.ShapeDtypeStruct((cfg.n_layers, B, max_len, kv, hd), jnp.bfloat16),
    }
