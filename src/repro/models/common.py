"""Shared model building blocks: norms, MLPs, embeddings, RoPE (incl. M-RoPE).

Pure-functional style: ``init_*`` builds param dicts, ``apply`` fns are stateless.
All matmuls take ``preferred_element_type=f32`` style accumulation via the
``compute_dtype``/``param_dtype`` policy in ModelConfig.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal_init(key, shape, scale, dtype):
    """MaxText-style scaled trunc-normal (std = scale / sqrt(fan_in))."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)  # stored as offset from 1


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: down( silu(x·gate) ⊙ (x·up) )."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def init_swiglu(key, d: int, f: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": truncated_normal_init(k1, (d, f), 1.0, dtype),
        "up": truncated_normal_init(k2, (d, f), 1.0, dtype),
        "down": truncated_normal_init(k3, (f, d), 1.0, dtype),
    }


def apply_swiglu(p: dict, x: jax.Array) -> jax.Array:
    return swiglu(x, p["gate"], p["up"], p["down"])


# ------------------------------------------------------------------ RoPE ----

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                            # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions_3d: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the rotary dims are split into (t, h, w)
    sections, each rotated by its own position stream.

    x: (B, S, H, hd); positions_3d: (3, B, S); sections sum to hd//2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                            # (hd/2,)
    # pick the position stream per rotary dim
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections), total_repeat_length=hd // 2)
    pos = jnp.take(positions_3d, sec_id, axis=0)             # (hd/2, B, S) — gather streams
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- embedding ----

def init_embedding(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    # one-hot-free gather; XLA partitions this over a vocab-sharded table
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    return x @ table.T.astype(x.dtype)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None,
                       chunks: int = 8):
    """Mean token NLL with f32 logsumexp, chunked + rematted over tokens so the
    f32 logits copy never materializes at full (B·S, V) size (≈2.5 GB/device
    per copy for the 151k-vocab cells)."""
    b, s, v = logits.shape
    # chunk along S (unsharded under SP; B is data-sharded, V vocab-sharded —
    # flattening/splitting those would force GSPMD replication)
    nc = chunks if s % chunks == 0 else 1
    lg = jnp.moveaxis(logits.reshape(b, nc, s // nc, v), 1, 0)   # (nc,B,S/nc,V)
    lb = jnp.moveaxis(labels.reshape(b, nc, s // nc), 1, 0)

    @jax.checkpoint
    def chunk_nll(lg, lb):
        lg = lg.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lb[..., None], axis=-1)[..., 0]
        return lse - gold

    nll = jnp.moveaxis(jax.lax.map(lambda args: chunk_nll(*args), (lg, lb)), 0, 1)
    nll = nll.reshape(b, s)
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
