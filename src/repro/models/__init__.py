"""Model zoo: the 10 assigned architectures as composable functional modules."""
from repro.models.api import ModelAPI, get_api, input_specs  # noqa: F401
from repro.models.transformer import Dist, NO_DIST  # noqa: F401
