"""Zamba2-style hybrid: Mamba2 backbone with a weight-tied shared attention block.

38 scanned Mamba2 layers; after every ``attn_every``-th layer the SAME
(attention + FFN) transformer block is applied (weight tying across call sites,
per Zamba2 — we omit the per-site LoRA deltas, noted in DESIGN.md). Each call
site has its own KV cache at decode time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ssm
from repro.models.common import (
    apply_rope,
    apply_swiglu,
    cross_entropy_loss,
    embed,
    init_embedding,
    init_rms,
    init_swiglu,
    rms_norm,
    truncated_normal_init,
)
from repro.models.transformer import NO_DIST, Dist


def n_shared_sites(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def shared_flags(cfg: ModelConfig) -> jax.Array:
    """(L,) 1 where the shared block runs after that mamba layer."""
    idx = jnp.arange(1, cfg.n_layers + 1)
    return ((idx % cfg.attn_every) == 0).astype(jnp.int32)


def init_hybrid_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ke, km, ka, kf, kh = jax.random.split(key, 5)
    layers = jax.vmap(lambda k: {
        "ln": init_rms(cfg.d_model),
        "mamba": ssm.init_mamba2_params(k, cfg, dtype),
    })(jax.random.split(km, cfg.n_layers))
    return {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "shared": {
            "ln1": init_rms(cfg.d_model),
            "ln2": init_rms(cfg.d_model),
            "attn": attn.init_attn_params(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dtype),
            "mlp": init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype),
        },
        "final_norm": init_rms(cfg.d_model),
        "lm_head": truncated_normal_init(kh, (cfg.d_model, cfg.vocab_size), 1.0, dtype),
    }


def _shared_block(sp, x, cfg, positions, dist: Dist, q_chunk, kv_chunk):
    B, S, _ = x.shape
    h = rms_norm(x, sp["ln1"], cfg.rms_eps)
    q = (h @ sp["attn"]["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    k = (h @ sp["attn"]["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (h @ sp["attn"]["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = attn.flash_attention(q, k, v, causal=True, q_chunk=q_chunk, kv_chunk=kv_chunk)
    x = x + out.reshape(B, S, cfg.n_heads * cfg.hd) @ sp["attn"]["wo"]
    h = rms_norm(x, sp["ln2"], cfg.rms_eps)
    return x + apply_swiglu(sp["mlp"], h)


def forward(params, tokens: jax.Array, cfg: ModelConfig, dist: Dist = NO_DIST,
            q_chunk: int = 512, kv_chunk: int = 1024):
    """Segmented layout: scan each run of ``attn_every`` mamba layers, then
    apply the shared block once — no lax.cond in the hot loop (a cond puts the
    shared block's compute/collectives into EVERY layer's static cost and can
    degrade to select-executes-both under partitioning; §Perf zamba2 log)."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    x = dist.constrain(x, dist.dp_axes, dist.seq_axis, None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    shared = params["shared"]

    def body(x, lp):
        h = rms_norm(x, lp["ln"], cfg.rms_eps)
        x = x + ssm.mamba2_forward(lp["mamba"], h, cfg, dist=dist)
        x = dist.constrain(x, dist.dp_axes, dist.seq_axis, None)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)

    def shared_fn(x):
        return _shared_block(shared, x, cfg, positions, dist, q_chunk, kv_chunk)

    if cfg.remat:
        shared_fn = jax.checkpoint(shared_fn)

    period = cfg.attn_every
    n_full = cfg.n_layers // period
    n_tail = cfg.n_layers - n_full * period
    # one nested scan over (groups × period) — reshaping the stacked params
    # keeps the grad accumulation a plain scan cotangent (a python loop over
    # slices materializes one full-size zero-padded cotangent per segment)
    main = jax.tree.map(
        lambda a: a[: n_full * period].reshape((n_full, period) + a.shape[1:]),
        params["layers"])

    def group(x, gp):
        x, _ = jax.lax.scan(body, x, gp, unroll=cfg.scan_unroll)
        return shared_fn(x), None

    x, _ = jax.lax.scan(group, x, main, unroll=cfg.scan_unroll)
    if n_tail:
        tail = jax.tree.map(lambda a: a[n_full * period:], params["layers"])
        x, _ = jax.lax.scan(body, x, tail, unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return x @ params["lm_head"]


def hybrid_loss(params, batch: dict, cfg: ModelConfig, dist: Dist = NO_DIST,
                q_chunk: int = 512, kv_chunk: int = 1024):
    logits = forward(params, batch["tokens"], cfg, dist, q_chunk, kv_chunk)
    loss = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss, {"nll": loss}


# ------------------------------------------------------------------ decode --

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    sites = n_shared_sites(cfg)
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, conv_ch), dtype),
        "k": jnp.zeros((sites, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((sites, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
    }


def decode_step(params, token: jax.Array, state: dict, cur_len: jax.Array,
                cfg: ModelConfig, dist: Dist = NO_DIST):
    B = token.shape[0]
    x = embed(params["embed"], token)
    pos = (cur_len - 1) * jnp.ones((B, 1), jnp.int32)
    flags = shared_flags(cfg)
    shared = params["shared"]

    def shared_decode(x, kc, vc):
        h = rms_norm(x, shared["ln1"], cfg.rms_eps)
        q = (h @ shared["attn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
        k = (h @ shared["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        v = (h @ shared["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        kc = attn.update_cache(kc, k, cur_len - 1)
        vc = attn.update_cache(vc, v, cur_len - 1)
        out = attn.decode_attention(q, kc, vc, cur_len)
        x = x + out.reshape(B, 1, cfg.n_heads * cfg.hd) @ shared["attn"]["wo"]
        h = rms_norm(x, shared["ln2"], cfg.rms_eps)
        return x + apply_swiglu(shared["mlp"], h), kc, vc

    def body(carry, layer):
        x, site, kall, vall = carry
        lp, sst, cst, flag = layer
        h = rms_norm(x, lp["ln"], cfg.rms_eps)
        y, new_state = ssm.mamba2_decode_step(lp["mamba"], h, {"ssm": sst, "conv": cst}, cfg)
        x = x + y

        def with_attn(op):
            x, site, kall, vall = op
            kc = jax.lax.dynamic_index_in_dim(kall, site, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vall, site, 0, keepdims=False)
            x, kc, vc = shared_decode(x, kc, vc)
            kall = jax.lax.dynamic_update_index_in_dim(kall, kc, site, 0)
            vall = jax.lax.dynamic_update_index_in_dim(vall, vc, site, 0)
            return x, site + 1, kall, vall

        x, site, kall, vall = jax.lax.cond(flag > 0, with_attn, lambda op: op, (x, site, kall, vall))
        return (x, site, kall, vall), (new_state["ssm"], new_state["conv"])

    (x, _, nk, nv), (nssm, nconv) = jax.lax.scan(
        body,
        (x, jnp.int32(0), state["k"], state["v"]),
        (params["layers"], state["ssm"], state["conv"], flags),
        unroll=cfg.scan_unroll,
    )
    new_state = {"ssm": nssm, "conv": nconv, "k": nk, "v": nv}
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return (x @ params["lm_head"])[:, 0], new_state
