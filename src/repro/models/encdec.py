"""Encoder-decoder backbone (SeamlessM4T-style): audio-frame encoder + text decoder.

The modality frontend is a stub — the encoder consumes precomputed frame
embeddings (B, S_enc, d) from input_specs(). Decoder blocks: causal self-attn,
cross-attn into the encoder output, SwiGLU FFN. Decode keeps a self-attention
KV cache plus a one-shot cross-attention KV computed from the encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (
    apply_rope,
    apply_swiglu,
    cross_entropy_loss,
    embed,
    init_embedding,
    init_rms,
    init_swiglu,
    rms_norm,
    truncated_normal_init,
)
from repro.models.transformer import NO_DIST, Dist


def _init_enc_block(key, cfg: ModelConfig, dtype):
    ka, kf = jax.random.split(key)
    return {
        "ln1": init_rms(cfg.d_model),
        "ln2": init_rms(cfg.d_model),
        "attn": attn.init_attn_params(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dtype),
        "mlp": init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_block(key, cfg: ModelConfig, dtype):
    ka, kc, kf = jax.random.split(key, 3)
    return {
        "ln1": init_rms(cfg.d_model),
        "ln_x": init_rms(cfg.d_model),
        "ln2": init_rms(cfg.d_model),
        "attn": attn.init_attn_params(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dtype),
        "xattn": attn.init_attn_params(kc, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dtype),
        "mlp": init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype),
    }


def init_encdec_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ke, kd, kt, kh = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(jax.random.split(ke, cfg.n_enc_layers))
    dec = jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(jax.random.split(kd, cfg.n_layers))
    return {
        "embed": init_embedding(kt, cfg.vocab_size, cfg.d_model, dtype),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm": init_rms(cfg.d_model),
        "final_norm": init_rms(cfg.d_model),
        "lm_head": truncated_normal_init(kh, (cfg.d_model, cfg.vocab_size), 1.0, dtype),
    }


def _mha(p, xq, xkv, cfg, positions_q, positions_kv, causal, dist: Dist,
         q_chunk=512, kv_chunk=1024, use_rope=True):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    q = (xq @ p["wq"]).reshape(B, Sq, cfg.n_heads, cfg.hd)
    k = (xkv @ p["wk"]).reshape(B, Skv, cfg.n_kv_heads, cfg.hd)
    v = (xkv @ p["wv"]).reshape(B, Skv, cfg.n_kv_heads, cfg.hd)
    q = dist.constrain(q, dist.dp_axes, None, dist.head_axis, None)
    k = dist.constrain(k, dist.dp_axes, None, dist.kv_head_axis, None)
    if use_rope:
        q = apply_rope(q, positions_q, cfg.rope_theta)
        k = apply_rope(k, positions_kv, cfg.rope_theta)
    out = attn.flash_attention(q, k, v, causal=causal, q_chunk=q_chunk, kv_chunk=kv_chunk)
    return out.reshape(B, Sq, cfg.n_heads * cfg.hd) @ p["wo"]


def encode(params, frames: jax.Array, cfg: ModelConfig, dist: Dist = NO_DIST,
           q_chunk: int = 512, kv_chunk: int = 1024) -> jax.Array:
    """frames (B, S_enc, d) → encoder states (B, S_enc, d). Bidirectional."""
    B, S, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = frames

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        x = x + _mha(lp["attn"], h, h, cfg, pos, pos, causal=False, dist=dist,
                     q_chunk=q_chunk, kv_chunk=kv_chunk)
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + apply_swiglu(lp["mlp"], h)
        x = dist.constrain(x, dist.dp_axes, dist.seq_axis, None)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"], unroll=cfg.scan_unroll)
    return rms_norm(x, params["enc_norm"], cfg.rms_eps)


def forward(params, frames: jax.Array, tokens: jax.Array, cfg: ModelConfig,
            dist: Dist = NO_DIST, q_chunk: int = 512, kv_chunk: int = 1024):
    """(frames (B,Se,d), tokens (B,Sd)) → logits (B, Sd, V)."""
    enc = encode(params, frames, cfg, dist, q_chunk, kv_chunk)
    B, Sd = tokens.shape
    Se = enc.shape[1]
    pos_d = jnp.broadcast_to(jnp.arange(Sd)[None], (B, Sd))
    pos_e = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
    x = embed(params["embed"], tokens)
    x = dist.constrain(x, dist.dp_axes, dist.seq_axis, None)

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        x = x + _mha(lp["attn"], h, h, cfg, pos_d, pos_d, causal=True, dist=dist,
                     q_chunk=q_chunk, kv_chunk=kv_chunk)
        h = rms_norm(x, lp["ln_x"], cfg.rms_eps)
        x = x + _mha(lp["xattn"], h, enc, cfg, pos_d, pos_e, causal=False, dist=dist,
                     q_chunk=q_chunk, kv_chunk=kv_chunk, use_rope=False)
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + apply_swiglu(lp["mlp"], h)
        x = dist.constrain(x, dist.dp_axes, dist.seq_axis, None)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"], unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = x @ params["lm_head"]
    return dist.constrain(logits, dist.dp_axes, None, dist.tp_axis)


def encdec_loss(params, batch: dict, cfg: ModelConfig, dist: Dist = NO_DIST,
                q_chunk: int = 512, kv_chunk: int = 1024):
    logits = forward(params, batch["frames"], batch["tokens"], cfg, dist, q_chunk, kv_chunk)
    loss = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss, {"nll": loss}


# ------------------------------------------------------------------ decode --

def init_decode_cache(params, frames: jax.Array, cfg: ModelConfig, max_len: int,
                      dist: Dist = NO_DIST, dtype=jnp.bfloat16) -> dict:
    """Run the encoder once; precompute cross K/V; allocate self-attn cache."""
    enc = encode(params, frames, cfg, dist)
    B = frames.shape[0]
    Se = enc.shape[1]

    def cross_kv(lp):
        k = (enc @ lp["xattn"]["wk"]).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
        v = (enc @ lp["xattn"]["wv"]).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
        return k.astype(dtype), v.astype(dtype)

    xk, xv = jax.vmap(cross_kv)(params["dec_layers"])
    shape = (cfg.n_layers, B, max_len, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "xk": xk,
        "xv": xv,
    }


def decode_step(params, token: jax.Array, cache: dict, cur_len: jax.Array,
                cfg: ModelConfig, dist: Dist = NO_DIST):
    B = token.shape[0]
    x = embed(params["embed"], token)
    pos = (cur_len - 1) * jnp.ones((B, 1), jnp.int32)

    def body(x, layer):
        lp, kc, vc, xk, xv = layer
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        q = (h @ lp["attn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
        k = (h @ lp["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        v = (h @ lp["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        kc = attn.update_cache(kc, k, cur_len - 1)
        vc = attn.update_cache(vc, v, cur_len - 1)
        out = attn.decode_attention(q, kc, vc, cur_len)
        x = x + out.reshape(B, 1, cfg.n_heads * cfg.hd) @ lp["attn"]["wo"]
        # cross attention over the full (precomputed) encoder KV
        h = rms_norm(x, lp["ln_x"], cfg.rms_eps)
        q = (h @ lp["xattn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
        out = attn.decode_attention(q, xk, xv, jnp.int32(xk.shape[1]))
        x = x + out.reshape(B, 1, cfg.n_heads * cfg.hd) @ lp["xattn"]["wo"]
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + apply_swiglu(lp["mlp"], h)
        return x, (kc, vc)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll=cfg.scan_unroll,
    )
    cache = dict(cache, k=nk, v=nv)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return (x @ params["lm_head"])[:, 0], cache
