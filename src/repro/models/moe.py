"""Mixture-of-Experts FFN: top-k token-choice routing with capacity, two paths.

- ``moe_apply_local``: single-shard sort-based dispatch (smoke tests, and the
  per-device compute inside the distributed path).
- ``moe_apply_ep``: expert parallelism via shard_map — tokens are sequence-
  sharded over the ``model`` axis, experts are sharded over the same axis, and
  two ``all_to_all`` collectives move token activations to/from their expert
  owners (the production EP pattern; DESIGN.md §5). Capacity-dropped tokens
  fall through on the residual path, standard for capacity-based MoE.

Routing uses softmax-then-top-k with gate renormalization and the switch-style
load-balance auxiliary loss.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import truncated_normal_init


def init_moe_params(key, d: int, f_expert: int, n_experts: int, n_shared: int,
                    d_ff_shared: int, dtype) -> dict:
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": truncated_normal_init(kr, (d, n_experts), 1.0, jnp.float32),
        "w_gate": truncated_normal_init(k1, (n_experts, d, f_expert), 1.0, dtype),
        "w_up": truncated_normal_init(k2, (n_experts, d, f_expert), 1.0, dtype),
        "w_down": truncated_normal_init(k3, (n_experts, f_expert, d), 1.0, dtype),
    }
    if n_shared:
        from repro.models.common import init_swiglu

        p["shared"] = init_swiglu(ks, d, n_shared * f_expert, dtype)
    return p


def route(router_w: jax.Array, x: jax.Array, k: int):
    """Top-k routing. x (T, d) → (ids (T,k), gates (T,k), me (E,), ce (E,)).

    me/ce are the switch load-balance statistics (mean router prob / top-1
    fraction per expert); the caller combines them as aux = E·Σ me·ce —
    distributed callers psum them FIRST so the loss matches the global batch.
    """
    logits = x.astype(jnp.float32) @ router_w                # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                     # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    e = router_w.shape[1]
    me = jnp.mean(probs, axis=0)                             # (E,)
    one_hot_top1 = jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    return ids, gates, me, ce


def aux_loss(me: jax.Array, ce: jax.Array) -> jax.Array:
    return me.shape[0] * jnp.sum(me * ce)


def _dispatch_indices(flat_expert: jax.Array, n_buckets: int, capacity: int):
    """Sort slots by destination bucket; return (sort order, position-in-bucket,
    keep mask). Works for both rank buckets and local-expert buckets."""
    s = flat_expert.shape[0]
    order = jnp.argsort(flat_expert)                         # stable
    sorted_e = flat_expert[order]
    # position of each sorted slot within its bucket
    idx = jnp.arange(s)
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_buckets))
    pos = idx - starts[sorted_e]
    keep = pos < capacity
    return order, sorted_e, pos, keep


def expert_ffn(w_gate, w_up, w_down, buf: jax.Array) -> jax.Array:
    """Per-expert SwiGLU. buf (E, C, d) with weights (E, d, f)/(E, f, d)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", buf, w_up
    )
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_apply_local(params: dict, x: jax.Array, k: int, capacity_factor: float):
    """Single-shard MoE on tokens x (T, d). Returns (y, aux_loss)."""
    t, d = x.shape
    e = params["router"].shape[1]
    ids, gates, me, ce = route(params["router"], x, k)
    aux = aux_loss(me, ce)
    cap = int(np.ceil(t * k / e * capacity_factor))
    cap = max(8, -(-cap // 8) * 8)                           # round up to 8

    flat_e = ids.reshape(-1)                                 # (T·k,)
    order, sorted_e, pos, keep = _dispatch_indices(flat_e, e, cap)
    tok = order // k                                         # source token per sorted slot
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[sorted_e, pos].add(jnp.where(keep[:, None], x[tok], 0))
    out_buf = expert_ffn(params["w_gate"], params["w_up"], params["w_down"], buf)
    # gather back: each sorted slot reads its expert output, weighted by gate
    slot_out = out_buf[sorted_e, pos] * jnp.where(keep, gates.reshape(-1)[order], 0.0)[:, None]
    y = jnp.zeros((t, d), x.dtype).at[tok].add(slot_out.astype(x.dtype))
    if "shared" in params:
        from repro.models.common import apply_swiglu

        y = y + apply_swiglu(params["shared"], x)
    return y, aux


def moe_apply_ep(params: dict, x: jax.Array, k: int, capacity_factor: float,
                 mesh: jax.sharding.Mesh, dp_axes: tuple[str, ...], ep_axis: str):
    """Distributed MoE: x (B, S, d) with B sharded over ``dp_axes`` and S over
    ``ep_axis``; experts sharded over ``ep_axis``. Two all_to_alls per layer."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    n_ep = mesh.shape[ep_axis]
    e = params["router"].shape[1]
    assert e % n_ep == 0, (e, n_ep)
    e_loc = e // n_ep

    def local_fn(router_w, w_gate, w_up, w_down, shared, xl):
        # xl: (B_l, S_l, d) — local tokens; experts local: w_* (E_loc, d, f)
        bl, sl, d = xl.shape
        tl = bl * sl
        xt = xl.reshape(tl, d)
        ids, gates, me, ce = route(router_w, xt, k)          # router is replicated
        # psum the statistics BEFORE the product — matches the global-batch loss
        for ax in (ep_axis, *dp_axes):
            me = jax.lax.pmean(me, ax)
            ce = jax.lax.pmean(ce, ax)
        aux = aux_loss(me, ce)

        # ---- A2A dispatch: bucket slots by owner rank -----------------------
        cap_s = int(np.ceil(tl * k / n_ep * capacity_factor))
        cap_s = max(8, -(-cap_s // 8) * 8)
        flat_e = ids.reshape(-1)
        rank = flat_e // e_loc
        order, sorted_r, pos, keep = _dispatch_indices(rank, n_ep, cap_s)
        tok = order // k
        send = jnp.zeros((n_ep, cap_s, d), xl.dtype)
        send = send.at[sorted_r, pos].add(jnp.where(keep[:, None], xt[tok], 0))
        # metadata rides along as fp32 lanes: local expert id, gate
        meta = jnp.zeros((n_ep, cap_s, 2), jnp.float32)
        meta = meta.at[sorted_r, pos].add(
            jnp.where(
                keep[:, None],
                jnp.stack([(flat_e[order] % e_loc).astype(jnp.float32) + 1.0,
                           gates.reshape(-1)[order]], axis=-1),
                0,
            )
        )
        recv = jax.lax.all_to_all(send, ep_axis, 0, 0, tiled=False)      # (n_ep, cap_s, d)
        meta_r = jax.lax.all_to_all(meta, ep_axis, 0, 0, tiled=False)

        # ---- local expert grouping -----------------------------------------
        rtok = recv.reshape(n_ep * cap_s, d)
        r_eid = meta_r.reshape(-1, 2)[:, 0]
        r_gate = meta_r.reshape(-1, 2)[:, 1]
        valid = r_eid > 0
        loc_e = jnp.where(valid, r_eid - 1.0, e_loc).astype(jnp.int32)   # invalid → overflow bucket
        cap_e = int(np.ceil(n_ep * cap_s / e_loc * capacity_factor))
        cap_e = max(8, -(-cap_e // 8) * 8)
        order2, sorted_e2, pos2, keep2 = _dispatch_indices(loc_e, e_loc + 1, cap_e)
        in_range = keep2 & (sorted_e2 < e_loc)
        buf = jnp.zeros((e_loc, cap_e, d), xl.dtype)
        buf = buf.at[jnp.minimum(sorted_e2, e_loc - 1), pos2].add(
            jnp.where(in_range[:, None], rtok[order2], 0)
        )
        out_buf = expert_ffn(w_gate, w_up, w_down, buf)
        slot_out = jnp.zeros((n_ep * cap_s, d), xl.dtype)
        slot_out = slot_out.at[order2].add(
            jnp.where(in_range[:, None], out_buf[jnp.minimum(sorted_e2, e_loc - 1), pos2], 0)
        )
        slot_out = slot_out * r_gate[:, None].astype(slot_out.dtype)

        # ---- A2A return + combine ------------------------------------------
        back = jax.lax.all_to_all(slot_out.reshape(n_ep, cap_s, d), ep_axis, 0, 0, tiled=False)
        flat_back = back.reshape(n_ep, cap_s, d)
        y = jnp.zeros((tl, d), xl.dtype)
        y = y.at[tok].add(jnp.where(keep[:, None], flat_back[sorted_r, pos], 0))
        yl = y.reshape(bl, sl, d)
        if shared is not None:
            from repro.models.common import apply_swiglu

            yl = yl + apply_swiglu(shared, xl)
        return yl, aux

    w_specs = (P(), P(ep_axis), P(ep_axis), P(ep_axis))
    x_spec = P(dp_axes, ep_axis, None)
    w_args = (params["router"], params["w_gate"], params["w_up"], params["w_down"])
    if "shared" in params:
        in_specs = w_specs + (P(), x_spec)
        args = w_args + (params["shared"], x)
        local = local_fn
    else:
        in_specs = w_specs + (x_spec,)
        args = w_args + (x,)

        def local(r, g, u, dn, xl):
            return local_fn(r, g, u, dn, None, xl)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(x_spec, P()),
        check_rep=False,
    )
    return fn(*args)
