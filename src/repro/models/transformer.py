"""Decoder-only LM covering the dense / MoE / VLM / local-global families.

Pure-functional params; layers are stacked and scanned (one compiled layer body
regardless of depth — essential for 512-device AOT lowering times), with
per-layer static variation (gemma local/global, kimi leading-dense) expressed
as scanned flag arrays + lax.cond. Distribution is injected via a ``Dist``
context: activation sharding constraints at block boundaries, shard_map EP for
MoE, and sequence-sharded KV caches for decode.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.common import (
    apply_mrope,
    apply_rope,
    apply_swiglu,
    cross_entropy_loss,
    embed,
    init_embedding,
    init_rms,
    init_swiglu,
    rms_norm,
    truncated_normal_init,
    unembed,
)


@dataclasses.dataclass(frozen=True)
class Dist:
    """Distribution context threaded through model code (None ⇒ single device)."""

    mesh: Any = None
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str | None = "model"
    head_axis: str | None = None   # q-head sharding (only when H % tp == 0)
    kv_head_axis: str | None = None
    use_ep: bool = True            # MoE: shard_map all-to-all EP over tp_axis
    sp: bool = False               # sequence-parallel activations between blocks
    seq_shard_cache: bool = False  # decode: shard KV cache sequence over tp_axis

    @property
    def seq_axis(self) -> str | None:
        """Megatron-SP: activations between blocks are sequence-sharded over TP."""
        return self.tp_axis if self.sp else None

    def constrain(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, jax.sharding.NamedSharding(self.mesh, P(*spec)))


NO_DIST = Dist()


# ------------------------------------------------------------------ params --

def _init_block(key, cfg: ModelConfig, moe_layer: bool) -> dict:
    ka, kf = jax.random.split(key)
    dtype = jnp.dtype(cfg.dtype)
    p = {
        "ln1": init_rms(cfg.d_model),
        "ln2": init_rms(cfg.d_model),
        "attn": attn.init_attn_params(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dtype),
    }
    if moe_layer:
        p["moe"] = moe_mod.init_moe_params(
            kf, cfg.d_model, cfg.moe_d_ff, cfg.n_experts, cfg.n_shared_experts, cfg.moe_d_ff, dtype
        )
    else:
        p["mlp"] = init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_lm_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ke, kl, kp, kh = jax.random.split(key, 4)
    n_scan = cfg.n_layers - cfg.first_k_dense
    moe_scan = cfg.family == "moe"
    layer_keys = jax.random.split(kl, n_scan)
    layers = jax.vmap(lambda k: _init_block(k, cfg, moe_scan))(layer_keys)
    params = {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": init_rms(cfg.d_model),
        "lm_head": truncated_normal_init(kh, (cfg.d_model, cfg.vocab_size), 1.0, dtype),
    }
    if cfg.first_k_dense:
        pre_keys = jax.random.split(kp, cfg.first_k_dense)
        params["pre_layers"] = [
            _init_block(pre_keys[i], cfg, moe_layer=False) for i in range(cfg.first_k_dense)
        ]
    return params


def layer_flags(cfg: ModelConfig) -> jax.Array:
    """(n_scan,) int32 — 1 where a gemma-style layer is GLOBAL attention."""
    n_scan = cfg.n_layers - cfg.first_k_dense
    if cfg.local_global_ratio:
        period = cfg.local_global_ratio + 1
        return ((jnp.arange(n_scan) % period) == (period - 1)).astype(jnp.int32)
    return jnp.ones((n_scan,), jnp.int32)


# ----------------------------------------------------------------- forward --

def _apply_positional(q, k, cfg: ModelConfig, positions, is_global):
    """RoPE / M-RoPE with gemma's dual-theta handled by a traced select."""
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        return q, k
    if cfg.rope_theta_global:
        ql = apply_rope(q, positions, cfg.rope_theta)
        kl = apply_rope(k, positions, cfg.rope_theta)
        qg = apply_rope(q, positions, cfg.rope_theta_global)
        kg = apply_rope(k, positions, cfg.rope_theta_global)
        sel = is_global.astype(q.dtype)
        return ql + sel * (qg - ql), kl + sel * (kg - kl)
    return apply_rope(q, positions, cfg.rope_theta), apply_rope(k, positions, cfg.rope_theta)


def _attention_block(p, x, cfg: ModelConfig, positions, is_global, dist: Dist,
                     q_chunk: int, kv_chunk: int, collect_kv: bool = False):
    B, S, d = x.shape
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    h = dist.constrain(h, dist.dp_axes, dist.seq_axis, None)
    q = (h @ p["attn"]["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    k = (h @ p["attn"]["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (h @ p["attn"]["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    q = dist.constrain(q, dist.dp_axes, None, dist.head_axis, None)
    k = dist.constrain(k, dist.dp_axes, None, dist.kv_head_axis, None)
    q, k = _apply_positional(q, k, cfg, positions, is_global)
    if cfg.sliding_window and cfg.local_global_ratio:
        # both window and global branches are compiled once; flag selects
        out = jax.lax.cond(
            is_global > 0,
            lambda args: attn.flash_attention(*args, causal=True, window=0,
                                              q_chunk=q_chunk, kv_chunk=kv_chunk),
            lambda args: attn.flash_attention(*args, causal=True, window=cfg.sliding_window,
                                              q_chunk=q_chunk, kv_chunk=kv_chunk),
            (q, k, v),
        )
    else:
        out = attn.flash_attention(q, k, v, causal=True, window=cfg.sliding_window,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    out = jax.ad_checkpoint.checkpoint_name(out, "attn_out")
    x = x + out @ p["attn"]["wo"]
    if collect_kv:
        return x, (k, v)
    return x


def _ffn_block(p, x, cfg: ModelConfig, dist: Dist):
    h = rms_norm(x, p["ln2"], cfg.rms_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        n_ep = dist.mesh.shape[dist.tp_axis] if (dist.mesh is not None and dist.tp_axis) else 1
        # all-to-all EP needs the sequence to split across the expert axis;
        # decode (S=1) falls through to the pjit-partitioned local path.
        if dist.mesh is not None and dist.use_ep and x.shape[1] % n_ep == 0:
            h = dist.constrain(h, dist.dp_axes, dist.tp_axis, None)
            y, aux = moe_mod.moe_apply_ep(
                p["moe"], h, cfg.experts_per_token, cfg.capacity_factor,
                dist.mesh, dist.dp_axes, dist.tp_axis,
            )
        else:
            B, S, d = h.shape
            y, aux = moe_mod.moe_apply_local(
                p["moe"], h.reshape(B * S, d), cfg.experts_per_token, cfg.capacity_factor
            )
            y = y.reshape(B, S, d)
    else:
        y = apply_swiglu(p["mlp"], h)
    return x + y, aux


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig, dist: Dist = NO_DIST,
            positions: jax.Array | None = None, vision_embeds: jax.Array | None = None,
            q_chunk: int = 512, kv_chunk: int = 1024):
    """tokens (B, S) → (logits (B, S, V), aux_loss)."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    if vision_embeds is not None:
        nv = vision_embeds.shape[1]
        x = jax.lax.dynamic_update_slice(x, vision_embeds.astype(x.dtype), (0, 1, 0))
        del nv
    if positions is None:
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        positions = jnp.broadcast_to(pos[None], (3, B, S)) if cfg.mrope_sections else pos
    x = dist.constrain(x, dist.dp_axes, dist.seq_axis, None)

    flags = layer_flags(cfg)

    def body(x, layer):
        lp, flag = layer
        x = _attention_block(lp, x, cfg, positions, flag, dist, q_chunk, kv_chunk)
        x, aux = _ffn_block(lp, x, cfg, dist)
        x = dist.constrain(x, dist.dp_axes, dist.seq_axis, None)
        return x, aux

    if cfg.remat:
        policy = (jax.checkpoint_policies.save_only_these_names("attn_out")
                  if cfg.remat_policy == "save_attn" else None)
        body = jax.checkpoint(body, policy=policy)

    def pre_block(x, pre):
        x = _attention_block(pre, x, cfg, positions, jnp.int32(1), dist, q_chunk, kv_chunk)
        x, _ = _ffn_block(pre, x, cfg, dist)
        return x

    if cfg.remat:
        pre_block = jax.checkpoint(pre_block)  # unscanned layers need remat too
    for pre in params.get("pre_layers", []):
        x = pre_block(x, pre)

    x, auxs = jax.lax.scan(body, x, (params["layers"], flags), unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = x @ params["lm_head"]
    logits = dist.constrain(logits, dist.dp_axes, None, dist.tp_axis)
    return logits, jnp.sum(auxs)


def lm_loss(params: dict, batch: dict, cfg: ModelConfig, dist: Dist = NO_DIST,
            q_chunk: int = 512, kv_chunk: int = 1024):
    logits, aux = forward(
        params, batch["tokens"], cfg, dist,
        positions=batch.get("positions"),
        vision_embeds=batch.get("vision_embeds"),
        q_chunk=q_chunk, kv_chunk=kv_chunk,
    )
    loss = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss + cfg.router_aux_coef * aux, {"nll": loss, "aux": aux}


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig, dist: Dist = NO_DIST,
            positions: jax.Array | None = None, vision_embeds: jax.Array | None = None,
            q_chunk: int = 512, kv_chunk: int = 1024, cache_dtype=jnp.bfloat16):
    """Process a prompt, returning (last-token logits (B, V), KV cache).

    The cache holds post-RoPE keys (matching decode_step's convention).
    """
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    if vision_embeds is not None:
        x = jax.lax.dynamic_update_slice(x, vision_embeds.astype(x.dtype), (0, 1, 0))
    if positions is None:
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        positions = jnp.broadcast_to(pos[None], (3, B, S)) if cfg.mrope_sections else pos
    x = dist.constrain(x, dist.dp_axes, dist.seq_axis, None)
    flags = layer_flags(cfg)

    def body(x, layer):
        lp, flag = layer
        x, (k, v) = _attention_block(lp, x, cfg, positions, flag, dist, q_chunk, kv_chunk,
                                     collect_kv=True)
        x, _ = _ffn_block(lp, x, cfg, dist)
        x = dist.constrain(x, dist.dp_axes, dist.seq_axis, None)
        return x, (k.astype(cache_dtype), v.astype(cache_dtype))

    if cfg.remat:
        body = jax.checkpoint(body)

    cache = {}
    if cfg.first_k_dense:
        pk, pv = [], []
        for pre in params["pre_layers"]:
            x, (k, v) = _attention_block(pre, x, cfg, positions, jnp.int32(1), dist,
                                         q_chunk, kv_chunk, collect_kv=True)
            x, _ = _ffn_block(pre, x, cfg, dist)
            pk.append(k.astype(cache_dtype))
            pv.append(v.astype(cache_dtype))
        cache["pre_k"] = jnp.stack(pk)
        cache["pre_v"] = jnp.stack(pv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], flags), unroll=cfg.scan_unroll)
    cache["k"] = ks
    cache["v"] = vs
    x = rms_norm(x[:, -1], params["final_norm"], cfg.rms_eps)
    logits = x @ params["lm_head"]
    return logits, cache


# ------------------------------------------------------------------ decode --

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    n_scan = cfg.n_layers - cfg.first_k_dense
    shape = (n_scan, batch, max_len, cfg.n_kv_heads, cfg.hd)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if cfg.first_k_dense:
        pshape = (cfg.first_k_dense, batch, max_len, cfg.n_kv_heads, cfg.hd)
        cache["pre_k"] = jnp.zeros(pshape, dtype)
        cache["pre_v"] = jnp.zeros(pshape, dtype)
    return cache


def decode_step(params: dict, token: jax.Array, cache: dict, cur_len: jax.Array,
                cfg: ModelConfig, dist: Dist = NO_DIST):
    """One incremental decode step.

    token (B, 1) int32; ``cur_len`` — number of valid tokens *after* this one.
    Returns (logits (B, V), new_cache).
    """
    B = token.shape[0]
    x = embed(params["embed"], token)                        # (B, 1, d)
    pos = (cur_len - 1) * jnp.ones((B, 1), jnp.int32)
    positions = jnp.broadcast_to(pos[None], (3, B, 1)) if cfg.mrope_sections else pos
    flags = layer_flags(cfg)

    def one_layer(lp, x, kc, vc, flag):
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        q = (h @ lp["attn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
        k = (h @ lp["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        v = (h @ lp["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        q, k = _apply_positional(q, k, cfg, positions, flag)
        kc = attn.update_cache(kc, k, cur_len - 1)
        vc = attn.update_cache(vc, v, cur_len - 1)
        window = 0
        if cfg.sliding_window and not cfg.local_global_ratio:
            window = cfg.sliding_window
        if cfg.sliding_window and cfg.local_global_ratio:
            out = jax.lax.cond(
                flag > 0,
                lambda a: attn.decode_attention(*a, window=0),
                lambda a: attn.decode_attention(*a, window=cfg.sliding_window),
                (q, kc, vc, cur_len),
            )
        else:
            out = attn.decode_attention(q, kc, vc, cur_len, window=window)
        x = x + out.reshape(B, 1, cfg.n_heads * cfg.hd) @ lp["attn"]["wo"]
        x, _ = _ffn_block(lp, x, cfg, dist)
        return x, kc, vc

    for i, pre in enumerate(params.get("pre_layers", [])):
        x, nk, nv = one_layer(pre, x, cache["pre_k"][i], cache["pre_v"][i], jnp.int32(1))
        cache = dict(cache, pre_k=cache["pre_k"].at[i].set(nk), pre_v=cache["pre_v"].at[i].set(nv))

    def body(x, layer):
        lp, kc, vc, flag = layer
        x, nk, nv = one_layer(lp, x, kc, vc, flag)
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"], flags),
                               unroll=cfg.scan_unroll)
    cache = dict(cache, k=nk, v=nv)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, cache
