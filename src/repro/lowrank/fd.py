"""Frequent-Directions accumulator — the deterministic l × p sketch.

Liberty's Frequent Directions maintains an (l, p) matrix B with the guarantee
0 ≼ S − BᵀB ≼ (‖A‖_F² / (l − k)) I for every k < l, where S = Σ_i w_i w_iᵀ.
Batches of compact sparse rows are appended in chunks of at most l rows —
scattered straight into the l-row buffer (the ``_scatter_outer`` pattern;
the (b, p) batch is never densified, only (≤l, p) chunks) — and on overflow
the stacked (≤2l, p) buffer is SVD-shrunk back to l rows
(σ'² = max(σ² − σ²_{l+1}, 0)).

Unlike :mod:`repro.lowrank.range_finder`, the shrink is NOT additive: FD folds
are order-dependent and fold sequentially on every backend (the ``repro.api``
reducer feeds each (step, shard) sketch in the same linear order regardless of
backend, so backends still agree bit-for-bit). The psum-able engine path is
the range-finder; FD is the deterministic-guarantee alternative behind
``Plan(lowrank_method="fd")``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.estimators import _cov_scale, stream_finalize_mean
from repro.core.sampling import SparseRows
from repro.lowrank.model import LowRankCov, eig_in_basis


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FDState:
    """FD sketch + the exact side accumulators (all O(p·l) or O(p)).

    sketch: (l, p) the current Frequent-Directions matrix B.
    diag:   (p,)   Σ w_i ∘ w_i (exact, for the Thm-6 debias).
    sum_w:  (p,)   Σ w_i (Thm-4 mean numerator).
    count:  ()     rows folded (int32).
    """

    sketch: jax.Array
    diag: jax.Array
    sum_w: jax.Array
    count: jax.Array

    def tree_flatten(self):
        return (self.sketch, self.diag, self.sum_w, self.count), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def nbytes(self) -> int:
        return sum(a.size * a.dtype.itemsize
                   for a in (self.sketch, self.diag, self.sum_w, self.count))


def fd_init(p: int, ell: int) -> FDState:
    return FDState(
        sketch=jnp.zeros((ell, p), jnp.float32),
        diag=jnp.zeros((p,), jnp.float32),
        sum_w=jnp.zeros((p,), jnp.float32),
        count=jnp.zeros((), jnp.int32),
    )


def _shrink(stacked: jax.Array, ell: int) -> jax.Array:
    """SVD-shrink a (>l, p) stack back to l rows (the FD overflow step)."""
    _, s, vt = jnp.linalg.svd(stacked, full_matrices=False)
    delta = s[ell] ** 2 if s.shape[0] > ell else jnp.float32(0.0)
    s_shrunk = jnp.sqrt(jnp.maximum(s[:ell] ** 2 - delta, 0.0))
    return s_shrunk[:, None] * vt[:ell]


@jax.jit
def fd_update(state: FDState, batch: SparseRows) -> FDState:
    """Fold one sketched batch (sequential — FD shrink is order-dependent)."""
    values, indices = batch.values, batch.indices
    n = values.shape[0]
    ell, p = state.sketch.shape

    sketch = state.sketch
    for start in range(0, n, ell):                # static chunk schedule
        v_c = values[start:start + ell].astype(jnp.float32)
        i_c = indices[start:start + ell]
        # scatter c ≤ l rows straight into the sketch buffer (the
        # _scatter_outer pattern) — the only dense intermediate is
        # (l, p)-bounded, never (b, p)
        rows = SparseRows(v_c, i_c, p).to_dense()
        sketch = _shrink(jnp.concatenate([sketch, rows]), ell)

    flat_idx = indices.reshape(-1)
    v32 = values.astype(jnp.float32)
    return FDState(
        sketch=sketch,
        diag=state.diag.at[flat_idx].add((v32 * v32).reshape(-1)),
        sum_w=state.sum_w.at[flat_idx].add(v32.reshape(-1)),
        count=state.count + jnp.int32(n),
    )


# THE Thm-4 mean formula lives in core.estimators (see range_finder.py).
fd_finalize_mean = stream_finalize_mean


def fd_finalize(state: FDState, m: int) -> LowRankCov:
    """Rank-l eigenmodel of Ĉ_n: S ≈ BᵀB = V diag(σ²) Vᵀ, then the Thm-6 scale
    and in-basis diagonal debias."""
    ell, p = state.sketch.shape
    if m < 2:
        raise ValueError("covariance estimator needs m >= 2 (Thm B4, Eq. 50)")
    _, s, vt = jnp.linalg.svd(state.sketch, full_matrices=False)
    return eig_in_basis(vt.T, jnp.diag(s ** 2),
                        scale=_cov_scale(p, m) / state.count,
                        diag_s=state.diag, corr=(p - m) / (p - 1))
