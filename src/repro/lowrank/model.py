"""Shared pieces of the low-rank spectral subsystem.

Both accumulators (:mod:`repro.lowrank.range_finder`,
:mod:`repro.lowrank.fd`) finalize to the same factored object: a rank-l
eigenmodel of the Thm-6 unbiased covariance Ĉ_n, held as (eigenvalues,
eigenvector rows) — O(l·p) memory, never a (p, p) array. PCA consumers slice
``top(k)``; ``dense()`` exists only for small-p diagnostics and tests.

The debiasing step of Thm 6 (Ĉ_n = Ĉ_emp − corr·diag(Ĉ_emp)) needs diag(S)
where S = Σ w wᵀ; both accumulators carry the exact (p,) diagonal alongside
their low-rank factor, and :func:`eig_in_basis` applies the correction inside
the captured l-dimensional basis — the component of the diagonal outside the
basis only perturbs the discarded tail.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils.prng import fold_in_str


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class LowRankCov:
    """Rank-l factored eigenmodel of Ĉ_n in the preconditioned domain.

    eigenvalues:    (l,) descending.
    components_pre: (l, p) rows are the corresponding eigenvectors.
    """

    eigenvalues: jax.Array
    components_pre: jax.Array

    def tree_flatten(self):
        return (self.eigenvalues, self.components_pre), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def rank(self) -> int:
        return self.components_pre.shape[0]

    def top(self, k: int) -> tuple[jax.Array, jax.Array]:
        """(components_pre (k, p), eigenvalues (k,)) — the PCA consumer's slice."""
        if k > self.rank:
            raise ValueError(f"asked for top-{k} of a rank-{self.rank} model; "
                             "raise Plan.rank")
        return self.components_pre[:k], self.eigenvalues[:k]

    def dense(self) -> jax.Array:
        """(p, p) reconstruction V diag(λ) Vᵀ — diagnostics/tests ONLY (this is
        the very allocation the low-rank path exists to avoid)."""
        v = self.components_pre
        return (v.T * self.eigenvalues) @ v

    def nbytes(self) -> int:
        return (self.eigenvalues.size * self.eigenvalues.dtype.itemsize
                + self.components_pre.size * self.components_pre.dtype.itemsize)


def omega(key: jax.Array, p: int, ell: int) -> jax.Array:
    """The fixed (p, l) Gaussian test matrix of the range-finder state.

    Derived from the sketch spec's root key under its own tag, so every
    backend/shard/worker regenerates the identical projection — the same
    discipline as the ROS signs.
    """
    return jax.random.normal(fold_in_str(key, "lowrank-omega"), (p, ell), jnp.float32)


def eig_in_basis(q: jax.Array, core: jax.Array, *,
                 scale: jax.Array | float = 1.0,
                 diag_s: jax.Array | None = None, corr: float = 0.0) -> LowRankCov:
    """Eigendecompose Ĉ_n restricted to an l-dimensional basis.

    q:      (p, l) orthonormal columns spanning the captured range.
    core:   (l, l) ≈ qᵀ S q (S = Σ w wᵀ, any low-rank estimate of it).
    scale:  Thm-6 scale p(p−1)/(m(m−1)) divided by the row count (fold it into
            ``core`` instead and leave 1.0 if the core is already scaled).
    diag_s / corr: the EXACT (p,) diagonal of S and the Thm-6 correction factor
            (p−m)/(p−1), applied in-basis — omit when the operator was already
            debiased before the basis was found (the range-finder path).

    Ĉ_n = scale · (S − corr·diag(diag_s)); in the q basis that is
    scale · (core − corr·qᵀ(diag_s ∘ q)) — an (l, l) symmetric eigenproblem
    whose eigenvectors lift back through q. All O(p·l²) flops, O(p·l) memory.
    """
    t = core
    if diag_s is not None and corr:
        t = t - corr * (q.T @ (diag_s[:, None] * q))
    t = scale * t
    t = 0.5 * (t + t.T)
    evals, evecs = jnp.linalg.eigh(t)                        # ascending
    order = jnp.argsort(evals)[::-1]
    return LowRankCov(eigenvalues=evals[order],
                      components_pre=(q @ evecs[:, order]).T)
