"""Randomized range-finder / co-occurrence accumulator — the psum-able state.

The Thm-6 covariance needs S = Σ_i w_i w_iᵀ; this state never forms S, only its
action on a fixed (p, l) Gaussian test matrix Omega (:func:`repro.lowrank.model.omega`):

    y    = S · Omega                (p, l)   accumulated EXACTLY (linear in batches)
    diag = diag(S) = Σ_i w_i∘w_i    (p,)     exact, for the Thm-6 debias
    sum_w, count                             the Thm-4 mean accumulator

Each batch's delta is Wᵀ(W·Omega) — two sparse-times-dense products
(``kernels.ops.spmm`` / ``spmm_t``) that never densify the (b, p) batch. The
delta is fixed-size and additive, so it follows the exact ``init / delta /
apply / finalize`` algebra of ``stream.accumulators``: single-device engines
apply it directly, sharded engines psum it (the only cross-shard traffic is
O(p·l) per step), and streaming == batch holds to float-sum reordering.

Finalize (single-pass randomized eigendecomposition, three deliberate choices):

1. **Debias first, then range-find.** Element-wise sampling inflates diag(S)
   by the large (p−m)/(p−1) mask-noise floor that Thm 6 subtracts; a range
   found on raw Y chases those diagonal directions instead of the spectrum.
   Because diag(S) is carried exactly, the debiased operator's sketch is
   available in closed form: Y' = (S − corr·diag(d))·Omega = Y − corr·(d ∘ Omega).
2. **Oversampled, truncated basis.** The basis is the top r = l/2 left
   singular vectors of Y', not all l — Omega then oversamples the basis 2×, which
   is what makes step 3 well-posed (a square Gaussian solve is notoriously
   ill-conditioned and produces ghost eigenvalues).
3. **Fat least-squares core.** From S' ≈ Q(QᵀS'Q)Qᵀ follows
   (QᵀY') ≈ core·(QᵀOmega); the r×l system is solved by pseudo-inverse and
   symmetrized — the standard single-pass core estimate (Halko et al. §5.5,
   stabilized by the oversampling of step 2).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.estimators import _cov_scale, stream_finalize_mean
from repro.core.sampling import SparseRows
from repro.kernels import ops
from repro.lowrank.model import LowRankCov, eig_in_basis


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class RangeState:
    """Constant-memory low-rank co-occurrence accumulators (all O(p·l)).

    y:     (p, l)  Σ w_i (w_iᵀ Omega) = S·Omega
    diag:  (p,)    Σ w_i ∘ w_i = diag(S)
    sum_w: (p,)    Σ w_i (Thm-4 mean numerator)
    count: ()      rows folded (int32 — exact, same rationale as MomentState)
    """

    y: jax.Array
    diag: jax.Array
    sum_w: jax.Array
    count: jax.Array

    def tree_flatten(self):
        return (self.y, self.diag, self.sum_w, self.count), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def nbytes(self) -> int:
        return sum(a.size * a.dtype.itemsize
                   for a in (self.y, self.diag, self.sum_w, self.count))


def range_init(p: int, ell: int) -> RangeState:
    return RangeState(
        y=jnp.zeros((p, ell), jnp.float32),
        diag=jnp.zeros((p,), jnp.float32),
        sum_w=jnp.zeros((p,), jnp.float32),
        count=jnp.zeros((), jnp.int32),
    )


def range_delta(batch: SparseRows, omega_mat: jax.Array,
                impl: str = "auto") -> RangeState:
    """One batch's contribution — local, additive, psum-able.

    ``impl`` routes the sparse-times-dense products ("auto" = Pallas kernel on
    TPU, jnp oracle elsewhere — the kernels.ops convention).
    """
    values, indices = batch.values, batch.indices
    t = ops.spmm(values, indices, omega_mat, mode=impl)              # (b, l)
    y = ops.spmm_t(values, indices, t, batch.p, mode=impl)           # (p, l)
    flat_idx = indices.reshape(-1)
    v32 = values.astype(jnp.float32)
    diag = jnp.zeros((batch.p,), jnp.float32).at[flat_idx].add(
        (v32 * v32).reshape(-1))
    sum_w = jnp.zeros((batch.p,), jnp.float32).at[flat_idx].add(v32.reshape(-1))
    return RangeState(y, diag, sum_w, jnp.int32(values.shape[0]))


def range_apply(state: RangeState, delta: RangeState) -> RangeState:
    """Fold a (possibly psum'd) delta into the accumulator."""
    return RangeState(state.y + delta.y, state.diag + delta.diag,
                      state.sum_w + delta.sum_w, state.count + delta.count)


def range_update(state: RangeState, batch: SparseRows, omega_mat: jax.Array,
                 impl: str = "auto") -> RangeState:
    return range_apply(state, range_delta(batch, omega_mat, impl))


# THE Thm-4 mean formula lives in core.estimators; RangeState duck-types the
# (sum_w, count) fields it reads, so a fix there fixes every backend at once.
range_finalize_mean = stream_finalize_mean


def range_finalize(state: RangeState, m: int, omega_mat: jax.Array,
                   rank: int | None = None) -> LowRankCov:
    """Rank-r eigenmodel of Ĉ_n from (Y, diag, count) alone — O(p·l²) flops.

    Returns ``rank`` (default l/2 — Omega must oversample the basis, see module
    docstring) eigenpairs of the debiased estimator; consumers slice ``top(k)``
    with k ≤ rank.
    """
    p, ell = state.y.shape
    if m < 2:
        raise ValueError("covariance estimator needs m >= 2 (Thm B4, Eq. 50)")
    r = max(1, ell // 2) if rank is None else int(rank)
    if not 0 < r <= ell:
        raise ValueError(f"rank must be in [1, l={ell}], got {r}")
    corr = (p - m) / (p - 1)
    # the debiased operator's sketch, exactly: (S − corr·diag(d))·Omega, scaled
    # by 1/count so the solve below is conditioned like Ĉ_n, not n·Ĉ_n
    yp = (state.y - corr * state.diag[:, None] * omega_mat) / state.count
    u, _, _ = jnp.linalg.svd(yp, full_matrices=False)
    q = u[:, :r]                                             # (p, r) basis
    core = (q.T @ yp) @ jnp.linalg.pinv(q.T @ omega_mat)     # r×l fat solve
    return eig_in_basis(q, _cov_scale(p, m) * core)
