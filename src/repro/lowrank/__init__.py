"""Low-rank spectral subsystem: streaming PCA without the (p, p) accumulator.

The Thm-6 covariance path of every other backend materializes Σ w wᵀ — a
(p, p) array — even when the consumer only wants k ≪ p principal components.
This package replaces it with constant-memory O(l·p) accumulators sharing the
``init / delta / apply / finalize`` algebra of ``repro.stream.accumulators``:

- :mod:`repro.lowrank.range_finder` — randomized range-finder / co-occurrence
  state: Y = S·Omega accumulated exactly via sparse-times-dense kernels; linear,
  so the (p, l) delta psums across shards (the StreamEngine / stream.sharded
  path). Finalized by single-pass Nyström + in-basis Thm-6 debias.
- :mod:`repro.lowrank.fd` — Frequent-Directions (l, p) sketch, SVD-shrink on
  overflow: deterministic guarantee, sequential fold.
- :mod:`repro.lowrank.model` — the shared :class:`LowRankCov` factored
  eigenmodel both finalize to, the fixed test matrix :func:`omega`, and the
  in-basis debiased eigensolve.

Front door: ``Plan(cov_path="lowrank", rank=l)`` — ``SparsifiedPCA`` then runs
O(l·p) on every backend. See also ``kernels/spmm.py`` (the feeding kernels).
"""
from repro.lowrank.fd import (  # noqa: F401
    FDState,
    fd_finalize,
    fd_finalize_mean,
    fd_init,
    fd_update,
)
from repro.lowrank.model import LowRankCov, eig_in_basis, omega  # noqa: F401
from repro.lowrank.range_finder import (  # noqa: F401
    RangeState,
    range_apply,
    range_delta,
    range_finalize,
    range_finalize_mean,
    range_init,
    range_update,
)
