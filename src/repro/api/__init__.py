"""repro.api — one front door over the paper's compression pipeline.

A :class:`Plan` picks the execution engine (``backend="batch" | "stream" |
"sharded"``, kernel ``impl``, batch geometry, mesh); the estimator classes —
:class:`SparsifiedMean`, :class:`SparsifiedCov`, :class:`SparsifiedPCA`,
:class:`SparsifiedKMeans`, :class:`GradCompressor` — share one
``SketchSpec``-derived key discipline (``sketch.batch_key(spec, step, shard)``)
and a ``fit / partial_fit / finalize / transform`` contract. Backends fold the
same per-(step, shard) sketches, so flipping ``Plan.backend`` re-runs the same
job tolerance-identically on a different engine::

    from repro.api import Plan, SparsifiedPCA

    plan = Plan(backend="batch", gamma=0.05, batch_size=2048)
    p1 = SparsifiedPCA(8, plan, key=0).fit(x)
    p2 = SparsifiedPCA(8, plan.replace(backend="stream"), key=0).fit(x)
    # p1.components_ == p2.components_ to float-sum reordering (1e-5)

One compression pass can feed EVERY consumer at once —
:func:`fit_many` registers any number of estimators on one shared
:class:`SketchCursor`, sketches each (step, shard) chunk exactly once, and
fans it out, reproducing the separate fits to 1e-5 on every backend::

    pca = SparsifiedPCA(8, plan, key=0)
    km = SparsifiedKMeans(10, plan, key=0)
    fit_many(plan, [pca, km], x)     # one sketch pass, both fitted

For unbounded sources (and the K-means/moments fused single pass), the same
Plan also constructs a :class:`repro.stream.StreamEngine` via
:func:`make_engine` — the launcher ``repro.launch.stream`` is a thin shim over
this; ``fit_many(plan, consumers, source=src, steps=n)`` is the estimator-API
front door to the same fused pass.

Single-pass is the floor, not the ceiling: because every batch's mask
regenerates from (seed, step, shard), ``SparsifiedPCA.fit_refine`` /
``SparsifiedKMeans.fit_refine`` (and ``fit_many(..., refine=True)``,
``Plan(refine_passes=)``) replay the source for second-pass refinement —
PCA power iteration and two-pass Alg.-2 K-means — storing nothing
(``repro.refine``).
"""
from __future__ import annotations

import jax

from repro.api.estimators import (  # noqa: F401
    GradCompressor,
    SketchCursor,
    SketchedEstimator,
    SparsifiedCov,
    SparsifiedKMeans,
    SparsifiedMean,
    SparsifiedPCA,
    as_key,
)
from repro.api.fused import SharedSketchRun, fit_many, restore_run  # noqa: F401
from repro.api.plan import BACKENDS, Plan  # noqa: F401


def make_engine(plan: Plan, p: int, key, source, *, track_cov: bool = True,
                kmeans=None):
    """Construct a :class:`repro.stream.StreamEngine` from a Plan.

    The engine is the fused one-pass runner (moments + streaming K-means over
    one sketch of each batch); backends "stream" (no mesh, shards folded
    sequentially) and "sharded" (shard_map over ``plan.resolve_mesh()``) apply.
    """
    from repro import cluster
    from repro.stream import StreamEngine

    if plan.backend not in ("stream", "sharded"):
        raise ValueError(
            f'make_engine needs backend "stream" or "sharded", got {plan.backend!r}; '
            "for in-memory data use the estimator classes directly")
    if plan.cov_path == "lowrank" and plan.lowrank_method == "fd":
        raise ValueError(
            "the engine's low-rank path psums the linear range-finder delta; "
            "lowrank_method='fd' (order-dependent shrink) is estimator-layer "
            "only — use the SparsifiedPCA classes, or lowrank_method='range'")
    spec = plan.spec(p, as_key(key))
    mesh = None
    if plan.backend == "sharded":
        # multi-process runs need the process-contiguous mesh, whatever the
        # Plan's auto-mesh would build locally (same rule as the estimators)
        mesh = (cluster.process_mesh(plan.n_shards, plan.axis)
                if cluster.is_multiprocess() else plan.resolve_mesh())
    return StreamEngine(spec, source, n_shards=plan.n_shards, mesh=mesh,
                        axis=plan.axis, track_cov=track_cov, kmeans=kmeans,
                        impl=plan.impl, cov_path=plan.cov_path, rank=plan.rank)
