"""The execution :class:`Plan` — one config object selecting how a sketch job runs.

A Plan captures everything about *how* an estimator executes — backend
(in-memory batch, constant-memory streaming accumulators, or shard_map
collectives), kernel choice, batch geometry, mesh — and nothing about *what*
is estimated (that's the estimator class) or the randomness (that's the key
handed to ``fit``). Flipping ``backend`` re-runs the same job on a different
execution engine with tolerance-identical results, because every backend folds
the same per-(step, shard) sketches under the shared
:func:`repro.core.sketch.batch_key` discipline.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Literal

import jax

from repro.core import ros, sketch

Backend = Literal["batch", "stream", "sharded"]

BACKENDS: tuple[str, ...] = ("batch", "stream", "sharded")


@dataclasses.dataclass(frozen=True)
class Plan:
    """How a sketched-estimation job executes.

    backend:    "batch"   — sketch everything, then one-shot ``repro.core``
                            estimators on the concatenated sketch;
                "stream"  — fold per-batch accumulator deltas
                            (``repro.stream.accumulators``), constant memory
                            for the moment estimators;
                "sharded" — reduce via the ``repro.stream.sharded`` shard_map
                            collectives over ``mesh`` (one psum of the
                            fixed-size accumulator per reduction).
    gamma / m:  sketch size — fraction kept (validated to (0, 1]) or absolute
                coordinate count; exactly one is required.
    transform:  ROS preconditioner ("hadamard" or "dct").
    impl:       Hadamard kernel choice forwarded to ``ros.precondition``
                ("auto" = Pallas kernel on TPU, jnp butterfly elsewhere).
    batch_size: rows per (step, shard) batch. fit/partial_fit consume their
                input in consecutive chunks of this size; chunk j is keyed
                (step = j // n_shards, shard = j % n_shards), so every backend
                sees identical per-batch masks.
    n_shards:   logical shards per step (the shard axis of the key discipline).
    axis:       mesh axis name for the sharded backend.
    mesh:       jax Mesh for the sharded backend; None auto-builds a
                (n_shards,)-device mesh at first use.
    cov_path:   covariance delta path — "dense" (scatter to (b, p), one MXU
                matmul), "compact" (scatter b·m² outer products; the γ ≪ 1
                memory fix — no dense (b, p) intermediate), or "lowrank"
                (repro.lowrank: O(rank·p) spectral accumulators for PCA-only
                consumers — the (p, p) accumulator itself disappears).
    rank:       sketch width l of the low-rank path (required when
                cov_path="lowrank"; the finalized eigenmodel holds l/2
                eigenpairs under the default "range" method, all l under "fd").
    lowrank_method: "range" — randomized range-finder / co-occurrence state,
                linear so its (p, l) delta psums across shards (the default);
                "fd" — Frequent Directions, deterministic guarantee but a
                sequential (order-dependent) fold.
    refine_passes: default number of second-pass replay refinements for
                ``fit_refine`` / ``fit_many(refine=True)`` (repro.refine: PCA
                power iteration on the lowrank-range path, two-pass Alg.-2
                K-means for the minibatch fold). 0 = plain one-pass fits;
                ``fit_refine`` with no explicit ``passes`` then runs 1.
    dtype:      input rows are cast to this before sketching.
    """

    backend: Backend = "batch"
    gamma: float | None = None
    m: int | None = None
    transform: ros.Transform = "hadamard"
    impl: str = "auto"
    batch_size: int = 4096
    n_shards: int = 1
    axis: str = "data"
    mesh: Any | None = None
    cov_path: Literal["dense", "compact", "lowrank"] = "dense"
    rank: int | None = None
    lowrank_method: Literal["range", "fd"] = "range"
    refine_passes: int = 0
    dtype: Any = "float32"

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {self.backend!r}")
        if self.cov_path not in ("dense", "compact", "lowrank"):
            raise ValueError(
                f"cov_path must be 'dense', 'compact' or 'lowrank', got {self.cov_path!r}")
        if self.lowrank_method not in ("range", "fd"):
            raise ValueError(
                f"lowrank_method must be 'range' or 'fd', got {self.lowrank_method!r}")
        if self.cov_path == "lowrank":
            if self.rank is None or self.rank < 2:
                raise ValueError(
                    f"cov_path='lowrank' needs rank >= 2 (the l of the (l, p) "
                    f"sketch), got rank={self.rank}")
        elif self.rank is not None:
            raise ValueError("rank= only applies to cov_path='lowrank'")
        if self.refine_passes < 0:
            raise ValueError(f"refine_passes must be >= 0, got {self.refine_passes}")
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {self.batch_size}")
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.mesh is not None and self.mesh.shape[self.axis] != self.n_shards:
            raise ValueError(
                f"mesh axis {self.axis!r} has size {self.mesh.shape[self.axis]}, "
                f"need n_shards={self.n_shards}")

    # ------------------------------------------------------------- helpers --

    def replace(self, **kw) -> "Plan":
        """A copy with fields overridden — e.g. ``plan.replace(backend="sharded")``."""
        return dataclasses.replace(self, **kw)

    def spec(self, p: int, key: jax.Array) -> sketch.SketchSpec:
        """The SketchSpec this plan induces at dimensionality ``p``."""
        return sketch.make_spec(p, key, gamma=self.gamma, m=self.m,
                                transform=self.transform)

    def resolve_mesh(self):
        """The mesh for the sharded backend (auto-built over n_shards devices).

        Auto-built meshes are cached per (n_shards, axis): repeated fits (and
        the per-step streaming reducer) then reuse one mesh object, so the
        compiled shard_map reductions keyed on it stay cached too.
        """
        if self.mesh is not None:
            return self.mesh
        if len(jax.devices()) < self.n_shards:
            raise ValueError(
                f"sharded backend needs {self.n_shards} devices for axis "
                f"{self.axis!r}, have {len(jax.devices())}; pass mesh= or lower n_shards")
        return _auto_mesh(self.n_shards, self.axis)

    def step_shard(self, chunk: int) -> tuple[int, int]:
        """Map a linear chunk index to its (step, shard) key coordinates."""
        return divmod(chunk, self.n_shards)


@functools.lru_cache(maxsize=None)
def _auto_mesh(n_shards: int, axis: str):
    return jax.make_mesh((n_shards,), (axis,))


# ----------------------------------------------------------- mesh (de)spec --
# A Mesh object is process-local (it holds live Device handles), but its
# GEOMETRY is not: (axis names, axis sizes) fully determine an equivalent
# mesh on any host with enough devices. Snapshots (repro.sketchserve) and
# checkpoints serialize the spec and rebuild the mesh on restore.


def mesh_spec(mesh) -> dict | None:
    """The JSON-safe geometry of a mesh: ``{"axis_names", "shape"}``.
    None stays None (auto-built meshes need no spec)."""
    if mesh is None:
        return None
    return {"axis_names": list(mesh.axis_names),
            "shape": [int(mesh.shape[a]) for a in mesh.axis_names]}


def mesh_from_spec(spec: dict | None):
    """Rebuild a mesh with the same geometry on THIS host's devices (raises
    if the host has too few)."""
    if spec is None:
        return None
    return jax.make_mesh(tuple(spec["shape"]), tuple(spec["axis_names"]))
