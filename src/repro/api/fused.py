"""fit_many — ONE compression pass feeds every consumer (the paper's pitch, §I).

Fitting ``SparsifiedPCA`` and ``SparsifiedKMeans`` separately on the same
:class:`Plan` sketches the data twice; :func:`fit_many` registers every
consumer on one shared :class:`~repro.api.estimators.SketchCursor`, so each
per-(step, shard) sketch is computed exactly once and folded into every
consumer's accumulator. Because the consumers are pure folders and the shared
cursor derives the SAME spec (same key) and the SAME per-chunk mask keys that
each consumer's lone ``fit`` would, ``fit_many`` reproduces the separate fits
exactly — on every backend (tests/test_api.py asserts ≤1e-5) — while doing a
single pass of ``sketch_mod.sketch`` per chunk.

Under ``backend="stream" | "sharded"`` this is the StreamEngine's fused
moment+K-means pass surfaced through the estimator API: moments fold into
constant-memory accumulators (sharded: one psum of the fixed-size per-step
delta — nothing is retained past its step), minibatch K-means folds the
engine's per-step summed deltas, and only Lloyd K-means retains the
γ-compressed sketch it clusters at finalize (Alg. 1's defining feature).

    from repro.api import Plan, SparsifiedKMeans, SparsifiedPCA, fit_many

    plan = Plan(backend="stream", gamma=0.05, batch_size=4096)
    pca = SparsifiedPCA(8, plan, key=0)
    km = SparsifiedKMeans(10, plan, key=0)
    run = fit_many(plan, [pca, km], x)      # one sketch pass, both fitted
    pca.components_; km.centers_            # identical to separate fits
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

from repro.api.estimators import SketchCursor, SketchedEstimator, as_key
from repro.api.plan import Plan
from repro.core import sketch as sketch_mod
from repro import refine as refine_mod
from repro.train import checkpoint

# Plan fields that determine WHAT the shared sketch is (spec + chunk→key
# mapping). Consumers must agree with the driving plan on these; the backend —
# and the fold choices cov_path / rank / lowrank_method, so an O(rank·p)
# lowrank PCA and a full dense covariance can ride ONE pass — may differ per
# consumer: they are pure fold/execution choices (tests/test_lowrank.py).
SKETCH_FIELDS = ("gamma", "m", "transform", "impl", "batch_size", "n_shards",
                 "dtype")


@dataclasses.dataclass
class SharedSketchRun:
    """Handle over one shared compression pass and its fitted consumers.

    Iterable/indexable like the consumer sequence passed to :func:`fit_many`.
    ``partial_fit`` + ``finalize`` extend the SAME pass (every consumer folds
    the new chunks' sketches once more), mirroring the estimator contract.
    """

    consumers: tuple[SketchedEstimator, ...]
    cursor: SketchCursor

    @property
    def spec(self) -> sketch_mod.SketchSpec:
        return self.cursor.spec

    @property
    def count(self) -> int:
        """Rows folded through the shared pass."""
        return self.cursor.count

    @property
    def n_sketches(self) -> int:
        """sketch() invocations — one per (step, shard) chunk, NOT per consumer."""
        return self.cursor.n_sketches

    def __iter__(self) -> Iterator[SketchedEstimator]:
        return iter(self.consumers)

    def __getitem__(self, i: int) -> SketchedEstimator:
        return self.consumers[i]

    def __len__(self) -> int:
        return len(self.consumers)

    def partial_fit(self, x) -> "SharedSketchRun":
        self.cursor.partial_fit(x)
        return self

    def sync(self) -> "SharedSketchRun":
        """Block until the shared pass's last sketch is materialized (the
        public ingest barrier — what api_bench times)."""
        self.cursor.sync()
        return self

    def finalize(self) -> "SharedSketchRun":
        for c in self.consumers:
            if c in self.cursor.consumers:  # skip consumers detached by reset()
                c.finalize()
        return self

    def checkpoint(self, ckpt_dir: str, *, keep_last: int = 3) -> "SharedSketchRun":
        """Checkpoint the shared pass — every consumer's fold state (the
        EngineState protocol wire format, ``SketchedEstimator.state_arrays``)
        plus the ONE shared cursor, atomically via ``train.checkpoint``.
        :func:`restore_run` resumes the pass bit-identically."""
        cur = self.cursor
        if cur.spec is None:
            raise RuntimeError("nothing folded yet — nothing to checkpoint")
        arrays: dict = {}
        for i, c in enumerate(self.consumers):
            for name, v in c.state_arrays().items():
                arrays[f"c{i}/{name}"] = np.asarray(v)
        extra = {"format": "fused-run-v1", "n_consumers": len(self.consumers),
                 "p": int(cur.spec.p), "chunk": cur.chunk, "count": cur.count,
                 "n_sketches": cur.n_sketches,
                 "chunk_rows": list(cur.chunk_rows)}
        checkpoint.save_arrays(ckpt_dir, cur.chunk, arrays, extra=extra,
                               keep_last=keep_last)
        return self


def restore_run(ckpt_dir: str, plan: Plan,
                consumers: Sequence[SketchedEstimator]) -> SharedSketchRun:
    """Rebuild a :class:`SharedSketchRun` from its latest checkpoint.

    ``consumers`` are freshly constructed estimators in the same order (and
    with the same plans/keys) as the checkpointed run's — the checkpoint holds
    fold STATE, not constructors. The restored run continues the interrupted
    pass bit-identically: the shared cursor resumes at the saved chunk index,
    so the next ``partial_fit`` folds under the very (step, shard) mask keys
    the uninterrupted pass would have used.
    """
    arrays, extra = checkpoint.load_arrays(ckpt_dir)
    if extra.get("format") != "fused-run-v1":
        raise ValueError(f"{ckpt_dir} is not a fused-run checkpoint "
                         f"(format={extra.get('format')!r})")
    consumers = tuple(consumers)
    if len(consumers) != int(extra["n_consumers"]):
        raise ValueError(f"checkpoint holds {extra['n_consumers']} consumers, "
                         f"got {len(consumers)}")
    key0 = as_key(consumers[0].key)
    for i, c in enumerate(consumers):
        _check_consumer(plan, c, i, key0)
    cursor = SketchCursor(plan, key0)
    for c in consumers:
        c.reset()
        c._cursor = cursor
        cursor.register(c)
    cursor.ensure_spec(int(extra["p"]))
    for i, c in enumerate(consumers):
        prefix = f"c{i}/"
        sub = {k[len(prefix):]: v for k, v in arrays.items()
               if k.startswith(prefix)}
        c.load_state_arrays(sub)
    cursor.chunk = int(extra["chunk"])
    cursor.count = int(extra["count"])
    cursor.n_sketches = int(extra["n_sketches"])
    cursor.chunk_rows = [int(r) for r in extra["chunk_rows"]]
    return SharedSketchRun(consumers, cursor)


def _check_consumer(plan: Plan, c: SketchedEstimator, i: int, key0) -> None:
    for f in SKETCH_FIELDS:
        mine, theirs = getattr(plan, f), getattr(c.plan, f)
        if f == "dtype":
            mine, theirs = np.dtype(mine), np.dtype(theirs)  # "float32" == jnp.float32
        if mine != theirs:
            raise ValueError(
                f"consumers[{i}] ({type(c).__name__}) was built with "
                f"plan.{f}={theirs!r}, but the shared pass uses {f}={mine!r}; "
                "a shared sketch requires every consumer to agree on the "
                f"sketch geometry fields {SKETCH_FIELDS}")
    if not np.array_equal(np.asarray(key0), np.asarray(c.key)):
        raise ValueError(
            f"consumers[{i}] ({type(c).__name__}) holds a different key than "
            "consumers[0] — a shared sketch means shared randomness; construct "
            "every consumer with the same key")


def fit_many(plan: Plan, consumers: Sequence[SketchedEstimator], data=None, *,
             source=None, steps: int | None = None, seed: int | None = None,
             finalize: bool = True, refine: bool | int = False,
             scan: bool = False) -> SharedSketchRun:
    """Fit every consumer from ONE ``source → sketch → fan-out`` pass.

    Parameters
    ----------
    plan: the shared execution plan. Every consumer's plan must agree with it
        on the sketch geometry fields (:data:`SKETCH_FIELDS`); backends may
        differ per consumer (each reducer folds its own way — the sketches are
        backend-independent).
    consumers: estimator instances, all constructed with the SAME key (shared
        sketch ⇒ shared randomness). They are reset, registered on one shared
        :class:`SketchCursor`, fed, and finalized in place.
    data: in-memory ``(rows, p)`` array, consumed in ``plan.batch_size``
        chunks — exactly like ``estimator.fit``. Mutually exclusive with
        ``source``.
    source / steps / seed: a ``(seed, step, shard) → (b, p)`` stream source
        (the StreamEngine contract) pulled for ``steps`` steps ×
        ``plan.n_shards`` shards — exactly like ``estimator.fit_stream``.
    finalize: pass False to stop after ingest (e.g. to keep feeding via
        ``run.partial_fit``); call ``run.finalize()`` when done.
    refine: run second-pass replay refinement (``repro.refine``) after
        finalize on every consumer that supports it — PCA power iteration on
        the lowrank-range path, two-pass (Alg. 2) minibatch K-means. ``True``
        uses ``plan.refine_passes`` (or 1); an int overrides the pass count.
        Each replay pass regenerates every (step, shard) sketch ONCE and fans
        it out to all refiners — the shared-cursor discipline applied to
        refinement, so one shared-sketch run feeds both refiners. Requires
        ``finalize=True`` (refinement replays a finalized first pass).
    scan: drive in-memory ingest through ONE jitted ``lax.scan`` over full
        (step × n_shards) blocks instead of the per-chunk host loop (mirrors
        ``StreamEngine.run_scanned``) — same sketches, same fold order, results
        match the host loop to float-summation reordering (which is why it is
        opt-in rather than the default). Requires ``data`` (a source pull is
        host-driven by nature) and consumers whose folds run inside a scan:
        stream-backend moments, lowrank PCA (non-sharded range / any-backend
        fd), and minibatch K-means; batch moments, Lloyd K-means, and sharded
        shard_map reductions raise.

    Returns the :class:`SharedSketchRun`; the fitted attributes live on the
    consumer objects themselves, identical (≤1e-5) to what separate ``fit``
    calls would produce — but the data was compressed once, not once per
    consumer.
    """
    consumers = tuple(consumers)
    if not consumers:
        raise ValueError("fit_many needs at least one consumer")
    if (data is None) == (source is None):
        raise ValueError("provide exactly one of data or source=")
    if source is not None and steps is None:
        raise ValueError("source= needs steps=")
    if refine and not finalize:
        raise ValueError("refine= replays a FINALIZED first pass; drop "
                         "finalize=False (or refine later via estimator.refine)")
    for i, c in enumerate(consumers):
        if not isinstance(c, SketchedEstimator):
            raise TypeError(f"consumers[{i}] is {type(c).__name__}, expected a "
                            "SketchedEstimator (SparsifiedMean/Cov/PCA/KMeans)")
    key0 = as_key(consumers[0].key)
    for i, c in enumerate(consumers):
        _check_consumer(plan, c, i, key0)
    refiners: tuple[SketchedEstimator, ...] = ()
    if refine:
        refiners = tuple(c for c in consumers if c._refine_supported())
        if not refiners:
            raise ValueError(
                "refine= given but no consumer supports second-pass "
                "refinement (SparsifiedPCA with cov_path='lowrank'/"
                "lowrank_method='range', or minibatch SparsifiedKMeans)")

    cursor = SketchCursor(plan, key0)
    for c in consumers:
        c.reset()
        c._cursor = cursor      # adopt the shared pass (reset() detaches again)
        cursor.register(c)
    if scan:
        if data is None:
            raise ValueError("scan=True stages in-memory data for lax.scan; "
                             "source= ingest is host-driven — drop scan=True")
        if cursor.scan_descs() is None:
            raise ValueError(
                "scan=True but a consumer cannot fold inside lax.scan "
                "(batch-backend moments, Lloyd K-means, and sharded shard_map "
                "reductions are host-loop only); drop scan=True or switch "
                "those consumers to stream/minibatch/lowrank folds")
        cursor.scan = True

    src = None
    if data is not None:
        cursor.partial_fit(data)
    else:
        from repro.stream.engine import normalize_source

        src = normalize_source(source)
        cursor.fold_source(src, steps, seed)

    run = SharedSketchRun(consumers, cursor)
    if not finalize:
        return run
    run.finalize()
    if refiners:
        passes = (plan.refine_passes or 1) if refine is True else int(refine)
        refine_mod.run_refine(plan, cursor.spec, refiners, passes, data=data,
                              source=src, steps=steps, seed=seed,
                              chunk_rows=(list(cursor.chunk_rows)
                                          if data is not None else None))
    return run
