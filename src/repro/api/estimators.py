"""Estimator classes: fit / partial_fit / finalize over any :class:`Plan` backend.

One compression operator feeding many consumers (the paper's pitch) as one
class family: a :class:`SketchCursor` owns the ``source → sketch`` pass —
it consumes input in consecutive ``plan.batch_size`` chunks, keys chunk j's
mask with ``sketch.batch_key(spec, step=j // n_shards, shard=j % n_shards)``,
sketches each chunk EXACTLY ONCE, and fans the sketch out to every registered
consumer. Estimators are pure folders: ``_fold_sketch(s, step, shard)`` is
their only ingest point, so a lone ``fit()`` is just the one-consumer special
case of :func:`repro.api.fit_many`'s shared pass. Each consumer's reducer then
hands the folds to its plan's backend —

- ``batch``:   keep the (γ·dense) sketch, one-shot ``repro.core`` estimators;
- ``stream``:  fold constant-memory accumulator deltas
               (``repro.stream.accumulators``) batch by batch;
- ``sharded``: reduce with the ``repro.stream.sharded`` shard_map collectives
               (one psum of the fixed-size accumulator over the mesh).

Because all three fold the *same* per-(step, shard) sketches, results agree to
float-summation reordering (tests/test_api.py asserts 1e-5) — the backend is a
pure execution choice.

Fitted attributes follow the sklearn trailing-underscore convention; estimates
come back in the ORIGINAL domain (eigenvectors / means / centers unmixed by
(HD)ᵀ) unless noted.
"""
from __future__ import annotations

import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import BACKENDS, Plan
from repro.core import estimators as est
from repro.core import ros
from repro.core import kmeans as km
from repro.core import pca as pca_mod
from repro.core import sketch as sketch_mod
from repro.core.grad_compress import CompressConfig, compress_grads, mask_spec
from repro.core.sampling import SparseRows
from repro.core.sketch import batch_key
from repro import lowrank as lowrank_mod
from repro import refine as refine_mod
from repro.stream import accumulators as acc
from repro.stream import sharded as sharded_mod
from repro.stream import state as state_mod
from repro.train import checkpoint as checkpoint_mod
from repro.utils.prng import fold_in_str


def as_key(key: jax.Array | int) -> jax.Array:
    """Accept an int seed or a PRNGKey — the one key-normalization point."""
    if isinstance(key, (int,)):
        return jax.random.PRNGKey(key)
    return key


# ------------------------------------------------------------ moment core ---
# The backend registry: one reduce function per Plan.backend, each mapping a
# reducer's folded state to (mean_pre, cov_pre | None, count) through the
# pre-existing implementation it wraps — core one-shot estimators,
# stream accumulators, or the stream.sharded shard_map collectives.

MOMENT_BACKENDS: dict[str, "callable"] = {}


def _is_multiprocess() -> bool:
    """True under a live multi-process jax.distributed runtime (lazy import —
    repro.cluster is only touched when a cluster actually exists)."""
    if jax.process_count() <= 1:
        return False
    return True


def _sharded_mesh(plan: Plan):
    """The mesh the sharded backend reduces over: plan.resolve_mesh() on a
    single host; under a multi-process runtime the process-contiguous
    repro.cluster mesh (each process's devices own a contiguous block of
    shard positions — what per-host global-array assembly requires)."""
    if _is_multiprocess():
        from repro import cluster

        return cluster.process_mesh(plan.n_shards, plan.axis)
    return plan.resolve_mesh()


def _moment_backend(name: str):
    def register(fn):
        MOMENT_BACKENDS[name] = fn
        return fn
    return register


@_moment_backend("batch")
def _reduce_batch(r: "_MomentReducer"):
    s_all = r.concat()
    mean = est.mean_estimator(s_all)
    cov = (est.cov_estimator(s_all, path=r.plan.cov_path) if r.track_cov else None)
    return mean, cov, jnp.int32(s_all.n)


@_moment_backend("stream")
def _reduce_stream(r: "_MomentReducer"):
    st = r.state
    if int(st.count) == 0:
        raise RuntimeError("no batches folded yet — call fit()/partial_fit() first")
    cov = acc.moment_finalize_cov(st, r.spec.m) if r.track_cov else None
    return acc.moment_finalize_mean(st, r.spec.m), cov, st.count


@_moment_backend("sharded")
def _reduce_sharded(r: "_MomentReducer"):
    r.flush_step()  # a trailing partial step still needs its psum
    st = r.state
    if int(st.count) == 0:
        raise RuntimeError("no batches folded yet — call fit()/partial_fit() first")
    cov = acc.moment_finalize_cov(st, r.spec.m) if r.track_cov else None
    return acc.moment_finalize_mean(st, r.spec.m), cov, st.count


assert set(MOMENT_BACKENDS) == set(BACKENDS), "registry out of sync with Plan.BACKENDS"


class _MomentReducer:
    """Backend-dispatched reduction of sketched batches to (mean, cov, count).

    ``fold`` ingests one per-(step, shard) sketch; ``reduce`` dispatches
    through :data:`MOMENT_BACKENDS` for the Thm-4 / Thm-6 estimates.

    Only the "batch" backend (and Lloyd K-means, which passes
    ``keep_sketch=True`` on every backend because Alg. 1 clusters the retained
    sketch) holds sketches past their step. "stream" folds each sketch into
    the constant-memory accumulator immediately; "sharded" buffers ONE step's
    shard sketches, reduces them with a single psum of the fixed-size delta
    (the StreamEngine's per-step discipline), and drops them — streaming
    per-step reduction, not concat()-then-reduce, so host memory stays
    constant in the stream length.
    """

    def __init__(self, plan: Plan, spec: sketch_mod.SketchSpec, track_cov: bool,
                 keep_sketch: bool = False, needs_moments: bool = True):
        self.plan, self.spec, self.track_cov = plan, spec, track_cov
        # the low-rank spectral path replaces the (p, p) accumulator with the
        # O(rank·p) repro.lowrank states — on EVERY backend (batch included:
        # sketches fold through the same per-chunk deltas instead of being
        # retained, which is the whole point of the path)
        self.lowrank = (plan.cov_path == "lowrank" and track_cov and needs_moments)
        self.keep_sketch = keep_sketch or (plan.backend == "batch" and needs_moments
                                           and not self.lowrank)
        self.parts: list[SparseRows] = []
        self._step_parts: list[SparseRows] = []  # sharded: the in-flight step
        self._mesh = None
        self._omega = None
        if self.lowrank:
            if plan.rank > spec.p_pad:
                raise ValueError(f"rank={plan.rank} exceeds p_pad={spec.p_pad}; "
                                 "a low-rank sketch must be narrower than p")
            if plan.lowrank_method == "range":
                self._omega = lowrank_mod.omega(spec.key, spec.p_pad, plan.rank)
                self.state = lowrank_mod.range_init(spec.p_pad, plan.rank)
            else:
                self.state = lowrank_mod.fd_init(spec.p_pad, plan.rank)
        else:
            # moment state only where reduce() will read it (K-means never does)
            self.state = (acc.moment_init(spec.p_pad, track_cov=track_cov)
                          if plan.backend in ("stream", "sharded") and needs_moments
                          else None)

    @property
    def _moment_cov_path(self) -> str:
        # stream_delta/sharded_moments only understand dense|compact; with the
        # lowrank path they are only ever called track_cov=False (mean-only)
        return "dense" if self.plan.cov_path == "lowrank" else self.plan.cov_path

    def fold(self, s: SparseRows, step: int, shard: int) -> None:
        if self.lowrank:
            if self.plan.lowrank_method == "fd":
                # FD shrink is order-dependent: fold in (step, shard) linear
                # order on every backend — backends agree bit-for-bit
                self.state = lowrank_mod.fd_update(self.state, s)
            elif self.plan.backend == "sharded":
                self._step_parts.append(s)
                if shard == self.plan.n_shards - 1:
                    self.flush_step()
            else:
                self.state = lowrank_mod.range_update(self.state, s, self._omega,
                                                      impl=self.plan.impl)
        elif self.state is not None:
            if self.plan.backend == "sharded":
                self._step_parts.append(s)
                if shard == self.plan.n_shards - 1:
                    self.flush_step()
            else:
                self.state = est.stream_update(self.state, s,
                                               cov_path=self._moment_cov_path)
        if self.keep_sketch:
            self.parts.append(s)

    def flush_step(self) -> None:
        """Sharded: reduce the buffered step with one psum'd delta, then drop it.

        Multi-process: each process buffered only ITS shards' sketches; they
        enter the same shard_map as this process's contiguous block of ONE
        global row-sharded array (repro.cluster.global_rows), and the psum
        reduces across hosts — every process must reach this flush once per
        step, in step order (the multiprocess fold_source loop guarantees it).
        """
        if not self._step_parts:
            return
        if self._mesh is None:
            self._mesh = _sharded_mesh(self.plan)
        step_sketch = self._assemble_step()
        if self.lowrank:
            delta = sharded_mod.sharded_lowrank(step_sketch, self._omega,
                                                self._mesh, (self.plan.axis,),
                                                impl=self.plan.impl)
            self.state = lowrank_mod.range_apply(self.state, delta)
        else:
            delta = sharded_mod.sharded_moments(
                step_sketch, self._mesh, (self.plan.axis,),
                track_cov=self.track_cov, cov_path=self._moment_cov_path)
            self.state = acc.moment_apply(self.state, delta)
        self._step_parts = []

    def _assemble_step(self) -> SparseRows:
        """The buffered step as one SparseRows: a plain host concat on a
        single host; under multi-process, the local shards' rows become this
        process's addressable block of a global row-sharded array."""
        if not _is_multiprocess():
            return _concat_sparse(self._step_parts, self.spec.p_pad)
        from repro import cluster

        vals = np.concatenate([np.asarray(s.values) for s in self._step_parts])
        idxs = np.concatenate([np.asarray(s.indices) for s in self._step_parts])
        return SparseRows(cluster.global_rows(vals, self._mesh, self.plan.axis),
                          cluster.global_rows(idxs, self._mesh, self.plan.axis),
                          self.spec.p_pad)

    def concat(self) -> SparseRows:
        if not self.parts:
            raise RuntimeError("no batches folded yet — call fit()/partial_fit() first")
        return _concat_sparse(self.parts, self.spec.p_pad)

    def reduce(self):
        """(mean_pre, cov_pre | LowRankCov | None, count) via the plan's backend."""
        if self.lowrank:
            return self._reduce_lowrank()
        return MOMENT_BACKENDS[self.plan.backend](self)

    def _reduce_lowrank(self):
        """Finalize the O(rank·p) spectral state — shared by all backends (they
        differ only in HOW the same linear deltas were reduced)."""
        self.flush_step()  # a trailing partial step still needs its psum
        st = self.state
        if int(st.count) == 0:
            raise RuntimeError("no batches folded yet — call fit()/partial_fit() first")
        if self.plan.lowrank_method == "range":
            return (lowrank_mod.range_finalize_mean(st, self.spec.m),
                    lowrank_mod.range_finalize(st, self.spec.m, self._omega),
                    st.count)
        return (lowrank_mod.fd_finalize_mean(st, self.spec.m),
                lowrank_mod.fd_finalize(st, self.spec.m), st.count)


def _concat_sparse(parts: list[SparseRows], p: int) -> SparseRows:
    return SparseRows(jnp.concatenate([s.values for s in parts]),
                      jnp.concatenate([s.indices for s in parts]), p)


# --------------------------------------------------------- scanned ingest ---
# The opt-in lax.scan hot loop (cursor.scan = True / fit_many(scan=True)):
# instead of one Python-dispatched sketch + fold round trip per chunk, the
# aligned full-step prefix of each partial_fit array is staged as
# (steps, n_shards, batch_size, p) and driven through ONE jitted scan whose
# body regenerates chunk (step, shard)'s mask key exactly as fold_rows does
# and applies the consumers' per-step fold semantics. This mirrors
# StreamEngine.run_scanned: same sketches, same fold order, so results match
# the host loop to float-summation reordering — but it is NOT bit-identical
# across backends the way the host loop is, which is why it stays opt-in.
#
# Consumers describe their in-scan fold with a small hashable descriptor
# (_scan_desc) so the compiled scan is shared across estimator instances via
# the lru_cache below; consumers whose fold cannot run inside a scan
# (retained sketches, shard_map reductions) return None and scan=True raises.


def _tree_sum(deltas):
    out = deltas[0]
    for d in deltas[1:]:
        out = jax.tree.map(jnp.add, out, d)
    return out


def _scan_step_fold(desc, plan: Plan):
    """desc → fold(carry, aux, step_sketches) -> (carry, y) for one scan step.

    Each fold replicates the corresponding host-loop semantics exactly:
    moment/range/fd fold the step's shard sketches in (step, shard) linear
    order; minibatch K-means takes every shard's delta against the step-start
    state, sums them, and applies once (the StreamEngine per-step discipline).
    """
    kind = desc[0]
    if kind == "moment":
        cov_path = desc[1]

        def fold(carry, aux, sketches):
            for s in sketches:
                carry = est.stream_update(carry, s, cov_path=cov_path)
            return carry, jnp.zeros((), jnp.int32)
    elif kind == "range":
        def fold(carry, aux, sketches):
            for s in sketches:
                carry = lowrank_mod.range_update(carry, s, aux, impl=plan.impl)
            return carry, jnp.zeros((), jnp.int32)
    elif kind == "fd":
        def fold(carry, aux, sketches):
            for s in sketches:
                carry = lowrank_mod.fd_update(carry, s)
            return carry, jnp.zeros((), jnp.int32)
    elif kind == "kmeans":
        track, decay = desc[1], desc[2]

        def fold(carry, aux, sketches):
            if track:
                pairs = [acc.kmeans_delta_with_assign(carry, s) for s in sketches]
                new = acc.kmeans_apply(carry, _tree_sum([d for d, _ in pairs]),
                                       decay=decay)
                counts = _tree_sum([acc.kmeans_reassigned(new, s, a0)
                                    for s, (_, a0) in zip(sketches, pairs)])
                return new, counts
            new = acc.kmeans_apply(
                carry, _tree_sum([acc.kmeans_delta(carry, s) for s in sketches]),
                decay=decay)
            return new, jnp.zeros((), jnp.int32)
    else:  # pragma: no cover - descriptors come from _scan_desc
        raise ValueError(f"unknown scan descriptor {desc!r}")
    return fold


@functools.lru_cache(maxsize=None)
def _build_scan_fn(plan: Plan, p: int, m: int, transform: str, impl: str,
                   descs: tuple):
    """The jitted scan over full (step × n_shards) blocks, cached on the
    static description so repeated fit_many calls (and benchmark loops) reuse
    one compilation per shape."""
    n_shards = plan.n_shards
    folds = tuple(_scan_step_fold(d, plan) for d in descs)

    @jax.jit
    def scan_all(carries, auxes, xs, step0, signs_key, mask_key):
        def body(carry, inp):
            t, x_step = inp
            step = step0 + t
            sketches = [
                sketch_mod._sketch_impl(
                    x_step[sh], signs_key,
                    jax.random.fold_in(jax.random.fold_in(mask_key, step), sh),
                    p, m, transform, impl)
                for sh in range(n_shards)
            ]
            new, ys = [], []
            for c, aux, fold in zip(carry, auxes, folds):
                nc, y = fold(c, aux, sketches)
                new.append(nc)
                ys.append(y)
            return tuple(new), tuple(ys)

        steps = xs.shape[0]
        return jax.lax.scan(body, carries,
                            (jnp.arange(steps, dtype=jnp.int32), xs))

    return scan_all


# ------------------------------------------------------------ the cursor ----


class SketchCursor:
    """The shared ``source → sketch`` pass: ONE sketch per (step, shard) chunk.

    The cursor owns everything sketching needs — spec derivation from
    (plan, key), the chunk counter mapping consecutive ``plan.batch_size``
    chunks to (step, shard) mask keys, and the ``sketch_mod.sketch`` call —
    and fans each sketch out to every registered consumer's ``_fold_sketch``.
    A lone estimator owns a one-consumer cursor; :func:`repro.api.fit_many`
    registers many consumers on one cursor, so a single compression pass feeds
    them all (the paper's pitch: compress once, answer every question).

    Thread-safety contract: ``partial_fit`` / ``fold_source`` hold an internal
    lock for the WHOLE call, so concurrent producers (e.g. several threads
    feeding one :class:`~repro.api.fused.SharedSketchRun`) serialize — each
    call folds atomically, chunk indices (hence (step, shard) mask keys) are
    assigned in lock-acquisition order, and counts stay exact. Which producer
    gets which chunk index is whatever the lock arbitration yields, so
    multi-producer results are run-to-run ordering-dependent (still valid
    estimates — every chunking is); a single producer (the
    ``repro.sketchserve`` worker loop, which funnels all ingest through one
    thread) stays fully deterministic. ``finalize``/``reduce`` are NOT
    guarded: quiesce producers (or go through the sketchserve queue, which
    orders queries after ingest) before reading fitted state.
    """

    def __init__(self, plan: Plan, key: jax.Array | int):
        self.plan = plan
        self.key = as_key(key)
        self._lock = threading.Lock()
        self.spec: sketch_mod.SketchSpec | None = None
        self.chunk = 0           # linear chunk index → plan.step_shard(chunk)
        self.count = 0           # rows folded through this cursor
        self.chunk_rows: list[int] = []  # rows per chunk — the replay contract
        self.n_sketches = 0      # sketch_mod.sketch invocations (one per chunk)
        self.last_sketch: SparseRows | None = None
        self.consumers: list["SketchedEstimator"] = []
        self.scan = False        # opt-in lax.scan hot loop for partial_fit
        self._scan_out = None    # last scan's carries — the sync() barrier

    def register(self, consumer: "SketchedEstimator") -> None:
        self.consumers.append(consumer)
        if self.spec is not None:
            consumer._bind_spec(self.spec)

    def ensure_spec(self, p: int) -> sketch_mod.SketchSpec:
        if self.spec is None:
            self.spec = self.plan.spec(p, self.key)
            for c in self.consumers:
                c._bind_spec(self.spec)
        elif self.spec.p != p:
            raise ValueError(
                f"batch has p={p}, but this pass was started with "
                f"p={self.spec.p}; start a new fit (estimator.fit/reset, or a "
                "fresh fit_many) to change dimensionality")
        return self.spec

    def fold_rows(self, rows: jax.Array) -> None:
        """Sketch one ≤batch_size chunk under its (step, shard) mask key and
        hand the SAME SparseRows to every consumer."""
        step, shard = self.plan.step_shard(self.chunk)
        s = sketch_mod.sketch(rows, self.spec,
                              batch_key=batch_key(self.spec, step, shard),
                              impl=self.plan.impl)
        self.n_sketches += 1
        self.last_sketch = s
        n = int(rows.shape[0])
        for c in self.consumers:
            c._consume(s, step, shard, n)
        self.chunk += 1
        self.count += n
        self.chunk_rows.append(n)

    def partial_fit(self, x) -> None:
        x = jnp.asarray(x)
        if x.ndim != 2:
            raise ValueError(f"expected (rows, p) data, got shape {x.shape}")
        x = x.astype(self.plan.dtype)
        with self._lock:  # concurrent producers serialize whole-call (see class doc)
            self.ensure_spec(x.shape[1])
            start = self._fold_rows_scanned(x) if self.scan else 0
            bs = self.plan.batch_size
            for i in range(start, x.shape[0], bs):
                self.fold_rows(x[i:i + bs])

    def scan_descs(self) -> tuple | None:
        """The consumers' in-scan fold descriptors, or None if any consumer
        cannot fold inside lax.scan (see SketchedEstimator._scan_desc)."""
        descs = tuple(c._scan_desc() for c in self.consumers)
        if not descs or any(d is None for d in descs):
            return None
        return descs

    def _fold_rows_scanned(self, x) -> int:
        """Fold the step-aligned full-step prefix of ``x`` through ONE jitted
        lax.scan (see _build_scan_fn) and return the rows consumed; the
        ordinary host loop takes the ragged tail. A cursor mid-step
        (chunk % n_shards != 0) folds everything on the host instead — the
        scan only ever starts at a step boundary so mask keys stay aligned."""
        plan, spec = self.plan, self.spec
        ns, bs = plan.n_shards, plan.batch_size
        if self.chunk % ns:
            return 0
        steps = x.shape[0] // (bs * ns)
        if steps == 0:
            return 0
        descs = self.scan_descs()
        if descs is None:
            raise ValueError(
                "scan=True but a registered consumer cannot fold inside "
                "lax.scan: batch-backend moment estimators and Lloyd K-means "
                "retain their sketches, and the sharded backend reduces "
                "through shard_map collectives — use the default host loop "
                "(scan=False) for those, or switch to stream/minibatch/"
                "lowrank folds")
        take = steps * ns * bs
        xs = x[:take].reshape(steps, ns, bs, x.shape[1])
        step0 = self.chunk // ns
        for c in self.consumers:
            c._scan_prepare(self, xs, step0)
        scan_fn = _build_scan_fn(plan, spec.p, spec.m, spec.transform,
                                 ros.resolve_impl(plan.impl), descs)
        carries = tuple(c._scan_carry() for c in self.consumers)
        auxes = tuple(c._scan_aux() for c in self.consumers)
        new_carries, ys = scan_fn(carries, auxes, xs, jnp.int32(step0),
                                  spec.signs_key(), spec.mask_key())
        for c, nc, y in zip(self.consumers, new_carries, ys):
            c._scan_absorb(nc, y, steps, ns * bs)
        self.chunk += steps * ns
        self.count += take
        self.chunk_rows.extend([bs] * (steps * ns))
        self.n_sketches += steps * ns
        self.last_sketch = None  # the scan never materializes its sketches
        self._scan_out = new_carries
        return take

    def sync(self) -> None:
        """Block until the last folded chunk's sketch is materialized — the
        public ingest barrier (benchmarks time ingest against this, not
        against private reducer state). After a scanned fold the barrier is
        the scan's output carries (no per-chunk sketch ever materializes)."""
        if self.last_sketch is not None:
            jax.block_until_ready((self.last_sketch.values, self.last_sketch.indices))
        if self._scan_out is not None:
            jax.block_until_ready(self._scan_out)

    def fold_source(self, source, steps: int, seed: int | None = None) -> None:
        """One pass over a normalized ``(seed, step, shard) → (b, p)`` source
        (the StreamEngine contract): each (step, shard) batch is folded under
        exactly that (step, shard) mask key.

        Under a multi-process runtime with the sharded backend, each process
        generates and sketches ONLY the shards it owns (the regenerable-source
        contract makes "distribute the stream" exactly that); the per-step
        shard_map reduction then psums across hosts.
        """
        with self._lock:  # concurrent producers serialize whole-call (see class doc)
            if _is_multiprocess() and self.plan.backend == "sharded":
                self._fold_source_multiprocess(source, steps, seed)
                return
            for step in range(steps):
                for shard in range(self.plan.n_shards):
                    rows = jnp.asarray(source(seed, step, shard)).astype(self.plan.dtype)
                    self.ensure_spec(rows.shape[1])
                    self.fold_rows(rows)

    def _fold_source_multiprocess(self, source, steps: int,
                                  seed: int | None) -> None:
        """The per-host slice of the shared (step, shard) grid: fold the
        shards this process owns, skip the rest (their chunk indices still
        advance — the mask-key discipline is global), and drive every
        consumer's step flush so all processes enter each step's collective
        reduction exactly once, in step order."""
        from repro import cluster

        for i, c in enumerate(self.consumers):
            why = c._multiprocess_unsupported()
            if why:
                raise ValueError(
                    f"consumers[{i}] ({type(c).__name__}) cannot fold under a "
                    f"multi-process runtime: {why}")
        mesh = _sharded_mesh(self.plan)
        mine = set(cluster.local_shards(mesh, self.plan.axis))
        if not mine:
            raise ValueError(f"process {jax.process_index()} owns no shards — "
                             "shrink n_shards or the process count")
        # data-dependent inits (minibatch K-means' K-means++ seeding) must be
        # bit-identical on every process: all of them sketch chunk (0, 0)
        # (replicated host compute) before any per-host folding starts.
        rows0 = None
        for c in self.consumers:
            if c._needs_first_sketch():
                if rows0 is None:
                    rows0 = jnp.asarray(source(seed, 0, 0)).astype(self.plan.dtype)
                    self.ensure_spec(rows0.shape[1])
                    s0 = sketch_mod.sketch(
                        rows0, self.spec, batch_key=batch_key(self.spec, 0, 0),
                        impl=self.plan.impl)
                c._seed_first_sketch(s0)
        for step in range(steps):
            for shard in range(self.plan.n_shards):
                if shard in mine:
                    rows = jnp.asarray(source(seed, step, shard)).astype(self.plan.dtype)
                    self.ensure_spec(rows.shape[1])
                    self.fold_rows(rows)
                else:
                    # the chunk happened — on another host. Mask keys are a
                    # pure function of the chunk index, so it must advance;
                    # rows-per-chunk is unknown here (0 = not locally held).
                    self.chunk += 1
                    self.chunk_rows.append(0)
            for c in self.consumers:
                c._step_flush()


# -------------------------------------------------------------- base class --


class SketchedEstimator:
    """Shared fit / partial_fit / finalize plumbing — a pure sketch FOLDER.

    Sketching itself lives in :class:`SketchCursor`; the estimator's only
    ingest point is ``_fold_sketch(s, step, shard)``, called by whichever
    cursor it is registered on (its own by default, a shared one under
    :func:`repro.api.fit_many`). Subclasses set ``_track_cov`` /
    ``_keep_sketch`` and implement ``_finalize()`` from the reducer.
    ``fit(X)`` = reset → partial_fit(X) → finalize; ``partial_fit`` may be
    called any number of times with (rows, p) arrays (each call consumes its
    input in ``plan.batch_size`` chunks, so a stream fed in batch_size pieces
    reproduces ``fit`` of the concatenation exactly); ``finalize()`` computes
    the fitted attributes and returns self.
    """

    _track_cov = False
    _keep_sketch = False
    _needs_moments = True  # False when _finalize never calls reducer.reduce()

    def __init__(self, plan: Plan, key: jax.Array | int = 0):
        self.plan = plan
        self.key = as_key(key)
        self.reset()

    # ------------------------------------------------------------ lifecycle --

    def reset(self) -> "SketchedEstimator":
        """Drop all folded state (spec is re-derived at the next first batch).

        Also detaches from any shared cursor — the old cursor stops fanning
        sketches into this estimator and a fresh one-consumer cursor takes
        over, so a still-live SharedSketchRun can't fold into reset state.
        """
        old = getattr(self, "_cursor", None)
        if old is not None and self in old.consumers:
            old.consumers.remove(self)
        self.spec_: sketch_mod.SketchSpec | None = None
        self._reducer: _MomentReducer | None = None
        self.count_ = 0
        self._fitted = False
        self._cursor = SketchCursor(self.plan, self.key)
        self._cursor.register(self)
        return self

    def _bind_spec(self, spec: sketch_mod.SketchSpec) -> None:
        """Cursor callback: the spec exists — allocate the reducer."""
        self.spec_ = spec
        self._reducer = _MomentReducer(self.plan, spec, self._track_cov,
                                       keep_sketch=self._keep_sketch,
                                       needs_moments=self._needs_moments)
        self._on_spec(spec)

    def _on_spec(self, spec: sketch_mod.SketchSpec) -> None:
        """Subclass hook: validate the spec once it exists (e.g. m >= 2)."""

    def partial_fit(self, x) -> "SketchedEstimator":
        """Fold more rows. Under a shared cursor (fit_many) this extends the
        shared pass — every co-registered consumer folds the same sketches."""
        self._cursor.partial_fit(x)
        return self

    def sync(self) -> "SketchedEstimator":
        """Block until this estimator's ingest (its cursor's last sketch) is
        materialized — for wall-clock measurements of the fold pass."""
        self._cursor.sync()
        return self

    def _consume(self, s: SparseRows, step: int, shard: int, n_rows: int) -> None:
        self._fold_sketch(s, step, shard)
        self.count_ += n_rows

    def _fold_sketch(self, s: SparseRows, step: int, shard: int) -> None:
        self._reducer.fold(s, step, shard)

    # --------------------------------------------------- multi-process fold --
    # Hooks for SketchCursor._fold_source_multiprocess: each process folds
    # only its own shards, so consumers must (a) reduce through per-step
    # collectives (sharded backend), (b) flush when the CURSOR says the step
    # ended (this process's last local shard is usually not shard
    # n_shards-1), and (c) run data-dependent inits from a sketch every
    # process regenerated identically.

    def _multiprocess_unsupported(self) -> str | None:
        """None when this consumer can fold under a multi-process runtime,
        else the reason it cannot."""
        if self.plan.backend != "sharded":
            return (f"backend={self.plan.backend!r} folds on the host — only "
                    "the sharded backend reduces across processes")
        if self._keep_sketch:
            return ("it retains its sketches (batch moments / Lloyd K-means); "
                    "a per-process buffer would hold only this host's shards")
        if (self.plan.cov_path == "lowrank" and self._track_cov
                and self._needs_moments and self.plan.lowrank_method == "fd"):
            return ("Frequent Directions is an order-dependent sequential "
                    "fold — its shrink cannot psum across processes")
        return None

    def _needs_first_sketch(self) -> bool:
        return False

    def _seed_first_sketch(self, s0: SparseRows) -> None:
        """Run a data-dependent init from chunk (0, 0)'s sketch (regenerated
        identically on every process)."""

    def _step_flush(self) -> None:
        """Cursor-driven step boundary: enter this step's collective
        reduction (exactly once per process per step)."""
        if self._reducer is not None:
            self._reducer.flush_step()

    # ------------------------------------------------------- scanned ingest --
    # Hooks for the cursor's opt-in lax.scan hot loop (cursor.scan = True /
    # fit_many(scan=True)). _scan_desc names the in-scan fold (a hashable
    # key into _scan_step_fold) or returns None when this consumer's fold
    # cannot run inside a scan; carry/aux/absorb move the fold state across
    # the jit boundary.

    def _scan_desc(self) -> tuple | None:
        plan = self.plan
        if self._keep_sketch:
            return None  # retained sketches can't stream through a scan
        if plan.cov_path == "lowrank" and self._track_cov and self._needs_moments:
            if plan.lowrank_method == "fd":
                return ("fd",)
            # range on sharded reduces through shard_map psums — host only
            return None if plan.backend == "sharded" else ("range",)
        if not self._needs_moments:
            return None
        if plan.backend != "stream":
            # batch retains the sketch; sharded reduces via shard_map
            return None
        # mean-only folds under cov_path="lowrank" still use the dense delta
        # (mirrors _MomentReducer._moment_cov_path)
        return ("moment", "dense" if plan.cov_path == "lowrank" else plan.cov_path)

    def _scan_prepare(self, cursor: "SketchCursor", xs, step0: int) -> None:
        """Called before the scan launches with the staged (steps, n_shards,
        batch_size, p) block — subclasses that lazily init from a first
        sketch do so here (on the host, outside the scan)."""

    def _scan_carry(self):
        return self._reducer.state

    def _scan_aux(self):
        return self._reducer._omega

    def _scan_absorb(self, carry, ys, steps: int, rows_per_step: int) -> None:
        self._reducer.state = carry
        self.count_ += steps * rows_per_step

    def fit(self, x) -> "SketchedEstimator":
        self.reset()
        self.partial_fit(x)
        return self.finalize()

    def fit_stream(self, source, steps: int, seed: int | None = None) -> "SketchedEstimator":
        """One pass over a ``(seed, step, shard) → (b, p)`` source (the
        repro.data.pipeline / StreamEngine contract)."""
        from repro.stream.engine import normalize_source

        self.reset()
        self._cursor.fold_source(normalize_source(source), steps, seed)
        return self.finalize()

    def finalize(self) -> "SketchedEstimator":
        if self.spec_ is None:
            raise RuntimeError("no batches folded yet — call fit()/partial_fit() first")
        self._finalize()
        self._fitted = True
        return self

    def _finalize(self) -> None:
        raise NotImplementedError

    # ---------------------------------------------------------- refinement --
    # Second-pass replay refinement (repro.refine): subclasses that support it
    # override _refine_supported/_refine_check and the _refine_* fold hooks
    # documented in repro.refine.replay; the base class only owns the drivers.

    def _refine_supported(self) -> bool:
        return False

    def _refine_check(self) -> None:
        raise ValueError(
            f"{type(self).__name__} has no second-pass refinement: its "
            "estimator is already exact given the sketch (nothing a replay "
            "could sharpen). fit_refine applies to SparsifiedPCA on the "
            "lowrank 'range' path and to minibatch SparsifiedKMeans")

    def _refine_needs_signal(self) -> bool:
        return False

    def _refine_metric(self) -> float:
        """The latest per-pass convergence measurement (smaller = settled):
        PCA's principal-angle change between consecutive power bases, the
        minibatch K-means rebuild's reassigned-row fraction. Subclasses that
        support refinement implement it; the ``tol=`` loop reads it."""
        raise NotImplementedError

    def _refine_tol_check(self) -> None:
        """Subclass hook: reject ``tol=`` when the convergence signal is off."""

    def _resolve_passes(self, passes: int | None) -> int:
        if passes is None:
            passes = self.plan.refine_passes or 1
        if passes < 1:
            raise ValueError(f"refinement needs passes >= 1, got {passes}")
        return int(passes)

    def refine(self, x=None, passes: int | None = None, *, tol: float | None = None,
               max_passes: int = 16, source=None,
               steps: int | None = None, seed: int | None = None) -> "SketchedEstimator":
        """Replay the FITTED pass more times and sharpen the fit.

        ``x`` must be the same array ``fit`` consumed (re-chunked and re-masked
        identically under the (step, shard) key discipline; the row count is
        checked), or ``source`` / ``steps`` / ``seed`` the same stream
        ``fit_stream`` pulled — the replay regenerates every sketch
        bit-identically, storing nothing. ``passes`` defaults to
        ``plan.refine_passes`` (or 1). Repeat calls RESUME: ``refine(x);
        refine(x)`` continues the iteration where the first call stopped
        (≡ one ``refine(x, passes=2)``), with ``refine_passes_`` accumulating.

        ``tol=`` replaces the fixed pass count with "refine until converged":
        single passes run (resuming, exactly as repeat calls do) until the
        per-pass convergence measurement — ``refine_subspace_change_[-1]`` for
        PCA, ``refine_reassign_fraction_[-1]`` for minibatch K-means (needs
        ``track_reassignments=True``, and prices one trailing measurement
        replay per pass) — drops to ``tol`` or ``max_passes`` is hit;
        ``refine_converged_`` records which. Mutually exclusive with
        ``passes``.
        """
        self._refine_check()
        if not self._fitted:
            raise RuntimeError("refine() replays a fitted estimator — call "
                               "fit()/fit_stream() first, or use fit_refine()")
        if tol is not None:
            if passes is not None:
                raise ValueError("pass a fixed passes= OR an adaptive tol=, not both")
            if tol <= 0:
                raise ValueError(f"tol must be > 0, got {tol}")
            if max_passes < 1:
                raise ValueError(f"max_passes must be >= 1, got {max_passes}")
            self._refine_tol_check()
        chunk_rows = None
        if x is not None:
            n = int(jnp.asarray(x).shape[0])
            if n != self.count_:
                raise ValueError(
                    f"refine(x) got {n} rows but the fitted pass folded "
                    f"{self.count_}; the replay must regenerate the SAME "
                    "chunks — pass the array fit() consumed")
            # an array replay must regenerate the SAME chunk boundaries (hence
            # (step, shard) mask keys) the fitted pass folded — the cursor's
            # recorded chunk_rows, which cover ragged partial_fit histories
            # that uniform batch_size re-chunking could not reproduce
            chunk_rows = list(self._cursor.chunk_rows)
        src = None
        if source is not None:
            from repro.stream.engine import normalize_source

            src = normalize_source(source)
        if tol is None:
            refine_mod.run_refine(self.plan, self.spec_, [self],
                                  self._resolve_passes(passes), data=x, source=src,
                                  steps=steps, seed=seed, chunk_rows=chunk_rows)
            return self
        # adaptive: one resuming pass at a time, watching the estimator's own
        # convergence measurement (pure loop control — the replay math is the
        # fixed-passes path's, so refine(tol=) ≡ refine(passes=q) for the q it
        # settles on)
        self.refine_converged_ = False
        for _ in range(int(max_passes)):
            refine_mod.run_refine(self.plan, self.spec_, [self], 1, data=x,
                                  source=src, steps=steps, seed=seed,
                                  chunk_rows=chunk_rows)
            if self._refine_metric() <= tol:
                self.refine_converged_ = True
                break
        return self

    def fit_refine(self, x=None, passes: int | None = None, *,
                   tol: float | None = None, max_passes: int = 16, source=None,
                   steps: int | None = None, seed: int | None = None) -> "SketchedEstimator":
        """One-pass fit + replay refinement in one call.

        The data argument doubles as the replay source: an in-memory ``x`` is
        fit then re-chunked per pass; a ``(seed, step, shard) → (b, p)``
        ``source`` is streamed once then replayed per pass. ``tol=`` switches
        from the fixed ``passes`` count to adaptive refine-until-converged
        (see :meth:`refine`).
        """
        self._refine_check()
        if (x is None) == (source is None):
            raise ValueError("fit_refine needs exactly one of x or source=")
        if x is not None:
            self.fit(x)
        else:
            if steps is None:
                raise ValueError("fit_refine(source=...) needs steps=")
            self.fit_stream(source, steps=steps, seed=seed)
        return self.refine(x, passes, tol=tol, max_passes=max_passes,
                           source=source, steps=steps, seed=seed)

    # ------------------------------------------------------------- utility --

    def sketch(self, x, mask_key: jax.Array | int | None = None) -> SparseRows:
        """The compression operator applied to new rows.

        On a fitted (or fitting) estimator this uses the fitted spec; on a
        fresh one, a THROWAWAY spec is derived from (plan, key) for this call
        only — reading a sketch never pins ``p`` or allocates fold state.

        ``mask_key=None`` reuses the spec's one-shot mask key, so repeated
        ``sketch()`` / ``predict()`` calls sample the SAME coordinates of
        equal inputs (deterministic, but not independent across calls). Pass
        an int (folded into the spec's mask key) or a PRNGKey for an
        independent mask per call.
        """
        x = jnp.asarray(x).astype(self.plan.dtype)
        spec = self.spec_ if self.spec_ is not None else self.plan.spec(x.shape[-1], self.key)
        if mask_key is None:
            bk = None
        elif isinstance(mask_key, int):
            bk = jax.random.fold_in(spec.mask_key(), mask_key)
        else:
            bk = mask_key
        return sketch_mod.sketch(x, spec, batch_key=bk, impl=self.plan.impl)

    def _unmix_vec(self, v_pre: jax.Array) -> jax.Array:
        return sketch_mod.unmix_dense(v_pre[None, :], self.spec_)[0]

    # ------------------------------------------------------------ snapshot --
    # State export/import for checkpoints and repro.sketchserve snapshots:
    # everything a restarted process needs to continue THIS estimator's ingest
    # bit-identically, as a flat {name: array} dict in the EngineState
    # protocol's wire format (repro.stream.state.to_arrays — the same keys the
    # StreamEngine checkpoints). The spec is NOT exported — it re-derives
    # deterministically from (plan, key, p); derived fitted attributes aren't
    # either — finalize() recomputes them from the fold state. Import targets
    # a freshly constructed estimator whose spec is already bound (the
    # importer calls cursor.ensure_spec first).

    def state_arrays(self) -> dict:
        r = self._reducer
        if r is None:
            raise RuntimeError("nothing folded yet — nothing to export")
        if r._step_parts:
            raise RuntimeError(
                "a sharded reducer is mid-step (buffered shard sketches not "
                "yet psum'd); ingest to a step boundary before snapshotting")
        out: dict = {"count": np.int64(self.count_)}
        if r.state is not None:
            out.update(state_mod.to_arrays(r.state))
        if r.parts:            # retained sketches (batch moments / Lloyd)
            out["parts.values"] = jnp.concatenate([s.values for s in r.parts])
            out["parts.indices"] = jnp.concatenate([s.indices for s in r.parts])
            out["parts.rows"] = np.array([s.n for s in r.parts], np.int64)
        return out

    def load_state_arrays(self, arrs: dict) -> None:
        if self.spec_ is None:
            raise RuntimeError("bind the spec (cursor.ensure_spec) before "
                               "importing snapshot state")
        r = self._reducer
        self.count_ = int(arrs["count"])
        # the reducer only ever holds a moment/range/fd state — the km kind
        # belongs to SparsifiedKMeans' own slot (its override loads it)
        st = state_mod.from_arrays(arrs, kinds=("moment", "range", "fd"))
        if st is not None:
            r.state = st
        if "parts.values" in arrs:
            values = jnp.asarray(arrs["parts.values"])
            indices = jnp.asarray(arrs["parts.indices"])
            r.parts = []
            i = 0
            for n in np.asarray(arrs["parts.rows"]).tolist():
                r.parts.append(SparseRows(values[i:i + n], indices[i:i + n],
                                          self.spec_.p_pad))
                i += n

    # Estimator-level checkpoint/restore — the fold state plus the cursor
    # counters, through the train.checkpoint atomic-rename protocol. restore()
    # rebinds the spec from (plan, key, p) and resumes the chunk cursor, so
    # partial_fit after restore() continues the interrupted pass
    # bit-identically (tests/test_engine_state.py).

    def checkpoint(self, ckpt_dir: str, *, keep_last: int = 3) -> "SketchedEstimator":
        """Write the fold state + ingest cursor to ``ckpt_dir`` (atomic)."""
        if self.spec_ is None:
            raise RuntimeError("nothing folded yet — nothing to checkpoint")
        cur = self._cursor
        extra = {"p": int(self.spec_.p), "chunk": cur.chunk, "count": cur.count,
                 "n_sketches": cur.n_sketches,
                 "chunk_rows": list(cur.chunk_rows)}
        checkpoint_mod.save_arrays(ckpt_dir, cur.chunk, self.state_arrays(),
                                   extra=extra, keep_last=keep_last)
        return self

    def restore(self, ckpt_dir: str) -> "SketchedEstimator":
        """Reset, rebind the spec, and load the latest checkpoint under
        ``ckpt_dir`` — the estimator continues ingest where it stopped."""
        arrs, extra = checkpoint_mod.load_arrays(ckpt_dir)
        self.reset()
        cur = self._cursor
        cur.ensure_spec(int(extra["p"]))
        self.load_state_arrays(arrs)
        cur.chunk = int(extra["chunk"])
        cur.count = int(extra["count"])
        cur.n_sketches = int(extra["n_sketches"])
        cur.chunk_rows = [int(r) for r in extra["chunk_rows"]]
        return self


# ----------------------------------------------------------- the estimators --


class SparsifiedMean(SketchedEstimator):
    """Thm-4 unbiased mean from the sketch alone.

    Fitted: ``mean_`` (p, original domain), ``mean_pre_`` (p_pad,
    preconditioned domain), ``count_``.
    """

    _track_cov = False

    def _finalize(self) -> None:
        mean_pre, _, n = self._reducer.reduce()
        self.mean_pre_ = mean_pre
        self.mean_ = self._unmix_vec(mean_pre)
        self.count_ = int(n)


class SparsifiedCov(SketchedEstimator):
    """Thm-6 unbiased covariance (uncentered second moment) from the sketch.

    Fitted: ``cov_`` ((p_pad, p_pad), PRECONDITIONED domain — the spectrum
    equals the original's since HD is orthonormal), ``mean_pre_``, ``mean_``,
    ``count_``. Use :meth:`cov_original` for the (p, p) original-domain matrix.
    """

    _track_cov = True

    def _on_spec(self, spec: sketch_mod.SketchSpec) -> None:
        if spec.m < 2:
            raise ValueError(f"covariance needs m >= 2 (Thm B4), got m={spec.m}; "
                             "raise gamma/m")
        if self.plan.cov_path == "lowrank":
            raise ValueError(
                "cov_path='lowrank' is a PCA-only factored path (it never forms "
                "the (p, p) matrix this estimator returns); use SparsifiedPCA, "
                "or cov_path='dense'/'compact' for the full covariance")

    def _finalize(self) -> None:
        mean_pre, cov_pre, n = self._reducer.reduce()
        self.mean_pre_ = mean_pre
        self.mean_ = self._unmix_vec(mean_pre)
        self.cov_ = cov_pre
        self.count_ = int(n)

    def cov_original(self) -> jax.Array:
        """(p, p) covariance in the original domain: (HD)ᵀ Ĉ_pre (HD)."""
        c1 = sketch_mod.unmix_dense(self.cov_, self.spec_)        # rows still pre-domain
        return sketch_mod.unmix_dense(c1.T, self.spec_)


class SparsifiedPCA(SketchedEstimator):
    """Principal components from the sketched covariance (paper §V).

    With ``Plan(cov_path="lowrank", rank=l)`` the (p, p) covariance accumulator
    is replaced by the O(l·p) ``repro.lowrank`` spectral states on every
    backend — same fit/finalize contract, and the factored eigenmodel is kept
    on ``cov_lowrank_``. Pick l ≥ 4·n_components (the "range" method finalizes
    l/2 eigenpairs from the 2×-oversampled sketch; "fd" finalizes all l).

    Fitted: ``components_`` ((n_components, p), original domain, rows are PCs),
    ``explained_variance_`` (eigenvalues, descending), ``mean_``, ``count_``,
    ``cov_lowrank_`` (:class:`repro.lowrank.LowRankCov` | None).
    """

    _track_cov = True

    def __init__(self, n_components: int, plan: Plan, key: jax.Array | int = 0):
        self.n_components = int(n_components)
        super().__init__(plan, key)

    def _on_spec(self, spec: sketch_mod.SketchSpec) -> None:
        if spec.m < 2:
            raise ValueError(f"PCA needs m >= 2 (Thm B4 covariance), got m={spec.m}")
        if self.plan.cov_path == "lowrank":
            model_rank = (self.plan.rank // 2 if self.plan.lowrank_method == "range"
                          else self.plan.rank)
            if self.n_components > model_rank:
                raise ValueError(
                    f"n_components={self.n_components} exceeds the rank-{model_rank} "
                    f"eigenmodel of a rank={self.plan.rank} "
                    f"{self.plan.lowrank_method!r} sketch; raise Plan.rank "
                    f"(l ≥ 4·n_components recommended)")

    def _finalize(self) -> None:
        mean_pre, cov_pre, n = self._reducer.reduce()
        if isinstance(cov_pre, lowrank_mod.LowRankCov):
            self.cov_lowrank_ = cov_pre
            comps_pre, evals = cov_pre.top(self.n_components)
        else:
            self.cov_lowrank_ = None
            comps_pre, evals = pca_mod._top_eig(cov_pre, self.n_components)
        self.components_ = sketch_mod.unmix_dense(comps_pre, self.spec_)
        self.explained_variance_ = evals
        self.mean_ = self._unmix_vec(mean_pre)
        self.count_ = int(n)
        self.refine_passes_ = 0           # refine() overwrites after its replay
        self.refine_subspace_change_ = None

    def transform(self, x) -> jax.Array:
        """Project rows onto the fitted components (original domain, uncentered
        — the paper's convention)."""
        return jnp.asarray(x).astype(self.plan.dtype) @ self.components_.T

    def result(self) -> pca_mod.PCAResult:
        return pca_mod.PCAResult(self.components_, self.explained_variance_, self.mean_)

    # ---------------------------------------------------------- refinement --
    # Power iteration against the regenerable source (repro.refine.power):
    # each pass replays every (step, shard) sketch and accumulates Y = S·Q
    # through the SAME RangeState deltas as the first pass (sharded: one
    # fixed-size psum per step via sharded_lowrank), squaring the one-pass
    # gap ratio. Extra fitted attrs: refine_passes_ (int, 0 = one-pass fit)
    # and refine_subspace_change_ ((passes,) max principal-angle sine between
    # consecutive power bases — the per-pass convergence diagnostic).

    def _refine_supported(self) -> bool:
        return (self.plan.cov_path == "lowrank"
                and self.plan.lowrank_method == "range")

    def _refine_check(self) -> None:
        if self.plan.cov_path != "lowrank":
            raise ValueError(
                "fit_refine sharpens the lowrank range-finder's subspace; "
                f"cov_path={self.plan.cov_path!r} accumulates the full "
                "covariance exactly, so its eigendecomposition has no "
                "refinement gap — use Plan(cov_path='lowrank', rank=l)")
        if self.plan.lowrank_method != "range":
            raise ValueError(
                "lowrank_method='fd' has no replayable linear operator (the "
                "SVD-shrink fold is order-dependent); power-iteration "
                "refinement needs lowrank_method='range'")

    def _refine_pass_begin(self, f: int) -> None:
        if f == 0 and not self.refine_passes_:
            # the first basis is free: orth of the ALREADY-FOLDED first-pass
            # state (debiased against Omega) — no extra replay. A repeat
            # refine() instead RESUMES from self._rq (the basis the previous
            # refinement's last pass produced), continuing the iteration.
            self._rq = refine_mod.power_orth(self._reducer.state,
                                             self._reducer._omega, self.spec_.m)
            self._rchanges: list[float] = []
        self._rstate = lowrank_mod.range_init(self.spec_.p_pad, self.plan.rank)
        self._rstep_parts: list[SparseRows] = []

    def _refine_fold(self, s: SparseRows, step: int, shard: int) -> None:
        if self.plan.backend == "sharded":
            self._rstep_parts.append(s)
            if shard == self.plan.n_shards - 1:
                self._refine_flush()
        else:
            self._rstate = lowrank_mod.range_update(self._rstate, s, self._rq,
                                                    impl=self.plan.impl)

    def _refine_flush(self) -> None:
        if not self._rstep_parts:
            return
        step_sketch = _concat_sparse(self._rstep_parts, self.spec_.p_pad)
        delta = sharded_mod.sharded_lowrank(step_sketch, self._rq,
                                            self.plan.resolve_mesh(),
                                            (self.plan.axis,), impl=self.plan.impl)
        self._rstate = lowrank_mod.range_apply(self._rstate, delta)
        self._rstep_parts = []

    def _refine_pass_end(self, f: int, last: bool, signal: bool) -> None:
        self._refine_flush()
        q_new = refine_mod.power_orth(self._rstate, self._rq, self.spec_.m)
        # convergence is watched on the top-n_components columns — the
        # subspace the consumer keeps; wider slices are dominated by the
        # oversampling columns churning in the (near-degenerate) tail
        r = self.n_components
        self._rchanges.append(
            refine_mod.subspace_change(q_new[:, :r], self._rq[:, :r]))
        self._rq_prev, self._rq = self._rq, q_new

    def _refine_end(self, passes: int) -> None:
        self.cov_lowrank_ = refine_mod.power_finalize(self._rstate, self._rq_prev,
                                                      self.spec_.m)
        comps_pre, evals = self.cov_lowrank_.top(self.n_components)
        self.components_ = sketch_mod.unmix_dense(comps_pre, self.spec_)
        self.explained_variance_ = evals
        self.refine_passes_ += passes    # cumulative across repeat refine()s
        self.refine_subspace_change_ = np.asarray(self._rchanges)

    def _refine_metric(self) -> float:
        return float(self.refine_subspace_change_[-1])


class SparsifiedKMeans(SketchedEstimator):
    """Sparsified K-means over any backend.

    algorithm="lloyd" (default, paper Alg. 1): the sketch — the γ-compressed
    dataset, which is the point of the method — is retained, and full Lloyd
    (``sparse_kmeans_core``; under the sharded backend, the same solver inside
    the mesh context via ``stream.sharded.sharded_kmeans``) runs at
    finalize. Fitted ``labels_`` covers every row folded.

    algorithm="minibatch": the constant-memory streaming accumulators of
    ``repro.stream.accumulators`` (online Eq. 39 update, r = n_init parallel
    hypotheses) — nothing is retained but the (r, K, p_pad) centers/counts.
    The fold is identical on every backend (per-step deltas against the
    step-start state, as the StreamEngine computes them), so backends stay
    tolerance-identical; ``labels_`` is None (use :meth:`predict`).

    Mini-batch extras (ROADMAP streaming-K-means items): ``decay`` < 1 is a
    forgetting factor for non-stationary streams — accumulated per-coordinate
    counts shrink by ``decay`` each step before the new deltas fold in, so the
    centers track drifting clusters with effective memory ≈ 1/(1−decay) steps.
    Unless ``track_reassignments=False``, each step's rows are re-assigned
    under the post-update centers and compared to their pre-update assignment;
    the per-step counts (best hypothesis) land on ``reassign_counts_`` /
    ``reassign_fraction_`` — a convergence signal that decays toward zero as
    the solution settles (costs one extra assignment pass per batch).

    Fitted: ``centers_`` ((k, p), original domain), ``centers_pre_``,
    ``objective_``, ``labels_``, ``n_iter_`` (lloyd), ``count_``,
    ``reassign_counts_`` / ``reassign_fraction_`` ((steps,) arrays; minibatch).
    """

    _track_cov = False
    _needs_moments = False  # centers come from the solver, not Thm-4/6

    def __init__(self, k: int, plan: Plan, key: jax.Array | int = 0, *,
                 n_init: int = 3, max_iter: int = 100, tol: float = 1e-6,
                 algorithm: str = "lloyd", decay: float = 1.0,
                 track_reassignments: bool = True):
        if algorithm not in ("lloyd", "minibatch"):
            raise ValueError(f"algorithm must be 'lloyd' or 'minibatch', got {algorithm!r}")
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        if decay < 1.0 and algorithm != "minibatch":
            raise ValueError("decay (forgetting) only applies to the streaming "
                             "algorithm='minibatch' accumulators")
        self.k = int(k)
        self.n_init = int(n_init)
        self.max_iter = int(max_iter)
        self.tol = float(tol)
        self.algorithm = algorithm
        self.decay = float(decay)
        self.track_reassignments = bool(track_reassignments) and algorithm == "minibatch"
        self._keep_sketch = algorithm == "lloyd"  # Alg. 1 clusters the retained sketch
        super().__init__(plan, key)

    def reset(self) -> "SparsifiedKMeans":
        super().reset()
        self._km_state: acc.KMeansState | None = None
        self._km_pending = None  # buffered deltas of the in-flight step
        # (sketch, pre-update labels) pairs of the in-flight step, for the
        # reassignment counts — dropped at every flush
        self._km_step_sketches: list[tuple[SparseRows, jax.Array]] = []
        # sharded backend: the in-flight step's raw shard sketches, reduced
        # in-mesh by sharded_kmeans_step at each flush
        self._km_step_parts: list[SparseRows] = []
        self._reassign_history: list[tuple[np.ndarray, int]] = []
        return self

    # --------------------------------------------------------- minibatch ----

    def _fold_sketch(self, s: SparseRows, step: int, shard: int) -> None:
        if self.algorithm == "lloyd":
            self._reducer.fold(s, step, shard)
            return
        if self._km_state is None:
            self._km_state = acc.kmeans_init(
                fold_in_str(self.spec_.key, "api-kmeans"), s, self.k, self.n_init,
                decay=self.decay)
        if self.plan.backend == "sharded":
            # mesh-resident fold: buffer the step's shard sketches and reduce
            # them in-mesh at the flush — assignment stays on-device per
            # shard, one psum of the fixed-size delta per step.
            self._km_step_parts.append(s)
            if shard == self.plan.n_shards - 1:
                self._flush_step()
            return
        # engine semantics: every shard's delta is taken against the step-start
        # state, summed, and applied once per step — backend-independent.
        if self.track_reassignments:
            # the pre-update labels ride along with the delta (computed once)
            d, a0 = acc.kmeans_delta_with_assign(self._km_state, s)
            self._km_step_sketches.append((s, a0))
        else:
            d = acc.kmeans_delta(self._km_state, s)
        self._km_pending = (d if self._km_pending is None
                            else jax.tree.map(jnp.add, self._km_pending, d))
        if shard == self.plan.n_shards - 1:
            self._flush_step()

    def _flush_step(self) -> None:
        if self._km_step_parts:
            old_count = int(self._km_state.count)
            mesh = _sharded_mesh(self.plan)
            parts, self._km_step_parts = self._km_step_parts, []
            mask = None
            if _is_multiprocess():
                from repro import cluster

                vals = np.concatenate([np.asarray(s.values) for s in parts])
                idxs = np.concatenate([np.asarray(s.indices) for s in parts])
                s_cat = SparseRows(
                    cluster.global_rows(vals, mesh, self.plan.axis),
                    cluster.global_rows(idxs, mesh, self.plan.axis),
                    parts[0].p)
                mask = cluster.global_rows(
                    np.ones(vals.shape[0], np.int32), mesh, self.plan.axis)
            else:
                s_cat = _concat_sparse(parts, parts[0].p)
            new, cnt = sharded_mod.sharded_kmeans_step(
                self._km_state, s_cat, mesh, axis=self.plan.axis,
                decay=self.decay,
                track_reassignments=self.track_reassignments, mask=mask)
            self._km_state = new
            if self.track_reassignments:
                rows = int(new.count) - old_count
                self._reassign_history.append((np.asarray(cnt), rows))
            return
        if self._km_pending is None:
            return
        self._km_state = acc.kmeans_apply(self._km_state, self._km_pending,
                                          decay=self.decay)
        self._km_pending = None
        if self.track_reassignments:
            counts = jnp.zeros((self.n_init,), jnp.int32)
            rows = 0
            for s, a0 in self._km_step_sketches:
                counts = counts + acc.kmeans_reassigned(self._km_state, s, a0)
                rows += s.n
            self._reassign_history.append((np.asarray(counts), rows))
        self._km_step_sketches = []

    # --------------------------------------------------- multi-process fold --

    def _needs_first_sketch(self) -> bool:
        return self.algorithm == "minibatch" and self._km_state is None

    def _seed_first_sketch(self, s0: SparseRows) -> None:
        self._km_state = acc.kmeans_init(
            fold_in_str(self.spec_.key, "api-kmeans"), s0, self.k, self.n_init,
            decay=self.decay)

    def _step_flush(self) -> None:
        super()._step_flush()
        self._flush_step()

    # ------------------------------------------------------- scanned ingest --

    def _scan_desc(self) -> tuple | None:
        if self.algorithm != "minibatch":
            return None  # lloyd retains the sketch — host loop only
        if self.plan.backend == "sharded":
            return None  # mesh-resident shard_map fold — host loop only
        # the host-delta minibatch fold is backend-independent (per-step
        # deltas against the step-start state), so the rest scan
        return ("kmeans", self.track_reassignments, self.decay)

    def _scan_prepare(self, cursor: "SketchCursor", xs, step0: int) -> None:
        if self._km_state is None:
            # host-sketch chunk (step0, shard 0) once for the data-dependent
            # init — the scan re-sketches it identically (same mask key)
            spec = cursor.spec
            s0 = sketch_mod.sketch(xs[0, 0], spec,
                                   batch_key=batch_key(spec, step0, 0),
                                   impl=self.plan.impl)
            self._km_state = acc.kmeans_init(
                fold_in_str(spec.key, "api-kmeans"), s0, self.k, self.n_init,
                decay=self.decay)

    def _scan_carry(self):
        return self._km_state

    def _scan_aux(self):
        return None

    def _scan_absorb(self, carry, ys, steps: int, rows_per_step: int) -> None:
        self._km_state = carry
        self.count_ += steps * rows_per_step
        if self.track_reassignments:
            counts = np.asarray(ys)  # (steps, n_init)
            for t in range(steps):
                self._reassign_history.append((counts[t], rows_per_step))

    # ----------------------------------------------------------- finalize ---

    def _finalize(self) -> None:
        self.reassign_counts_ = None
        self.reassign_fraction_ = None
        if self.algorithm == "minibatch":
            self._flush_step()
            if self._km_state is None:
                raise RuntimeError("no batches folded yet — call fit()/partial_fit() first")
            centers_pre, obj = acc.kmeans_finalize(self._km_state)
            if self.track_reassignments and self._reassign_history:
                best = int(np.argmin(np.asarray(self._km_state.obj)))
                cnt = np.array([c[best] for c, _ in self._reassign_history])
                rows = np.array([max(r, 1) for _, r in self._reassign_history])
                self.reassign_counts_ = cnt
                self.reassign_fraction_ = cnt / rows
            self.labels_ = None
            self.n_iter_ = None
            self.count_ = int(self._km_state.count)
        else:
            s_all = self._reducer.concat()
            init_key = fold_in_str(self.spec_.key, "api-kmeans")
            if self.plan.backend == "sharded":
                centers_pre, a, obj, it = sharded_mod.sharded_kmeans(
                    s_all, self.k, init_key, self.plan.resolve_mesh(),
                    n_init=self.n_init, max_iter=self.max_iter, tol=self.tol)
            else:
                centers_pre, a, obj, it = km.sparse_kmeans_core(
                    s_all.values, s_all.indices, s_all.p, self.k, init_key,
                    n_init=self.n_init, max_iter=self.max_iter, tol=self.tol)
            self.labels_ = a
            self.n_iter_ = int(it)
        self.centers_pre_ = centers_pre
        self.centers_ = sketch_mod.unmix_dense(centers_pre, self.spec_)
        self.objective_ = obj
        self.refine_passes_ = 0           # refine() overwrites after its replay
        self.refine_reassign_counts_ = None
        self.refine_reassign_fraction_ = None

    def predict(self, x) -> jax.Array:
        """Nearest-center labels for new rows (sketched with a one-shot mask)."""
        s = self.sketch(x)
        return acc.kmeans_assign(self.centers_pre_, s)

    # ------------------------------------------------------------ snapshot --

    def state_arrays(self) -> dict:
        out = super().state_arrays()
        if self.algorithm == "minibatch":
            if (self._km_pending is not None or self._km_step_sketches
                    or self._km_step_parts):
                raise RuntimeError(
                    "the minibatch fold is mid-step (pending shard deltas); "
                    "ingest to a step boundary before snapshotting")
            if self._km_state is not None:
                out.update(state_mod.to_arrays(self._km_state))
            if self._reassign_history:
                out["km.reassign_counts"] = np.stack(
                    [c for c, _ in self._reassign_history])
                out["km.reassign_rows"] = np.array(
                    [r for _, r in self._reassign_history], np.int64)
        return out

    def load_state_arrays(self, arrs: dict) -> None:
        super().load_state_arrays(arrs)
        if "km.centers" in arrs:
            self._km_state = state_mod.from_arrays(arrs, kinds=("km",))
        if "km.reassign_counts" in arrs:
            cnts = np.asarray(arrs["km.reassign_counts"])
            rows = np.asarray(arrs["km.reassign_rows"]).tolist()
            self._reassign_history = [(cnts[i], int(rows[i]))
                                      for i in range(len(rows))]

    # ---------------------------------------------------------- refinement --
    # Two-pass (Alg. 2) replay refinement (repro.refine.kmeans2): each pass
    # re-assigns every replayed row against FROZEN pass-start centers (the
    # best first-pass hypothesis) and rebuilds centers from those consistent
    # assignments — the unbiased per-coordinate center estimator over ONE
    # assignment, instead of the streaming fold's evolving ones. The per-batch
    # delta depends only on the frozen centers, so folds commute and all three
    # backends produce BIT-IDENTICAL refined centers. Extra fitted attrs:
    # refine_passes_, refine_reassign_counts_ / refine_reassign_fraction_ —
    # rows reassigned by each rebuild, continuing the streaming
    # reassign_counts_ convergence signal across passes. The count for the
    # LAST rebuild is only observable one replay later, so when
    # track_reassignments is on, one trailing measurement-only replay runs
    # (rebuild discarded; it also upgrades objective_ to the true objective
    # of the FINAL centers). With tracking off the counts cover the first
    # passes-1 rebuilds and objective_ is measured under the pre-rebuild
    # centers of the last pass.

    def _refine_supported(self) -> bool:
        return self.algorithm == "minibatch" and self.decay == 1.0

    def _refine_check(self) -> None:
        if self.algorithm != "minibatch":
            raise ValueError(
                "algorithm='lloyd' retains the sketch and already iterates "
                "assignment/update to a fixed point on it — there is no "
                "second-pass gap to close; two-pass refinement applies to "
                "the streaming algorithm='minibatch' fold")
        if self.decay < 1.0:
            raise ValueError(
                "two-pass refinement rebuilds centers as a UNIFORM mean over "
                "the whole replayed history, which would resurrect exactly the "
                "stale rows a decay= fit deliberately forgets (and drag the "
                "centers back toward pre-drift positions); refine the "
                "undecayed fit, or keep the decayed one-pass centers "
                "(decay-weighted rebuilds are a ROADMAP item)")

    def _refine_needs_signal(self) -> bool:
        return self.track_reassignments

    def _refine_pass_begin(self, f: int) -> None:
        if f == 0 and not self.refine_passes_:
            # fresh refinement freezes the best first-pass hypothesis (THE
            # selection rule — kmeans_finalize); a repeat refine() resumes
            # from self._rc, the previous refinement's rebuilt centers
            self._rc, _ = acc.kmeans_finalize(self._km_state)
            self._rc_prev = None
            self._rflips: list[tuple[int, int]] = []
        self._r2 = refine_mod.kmeans2_init(self.k, self.spec_.p_pad)

    def _refine_fold(self, s: SparseRows, step: int, shard: int) -> None:
        self._r2 = refine_mod.kmeans2_apply(
            self._r2, refine_mod.kmeans2_delta(s, self._rc, self._rc_prev))

    def _refine_pass_end(self, f: int, last: bool, signal: bool) -> None:
        if self._rc_prev is not None:
            # flips between c_{f-1} and c_f = rows reassigned by rebuild f
            self._rflips.append((int(self._r2.flips), int(self._r2.count)))
        self._robj = self._r2.obj
        if signal:
            # every rebuild so far is measured — a resumed refine() must not
            # re-count the last one, so drop the pending comparison centers
            self._rc_prev = None
        else:
            self._rc_prev = self._rc
            self._rc = refine_mod.kmeans2_centers(self._r2, self._rc)

    def _refine_end(self, passes: int) -> None:
        self.centers_pre_ = self._rc
        self.centers_ = sketch_mod.unmix_dense(self._rc, self.spec_)
        self.objective_ = self._robj
        self.refine_passes_ += passes    # cumulative across repeat refine()s
        if self._rflips:
            cnt = np.array([c for c, _ in self._rflips])
            rows = np.array([max(r, 1) for _, r in self._rflips])
            self.refine_reassign_counts_ = cnt
            self.refine_reassign_fraction_ = cnt / rows

    def _refine_tol_check(self) -> None:
        if not self.track_reassignments:
            raise ValueError(
                "refine(tol=) watches the reassigned-row fraction of each "
                "rebuild, which track_reassignments=False turned off — "
                "re-construct with track_reassignments=True or use a fixed "
                "passes=")

    def _refine_metric(self) -> float:
        return float(self.refine_reassign_fraction_[-1])


# --------------------------------------------------------- grad compressor --


class GradCompressor:
    """The paper's estimator as a stateful gradient compressor — one front door
    over ``core.grad_compress`` sharing the repo's (seed, step, shard) key
    discipline: masks are ``sketch.batch_key(mask_spec(cfg, key), step, shard)``,
    exactly as a stream shard's data masks are.

    Holds the error-feedback residual and a step cursor; ``transform`` (alias
    ``compress``) is the per-step round trip. For jitted training loops keep
    using the pure ``core.grad_compress.compress_grads`` with the same cfg/key
    — the masks are identical by construction.
    """

    def __init__(self, cfg: CompressConfig = CompressConfig(),
                 key: jax.Array | int = 0, shard: int = 0):
        self.cfg = cfg
        self.key = as_key(key)
        self.shard = int(shard)
        self.spec_ = mask_spec(cfg, self.key)
        self.reset()

    def reset(self) -> "GradCompressor":
        self.residual_ = None
        self.step_ = 0
        self.wire_floats_ = 0
        return self

    def transform(self, grads, step: int | None = None):
        """Compress-decompress one gradient pytree; returns ĝ (same structure).

        ``step`` defaults to the internal cursor (auto-incremented); pass the
        trainer's step to stay aligned with a resumed run.
        """
        s = self.step_ if step is None else int(step)
        g_hat, self.residual_, wire = compress_grads(
            grads, self.key, jnp.int32(s), self.cfg,
            residual=self.residual_, shard=self.shard)
        self.wire_floats_ = wire
        self.step_ = s + 1
        return g_hat

    compress = transform
