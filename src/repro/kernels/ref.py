"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret=True on
CPU, compiled on TPU) across shape/dtype sweeps — see tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ros


def ref_hd_precondition(x: jax.Array, signs: jax.Array) -> jax.Array:
    """y = H·(d ⊙ x) along the last axis — oracle for kernels.fwht.

    ``x``: (n, p) with p a power of two; ``signs``: (p,) of ±1.
    """
    return ros.fwht(x * signs[None, :])


def ref_sparse_assign(values: jax.Array, indices: jax.Array, centers: jax.Array):
    """Sparsified K-means assignment oracle — kernels.sparse_assign.

    values (n, m), indices (n, m) int32 (distinct per row), centers (K, p).
    Returns (dists (n, K), argmin (n,) int32) of ‖z_i − R_iᵀμ_k‖² (paper Eq. 36).
    """
    g = centers.T[indices]                                   # (n, m, K)
    d = jnp.sum((values[..., None] - g) ** 2, axis=1)
    return d, jnp.argmin(d, axis=1).astype(jnp.int32)


def _spmm_out_dtype(a, b) -> jnp.dtype:
    """The shared spmm promotion rule: operands promote jointly, accumulation
    and output are at least f32 (kernels.spmm.promoted_dtypes agrees)."""
    return jnp.promote_types(jnp.promote_types(a, b), jnp.float32)


def ref_spmm(values: jax.Array, indices: jax.Array, dense: jax.Array) -> jax.Array:
    """T (n, l) = W @ dense — oracle for kernels.spmm.spmm.

    values/indices (n, m) compact sparse rows over p columns; dense (p, l).
    """
    out = _spmm_out_dtype(values.dtype, dense.dtype)
    return jnp.einsum("nm,nml->nl", values.astype(out), dense.astype(out)[indices])


def ref_spmm_t(values: jax.Array, indices: jax.Array, t: jax.Array, p: int) -> jax.Array:
    """Y (p, l) = Wᵀ @ t — oracle for kernels.spmm.spmm_t (scatter-add rows)."""
    out = _spmm_out_dtype(values.dtype, t.dtype)
    contrib = values.astype(out)[..., None] * t.astype(out)[:, None, :]
    return jnp.zeros((p, t.shape[1]), out).at[
        indices.reshape(-1)].add(contrib.reshape(-1, t.shape[1]))


def ref_sketch_fused(x: jax.Array, signs: jax.Array, indices: jax.Array) -> jax.Array:
    """values (n, m) = (H·(signs⊙x))[i, indices[i]] — oracle for
    kernels.sketch_fused (the composed precondition → gather it fuses away)."""
    return jnp.take_along_axis(ref_hd_precondition(x, signs), indices, axis=-1)
