"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret=True on
CPU, compiled on TPU) across shape/dtype sweeps — see tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ros


def ref_hd_precondition(x: jax.Array, signs: jax.Array) -> jax.Array:
    """y = H·(d ⊙ x) along the last axis — oracle for kernels.fwht.

    ``x``: (n, p) with p a power of two; ``signs``: (p,) of ±1.
    """
    return ros.fwht(x * signs[None, :])


def ref_sparse_assign(values: jax.Array, indices: jax.Array, centers: jax.Array):
    """Sparsified K-means assignment oracle — kernels.sparse_assign.

    values (n, m), indices (n, m) int32 (distinct per row), centers (K, p).
    Returns (dists (n, K), argmin (n,) int32) of ‖z_i − R_iᵀμ_k‖² (paper Eq. 36).
    """
    g = centers.T[indices]                                   # (n, m, K)
    d = jnp.sum((values[..., None] - g) ** 2, axis=1)
    return d, jnp.argmin(d, axis=1).astype(jnp.int32)


def ref_spmm(values: jax.Array, indices: jax.Array, dense: jax.Array) -> jax.Array:
    """T (n, l) = W @ dense — oracle for kernels.spmm.spmm.

    values/indices (n, m) compact sparse rows over p columns; dense (p, l).
    """
    v = values.astype(jnp.float32)
    return jnp.einsum("nm,nml->nl", v, dense.astype(jnp.float32)[indices])


def ref_spmm_t(values: jax.Array, indices: jax.Array, t: jax.Array, p: int) -> jax.Array:
    """Y (p, l) = Wᵀ @ t — oracle for kernels.spmm.spmm_t (scatter-add rows)."""
    contrib = values.astype(jnp.float32)[..., None] * t.astype(jnp.float32)[:, None, :]
    return jnp.zeros((p, t.shape[1]), jnp.float32).at[
        indices.reshape(-1)].add(contrib.reshape(-1, t.shape[1]))
