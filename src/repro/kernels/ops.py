"""Public jit'd wrappers for the Pallas kernels, with backend auto-selection.

On TPU the compiled kernels run natively; elsewhere (this CI container is
CPU-only) they execute via ``interpret=True`` (Pallas interpreter) or fall back
to the jnp oracles for speed. Call sites in core/ go through these wrappers so
the backend choice is one switch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import fwht as _fwht
from repro.kernels import ref as _ref
from repro.kernels import sketch_fused as _sf
from repro.kernels import sparse_assign as _sa
from repro.kernels import spmm as _spmm


@functools.cache
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _count_dispatch(op: str, path: str) -> None:
    """Tally a resolved backend choice as ``kernels.dispatch{op=,path=}``.

    The wrappers below run at JAX trace time, not per device step, so this is
    a handful of counter bumps per compilation — cheap enough to be always-on.
    Watch the ``path="ref"`` series to catch silent demotions to the jnp
    fallback (e.g. a VMEM-gate regression) that would otherwise only show up
    as a perf cliff.
    """
    obs.default_registry().counter("kernels.dispatch", op=op, path=path).inc()


def hd_precondition(x: jax.Array, signs: jax.Array, mode: str = "auto") -> jax.Array:
    """Fused y = H(d⊙x). mode ∈ {auto, kernel, interpret, ref}."""
    if mode == "auto":
        mode = "kernel" if _on_tpu() else "ref"
    _count_dispatch("hd_precondition", mode)
    if mode == "ref":
        return _ref.ref_hd_precondition(x, signs)
    return _fwht.hd_precondition(x, signs, interpret=(mode == "interpret"))


def sparse_assign(values: jax.Array, indices: jax.Array, centers: jax.Array, mode: str = "auto"):
    """(dists, argmin) for sparsified K-means assignment."""
    if mode == "auto":
        mode = "kernel" if _on_tpu() else "ref"
    _count_dispatch("sparse_assign", mode)
    if mode == "ref":
        return _ref.ref_sparse_assign(values, indices, centers)
    return _sa.sparse_assign(values, indices, centers, interpret=(mode == "interpret"))


# The spmm kernels tile BOTH grid axes (row blocks × column blocks —
# kernels/spmm.py), so their VMEM footprint is bounded by plan_tiles against
# this budget at ANY p: the old "fall back to jnp past ~2^15" ceiling is gone.
# The budget is defined once in kernels/spmm.py (the tile planner's input) and
# re-exported here so the dispatch gate and the planner can never disagree;
# "kernel" only demotes to "ref" in the pathological corner where even the
# minimum (8, 256) tile exceeds it (an extremely wide l).
_SPMM_VMEM_BUDGET = _spmm.SPMM_VMEM_BUDGET


def _sparse_mode(mode: str, p: int, ell: int,
                 value_dtype=jnp.float32, dense_dtype=jnp.float32) -> str:
    """Normalize a backend name to this module's vocabulary.

    Call sites forward ``Plan.impl`` / ``StreamEngine.impl`` here verbatim, and
    that knob speaks the Hadamard vocabulary where the jnp reference is spelled
    "jnp" — map it (and any other non-kernel spelling) to "ref" rather than
    falling through to a Pallas compile that CPU hosts reject. The VMEM check
    uses the ONE tile model (spmm.plan_tiles / tile_vmem_bytes) at the actual
    operand dtypes — no second, disagreeing footprint estimate lives here.
    """
    if mode == "auto":
        mode = "kernel" if _on_tpu() else "ref"
    if mode not in ("kernel", "interpret"):
        return "ref"
    if mode == "interpret":  # host interpreter: no VMEM constraint to respect
        return mode
    br, pb = _spmm.plan_tiles(p, ell, value_dtype, dense_dtype)
    vmem = _spmm.tile_vmem_bytes(p, ell, value_dtype, dense_dtype, br, pb)
    return "kernel" if vmem <= _SPMM_VMEM_BUDGET else "ref"


def spmm(values: jax.Array, indices: jax.Array, dense: jax.Array,
         mode: str = "auto") -> jax.Array:
    """T (n, l) = W @ dense for compact sparse rows (the low-rank projection)."""
    mode = _sparse_mode(mode, *dense.shape, values.dtype, dense.dtype)
    _count_dispatch("spmm", mode)
    if mode == "ref":
        return _ref.ref_spmm(values, indices, dense)
    return _spmm.spmm(values, indices, dense, interpret=(mode == "interpret"))


def spmm_t(values: jax.Array, indices: jax.Array, t: jax.Array, p: int,
           mode: str = "auto") -> jax.Array:
    """Y (p, l) = Wᵀ @ t — scatter sparse rows into the l-dim sketch."""
    mode = _sparse_mode(mode, p, t.shape[1], values.dtype, t.dtype)
    _count_dispatch("spmm_t", mode)
    if mode == "ref":
        return _ref.ref_spmm_t(values, indices, t, p)
    return _spmm.spmm_t(values, indices, t, p, interpret=(mode == "interpret"))


def sketch_fused(x: jax.Array, signs: jax.Array, indices: jax.Array,
                 mode: str = "auto") -> jax.Array:
    """values (n, m) = (H·(signs⊙x))[i, indices[i]] — the full compression
    operator's value pass in one VMEM round trip (kernels.sketch_fused).

    Above the fused kernel's single-tile ceiling (p > 2^15) the kernel modes
    compose the chunked FWHT with an XLA gather — still the kernel FWHT path,
    just not single-pass.
    """
    if mode == "auto":
        mode = "kernel" if _on_tpu() else "ref"
    if mode in ("kernel", "interpret"):
        if x.shape[-1] <= _sf.MAX_P_FUSED:
            _count_dispatch("sketch_fused", mode)
            return _sf.sketch_fused(x, signs, indices,
                                    interpret=(mode == "interpret"))
        _count_dispatch("sketch_fused", f"{mode}_chunked")
        y = _fwht.hd_precondition(x, signs, interpret=(mode == "interpret"))
        return jnp.take_along_axis(y, indices, axis=-1)
    _count_dispatch("sketch_fused", mode)
    return _ref.ref_sketch_fused(x, signs, indices)


def kernel_assign_fn(mode: str = "auto"):
    """Adapter matching core.kmeans assign_fn signature (returns distances only)."""

    def fn(values, indices, centers):
        d, _ = sparse_assign(values, indices, centers, mode=mode)
        return d

    return fn
