"""Public jit'd wrappers for the Pallas kernels, with backend auto-selection.

On TPU the compiled kernels run natively; elsewhere (this CI container is
CPU-only) they execute via ``interpret=True`` (Pallas interpreter) or fall back
to the jnp oracles for speed. Call sites in core/ go through these wrappers so
the backend choice is one switch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fwht as _fwht
from repro.kernels import ref as _ref
from repro.kernels import sparse_assign as _sa
from repro.kernels import spmm as _spmm


@functools.cache
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def hd_precondition(x: jax.Array, signs: jax.Array, mode: str = "auto") -> jax.Array:
    """Fused y = H(d⊙x). mode ∈ {auto, kernel, interpret, ref}."""
    if mode == "auto":
        mode = "kernel" if _on_tpu() else "ref"
    if mode == "ref":
        return _ref.ref_hd_precondition(x, signs)
    return _fwht.hd_precondition(x, signs, interpret=(mode == "interpret"))


def sparse_assign(values: jax.Array, indices: jax.Array, centers: jax.Array, mode: str = "auto"):
    """(dists, argmin) for sparsified K-means assignment."""
    if mode == "auto":
        mode = "kernel" if _on_tpu() else "ref"
    if mode == "ref":
        return _ref.ref_sparse_assign(values, indices, centers)
    return _sa.sparse_assign(values, indices, centers, interpret=(mode == "interpret"))


# the spmm kernels hold the full (p, l) operand/output block + a (block_rows, p)
# densify scratch in VMEM with no p-tiling yet (ROADMAP); past this budget the
# compiled kernel cannot fit, so "auto"/"kernel" fall back to the jnp path
# (which XLA still runs on-device) instead of failing to compile.
_SPMM_VMEM_BUDGET = 12 << 20


def _sparse_mode(mode: str, p: int, ell: int) -> str:
    """Normalize a backend name to this module's vocabulary.

    Call sites forward ``Plan.impl`` / ``StreamEngine.impl`` here verbatim, and
    that knob speaks the Hadamard vocabulary where the jnp reference is spelled
    "jnp" — map it (and any other non-kernel spelling) to "ref" rather than
    falling through to a Pallas compile that CPU hosts reject.
    """
    if mode == "auto":
        mode = "kernel" if _on_tpu() else "ref"
    if mode not in ("kernel", "interpret"):
        return "ref"
    if mode == "interpret":  # host interpreter: no VMEM constraint to respect
        return mode
    vmem = (p * ell + _spmm.default_block_rows(p) * p) * 4
    return "kernel" if vmem <= _SPMM_VMEM_BUDGET else "ref"


def spmm(values: jax.Array, indices: jax.Array, dense: jax.Array,
         mode: str = "auto") -> jax.Array:
    """T (n, l) = W @ dense for compact sparse rows (the low-rank projection)."""
    mode = _sparse_mode(mode, *dense.shape)
    if mode == "ref":
        return _ref.ref_spmm(values, indices, dense)
    return _spmm.spmm(values, indices, dense, interpret=(mode == "interpret"))


def spmm_t(values: jax.Array, indices: jax.Array, t: jax.Array, p: int,
           mode: str = "auto") -> jax.Array:
    """Y (p, l) = Wᵀ @ t — scatter sparse rows into the l-dim sketch."""
    mode = _sparse_mode(mode, p, t.shape[1])
    if mode == "ref":
        return _ref.ref_spmm_t(values, indices, t, p)
    return _spmm.spmm_t(values, indices, t, p, interpret=(mode == "interpret"))


def kernel_assign_fn(mode: str = "auto"):
    """Adapter matching core.kmeans assign_fn signature (returns distances only)."""

    def fn(values, indices, centers):
        d, _ = sparse_assign(values, indices, centers, mode=mode)
        return d

    return fn
