"""Public jit'd wrappers for the Pallas kernels, with backend auto-selection.

On TPU the compiled kernels run natively; elsewhere (this CI container is
CPU-only) they execute via ``interpret=True`` (Pallas interpreter) or fall back
to the jnp oracles for speed. Call sites in core/ go through these wrappers so
the backend choice is one switch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fwht as _fwht
from repro.kernels import ref as _ref
from repro.kernels import sparse_assign as _sa


@functools.cache
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def hd_precondition(x: jax.Array, signs: jax.Array, mode: str = "auto") -> jax.Array:
    """Fused y = H(d⊙x). mode ∈ {auto, kernel, interpret, ref}."""
    if mode == "auto":
        mode = "kernel" if _on_tpu() else "ref"
    if mode == "ref":
        return _ref.ref_hd_precondition(x, signs)
    return _fwht.hd_precondition(x, signs, interpret=(mode == "interpret"))


def sparse_assign(values: jax.Array, indices: jax.Array, centers: jax.Array, mode: str = "auto"):
    """(dists, argmin) for sparsified K-means assignment."""
    if mode == "auto":
        mode = "kernel" if _on_tpu() else "ref"
    if mode == "ref":
        return _ref.ref_sparse_assign(values, indices, centers)
    return _sa.sparse_assign(values, indices, centers, interpret=(mode == "interpret"))


def kernel_assign_fn(mode: str = "auto"):
    """Adapter matching core.kmeans assign_fn signature (returns distances only)."""

    def fn(values, indices, centers):
        d, _ = sparse_assign(values, indices, centers, mode=mode)
        return d

    return fn
