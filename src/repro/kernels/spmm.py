"""Sparse-times-dense kernels for the low-rank spectral accumulators.

Two primitives over compact sparse rows (values (n, m), indices (n, m)) and a
narrow dense matrix of ``l`` columns (the sketch dimension, l ≪ p):

    spmm:    T = W @ Omega          (n, l)   — project each sparse row
    spmm_t:  Y = Wᵀ @ T             (p, l)   — scatter rows into the l-dim sketch

Together they realize the low-rank co-occurrence delta Wᵀ(W·Omega) = S·Omega
(repro.lowrank) without ever materializing the dense (n, p) batch or the (p, p)
co-occurrence matrix S — the only dense objects are (n, l) and (p, l).

TPU adaptation: like sparse_assign, the irregular gather Omega[indices] has no
fast MXU form, so each row block is densified into VMEM scratch (a rolled
scalar-store loop — the _scatter_outer pattern moved into VMEM) and both
products become dense MXU matmuls against the narrow operand.

The p axis is TILED: the grid carries a second dimension over column blocks of
``block_cols`` columns, the densify scratch is (block_rows, block_cols), and
each step sees only a (block_cols, l) slice of the dense operand — so the VMEM
footprint is bounded by :func:`plan_tiles` against :data:`SPMM_VMEM_BUDGET`
regardless of p (no more p ≲ 2^15 ceiling). Stores into the scratch are MASKED
(load-select-store) because a block only owns indices in [col0, col0+block_cols).
For ``spmm`` the column blocks are the inner (fastest) grid axis, so each
(block_rows, l) output block stays resident while its partial products
accumulate; for ``spmm_t`` the ROW blocks are the inner axis and the (block_cols,
l) output block is the one revisited — zero-initialized at the first reduction
index, accumulated thereafter (the standard reduction-grid pattern), so HBM
writes stay O(p·l) regardless of n.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# THE spmm VMEM model: tiles are planned against this budget (plan_tiles), and
# kernels/ops.py imports it for its dispatch gate — one number, one model.
SPMM_VMEM_BUDGET = 12 << 20


def promoted_dtypes(value_dtype, dense_dtype) -> tuple[jnp.dtype, jnp.dtype]:
    """(operand, accumulator/output) dtypes — the kernels' promotion rule.

    Operands promote jointly (bf16·bf16 stays bf16 into the MXU, mixed
    bf16/f32 runs in f32, f64 stays f64); accumulation and the output are at
    least f32 — the same promote_types ladder the ref.py oracles follow, so
    kernel and oracle agree on output dtype for every input combination.
    """
    op = jnp.promote_types(value_dtype, dense_dtype)
    return op, jnp.promote_types(op, jnp.float32)


def tile_vmem_bytes(p: int, ell: int, value_dtype=jnp.float32,
                    dense_dtype=jnp.float32, block_rows: int = 128,
                    block_cols: int | None = None) -> int:
    """Per-grid-step VMEM footprint of the tiled schedule (dominant terms).

    Counts the (block_cols, l) dense operand tile, the (block_rows,
    block_cols) densify scratch, and the resident output/input row tiles of
    both kernels — all at the ACTUAL promoted dtypes.
    """
    op, out = promoted_dtypes(value_dtype, dense_dtype)
    osz, outsz = jnp.dtype(op).itemsize, jnp.dtype(out).itemsize
    pb = min(block_cols or p, p) if block_cols else p
    return (pb * ell * osz                      # dense / t operand tile
            + block_rows * pb * osz             # densify scratch
            + (block_rows + pb) * ell * outsz)  # out tiles of spmm + spmm_t


def plan_tiles(p: int, ell: int, value_dtype=jnp.float32,
               dense_dtype=jnp.float32,
               vmem_budget: int = SPMM_VMEM_BUDGET) -> tuple[int, int]:
    """(block_rows, block_cols) so the tiled schedule fits ``vmem_budget``.

    Prefers wide column blocks (fewer densify passes over the sparse rows,
    fewer operand re-reads) and tall row blocks, shrinking column blocks
    first, then rows, both by powers of two down to (8, 256).
    """
    pow2_p = 1 << max(0, (p - 1).bit_length())
    br, pb = 128, min(pow2_p, 1 << 15)

    def fits(br_, pb_):
        return tile_vmem_bytes(p, ell, value_dtype, dense_dtype, br_, pb_) <= vmem_budget

    while pb > 256 and not fits(br, pb):
        pb //= 2
    while br > 8 and not fits(br, pb):
        br //= 2
    return br, pb


def _densify(vals_ref, idx_ref, w_ref, *, bn: int, m: int, col0):
    """Masked scatter of the block's sparse rows into the (bn, pb) scratch.

    Only indices in [col0, col0 + pb) land; out-of-block entries must not
    clobber, so the store is load-select-store (a clamped blind store could
    overwrite an in-block value already scattered at the clamp target).
    """
    w_ref[...] = jnp.zeros_like(w_ref)
    pb = w_ref.shape[1]

    def body(t, _):
        i = t // m
        j = t % m
        local = idx_ref[i, j] - col0
        inside = (local >= 0) & (local < pb)
        slot = jnp.where(inside, local, 0)
        cur = pl.load(w_ref, (i, pl.dslice(slot, 1)))
        v = jnp.full((1,), vals_ref[i, j], w_ref.dtype)
        pl.store(w_ref, (i, pl.dslice(slot, 1)), jnp.where(inside, v, cur))
        return 0

    jax.lax.fori_loop(0, bn * m, body, 0)


def _spmm_kernel(vals_ref, idx_ref, dense_ref, out_ref, w_ref, *,
                 bn: int, m: int, pb: int, acc_dtype):
    j = pl.program_id(1)  # column-block (reduction) axis — innermost

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    _densify(vals_ref, idx_ref, w_ref, bn=bn, m=m, col0=j * pb)
    out_ref[...] += jax.lax.dot(
        w_ref[...], dense_ref[...], preferred_element_type=acc_dtype
    ).astype(out_ref.dtype)


def _spmm_t_kernel(vals_ref, idx_ref, t_ref, out_ref, w_ref, *,
                   bn: int, m: int, pb: int, acc_dtype):
    i = pl.program_id(1)  # row-block (reduction) axis — innermost

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    _densify(vals_ref, idx_ref, w_ref, bn=bn, m=m, col0=pl.program_id(0) * pb)
    # Wᵀ @ T as a dot_general contracting the row axis — no explicit transpose
    acc = jax.lax.dot_general(
        w_ref[...], t_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=acc_dtype)
    out_ref[...] += acc.astype(out_ref.dtype)


def _pad_rows(values, indices, extra, br):
    n = values.shape[0]
    n_pad = -n % br
    if n_pad:
        values = jnp.pad(values, ((0, n_pad), (0, 0)))
        indices = jnp.pad(indices, ((0, n_pad), (0, 0)))
        if extra is not None:
            extra = jnp.pad(extra, ((0, n_pad), (0, 0)))
    return values, indices, extra, n_pad


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def spmm(values: jax.Array, indices: jax.Array, dense: jax.Array,
         block_rows: int | None = None, block_cols: int | None = None,
         interpret: bool = False) -> jax.Array:
    """T (n, l) = W @ dense for compact sparse rows W and dense (p, l).

    Padded rows (zero values, index 0) only ever write zeros into column
    block 0, so ragged row blocks are exact; zero-padded dense rows past p
    are never gathered (indices < p).
    """
    n, m = values.shape
    p, ell = dense.shape
    op_dt, out_dt = promoted_dtypes(values.dtype, dense.dtype)
    br0, pb0 = plan_tiles(p, ell, values.dtype, dense.dtype)
    br = block_rows or br0
    pb = block_cols or pb0
    values, indices, _, n_pad = _pad_rows(values, indices, None, br)
    pc = -p % pb
    dense = dense.astype(op_dt)
    if pc:
        dense = jnp.pad(dense, ((0, pc), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_spmm_kernel, bn=br, m=m, pb=pb, acc_dtype=out_dt),
        grid=((n + n_pad) // br, (p + pc) // pb),
        in_specs=[
            pl.BlockSpec((br, m), lambda i, j: (i, 0)),
            pl.BlockSpec((br, m), lambda i, j: (i, 0)),
            pl.BlockSpec((pb, ell), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((br, ell), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, ell), out_dt),
        scratch_shapes=[pltpu.VMEM((br, pb), op_dt)],
        interpret=interpret,
    )(values, indices, dense)
    return out[:n] if n_pad else out


@functools.partial(jax.jit, static_argnames=("p", "block_rows", "block_cols", "interpret"))
def spmm_t(values: jax.Array, indices: jax.Array, t: jax.Array, p: int,
           block_rows: int | None = None, block_cols: int | None = None,
           interpret: bool = False) -> jax.Array:
    """Y (p, l) = Wᵀ @ t for compact sparse rows W (n over p columns), t (n, l).

    Zero-padded rows contribute nothing, so ragged blocks are exact. Column
    blocks are the OUTER grid axis here (the output is indexed by them), so
    the compact rows are re-read once per column block — n·m·(p/block_cols)
    sparse traffic against O(p·l) output writes.
    """
    n, m = values.shape
    ell = t.shape[1]
    op_dt, out_dt = promoted_dtypes(values.dtype, t.dtype)
    br0, pb0 = plan_tiles(p, ell, values.dtype, t.dtype)
    br = block_rows or br0
    pb = block_cols or pb0
    values, indices, t, n_pad = _pad_rows(values, indices, t, br)
    pc = -p % pb

    out = pl.pallas_call(
        functools.partial(_spmm_t_kernel, bn=br, m=m, pb=pb, acc_dtype=out_dt),
        grid=((p + pc) // pb, (n + n_pad) // br),
        in_specs=[
            pl.BlockSpec((br, m), lambda j, i: (i, 0)),
            pl.BlockSpec((br, m), lambda j, i: (i, 0)),
            pl.BlockSpec((br, ell), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((pb, ell), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((p + pc, ell), out_dt),
        scratch_shapes=[pltpu.VMEM((br, pb), op_dt)],
        interpret=interpret,
    )(values, indices, t.astype(op_dt))
    return out[:p] if pc else out
