"""Sparse-times-dense kernels for the low-rank spectral accumulators.

Two primitives over compact sparse rows (values (n, m), indices (n, m)) and a
narrow dense matrix of ``l`` columns (the sketch dimension, l ≪ p):

    spmm:    T = W @ Omega          (n, l)   — project each sparse row
    spmm_t:  Y = Wᵀ @ T             (p, l)   — scatter rows into the l-dim sketch

Together they realize the low-rank co-occurrence delta Wᵀ(W·Omega) = S·Omega
(repro.lowrank) without ever materializing the dense (n, p) batch or the (p, p)
co-occurrence matrix S — the only dense objects are (n, l) and (p, l).

TPU adaptation: like sparse_assign, the irregular gather Omega[indices] has no
fast MXU form, so each row block is densified into a (block_rows, p) VMEM
scratch (a rolled scalar-store loop — the _scatter_outer pattern moved into
VMEM) and both products become dense MXU matmuls against the narrow (p, l)
operand. For spmm_t the (p, l) output block is revisited by every grid step:
zero-initialized at step 0, accumulated thereafter (the standard reduction
grid pattern), so the kernel's HBM writes stay O(p·l) regardless of n.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def default_block_rows(p: int, dtype=jnp.float32, vmem_budget: int = 8 << 20) -> int:
    """Row-block size so the (block_rows, p) densify scratch fits the budget."""
    bytes_per_row = p * jnp.dtype(dtype).itemsize
    br = max(8, vmem_budget // max(1, bytes_per_row))
    return int(min(128, 1 << int(np.floor(np.log2(br)))))


def _densify(vals_ref, idx_ref, w_ref, bn: int, m: int):
    """Scatter the block's sparse rows into the (bn, p) VMEM scratch."""
    w_ref[...] = jnp.zeros_like(w_ref)

    def body(t, _):
        i = t // m
        j = t % m
        col = idx_ref[i, j]
        v = vals_ref[i, j]
        pl.store(w_ref, (i, pl.dslice(col, 1)), jnp.full((1,), v, w_ref.dtype))
        return 0

    jax.lax.fori_loop(0, bn * m, body, 0)


def _spmm_kernel(vals_ref, idx_ref, dense_ref, out_ref, w_ref, *, bn: int, m: int):
    _densify(vals_ref, idx_ref, w_ref, bn, m)
    out_ref[...] = jax.lax.dot(
        w_ref[...], dense_ref[...], preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)


def _spmm_t_kernel(vals_ref, idx_ref, t_ref, out_ref, w_ref, *, bn: int, m: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    _densify(vals_ref, idx_ref, w_ref, bn, m)
    # Wᵀ @ T as a dot_general contracting the row axis — no explicit transpose
    acc = jax.lax.dot_general(
        w_ref[...], t_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] += acc.astype(out_ref.dtype)


def _pad_rows(values, indices, extra, br):
    n = values.shape[0]
    n_pad = -n % br
    if n_pad:
        values = jnp.pad(values, ((0, n_pad), (0, 0)))
        indices = jnp.pad(indices, ((0, n_pad), (0, 0)))
        if extra is not None:
            extra = jnp.pad(extra, ((0, n_pad), (0, 0)))
    return values, indices, extra, n_pad


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def spmm(values: jax.Array, indices: jax.Array, dense: jax.Array,
         block_rows: int | None = None, interpret: bool = False) -> jax.Array:
    """T (n, l) = W @ dense for compact sparse rows W and dense (p, l)."""
    n, m = values.shape
    p, ell = dense.shape
    br = block_rows or default_block_rows(p, values.dtype)
    values, indices, _, n_pad = _pad_rows(values, indices, None, br)

    out = pl.pallas_call(
        functools.partial(_spmm_kernel, bn=br, m=m),
        grid=((n + n_pad) // br,),
        in_specs=[
            pl.BlockSpec((br, m), lambda i: (i, 0)),
            pl.BlockSpec((br, m), lambda i: (i, 0)),
            pl.BlockSpec((p, ell), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, ell), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, ell), jnp.float32),
        scratch_shapes=[pltpu.VMEM((br, p), values.dtype)],
        interpret=interpret,
    )(values, indices, dense.astype(values.dtype))
    return out[:n] if n_pad else out


@functools.partial(jax.jit, static_argnames=("p", "block_rows", "interpret"))
def spmm_t(values: jax.Array, indices: jax.Array, t: jax.Array, p: int,
           block_rows: int | None = None, interpret: bool = False) -> jax.Array:
    """Y (p, l) = Wᵀ @ t for compact sparse rows W (n over p columns), t (n, l).

    Zero-padded rows contribute nothing, so ragged blocks are exact.
    """
    n, m = values.shape
    ell = t.shape[1]
    br = block_rows or default_block_rows(p, values.dtype)
    values, indices, t, n_pad = _pad_rows(values, indices, t, br)

    return pl.pallas_call(
        functools.partial(_spmm_t_kernel, bn=br, m=m),
        grid=((n + n_pad) // br,),
        in_specs=[
            pl.BlockSpec((br, m), lambda i: (i, 0)),
            pl.BlockSpec((br, m), lambda i: (i, 0)),
            pl.BlockSpec((br, ell), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((p, ell), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, ell), jnp.float32),
        scratch_shapes=[pltpu.VMEM((br, p), values.dtype)],
        interpret=interpret,
    )(values, indices, t.astype(values.dtype))
