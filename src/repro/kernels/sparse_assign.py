"""Sparsified K-means assignment kernel (paper Eq. 36) on compact sparse rows.

Computes, for every sample i with kept coordinates (values V_i, indices I_i):

    d[i, k] = ‖z_i − R_iᵀ μ_k‖² = Σ_j V_ij² − 2⟨W_i, μ_k⟩ + ⟨S_i, μ_k²⟩

where W_i is the densified sparse row and S_i its 0/1 support mask.

TPU adaptation (DESIGN.md §3.2): the irregular gather μ_k[I_ij] has no fast MXU
form, so we *densify inside VMEM* (never materializing W, S in HBM) and realize
both inner products as dense (block_rows × p) @ (p × K) MXU matmuls. HBM traffic
stays compact — 8·n·m bytes in, 4·n·(K+1) out — so the paper's γ saving survives
as a *bandwidth* saving while the arithmetic runs at MXU rate. Densification is
a rolled scalar loop of VMEM stores (indices are distinct per row, so plain
stores suffice); its trip count is block_rows·m, amortized across the two
matmuls that follow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(vals_ref, idx_ref, ctr_t_ref, ctr2_t_ref, dist_ref, amin_ref,
            w_ref, s_ref, *, bn: int, m: int):
    w_ref[...] = jnp.zeros_like(w_ref)
    s_ref[...] = jnp.zeros_like(s_ref)

    def body(t, _):
        i = t // m
        j = t % m
        col = idx_ref[i, j]
        v = vals_ref[i, j]
        pl.store(w_ref, (i, pl.dslice(col, 1)), jnp.full((1,), v, w_ref.dtype))
        pl.store(s_ref, (i, pl.dslice(col, 1)), jnp.ones((1,), s_ref.dtype))
        return 0

    jax.lax.fori_loop(0, bn * m, body, 0)

    v = vals_ref[...]
    v2 = jnp.sum(v * v, axis=1, keepdims=True)               # (bn, 1)
    f32 = jnp.float32
    cross = jax.lax.dot(w_ref[...], ctr_t_ref[...], preferred_element_type=f32)
    mask2 = jax.lax.dot(s_ref[...], ctr2_t_ref[...], preferred_element_type=f32)
    d = v2.astype(f32) - 2.0 * cross + mask2
    dist_ref[...] = d.astype(dist_ref.dtype)
    amin_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)[:, None]


def default_block_rows(p: int, dtype=jnp.float32, vmem_budget: int = 8 << 20) -> int:
    bytes_per_row = 2 * p * jnp.dtype(dtype).itemsize        # w + s scratch
    br = max(8, vmem_budget // max(1, bytes_per_row))
    return int(min(128, 1 << int(np.floor(np.log2(br)))))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sparse_assign(values: jax.Array, indices: jax.Array, centers: jax.Array,
                  block_rows: int | None = None, interpret: bool = False):
    """(dists (n, K) f32, argmin (n,) int32) for compact sparse rows vs centers (K, p)."""
    n, m = values.shape
    k, p = centers.shape
    br = block_rows or default_block_rows(p, values.dtype)
    n_pad = -n % br
    if n_pad:
        values = jnp.pad(values, ((0, n_pad), (0, 0)))
        indices = jnp.pad(indices, ((0, n_pad), (0, 0)))
    ctr_t = centers.astype(values.dtype).T                   # (p, K)
    ctr2_t = (centers.astype(jnp.float32) ** 2).astype(values.dtype).T

    dists, amin = pl.pallas_call(
        functools.partial(_kernel, bn=br, m=m),
        grid=((n + n_pad) // br,),
        in_specs=[
            pl.BlockSpec((br, m), lambda i: (i, 0)),
            pl.BlockSpec((br, m), lambda i: (i, 0)),
            pl.BlockSpec((p, k), lambda i: (0, 0)),
            pl.BlockSpec((p, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + n_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((n + n_pad, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((br, p), values.dtype),
            pltpu.VMEM((br, p), values.dtype),
        ],
        interpret=interpret,
    )(values, indices, ctr_t, ctr2_t)
    dists = dists[:n] if n_pad else dists
    amin = (amin[:n] if n_pad else amin)[:, 0]
    return dists, amin
