"""Pallas TPU kernels for the paper's two compute hot-spots.

- fwht:          fused ROS preconditioning y = H(d⊙x) — Kronecker MXU form
- sparse_assign: sparsified K-means assignment on compact sparse rows
- ops:           public wrappers (backend auto-selection)
- ref:           pure-jnp oracles used for validation
"""
from repro.kernels import fwht, ops, ref, sparse_assign  # noqa: F401
