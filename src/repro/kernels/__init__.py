"""Pallas TPU kernels for the paper's compute hot-spots.

- fwht:          fused ROS preconditioning y = H(d⊙x) — Kronecker MXU form
- sketch_fused:  the FULL compression operator (precondition → sample) in one
                 VMEM round trip — the streaming-ingest fast path
- sparse_assign: sparsified K-means assignment on compact sparse rows
- spmm:          sparse-times-dense pair (W·Omega and Wᵀ·T) feeding the
                 low-rank spectral accumulators without densifying the batch;
                 p-tiled so the VMEM footprint is bounded at any p
- ops:           public wrappers (backend auto-selection)
- ref:           pure-jnp oracles used for validation
"""
from repro.kernels import fwht, ops, ref, sketch_fused, sparse_assign, spmm  # noqa: F401
