"""Fused ROS preconditioning kernel: y = H·(d ⊙ x) as Kronecker-factored MXU matmuls.

TPU adaptation (DESIGN.md §3.1): GPU FWHTs use warp-shuffle butterflies; the TPU
equivalent is the Kronecker identity

    H_p = H_a ⊗ H_b   (p = a·b, Sylvester ordering)
    H_p x = vec( H_a · mat_{a×b}(x) · H_bᵀ )      (row-major reshape)

so the whole transform becomes two dense matmuls on the systolic array, with the
sign flip (D) fused into the same VMEM round-trip. Cost p·(a+b) MACs/row instead
of the butterfly's p·log₂p VPU ops — fewer passes over VMEM and ~all of it on
the MXU. For p ≤ 256 a single dense H_p matmul is used (a = 1).

The kernel tiles rows; each grid step owns a (block_rows, p) tile resident in
VMEM. H_a, H_b (and the sign vector) are small and replicated to every step.

**Large p (the streaming regime, p > 2^15):** a (block_rows, p) tile no longer
fits VMEM, so the transform is *chunked* with the three-factor identity

    H_p = H_a ⊗ H_b ⊗ H_c   (a·b·c = p, each factor ≤ 2^9)

and realized as three passes over the data, each pass a tiled (rows, f) @ H_f
matmul whose (block, f) chunks fit VMEM independent of p. The sign flip is
fused into the first pass; the reorderings between passes are XLA transposes.
This lifts the previous MAX_P = 2^15 ceiling to 2^27 — see
:func:`hd_precondition_chunked` and tests/test_stream.py for the p = 2^17
interpret-mode equivalence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.ros import hadamard_matrix

# largest p the single-tile kernel supports: (block_rows × p) must fit VMEM.
MAX_P_SINGLE = 1 << 15
# largest p overall — the chunked three-pass schedule with factors ≤ 2^9.
MAX_P = 1 << 27


def factor_p(p: int) -> tuple[int, int]:
    """Split p = a·b with b the MXU-friendly inner factor (b ≥ 128 when possible)."""
    if p & (p - 1):
        raise ValueError(f"p must be a power of two, got {p}")
    if p <= 256:
        return 1, p
    k = p.bit_length() - 1
    b = 1 << max(7, (k + 1) // 2)    # inner factor ≥ 128
    return p // b, b


def factor_p3(p: int) -> tuple[int, int, int]:
    """Split p = a·b·c (Sylvester order) with every factor ≤ 2^9.

    The trailing factors are filled greedily to 2^9 so the two hot passes
    contract MXU-friendly 512-lane dimensions; the outer factor a absorbs the
    remainder (a = 1 for p ≤ 2^18).
    """
    if p & (p - 1):
        raise ValueError(f"p must be a power of two, got {p}")
    k = p.bit_length() - 1
    kc = min(9, k)
    kb = min(9, k - kc)
    ka = k - kc - kb
    if ka > 9:
        raise ValueError(f"p={p} exceeds chunked-kernel limit {MAX_P}")
    return 1 << ka, 1 << kb, 1 << kc


def default_block_rows(p: int, dtype=jnp.float32, vmem_budget: int = 6 << 20) -> int:
    """Rows per tile so that in+out tiles fit the VMEM budget."""
    bytes_per_row = 2 * p * jnp.dtype(dtype).itemsize
    br = max(8, vmem_budget // max(1, bytes_per_row))
    return int(min(256, 1 << int(np.floor(np.log2(br)))))


def _kernel(x_ref, d_ref, ha_ref, hb_ref, o_ref, *, a: int, b: int):
    x = x_ref[...] * d_ref[...]                              # sign flip (D), fused
    bn = x.shape[0]
    f32 = jnp.float32
    if a == 1:
        y = jax.lax.dot(x, hb_ref[...], preferred_element_type=f32)
    else:
        # inner factor: contract the trailing b axis with H_b
        y = jax.lax.dot(x.reshape(bn * a, b), hb_ref[...], preferred_element_type=f32)
        # outer factor: contract the a axis with H_a
        y = y.reshape(bn, a, b).transpose(0, 2, 1).reshape(bn * b, a)
        y = jax.lax.dot(y, ha_ref[...], preferred_element_type=f32)
        y = y.reshape(bn, b, a).transpose(0, 2, 1).reshape(bn, a * b)
    o_ref[...] = y.astype(o_ref.dtype)


# ---------------------------------------------------- chunked three-pass ----

def _pass_kernel(x_ref, h_ref, o_ref):
    o_ref[...] = jax.lax.dot(
        x_ref[...], h_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pass_signs_kernel(x_ref, s_ref, h_ref, o_ref):
    o_ref[...] = jax.lax.dot(
        x_ref[...] * s_ref[...], h_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _factor_pass(z: jax.Array, h: jax.Array, block_rows: int, interpret: bool,
                 signs2d: jax.Array | None = None) -> jax.Array:
    """One Kronecker-factor contraction: (R, f) @ H_f in (block_rows, f) chunks.

    ``signs2d`` (rows_per_cycle, f), when given, is the D diagonal reshaped so
    that the sign row for global row r is r mod rows_per_cycle; block_rows must
    divide rows_per_cycle for the modular BlockSpec below to tile it exactly
    (guaranteed by the power-of-two choices in :func:`hd_precondition_chunked`).
    """
    rows, f = z.shape
    if rows % block_rows:
        raise ValueError(f"block_rows={block_rows} must divide the pass row count {rows}")
    if signs2d is not None and signs2d.shape[0] % block_rows:
        raise ValueError(
            f"block_rows={block_rows} must divide the sign cycle {signs2d.shape[0]}")
    grid = (rows // block_rows,)
    out_shape = jax.ShapeDtypeStruct(z.shape, z.dtype)
    io_spec = pl.BlockSpec((block_rows, f), lambda i: (i, 0))
    h_spec = pl.BlockSpec((f, f), lambda i: (0, 0))
    if signs2d is None:
        return pl.pallas_call(
            _pass_kernel, grid=grid, in_specs=[io_spec, h_spec],
            out_specs=io_spec, out_shape=out_shape, interpret=interpret,
        )(z, h)
    n_sign_blocks = signs2d.shape[0] // block_rows
    sign_spec = pl.BlockSpec((block_rows, f), lambda i: (i % n_sign_blocks, 0))
    return pl.pallas_call(
        _pass_signs_kernel, grid=grid, in_specs=[io_spec, sign_spec, h_spec],
        out_specs=io_spec, out_shape=out_shape, interpret=interpret,
    )(z, signs2d, h)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def hd_precondition_chunked(x: jax.Array, signs: jax.Array,
                            block_rows: int | None = None,
                            interpret: bool = False) -> jax.Array:
    """y = H·(signs ⊙ x) for p > 2^15 via the chunked H_a ⊗ H_b ⊗ H_c schedule.

    Three passes over the data, each a tiled small-f matmul whose working set is
    (block_rows, f) ≤ (256, 512) regardless of p; the D sign flip rides the
    first pass. Exact (up to f32 rounding) for any power of two p ≤ 2^27.
    ``block_rows``, when given, caps the per-pass tile height and must be a
    power of two (each pass validates divisibility against its row count).
    """
    n, p = x.shape
    a, b, c = factor_p3(p)
    dt = x.dtype
    ab = a * b
    cap = block_rows or 256

    # pass 1 — contract c, signs fused. Rows of the (n·a·b, c) view cycle
    # through sign rows with period a·b, so br | a·b keeps sign blocks exact.
    br1 = min(cap, ab)
    z = _factor_pass(x.reshape(n * ab, c), hadamard_matrix(c, dt), br1,
                     interpret, signs2d=signs.astype(dt).reshape(ab, c))

    # pass 2 — contract b (bring it to the lane axis, contract, restore).
    if b > 1:
        z = z.reshape(n, a, b, c).transpose(0, 1, 3, 2).reshape(n * a * c, b)
        z = _factor_pass(z, hadamard_matrix(b, dt), min(cap, a * c), interpret)
        z = z.reshape(n, a, c, b).transpose(0, 1, 3, 2)

    # pass 3 — contract the outer factor a (identity when a == 1).
    if a > 1:
        z = z.reshape(n, a, b * c).transpose(0, 2, 1).reshape(n * b * c, a)
        z = _factor_pass(z, hadamard_matrix(a, dt), min(cap, b * c), interpret)
        z = z.reshape(n, b * c, a).transpose(0, 2, 1)

    return z.reshape(n, p)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def hd_precondition(x: jax.Array, signs: jax.Array, block_rows: int | None = None,
                    interpret: bool = False) -> jax.Array:
    """y = H·(signs ⊙ x) along the last axis. x: (n, p), p a power of two ≤ 2^27.

    Dispatches to the single-tile two-factor kernel for p ≤ 2^15 and to the
    chunked three-pass schedule above it.
    """
    n, p = x.shape
    if p > MAX_P:
        raise ValueError(f"p={p} exceeds chunked kernel limit {MAX_P}")
    if p > MAX_P_SINGLE:
        return hd_precondition_chunked(x, signs, block_rows=block_rows, interpret=interpret)
    a, b = factor_p(p)
    br = block_rows or default_block_rows(p, x.dtype)
    n_pad = -n % br
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
    ha = hadamard_matrix(a, x.dtype) if a > 1 else jnp.zeros((1, 1), x.dtype)
    hb = hadamard_matrix(b, x.dtype)
    d2 = signs.astype(x.dtype)[None, :]

    out = pl.pallas_call(
        functools.partial(_kernel, a=a, b=b),
        grid=((n + n_pad) // br,),
        in_specs=[
            pl.BlockSpec((br, p), lambda i: (i, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
            pl.BlockSpec((max(a, 1), max(a, 1)), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((n + n_pad), p), x.dtype),
        interpret=interpret,
    )(x, d2, ha, hb)
    return out[:n] if n_pad else out
