"""Fused ROS preconditioning kernel: y = H·(d ⊙ x) as Kronecker-factored MXU matmuls.

TPU adaptation (DESIGN.md §3.1): GPU FWHTs use warp-shuffle butterflies; the TPU
equivalent is the Kronecker identity

    H_p = H_a ⊗ H_b   (p = a·b, Sylvester ordering)
    H_p x = vec( H_a · mat_{a×b}(x) · H_bᵀ )      (row-major reshape)

so the whole transform becomes two dense matmuls on the systolic array, with the
sign flip (D) fused into the same VMEM round-trip. Cost p·(a+b) MACs/row instead
of the butterfly's p·log₂p VPU ops — fewer passes over VMEM and ~all of it on
the MXU. For p ≤ 256 a single dense H_p matmul is used (a = 1).

The kernel tiles rows; each grid step owns a (block_rows, p) tile resident in
VMEM. H_a, H_b (and the sign vector) are small and replicated to every step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.ros import hadamard_matrix

# largest p the single-tile kernel supports: (block_rows × p) must fit VMEM.
MAX_P = 1 << 15


def factor_p(p: int) -> tuple[int, int]:
    """Split p = a·b with b the MXU-friendly inner factor (b ≥ 128 when possible)."""
    if p & (p - 1):
        raise ValueError(f"p must be a power of two, got {p}")
    if p <= 256:
        return 1, p
    k = p.bit_length() - 1
    b = 1 << max(7, (k + 1) // 2)    # inner factor ≥ 128
    return p // b, b


def default_block_rows(p: int, dtype=jnp.float32, vmem_budget: int = 6 << 20) -> int:
    """Rows per tile so that in+out tiles fit the VMEM budget."""
    bytes_per_row = 2 * p * jnp.dtype(dtype).itemsize
    br = max(8, vmem_budget // max(1, bytes_per_row))
    return int(min(256, 1 << int(np.floor(np.log2(br)))))


def _kernel(x_ref, d_ref, ha_ref, hb_ref, o_ref, *, a: int, b: int):
    x = x_ref[...] * d_ref[...]                              # sign flip (D), fused
    bn = x.shape[0]
    f32 = jnp.float32
    if a == 1:
        y = jax.lax.dot(x, hb_ref[...], preferred_element_type=f32)
    else:
        # inner factor: contract the trailing b axis with H_b
        y = jax.lax.dot(x.reshape(bn * a, b), hb_ref[...], preferred_element_type=f32)
        # outer factor: contract the a axis with H_a
        y = y.reshape(bn, a, b).transpose(0, 2, 1).reshape(bn * b, a)
        y = jax.lax.dot(y, ha_ref[...], preferred_element_type=f32)
        y = y.reshape(bn, b, a).transpose(0, 2, 1).reshape(bn, a * b)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def hd_precondition(x: jax.Array, signs: jax.Array, block_rows: int | None = None,
                    interpret: bool = False) -> jax.Array:
    """y = H·(signs ⊙ x) along the last axis. x: (n, p), p a power of two ≤ 2^15."""
    n, p = x.shape
    if p > MAX_P:
        raise ValueError(f"p={p} exceeds single-tile kernel limit {MAX_P}; chunk first")
    a, b = factor_p(p)
    br = block_rows or default_block_rows(p, x.dtype)
    n_pad = -n % br
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
    ha = hadamard_matrix(a, x.dtype) if a > 1 else jnp.zeros((1, 1), x.dtype)
    hb = hadamard_matrix(b, x.dtype)
    d2 = signs.astype(x.dtype)[None, :]

    out = pl.pallas_call(
        functools.partial(_kernel, a=a, b=b),
        grid=((n + n_pad) // br,),
        in_specs=[
            pl.BlockSpec((br, p), lambda i: (i, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
            pl.BlockSpec((max(a, 1), max(a, 1)), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((n + n_pad), p), x.dtype),
        interpret=interpret,
    )(x, d2, ha, hb)
    return out[:n] if n_pad else out
