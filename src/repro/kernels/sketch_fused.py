"""Fused one-pass sketch kernel: y = gather_m( H·(d ⊙ x) ) — the paper's full
compression operator in a single VMEM round trip.

Composition of the two stages (fwht kernel then an XLA gather) writes the dense
preconditioned tile back to HBM only to re-read γ of it. Fusing keeps the
dense intermediate in VMEM and writes ONLY the m kept values per row — HBM
traffic drops from (2 + γ)·n·p·4 bytes to (1 + 2γ)·n·p·4, i.e. ~2.5× for
γ = 0.05 on the streaming-ingest path (the paper's Tables III/IV setting).

The per-row gather uses the indices as a VMEM scalar walk (rolled loop, same
pattern as sparse_assign's densify but in reverse); the FWHT itself stays on
the MXU via the Kronecker form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ros import hadamard_matrix
from repro.kernels.fwht import MAX_P_SINGLE, default_block_rows, factor_p

# The fused kernel holds a whole (block_rows, p) preconditioned tile in VMEM,
# so it shares the single-tile FWHT ceiling; above it, kernels.ops composes
# the chunked FWHT with an XLA gather instead.
MAX_P_FUSED = MAX_P_SINGLE


def _kernel(x_ref, d_ref, ha_ref, hb_ref, idx_ref, out_ref, *, a: int, b: int, m: int):
    x = x_ref[...] * d_ref[...]
    bn = x.shape[0]
    f32 = jnp.float32
    if a == 1:
        y = jax.lax.dot(x, hb_ref[...], preferred_element_type=f32)
    else:
        y = jax.lax.dot(x.reshape(bn * a, b), hb_ref[...], preferred_element_type=f32)
        y = y.reshape(bn, a, b).transpose(0, 2, 1).reshape(bn * b, a)
        y = jax.lax.dot(y, ha_ref[...], preferred_element_type=f32)
        y = y.reshape(bn, b, a).transpose(0, 2, 1).reshape(bn, a * b)
    y = y.astype(out_ref.dtype)

    def body(t, _):
        i = t // m
        j = t % m
        col = idx_ref[i, j]
        pl.store(out_ref, (i, pl.dslice(j, 1)),
                 jax.lax.dynamic_slice(y, (i, col), (1, 1))[0])
        return 0

    jax.lax.fori_loop(0, bn * m, body, 0)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sketch_fused(x: jax.Array, signs: jax.Array, indices: jax.Array,
                 block_rows: int | None = None, interpret: bool = False) -> jax.Array:
    """values (n, m) = (H·(signs⊙x))[i, indices[i]] — fused precondition+sample.

    x (n, p) with p a power of two ≤ MAX_P_FUSED; indices (n, m) int32
    (sorted, distinct). Dispatch through kernels.ops.sketch_fused to get the
    composed chunked-FWHT + gather fallback above the ceiling.
    """
    n, p = x.shape
    m = indices.shape[1]
    if p > MAX_P_FUSED:
        raise ValueError(
            f"p={p} exceeds the fused kernel's single-tile ceiling "
            f"{MAX_P_FUSED}; use kernels.ops.sketch_fused (composed fallback)")
    a, b = factor_p(p)
    br = block_rows or default_block_rows(p, x.dtype)
    n_pad = -n % br
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
        indices = jnp.pad(indices, ((0, n_pad), (0, 0)))
    ha = hadamard_matrix(a, x.dtype) if a > 1 else jnp.zeros((1, 1), x.dtype)
    hb = hadamard_matrix(b, x.dtype)
    d2 = signs.astype(x.dtype)[None, :]

    out = pl.pallas_call(
        functools.partial(_kernel, a=a, b=b, m=m),
        grid=((n + n_pad) // br,),
        in_specs=[
            pl.BlockSpec((br, p), lambda i: (i, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
            pl.BlockSpec((max(a, 1), max(a, 1)), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
            pl.BlockSpec((br, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, m), x.dtype),
        interpret=interpret,
    )(x, d2, ha, hb, indices)
    return out[:n] if n_pad else out
