from repro.roofline import analysis, hlo, hw, kernels  # noqa: F401
