from repro.roofline import analysis, hlo, hw  # noqa: F401
