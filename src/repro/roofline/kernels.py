"""Analytic roofline models for the Pallas kernels (TPU v5e constants, hw.py).

One model per kernel, each reflecting the kernel's ACTUAL schedule — not a
generic bytes-in-bytes-out guess. The spmm pair re-reads operands once per
grid block exactly as the tiled BlockSpecs do (kernels/spmm.py plans the
(block_rows, block_cols) tiles; the models call the same planner), the FWHT
models the Kronecker (a + b) MAC count, and sketch_fused carries the fused
vs composed HBM-traffic story the kernel exists for. benchmarks/kernel_bench.py
divides measured throughput by these predictions to report the per-kernel
roofline fraction into BENCH_kernels.json.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.kernels import fwht as _fwht
from repro.kernels import sketch_fused as _sf
from repro.kernels import spmm as _spmm
from repro.roofline import hw


@dataclasses.dataclass(frozen=True)
class KernelRoofline:
    """Roofline prediction for one kernel invocation shape."""

    name: str
    n: int            # rows processed per invocation
    hbm_bytes: int    # total HBM traffic under the kernel's tiling schedule
    flops: int        # total floating-point ops (2 per MAC)

    @property
    def mem_us(self) -> float:
        return self.hbm_bytes / hw.HBM_BW * 1e6

    @property
    def compute_us(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16 * 1e6

    @property
    def us(self) -> float:
        """Roofline time: max of the memory and compute legs."""
        return max(self.mem_us, self.compute_us)

    @property
    def bound(self) -> str:
        return "memory" if self.mem_us >= self.compute_us else "compute"

    @property
    def rows_per_sec(self) -> float:
        return self.n / (self.us / 1e6)


def _isz(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def _pow2ceil(p: int) -> int:
    return 1 << max(p - 1, 1).bit_length() if p & (p - 1) else p


def spmm_roofline(n: int, m: int, p: int, ell: int,
                  value_dtype=jnp.float32, dense_dtype=jnp.float32) -> KernelRoofline:
    """T = W @ dense under the tiled (row_blocks × col_blocks) grid.

    Sparse rows stream once ((n, m) values + int32 indices, resident across
    the inner column-block axis); the (p, ell) dense operand is re-read once
    per ROW block (each row block walks every column block); the (n, ell)
    output block stays VMEM-resident over the reduction and writes once. The
    densify trick buys dense MXU compute over the padded p: 2·n·p_pad·ell.
    """
    op_dt, out_dt = _spmm.promoted_dtypes(value_dtype, dense_dtype)
    br, pb = _spmm.plan_tiles(p, ell, value_dtype, dense_dtype)
    pp = -(-p // pb) * pb
    row_blocks = -(-n // br)
    hbm = (n * m * (_isz(value_dtype) + 4)
           + row_blocks * pp * ell * _isz(op_dt)
           + n * ell * _isz(out_dt))
    return KernelRoofline("spmm", n, hbm, 2 * n * pp * ell)


def spmm_t_roofline(n: int, m: int, p: int, ell: int,
                    value_dtype=jnp.float32, t_dtype=jnp.float32) -> KernelRoofline:
    """Y = Wᵀ @ t under the tiled (col_blocks × row_blocks) grid.

    The (p_block, ell) output block is resident while the row-block axis
    reduces, so the sparse rows AND the (n, ell) t operand are re-read once
    per COLUMN block; the (p, ell) output writes once.
    """
    op_dt, out_dt = _spmm.promoted_dtypes(value_dtype, t_dtype)
    br, pb = _spmm.plan_tiles(p, ell, value_dtype, t_dtype)
    pp = -(-p // pb) * pb
    col_blocks = pp // pb
    hbm = (col_blocks * (n * m * (_isz(value_dtype) + 4)
                         + n * ell * _isz(op_dt))
           + pp * ell * _isz(out_dt))
    return KernelRoofline("spmm_t", n, hbm, 2 * n * pp * ell)


def fwht_roofline(n: int, p: int, dtype=jnp.float32) -> KernelRoofline:
    """y = H(d⊙x) via the Kronecker MXU form: p = a·b costs (a + b) MACs per
    element instead of the p a naive matmul would. Above the single-tile
    ceiling the chunked 3-pass schedule makes three read+write sweeps."""
    pp = _pow2ceil(max(p, 2))
    sz = _isz(dtype)
    if pp <= _fwht.MAX_P_SINGLE:
        a, b = _fwht.factor_p(pp)
        passes, macs = 1, n * pp * (a + b)
    else:
        f1, f2, f3 = _fwht.factor_p3(pp)
        passes, macs = 3, n * pp * (f1 + f2 + f3)
    return KernelRoofline("fwht", n, passes * 2 * n * pp * sz, 2 * macs)


def sketch_fused_roofline(n: int, p: int, m: int, dtype=jnp.float32) -> KernelRoofline:
    """The full compression operator values pass, fused: read x once, write
    ONLY the (n, m) kept values + their indices — (1 + 2γ)·n·p traffic vs the
    composed (3 + 2γ)-ish path (kernel_bench reports both so the ~2.5× HBM
    win at γ=0.05 is visible in the trajectory)."""
    pp = _pow2ceil(max(p, 2))
    sz = _isz(dtype)
    if pp <= _sf.MAX_P_FUSED:
        a, b = _fwht.factor_p(pp)
        hbm = n * pp * sz + n * m * (sz + 4)
        macs = n * pp * (a + b)
    else:
        # composed fallback: chunked FWHT (3 read+write sweeps) then a gather
        # that re-reads the dense intermediate and writes the kept values
        f1, f2, f3 = _fwht.factor_p3(pp)
        hbm = 3 * 2 * n * pp * sz + n * pp * sz + n * m * (sz + 4)
        macs = n * pp * (f1 + f2 + f3)
    return KernelRoofline("sketch_fused", n, hbm, 2 * macs)
