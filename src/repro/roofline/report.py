"""Assemble EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run JSONs.

    PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def load(dir_: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def dryrun_table(recs):
    lines = ["| arch | shape | mesh | kind | status | peak GB/chip | fits 16GB | compile s |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | skip: sub-quadratic rule | — | — | — |")
            continue
        if r["status"] == "fail":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | FAIL: {r['error'][:60]} | — | — | — |")
            continue
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} | ok "
            f"| {fmt_bytes(m['peak_bytes'])} | {'✓' if m['fits_16GB'] else '✗'} "
            f"| {r['compile_s']} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = ["| arch | shape | t_comp | t_mem | t_coll | dominant | MODEL_FLOPS | useful ratio | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != "single" or "roofline" not in r:
            continue
        t = r["roofline"]["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['t_compute_s'])} | {fmt_s(t['t_memory_s'])} "
            f"| {fmt_s(t['t_collective_s'])} | **{t['dominant']}** | {t['model_flops']:.3g} "
            f"| {t['useful_ratio']:.2f} | {t['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def collective_mix(recs):
    lines = ["| arch | shape | all-gather GB | all-reduce GB | reduce-scatter GB | all-to-all GB | permute GB |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != "single" or "roofline" not in r:
            continue
        bk = r["roofline"]["per_device"]["wire_by_kind"]
        g = lambda k: f"{bk.get(k, 0)/2**30:.2f}"  # noqa: E731
        lines.append(f"| {r['arch']} | {r['shape']} | {g('all-gather')} | {g('all-reduce')} "
                     f"| {g('reduce-scatter')} | {g('all-to-all')} | {g('collective-permute')} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_fail = sum(r["status"] == "fail" for r in recs)
    n_skip = sum(r["status"] == "skip" for r in recs)
    print(f"### Dry-run status: {n_ok} ok / {n_skip} skip / {n_fail} fail\n")
    print(dryrun_table(recs))
    print("\n### Roofline (single-pod, per chip)\n")
    print(roofline_table(recs))
    print("\n### Collective mix (per chip per step)\n")
    print(collective_mix(recs))


if __name__ == "__main__":
    main()
