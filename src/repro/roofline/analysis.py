"""Three-term roofline from AOT-compiled artifacts (no hardware required).

Method (EXPERIMENTS.md §Roofline):
1. XLA's cost analysis counts while-loop bodies ONCE, so layer-scanned models
   undercount. We lower two *unrolled* depth probes (scan_unroll = depth ⇒
   every layer instance visible to the static analysis) at FULL width on the
   production mesh and extrapolate affinely in the scan trip count:
       f(L) = intercept + slope·L,  slope = (f(d₂)−f(d₁))/(d₂−d₁).
2. Shapes in partitioned HLO are per-device ⇒ flops/bytes/wire are per-chip.
       compute    = flops/chip ÷ 197 TF/s
       memory     = bytes/chip ÷ 819 GB/s
       collective = wire bytes/chip ÷ 50 GB/s per link
3. MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (prefill/decode) with
   N_active counting routed experts at top-k/E weight; the ratio
   MODEL_FLOPS/HLO_FLOPs exposes remat/causal/cond-branch waste.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.api import get_api
from repro.roofline import hw
from repro.roofline.hlo import collective_stats
from repro.utils.tree import tree_count_params


def count_params(cfg: ModelConfig) -> dict:
    """Total and activated (per-token) parameter counts from the real param tree."""
    api = get_api(cfg)
    specs = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_leaves_with_path(specs)
    total = expert = embed = enc = 0
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        total += n
        ks = jax.tree_util.keystr(path)
        if "moe" in ks and ("w_gate" in ks or "w_up" in ks or "w_down" in ks):
            expert += n
        if ks.endswith("embed']"):
            embed += n          # gather: ~0 matmul flops
        if "enc_layers" in ks:
            enc += n
    active = total - expert - embed
    if cfg.n_experts:
        active += expert * cfg.experts_per_token / cfg.n_experts
    return {"total": total, "active": int(active), "expert": expert,
            "embed": embed, "encoder": enc}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference fwd), D = processed tokens.

    N_active excludes embedding gathers; enc-dec prefill (= encode only) uses
    the encoder share of the parameters.
    """
    counts = count_params(cfg)
    n_active = counts["active"]
    if shape.kind == "prefill" and cfg.family == "audio":
        n_active = counts["encoder"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch          # decode: one token per seq


def probe_depths(cfg: ModelConfig) -> tuple[int, int]:
    period = cfg.attn_every or 1
    return period, 2 * period


def _probe_cfg(cfg: ModelConfig, depth: int) -> ModelConfig:
    return dataclasses.replace(
        cfg,
        n_layers=depth + cfg.first_k_dense,
        n_enc_layers=depth if cfg.n_enc_layers else 0,
        scan_unroll=max(depth, 1),
    )


def probe_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, tcfg=None) -> dict:
    """Lower+compile the two unrolled depth probes; extrapolate to full depth."""
    from repro.train.trainer import TrainerConfig, lower_cell

    tcfg = tcfg or TrainerConfig(sp=True)
    d1, d2 = probe_depths(cfg)
    results = []
    for d in (d1, d2):
        t0 = time.time()
        lowered, _ = lower_cell(_probe_cfg(cfg, d), shape, mesh, tcfg)
        compiled = lowered.compile()
        ca = compiled.cost_analysis()
        txt = compiled.as_text()
        coll = collective_stats(txt)
        results.append({
            "depth": d,
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "wire": coll["total_wire_bytes"],
            "by_kind": {k: v["wire_bytes"] for k, v in coll["by_kind"].items()},
            "compile_s": time.time() - t0,
        })
        del lowered, compiled, txt

    L_full = cfg.n_layers - cfg.first_k_dense
    # slopes are physically ≥ 0 (adding layers can't remove work); tiny negative
    # slopes appear on intercept-dominated cells when the two probes partition
    # slightly differently — clamp instead of extrapolating below the probe.
    def extrap(key):
        f1, f2 = results[0][key], results[1][key]
        slope = max((f2 - f1) / (d2 - d1), 0.0)
        return max(f1 + slope * (L_full - d1), f1), slope

    flops, flops_slope = extrap("flops")
    bytes_, bytes_slope = extrap("bytes")
    wire, wire_slope = extrap("wire")
    kinds = sorted(set(results[0]["by_kind"]) | set(results[1]["by_kind"]))
    by_kind = {}
    for k in kinds:
        f1 = results[0]["by_kind"].get(k, 0.0)
        f2 = results[1]["by_kind"].get(k, 0.0)
        slope_k = max((f2 - f1) / (d2 - d1), 0.0)
        by_kind[k] = max(f1 + slope_k * (L_full - d1), f1)

    return {
        "per_device": {"flops": flops, "bytes": bytes_, "wire_bytes": wire, "wire_by_kind": by_kind},
        "slopes": {"flops": flops_slope, "bytes": bytes_slope, "wire": wire_slope},
        "probes": results,
    }


def roofline_terms(per_device: dict, n_chips: int, cfg, shape) -> dict:
    t_comp = per_device["flops"] / hw.PEAK_FLOPS_BF16
    t_mem = per_device["bytes"] / hw.HBM_BW
    t_coll = per_device["wire_bytes"] / hw.ICI_BW
    dominant = max(
        [("compute", t_comp), ("memory", t_mem), ("collective", t_coll)], key=lambda kv: kv[1]
    )[0]
    mf = model_flops(cfg, shape)
    hlo_global = per_device["flops"] * n_chips
    step_time = max(t_comp, t_mem, t_coll)    # perfect-overlap lower bound
    mfu = mf / (n_chips * hw.PEAK_FLOPS_BF16 * step_time) if step_time > 0 else 0.0
    return {
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_fraction": mfu,             # MODEL_FLOPS-based MFU at the bound
    }
