"""Analytic per-chip memory planner for the TPU target.

Why this exists: the dry-run compiles with the XLA *CPU* backend, whose buffer
assignment widens every bf16 dynamic-update-slice to an f32 round-trip inside
fusions and charges the full-size f32 intermediate to temp memory (verified in
the kimi buffer dump: ``bf16 stack → convert f32 → DUS → convert bf16``
fusions account for >40 GB of "temp" that has no TPU analogue — TPU executes
bf16 DUS natively in HBM and streams fusion temps through VMEM).

We therefore report BOTH numbers per cell: the CPU-measured peak (transparent,
machine-checked) and this model's TPU projection (what the fleet planner would
use). The model is deliberately simple and conservative; constants are
validated against the small cells where CPU accounting is artifact-free
(glm4/gemma3/mamba2 agree within ~25%).
"""
from __future__ import annotations

import math

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _moe_buffer_bytes(cfg: ModelConfig, tokens_loc: int, n_tp: int) -> int:
    if not cfg.n_experts:
        return 0
    t_l = max(1, tokens_loc // n_tp)
    cap_s = math.ceil(t_l * cfg.experts_per_token / n_tp * cfg.capacity_factor)
    cap_s = max(8, -(-cap_s // 8) * 8)
    send = n_tp * cap_s * cfg.d_model * 2
    e_loc = max(1, cfg.n_experts // n_tp)
    cap_e = max(8, math.ceil(n_tp * cap_s / e_loc * cfg.capacity_factor))
    buf = e_loc * cap_e * cfg.d_model * 2
    hid = e_loc * cap_e * cfg.moe_d_ff * 2 * 2
    # fwd + bwd copies of the four stages
    return 2 * (2 * send + buf + hid)


def params_bytes(total_params: int, n_dev: int) -> int:
    return int(total_params * 2 / n_dev * 1.02)          # bf16, 2% replication slack


def opt_bytes(total_params: int, n_dev: int, momentum: bool, factored: bool,
              moment_bytes: int) -> int:
    b = 0.0
    if momentum:
        b += total_params * moment_bytes / n_dev                # m
    if factored:
        b += total_params * moment_bytes / n_dev * 0.01        # rows+cols ≈ 1%
    else:
        b += total_params * moment_bytes / n_dev                # full v
    return int(b)


def peak_model(cfg: ModelConfig, shape: ShapeConfig, n_dev: int, n_dp: int, n_tp: int,
               total_params: int, *, sp: bool = True, momentum: bool = True,
               factored: bool = False, moment_bytes: int = 4, ce_chunks: int = 8) -> dict:
    """Per-chip peak bytes for one cell. Returns component breakdown + total."""
    d = cfg.d_model
    L = cfg.n_layers + cfg.n_enc_layers
    comp: dict[str, float] = {}
    comp["params"] = params_bytes(total_params, n_dev)
    if shape.kind == "train":
        tokens_loc = shape.global_batch * shape.seq_len / n_dp
        comp["optimizer"] = opt_bytes(total_params, n_dev, momentum, factored, moment_bytes)
        comp["grads"] = total_params * 2 / n_dev
        comp["saved_x"] = L * tokens_loc * d * 2 / (n_tp if sp else 1)
        comp["logits"] = tokens_loc * cfg.vocab_size / n_tp * 2 \
            + tokens_loc / ce_chunks * cfg.vocab_size / n_tp * 4
        # per-layer fwd+bwd workspace (qkv/mlp/norm temporaries), ~12 residences
        comp["layer_ws"] = 12 * tokens_loc * d * 2
        comp["moe_ws"] = _moe_buffer_bytes(cfg, int(tokens_loc), n_tp)
        if cfg.ssm_state:
            q = cfg.ssm_chunk
            h = cfg.ssm_heads
            h_loc = h / n_tp if h % n_tp == 0 else h
            comp["ssd_ws"] = 2 * tokens_loc * q * h_loc * 4
    elif shape.kind == "prefill":
        tokens_loc = shape.global_batch * shape.seq_len / n_dp
        if cfg.n_heads:   # attention-free archs have no KV cache
            comp["cache_out"] = 2 * L * tokens_loc * cfg.n_kv_heads * cfg.hd * 2 / max(1, n_tp if sp else 1)
        comp["layer_ws"] = 8 * tokens_loc * d * 2
        comp["moe_ws"] = _moe_buffer_bytes(cfg, int(tokens_loc), n_tp)
        if cfg.ssm_state:
            comp["ssd_ws"] = 2 * tokens_loc * cfg.ssm_chunk * (cfg.ssm_heads / n_tp if cfg.ssm_heads % n_tp == 0 else cfg.ssm_heads) * 4
            comp["states_out"] = cfg.n_layers * (shape.global_batch / n_dp) * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * 4
    else:  # decode
        b_loc = max(1, shape.global_batch / n_dp)
        if cfg.ssm_state and cfg.attn_every == 0:
            comp["state"] = cfg.n_layers * b_loc * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * 4 / n_tp
        else:
            sites = cfg.n_layers // cfg.attn_every if cfg.attn_every else (cfg.n_layers + cfg.n_enc_layers)
            seq_shard = n_tp if shape.seq_len % n_tp == 0 else 1
            comp["kv_cache"] = 2 * sites * b_loc * shape.seq_len * cfg.n_kv_heads * cfg.hd * 2 / seq_shard
            if cfg.ssm_state:
                comp["state"] = cfg.n_layers * b_loc * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * 4 / n_tp
        comp["workspace"] = 4 * b_loc * max(shape.seq_len / (n_tp if shape.seq_len % n_tp == 0 else 1) * cfg.n_heads / max(1,n_tp) * 4, d * 16)
    total = int(sum(comp.values()))
    return {"components": {k: int(v) for k, v in comp.items()}, "total": total,
            "fits_16GB": total < (16 << 30)}
