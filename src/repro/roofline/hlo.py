"""Collective accounting from partitioned HLO text.

After SPMD partitioning, shapes in ``compiled.as_text()`` are PER-DEVICE, so
summed bytes here are per-chip; the roofline's ``/(chips × link_bw)`` over
global bytes is equivalent to ``/link_bw`` over these.

Wire-byte model per op (ring algorithms, group size g):
    all-reduce:          2·B·(g−1)/g      (reduce-scatter + all-gather phases)
    all-gather:          B_result·(g−1)/g
    reduce-scatter:      B_operand·(g−1)/g
    all-to-all:          B·(g−1)/g
    collective-permute:  B                 (point-to-point)

Ops inside while loops appear once in the text — callers use the unrolled depth
probes (roofline.analysis) so every instance is visible.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
    r"([^)]*)\)"
)
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1_RE.search(line)
    if m and m.group(1):
        return len(m.group(1).split(","))
    return default


def collective_stats(hlo_text: str, default_group: int = 2) -> dict:
    """Per-kind (wire_bytes, count) + total, from one HLO module text."""
    out: dict = defaultdict(lambda: {"wire_bytes": 0.0, "count": 0})
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_s, kind, operands_s = m.groups()
        if "-done(" in line:
            continue  # the -start op carries the shape; -done would double count
        g = _group_size(line, default_group)
        rb = _shape_bytes(result_s)
        ob = _shape_bytes(operands_s)
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-reduce":
            wire = 2.0 * rb * frac
        elif kind == "all-gather":
            wire = rb * frac
        elif kind == "reduce-scatter":
            wire = max(ob, rb) * frac
        elif kind == "all-to-all":
            wire = rb * frac
        else:  # collective-permute
            wire = float(rb)
        out[kind]["wire_bytes"] += wire
        out[kind]["count"] += 1
    total = sum(v["wire_bytes"] for v in out.values())
    return {"by_kind": dict(out), "total_wire_bytes": total}
