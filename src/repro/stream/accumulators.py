"""Constant-memory accumulators for the one-pass streaming estimators.

The paper's single-pass story (§I, §IV–V) reduces every workload to the same
shape: fold a sketched batch into a fixed-size accumulator, then finalize.
This module holds the accumulator algebra — pure, jit/scan/shard_map friendly,
and split into

    delta(batch)  →  local, embarrassingly parallel (no collectives), then
    apply(state, delta)  →  the only state mutation,

so the distributed engine can psum the *delta* (the fixed-size cross-shard
traffic) and apply it to replicated state, while the single-device engine
applies the same delta directly. Streaming-equals-batch (tests/test_stream.py)
holds because finalize uses exactly the Thm-4 / Thm-6 formulas of
repro.core.estimators.

Three accumulators:

- :class:`MomentState` — Σ R_iR_iᵀx_i (p,) and Σ w_iw_iᵀ (p,p) for the Thm-4
  mean and Thm-6 covariance estimators;
- :class:`KMeansState` — mini-batch streaming sparsified K-means: per-cluster,
  per-coordinate running means in the *preconditioned* domain (the online form
  of the paper's Eq. 39 update), with ``r`` independent center hypotheses
  folded in parallel and the best kept at finalize.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import estimators as _est
from repro.core.kmeans import kpp_init_sparse, sparse_sq_dists
from repro.core.sampling import SparseRows

# ------------------------------------------------------------- moments ------
# The moment accumulator IS estimators.StreamState — one source of truth for
# the Thm-4/Thm-6 algebra; this module only re-exports it under the engine's
# delta/apply naming and adds the K-means accumulator below.

MomentState = _est.StreamState
moment_init = _est.stream_init
moment_delta = _est.stream_delta
moment_apply = _est.stream_apply
moment_finalize_mean = _est.stream_finalize_mean
moment_finalize_cov = _est.stream_finalize_cov


# -------------------------------------------- mini-batch streaming K-means --


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class KMeansState:
    """r parallel center hypotheses in the preconditioned domain.

    centers: (r, K, p) — per-cluster, per-coordinate running means;
    counts:  (r, K, p) — per-coordinate observation counts (Eq. 39 weights);
                         int32: the running-mean weights must stay exact —
                         f32 would saturate at 2^24 and silently turn the
                         mean update into a fixed-rate EMA. With a decay
                         (forgetting) factor they ARE float32: decay bounds
                         the counts by b·n_shards/(1−decay), far below the
                         2^24 saturation point, so exactness survives;
    obj:     (r,)      — accumulated mini-batch objective (hypothesis selector);
    count:   ()        — samples folded so far (int32, exact to 2^31 rows).
    """

    centers: jax.Array
    counts: jax.Array
    obj: jax.Array
    count: jax.Array

    def tree_flatten(self):
        return (self.centers, self.counts, self.obj, self.count), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def kmeans_init(key: jax.Array, first_batch: SparseRows, k: int, n_init: int = 3,
                decay: float = 1.0) -> KMeansState:
    """Seed r = n_init hypotheses with K-means++ on the first sketched batch.

    Runs on replicated data so sharded and single-device engines start from
    bit-identical centers. ``decay`` < 1 switches the count accumulators to
    float32 (see :class:`KMeansState`); pass the same value to
    :func:`kmeans_apply`.
    """

    def one(rkey):
        return kpp_init_sparse(rkey, first_batch.values, first_batch.indices,
                               first_batch.p, k)

    centers = jax.lax.map(one, jax.random.split(key, n_init))
    return KMeansState(
        centers=centers.astype(jnp.float32),
        counts=jnp.zeros(centers.shape, jnp.int32 if decay == 1.0 else jnp.float32),
        obj=jnp.zeros((n_init,), jnp.float32),
        count=jnp.zeros((), jnp.int32),
    )


def kmeans_delta_with_assign(state: KMeansState, batch: SparseRows):
    """(delta, assign) for one batch under every hypothesis.

    ``assign`` (r, n) int32 are the nearest-center labels under the
    step-start centers — already computed inside the delta, returned for
    callers that also track reassignment counts (so the convergence signal
    costs ONE extra assignment pass after the apply, not a recomputation of
    this one).
    """
    values, indices = batch.values, batch.indices
    k, p = state.centers.shape[1:]

    def one(centers):
        d = sparse_sq_dists(values, indices, centers)          # (n, K)
        a = jnp.argmin(d, axis=1)
        rows = jnp.broadcast_to(a[:, None], indices.shape)
        sums = jnp.zeros((k, p), jnp.float32).at[rows, indices].add(
            values.astype(jnp.float32))
        cnts = jnp.zeros((k, p), jnp.int32).at[rows, indices].add(1)
        return sums, cnts, jnp.sum(jnp.min(d, axis=1)).astype(jnp.float32), \
            a.astype(jnp.int32)

    sums, cnts, obj, assign = jax.vmap(one)(state.centers)
    return (sums, cnts, obj, jnp.int32(values.shape[0])), assign


def kmeans_delta(state: KMeansState, batch: SparseRows):
    """Assignment + scatter sums for one batch under every hypothesis.

    Assignment (the hot, O(n·m·K) step) stays local to the shard; only the
    returned (sums, cnts, obj, n) — fixed-size in the batch — ever needs a psum
    (the per-row labels of :func:`kmeans_delta_with_assign` are dead code here,
    eliminated under jit).
    """
    delta, _ = kmeans_delta_with_assign(state, batch)
    return delta


def kmeans_apply(state: KMeansState, delta, decay: float = 1.0) -> KMeansState:
    """Online per-coordinate mean update — the streaming form of Eq. 39.

    new_center = (count·center + batch_sum) / (count + batch_count) wherever the
    batch touched the coordinate; untouched coordinates keep their value (the
    paper's never-sampled-coordinate convention).

    ``decay`` < 1 is the forgetting factor for non-stationary streams: the
    accumulated counts shrink BEFORE the delta is applied, so older
    observations are geometrically down-weighted (effective memory
    ≈ 1/(1−decay) steps) and the centers can track drifting clusters. The
    state must have been built with ``kmeans_init(..., decay=...)`` (float
    counts). Decay is applied once per psum'd step — the same place the delta
    is — so sharded and single-device streams stay identical.
    """
    sums, cnts, obj, n = delta
    old_counts = state.counts if decay == 1.0 else state.counts * decay
    new_counts = old_counts + cnts.astype(state.counts.dtype)
    cnts_f = cnts.astype(jnp.float32)
    centers = jnp.where(
        cnts > 0,
        state.centers + (sums - cnts_f * state.centers)
        / jnp.maximum(new_counts, 1).astype(jnp.float32),
        state.centers,
    )
    return KMeansState(centers, new_counts, state.obj + obj, state.count + n)


def kmeans_reassigned(state: KMeansState, batch: SparseRows,
                      prev_assign: jax.Array) -> jax.Array:
    """(r,) int32 — how many of the batch's rows change nearest center across
    one apply: labels under ``state.centers`` (post-update) vs ``prev_assign``
    (the labels :func:`kmeans_delta_with_assign` computed pre-update).

    The mini-batch convergence signal (ROADMAP streaming-K-means item): as the
    per-coordinate means settle, the count decays toward zero; a persistently
    high count means the stream is still reshaping the solution (or drifting,
    under a decay factor).
    """

    def one(c_new, a_prev):
        a1 = jnp.argmin(sparse_sq_dists(batch.values, batch.indices, c_new), axis=1)
        return jnp.sum(a1.astype(jnp.int32) != a_prev).astype(jnp.int32)

    return jax.vmap(one)(state.centers, prev_assign)


def kmeans_finalize(state: KMeansState):
    """(best centers (K, p) in the preconditioned domain, best accumulated obj)."""
    best = jnp.argmin(state.obj)
    return state.centers[best], state.obj[best]


def kmeans_assign(centers_pre: jax.Array, batch: SparseRows) -> jax.Array:
    """Nearest-center labels for sketched rows under the sparsified metric."""
    d = sparse_sq_dists(batch.values, batch.indices, centers_pre)
    return jnp.argmin(d, axis=1).astype(jnp.int32)
