"""The EngineState lifecycle protocol — ``init / fold / merge / finalize /
to_arrays / from_arrays`` for every accumulator kind in the repo.

The paper's one-pass estimators all reduce to the same state shape: a
fixed-size accumulator folded per (step, shard) sketch, finalized once. This
module makes that lifecycle EXPLICIT and uniform across the four state kinds —

- ``moment``  (:class:`repro.stream.accumulators.MomentState`) — Thm-4/Thm-6;
- ``km``      (:class:`repro.stream.accumulators.KMeansState`) — mini-batch
              streaming K-means (Eq. 39 online means);
- ``range``   (:class:`repro.lowrank.RangeState`) — randomized range-finder;
- ``fd``      (:class:`repro.lowrank.FDState`) — Frequent Directions —

so every layer (stream engine, api estimators, fused runs, sketchserve
snapshots, cluster re-sharding) speaks ONE serialization and ONE merge
algebra instead of per-layer bespoke export paths:

- ``to_arrays(state)`` → flat ``{"<kind>.<field>": np.ndarray}`` dict (the
  checkpoint wire format of ``repro.train.checkpoint.save_arrays``);
- ``from_arrays(arrs)`` → the state back, kind detected from the key prefix;
- ``merge(a, b)`` → the combined state, as if a's and b's folds had been one
  stream. Moment/range states are linear (element-wise add — Thm-4/6 sums
  commute); K-means merges per-coordinate running means by their counts
  (count-weighted mean — exactly what folding both delta streams would have
  accumulated); FD row-appends both sketches and SVD-shrinks back to l (the
  associative coreset-tree merge of Barger & Feldman). Merge-ability is what
  elastic re-sharding (repro.cluster.elastic) and the ROADMAP coreset trees
  stand on: partial per-worker states combine into the global one.

The composite :class:`repro.stream.engine.EngineState` (moments/kmeans/
lowrank/reassign slots) serializes through the same functions via
``engine_to_arrays`` / ``engine_from_arrays`` / ``engine_merge``, and
``save_engine`` / ``load_engine`` put it on disk through the
``train.checkpoint`` atomic-rename protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import lowrank as lowrank_mod
from repro.lowrank import fd as _fd
from repro.stream import accumulators as acc
from repro.train import checkpoint


# ----------------------------------------------------------------- registry --


@dataclasses.dataclass(frozen=True)
class StateKind:
    """One accumulator kind's protocol entry.

    ``fields`` are serialized in order as ``<name>.<field>``; ``optional``
    fields may be None (skipped on save, restored as None when absent).
    ``merge(a, b)`` combines two states folded from disjoint sub-streams.
    """

    name: str
    cls: type
    fields: tuple[str, ...]
    merge: Callable[[Any, Any], Any]
    optional: tuple[str, ...] = ()


STATE_KINDS: dict[str, StateKind] = {}
_CLS_TO_KIND: dict[type, StateKind] = {}


def register_state(kind: StateKind) -> StateKind:
    STATE_KINDS[kind.name] = kind
    _CLS_TO_KIND[kind.cls] = kind
    return kind


def kind_of(state: Any) -> StateKind:
    k = _CLS_TO_KIND.get(type(state))
    if k is None:
        raise TypeError(f"{type(state).__name__} is not a registered "
                        f"EngineState kind (have: {sorted(STATE_KINDS)})")
    return k


# ------------------------------------------------------------ merge algebra --


def _merge_linear(a, b):
    """Element-wise add — the merge of any linear (delta-sum) accumulator.
    None-aware for optional fields (e.g. MomentState.sum_wwt, mean-only)."""
    cls = type(a)
    vals = []
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if va is None or vb is None:
            if (va is None) != (vb is None):
                raise ValueError(f"cannot merge: field {f.name!r} is None on "
                                 "one state only (track_cov mismatch?)")
            vals.append(None)
        else:
            vals.append(va + vb)
    return cls(*vals)


def _merge_kmeans(a: acc.KMeansState, b: acc.KMeansState) -> acc.KMeansState:
    """Count-weighted per-coordinate mean merge (the Eq.-39 running means of
    the union stream): each center coordinate is Σ values / Σ counts over both
    halves, which is exactly what folding both delta streams into one state
    accumulates. Coordinates untouched by either half keep a's value (the
    never-sampled-coordinate convention); obj and count add."""
    ca, cb = a.counts.astype(jnp.float32), b.counts.astype(jnp.float32)
    tot = ca + cb
    centers = jnp.where(
        tot > 0,
        (a.centers * ca + b.centers * cb) / jnp.maximum(tot, 1.0),
        a.centers)
    return acc.KMeansState(centers, a.counts + b.counts, a.obj + b.obj,
                           a.count + b.count)


def _merge_fd(a: lowrank_mod.FDState, b: lowrank_mod.FDState) -> lowrank_mod.FDState:
    """Row-append both sketches, SVD-shrink back to l (Frequent Directions'
    associative merge — error bounds add, so a merge tree of segment sketches
    is as good as one sequential pass up to the summed shrink error)."""
    ell = a.sketch.shape[0]
    if b.sketch.shape[0] != ell:
        raise ValueError(f"cannot merge FD states of widths {ell} and "
                         f"{b.sketch.shape[0]}")
    stacked = jnp.concatenate([a.sketch, b.sketch], axis=0)
    return lowrank_mod.FDState(_fd._shrink(stacked, ell), a.diag + b.diag,
                               a.sum_w + b.sum_w, a.count + b.count)


register_state(StateKind(
    name="moment", cls=acc.MomentState,
    fields=("sum_w", "sum_wwt", "count"), merge=_merge_linear,
    optional=("sum_wwt",)))
register_state(StateKind(
    name="km", cls=acc.KMeansState,
    fields=("centers", "counts", "obj", "count"), merge=_merge_kmeans))
register_state(StateKind(
    name="range", cls=lowrank_mod.RangeState,
    fields=("y", "diag", "sum_w", "count"), merge=_merge_linear))
register_state(StateKind(
    name="fd", cls=lowrank_mod.FDState,
    fields=("sketch", "diag", "sum_w", "count"), merge=_merge_fd))


def merge(a: Any, b: Any) -> Any:
    """Combine two same-kind states folded from disjoint sub-streams."""
    ka, kb = kind_of(a), kind_of(b)
    if ka.name != kb.name:
        raise TypeError(f"cannot merge {ka.name!r} with {kb.name!r}")
    return ka.merge(a, b)


# ------------------------------------------------------------ serialization --


def to_arrays(state: Any, prefix: str = "") -> dict[str, np.ndarray]:
    """State → flat ``{prefix<kind>.<field>: np.ndarray}`` (the checkpoint
    wire format). None fields are skipped; :func:`from_arrays` restores them
    as None."""
    k = kind_of(state)
    out: dict[str, np.ndarray] = {}
    for f in k.fields:
        v = getattr(state, f)
        if v is None:
            if f not in k.optional:
                raise ValueError(f"{k.name}.{f} is None but not optional")
            continue
        out[f"{prefix}{k.name}.{f}"] = np.asarray(v)
    return out


def from_arrays(arrs: dict, prefix: str = "", kinds: tuple[str, ...] | None = None) -> Any:
    """The :func:`to_arrays` inverse — kind detected from the key prefix.
    Returns None when ``arrs`` holds no state under ``prefix``. ``kinds``
    restricts detection (e.g. a dict holding both a moment and a km state
    needs the caller to say which slot it is loading)."""
    for k in STATE_KINDS.values():
        if kinds is not None and k.name not in kinds:
            continue
        head = f"{prefix}{k.name}."
        if any(key.startswith(head) for key in arrs):
            vals = []
            for f in k.fields:
                v = arrs.get(f"{head}{f}")
                if v is None and f not in k.optional:
                    raise KeyError(f"state arrays missing {head}{f}")
                vals.append(None if v is None else jnp.asarray(v))
            return k.cls(*vals)
    return None


# ----------------------------------------------- the engine-state composite --
# EngineState (repro.stream.engine) is a fixed composite of protocol states:
# moments | lowrank (exactly one second-moment path), optional kmeans, and
# the optional reassignment-count slot. Serializing it is just serializing
# each occupied slot under its slot prefix.

_ENGINE_SLOTS = ("moments", "kmeans", "lowrank")


def engine_to_arrays(state) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    for slot in _ENGINE_SLOTS:
        sub = getattr(state, slot)
        if sub is not None:
            out.update(to_arrays(sub, prefix=f"{slot}/"))
    reassign = getattr(state, "reassign", None)
    if reassign is not None:
        out["reassign/total"] = np.asarray(reassign[0])
        out["reassign/last"] = np.asarray(reassign[1])
    return out


def engine_from_arrays(arrs: dict):
    from repro.stream.engine import EngineState

    slots = {slot: from_arrays(arrs, prefix=f"{slot}/") for slot in _ENGINE_SLOTS}
    reassign = None
    if "reassign/total" in arrs:
        reassign = (jnp.asarray(arrs["reassign/total"]),
                    jnp.asarray(arrs["reassign/last"]))
    return EngineState(**slots, reassign=reassign)


def engine_merge(a, b):
    """Merge two EngineStates folded from disjoint (step, shard) cells of the
    same grid — the elastic re-sharding primitive. Reassignment counters add
    (total) / add (last: both halves saw the same last step's disjoint rows)."""
    from repro.stream.engine import EngineState

    merged = {}
    for slot in _ENGINE_SLOTS:
        sa, sb = getattr(a, slot), getattr(b, slot)
        if (sa is None) != (sb is None):
            raise ValueError(f"cannot merge EngineStates: slot {slot!r} "
                             "occupied on one side only")
        merged[slot] = None if sa is None else merge(sa, sb)
    ra, rb = a.reassign, b.reassign
    if (ra is None) != (rb is None):
        raise ValueError("cannot merge EngineStates: reassign tracked on one "
                         "side only")
    reassign = None if ra is None else (ra[0] + rb[0], ra[1] + rb[1])
    return EngineState(**merged, reassign=reassign)


# ------------------------------------------------------------- persistence --


def save_engine(ckpt_dir: str, step: int, state, extra: dict | None = None,
                keep_last: int = 3) -> None:
    """Checkpoint an EngineState (+ JSON ``extra``, e.g. the stream cursor)
    through the ``train.checkpoint`` atomic-rename protocol. ``step`` is the
    number of steps already folded — the step the restored run resumes AT."""
    meta = dict(extra or {})
    meta["next_step"] = int(step)
    checkpoint.save_arrays(ckpt_dir, step, engine_to_arrays(state), extra=meta,
                           keep_last=keep_last)


def load_engine(ckpt_dir: str):
    """(state, next_step, extra) from the latest checkpoint under ``ckpt_dir``."""
    arrs, extra = checkpoint.load_arrays(ckpt_dir)
    state = engine_from_arrays(arrs)
    return state, int(extra.get("next_step", 0)), extra
