"""StreamEngine — the paper's one-pass pipeline as a single jitted, shardable loop.

Drives ``source → sketch → accumulate → finalize`` (paper §I's streaming and
distributed settings, §IV–V estimators, §VI K-means):

- **source** is any pure function ``(seed, step, shard) → (b, p) batch`` — the
  (seed, step, shard) contract of repro.data.pipeline, so any worker can
  regenerate any batch (straggler backup dispatch, exactly-once by construction);
- **sketch** applies HD then R_i per sample with an *independent mask per
  (step, shard) batch* (fold of the spec's mask key), preserving the per-sample
  independence the estimators' guarantees hinge on;
- **accumulate** folds each sketched batch into donated constant-memory
  accumulators (repro.stream.accumulators) — Thm-4 mean, Thm-6 covariance, and
  mini-batch streaming sparsified K-means;
- **finalize** applies the closed-form debiasing once, after the last batch.

Distribution: with ``mesh=``, the update runs under ``shard_map`` — every shard
sketches and assigns locally, and the **only cross-shard traffic is the psum of
the fixed-size accumulator deltas** ((p,) + (p,p) + (r,K,p)·2 per step,
independent of batch size). Single-device and sharded engines fold identical
per-(step, shard) sketches, so they agree to float-sum reordering
(tests/test_stream.py asserts 1e-5).

The estimator API surfaces this fused pass: ``repro.api.fit_many`` drives any
set of consumers from one shared ``source → sketch`` cursor under the same
(seed, step, shard) contract (:func:`normalize_source` is the shared adapter),
with the engine's per-step discipline — summed shard deltas applied once per
step, sharded moments reduced by one psum of the fixed-size delta and nothing
retained past its step.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import sketch as sketch_mod
from repro.core.sampling import SparseRows
from repro.core.sketch import batch_key  # noqa: F401  (re-exported; the repo-wide discipline)
from repro import lowrank as lowrank_mod
from repro import obs
from repro import refine as refine_mod
from repro.stream import accumulators as acc
from repro.utils.prng import fold_in_str

Source = Callable[[int, int, int], Any]  # (seed, step, shard) -> (b, p) array


@dataclasses.dataclass
class EngineTelemetry:
    """Opt-in per-step observability for :meth:`StreamEngine.run`.

    Strictly observe-only: the instrumented loop folds bit-identical state to
    an uninstrumented one (tests assert it) — telemetry reads timings, shapes,
    and already-materialized signals, never the stream. Per step it records
    into ``registry``:

    - counters ``engine.steps`` / ``engine.rows`` / ``engine.checkpoints``
      (+ ``engine.reassigned`` when the K-means config tracks reassignments);
    - histograms ``engine.step_seconds`` / ``engine.source_seconds`` /
      ``engine.update_seconds`` / ``engine.checkpoint_seconds`` — wall time of
      the whole step, the host-side batch generation, the jitted update
      dispatch, and checkpoint writes (the update's *internal* sketch/fold/
      psum phases are jax.named_scope-annotated, so an XLA profile breaks the
      device step down further — see ``_build_update``);
    - gauges ``engine.rows_per_sec`` (cumulative over this run) and
      ``engine.state_bytes`` (accumulator footprint — constant in stream
      length by construction, so a drift here is a leak).

    ``step_logger``/``log_every`` add a structured JSONL record per logged
    step (step, rows, rows/sec, phase seconds, reassign fraction, state
    bytes, checkpoint timestamps); ``on_step`` receives the same record dict
    (the cluster launcher's heartbeat hook).
    """

    registry: obs.MetricsRegistry | None = None
    step_logger: obs.StepLogger | None = None
    log_every: int = 1
    on_step: Callable[[dict], None] | None = None

    def _reg(self) -> obs.MetricsRegistry:
        return self.registry if self.registry is not None else obs.default_registry()

    def emit(self, record: dict) -> None:
        if self.step_logger is not None and record["step"] % self.log_every == 0:
            self.step_logger.log(**record)
        if self.on_step is not None:
            self.on_step(record)


@dataclasses.dataclass(frozen=True)
class StreamKMeansConfig:
    """Mini-batch streaming sparsified K-means: K clusters, r parallel seeds.

    ``decay`` < 1 is the forgetting factor for non-stationary streams: the
    per-coordinate count accumulators shrink by ``decay`` once per psum'd step
    (inside ``kmeans_apply``, so sharded == single-device holds), giving the
    centers an effective memory of ≈ 1/(1−decay) steps.
    """

    k: int
    n_init: int = 3
    decay: float = 1.0
    track_reassignments: bool = False

    def __post_init__(self):
        if not 0.0 < self.decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {self.decay}")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class EngineState:
    """Everything the engine carries between batches — a donated pytree.

    Exactly one of ``moments`` / ``lowrank`` accumulates the second moment AND
    the Thm-4 mean (RangeState carries sum_w/count itself, so the lowrank path
    runs no moment accumulator — one (p,) scatter and psum per step, not two).

    ``reassign`` is the engine-level K-means convergence signal (present iff
    ``StreamKMeansConfig.track_reassignments``): a ``(total, last)`` pair of
    (r,) int32 counters — rows whose nearest center changed across an apply,
    cumulative and for the last folded step — computed INSIDE the jitted
    update (one extra assignment pass per shard, psum'd with the deltas'
    step), so the drift signal exists without the estimator layer.

    Serialization/merge go through the :mod:`repro.stream.state` protocol:
    ``state.engine_to_arrays`` / ``engine_from_arrays`` / ``engine_merge``.
    """

    moments: acc.MomentState | None
    kmeans: acc.KMeansState | None
    lowrank: lowrank_mod.RangeState | None = None
    reassign: tuple | None = None  # ((r,) int32 total, (r,) int32 last step)

    def tree_flatten(self):
        return (self.moments, self.kmeans, self.lowrank, self.reassign), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """Finalized one-pass estimates (mean/cov in the preconditioned domain when
    the spec preconditions; kmeans centers returned in both domains)."""

    mean: jax.Array | None
    cov: jax.Array | None
    count: jax.Array
    centers: jax.Array | None = None        # original domain, (K, p)
    centers_pre: jax.Array | None = None    # preconditioned domain, (K, p_pad)
    kmeans_obj: jax.Array | None = None
    cov_lowrank: "lowrank_mod.LowRankCov | None" = None  # cov_path="lowrank"
    refine_passes: int = 0                  # replay() passes folded into this
    refine_reassigned: tuple | None = None  # rows reassigned by rebuilds 1..q-1
    # engine-level K-means drift signal (StreamKMeansConfig.track_reassignments):
    reassign_total: np.ndarray | None = None   # (r,) cumulative over the run
    reassign_last: np.ndarray | None = None    # (r,) of the last folded step
    reassign_counts: np.ndarray | None = None  # (steps, r) per-step (run() only)


def _normalize_source(source) -> Source:
    """Adapt a source to (seed, step, shard) → batch. seed=None means "the
    source's own default" (0 for plain callables); an explicit seed must not be
    silently ignored, so batch_at objects that can't take one reject it."""
    if callable(source):
        return lambda seed, step, shard: source(0 if seed is None else seed, step, shard)
    if hasattr(source, "batch_at"):
        accepts_seed = "seed" in inspect.signature(source.batch_at).parameters

        def from_obj(seed, step, shard):
            if seed is None:
                return source.batch_at(step, shard)
            if not accepts_seed:
                raise ValueError(
                    "run(seed=...) given, but this source's batch_at() has no seed "
                    "parameter — it streams its constructed seed; pass seed=None")
            return source.batch_at(step, shard, seed=seed)

        return from_obj
    raise TypeError(f"source must be callable or expose batch_at, got {type(source)}")


# the (seed, step, shard) source contract is repo-wide — the estimator layer's
# fit_stream / fit_many consume it through the same adapter
normalize_source = _normalize_source


class StreamEngine:
    """One-pass sharded estimation over a (seed, step, shard) batch stream.

    Parameters
    ----------
    spec: the sketch (p, m, transform, key) — see repro.core.sketch.
    source: ``(seed, step, shard) → (b, p)`` array, or an object with
        ``batch_at(step, shard)`` (e.g. data.pipeline.VectorStreamSource).
    n_shards: logical shards per step. Without a mesh they are folded
        sequentially on one device; with a mesh they run data-parallel.
    mesh / axis: optional jax Mesh and its data axis name; axis size must
        equal ``n_shards``.
    track_cov: accumulate the (p, p) second moment (Thm-6). Disable for
        mean-only streams at very large p.
    kmeans: optional :class:`StreamKMeansConfig` to run mini-batch streaming
        sparsified K-means alongside the moment estimators.
    impl: preconditioning backend forwarded to sketch ("auto" = Pallas kernel
        on TPU, jnp butterfly elsewhere).
    cov_path: "dense" (scatter batch to (b, p), one matmul), "compact"
        (scatter b·m² outer products directly — pick it when γ ≪ 1 and the
        dense (b, p) intermediate would dominate the step's memory), or
        "lowrank" (the repro.lowrank range-finder state: the second-moment
        accumulator shrinks from (p, p) to the (p, rank) projection S·Omega, and
        the per-step psum shrinks with it; finalize returns the factored
        eigenmodel on ``StreamResult.cov_lowrank`` instead of ``cov``).
    rank: sketch width l of the "lowrank" path (required there). The engine's
        lowrank path is the linear range-finder — the order-dependent FD
        variant lives behind the estimator layer (``Plan(lowrank_method="fd")``),
        where folds are sequential by construction.
    """

    def __init__(self, spec: sketch_mod.SketchSpec, source, *, n_shards: int = 1,
                 mesh=None, axis: str = "data", track_cov: bool = True,
                 kmeans: StreamKMeansConfig | None = None, impl: str = "auto",
                 cov_path: str = "dense", rank: int | None = None):
        self.spec = spec
        self.source = _normalize_source(source)
        self.n_shards = int(n_shards)
        self.mesh = mesh
        self.axis = axis
        self.track_cov = track_cov
        self.kmeans = kmeans
        self.impl = impl
        self.cov_path = cov_path
        if mesh is not None and mesh.shape[axis] != self.n_shards:
            raise ValueError(
                f"mesh axis {axis!r} has size {mesh.shape[axis]}, need n_shards={n_shards}")
        # a mesh spanning >1 process runs true multi-host ingest: each process
        # generates ONLY its own shards' batches (repro.cluster assembles the
        # global array from process-local data); state stays replicated and
        # the per-step psum is unchanged.
        self._multiprocess = (mesh is not None and len(
            {d.process_index for d in mesh.devices.flat}) > 1)
        if track_cov and spec.m < 2:
            # fail before streaming, not at finalize (Thm B4 needs m ≥ 2)
            raise ValueError(f"track_cov needs m >= 2, got m={spec.m}; "
                             "raise gamma/m or pass track_cov=False")
        self.lowrank = cov_path == "lowrank" and track_cov
        self._omega = None
        if self.lowrank:
            if rank is None or not 2 <= rank <= spec.p_pad:
                raise ValueError(f"cov_path='lowrank' needs 2 <= rank <= "
                                 f"p_pad={spec.p_pad}, got rank={rank}")
            self.rank = int(rank)
            self._omega = lowrank_mod.omega(spec.key, spec.p_pad, self.rank)
        self._update = jax.jit(self._build_update(), donate_argnums=0)
        self._scan = None  # compiled-once lax.scan over a whole stream
        self._refine_update = None  # lazily jitted replay() step update
        self._refine_scan = None    # compiled-once lax.scan of one replay pass
        self.state: EngineState | None = None  # set by run()/run_scanned()

    # ------------------------------------------------------------ plumbing --

    def _sketch_local(self, x, step, shard) -> SparseRows:
        return sketch_mod.sketch(jnp.asarray(x), self.spec,
                                 batch_key=batch_key(self.spec, step, shard),
                                 impl=self.impl)

    def _deltas(self, state: EngineState, batch: SparseRows):
        md = (None if self.lowrank
              else acc.moment_delta(batch, track_cov=self.track_cov,
                                    cov_path=self.cov_path))
        kd = acc.kmeans_delta(state.kmeans, batch) if state.kmeans is not None else None
        ld = (lowrank_mod.range_delta(batch, self._omega, impl=self.impl)
              if self.lowrank else None)
        return md, kd, ld

    def _apply(self, state: EngineState, deltas) -> EngineState:
        md, kd, ld = deltas
        return EngineState(
            moments=(acc.moment_apply(state.moments, md)
                     if md is not None else state.moments),
            kmeans=(acc.kmeans_apply(state.kmeans, kd, decay=self.kmeans.decay)
                    if kd is not None else state.kmeans),
            lowrank=(lowrank_mod.range_apply(state.lowrank, ld)
                     if ld is not None else state.lowrank),
            reassign=state.reassign,
        )

    def _build_update(self):
        """update(state, x (n_shards, b, p), step) → state, single-device or
        shard_map'd; both fold the same per-(step, shard) sketches.

        With ``track_reassignments`` the update ALSO re-assigns each shard's
        rows under the post-apply centers and compares to the pre-apply labels
        (already computed inside the K-means delta) — the (r,) counts travel
        in ``state.reassign`` and, under a mesh, ride one extra int psum."""
        track = self.kmeans is not None and self.kmeans.track_reassignments

        # jax.named_scope annotations: zero-cost trace-time names, so an XLA
        # profile splits the fused device step into sketch / fold / psum —
        # the in-jit counterpart of the host-side obs.span timings.
        def local_deltas(state, x, step, shard):
            with jax.named_scope("obs.sketch"):
                s = self._sketch_local(x, step, shard)
            with jax.named_scope("obs.fold"):
                return self._deltas(state, s)

        def local_deltas_tracked(state, x, step, shard):
            with jax.named_scope("obs.sketch"):
                s = self._sketch_local(x, step, shard)
            with jax.named_scope("obs.fold"):
                md = (None if self.lowrank
                      else acc.moment_delta(s, track_cov=self.track_cov,
                                            cov_path=self.cov_path))
                kd, a0 = acc.kmeans_delta_with_assign(state.kmeans, s)
                ld = (lowrank_mod.range_delta(s, self._omega, impl=self.impl)
                      if self.lowrank else None)
            return (md, kd, ld), (s, a0)

        def with_counts(state: EngineState, cnt) -> EngineState:
            return dataclasses.replace(state,
                                       reassign=(state.reassign[0] + cnt, cnt))

        if self.mesh is None:
            if not track:
                def update(state, x, step):
                    # same semantics as the psum path: every shard's delta is
                    # taken against the step-start state, summed, applied once.
                    deltas = local_deltas(state, x[0], step, 0)
                    for shard in range(1, self.n_shards):
                        d = local_deltas(state, x[shard], step, shard)
                        deltas = jax.tree.map(jnp.add, deltas, d)
                    return self._apply(state, deltas)
                return update

            def update(state, x, step):
                deltas = None
                pairs = []
                for shard in range(self.n_shards):
                    d, pair = local_deltas_tracked(state, x[shard], step, shard)
                    deltas = d if deltas is None else jax.tree.map(jnp.add, deltas, d)
                    pairs.append(pair)
                new = self._apply(state, deltas)
                cnt = jnp.zeros_like(state.reassign[1])
                for s, a0 in pairs:
                    cnt = cnt + acc.kmeans_reassigned(new.kmeans, s, a0)
                return with_counts(new, cnt)
            return update

        axis = self.axis
        state_spec = P()  # replicated accumulators; deltas psum'd each step

        if not track:
            def sharded_update(state, x, step):
                deltas = local_deltas(state, x[0], step, jax.lax.axis_index(axis))
                with jax.named_scope("obs.psum"):
                    deltas = jax.lax.psum(deltas, axis)  # the only cross-shard traffic
                return self._apply(state, deltas)
        else:
            def sharded_update(state, x, step):
                deltas, (s, a0) = local_deltas_tracked(
                    state, x[0], step, jax.lax.axis_index(axis))
                with jax.named_scope("obs.psum"):
                    deltas = jax.lax.psum(deltas, axis)
                new = self._apply(state, deltas)
                cnt = jax.lax.psum(acc.kmeans_reassigned(new.kmeans, s, a0), axis)
                return with_counts(new, cnt)

        return shard_map(
            sharded_update, mesh=self.mesh,
            in_specs=(state_spec, P(axis), state_spec),
            out_specs=state_spec,
        )

    # ------------------------------------------------------------- running --

    def init_state(self, seed: int | None = None) -> EngineState:
        """Fresh accumulators; K-means hypotheses seed from the step-0 global
        batch (replicated, so sharded and single-device runs start identically)."""
        km = None
        if self.kmeans is not None:
            x0 = self._host_global_batch(seed, 0, device_put=False)
            # shard id n_shards is never used by the stream — an independent mask
            s0 = self._sketch_local(x0.reshape(-1, x0.shape[-1]), jnp.int32(0), self.n_shards)
            km = acc.kmeans_init(fold_in_str(self.spec.key, "stream-kmeans"), s0,
                                 self.kmeans.k, self.kmeans.n_init,
                                 decay=self.kmeans.decay)
        return self._fresh_state(km)

    def _fresh_state(self, km) -> EngineState:
        reassign = None
        if self.kmeans is not None and self.kmeans.track_reassignments:
            z = jnp.zeros((self.kmeans.n_init,), jnp.int32)
            reassign = (z, z)
        return EngineState(
            moments=(None if self.lowrank
                     else acc.moment_init(self.spec.p_pad, track_cov=self.track_cov)),
            kmeans=km,
            lowrank=(lowrank_mod.range_init(self.spec.p_pad, self.rank)
                     if self.lowrank else None),
            reassign=reassign,
        )

    def _host_global_batch(self, seed, step, device_put: bool = True):
        if device_put and self._multiprocess:
            # multi-host: each process materializes ONLY its own shards' rows
            # and contributes them as the addressable part of one global array
            from repro import cluster

            return cluster.global_shard_batch(self.source, seed, step,
                                              self.mesh, self.axis)
        x = np.stack([np.asarray(self.source(seed, step, s)) for s in range(self.n_shards)])
        if device_put and self.mesh is not None:
            x = jax.device_put(x, NamedSharding(self.mesh, P(self.axis)))
        return x

    def update(self, state: EngineState, x, step) -> EngineState:
        """Fold one global batch x (n_shards, b, p); x's leading axis is the
        shard axis (row-sharded under a mesh)."""
        return self._update(state, x, jnp.int32(step))

    def run(self, steps: int, seed: int | None = None,
            state: EngineState | None = None, *, start_step: int = 0,
            checkpoint_dir: str | None = None,
            checkpoint_every: int = 0,
            telemetry: EngineTelemetry | None = None) -> StreamResult:
        """Fold global batches ``start_step .. steps-1`` from the source.

        ``seed`` is forwarded to the source (None = the source's own default);
        it only selects the data stream — sketch masks key off the spec.

        The loop is an explicit-state fold, resumable from ANY step: a fresh
        call starts at step 0 from :meth:`init_state`; passing ``state=`` and
        ``start_step=`` (e.g. from :meth:`restore_state`) continues a prior
        run bit-identically — the (seed, step, shard) contract regenerates
        every remaining batch and mask, so nothing about the interrupted run
        needs to have been stored beyond the fixed-size state.

        ``checkpoint_every=t`` writes the EngineState to ``checkpoint_dir``
        every t folded steps via ``train.checkpoint``'s atomic protocol
        (multi-process runs: process 0 writes; the state is replicated).

        ``telemetry=`` opts into per-step observability (see
        :class:`EngineTelemetry`). None — the default — leaves the loop
        untouched; enabled, the fold stays bit-identical (observe-only) and
        overhead is gated ≤3% by ``benchmarks/obs_bench.py``."""
        if checkpoint_every and not checkpoint_dir:
            raise ValueError("checkpoint_every needs checkpoint_dir=")
        if state is None:
            if start_step != 0:
                raise ValueError("start_step > 0 needs the state that was "
                                 "current at that step (restore_state)")
            state = self.init_state(seed)
        if self._multiprocess:
            # host-ify so jit replicates identical per-process copies onto the
            # multi-host mesh (init/restored states live on local devices)
            state = jax.tree.map(np.asarray, state)
        track = self.kmeans is not None and self.kmeans.track_reassignments
        history: list[np.ndarray] = []
        tel = telemetry
        if tel is not None:
            reg = tel._reg()
            c_steps, c_rows = reg.counter("engine.steps"), reg.counter("engine.rows")
            h_step = reg.histogram("engine.step_seconds")
            h_source = reg.histogram("engine.source_seconds")
            h_update = reg.histogram("engine.update_seconds")
            g_rate = reg.gauge("engine.rows_per_sec")
            g_bytes = reg.gauge("engine.state_bytes")
            rows_run, run_t0 = 0, time.perf_counter()
        for step in range(start_step, steps):
            if tel is None:
                state = self.update(state, self._host_global_batch(seed, step), step)
            else:
                t0 = time.perf_counter()
                with obs.span("engine.source", reg):
                    x = self._host_global_batch(seed, step)
                t1 = time.perf_counter()
                with obs.span("engine.update", reg):
                    state = self.update(state, x, step)
                t2 = time.perf_counter()
            if track:
                # copy NOW — the buffer is donated back at the next update
                history.append(np.asarray(state.reassign[1]))
            ckpt_s = None
            if checkpoint_every and (step + 1 - start_step) % checkpoint_every == 0:
                t3 = time.perf_counter()
                if tel is None:
                    self.save_state(checkpoint_dir, step + 1, state, seed=seed)
                else:
                    with obs.span("engine.checkpoint", reg):
                        self.save_state(checkpoint_dir, step + 1, state, seed=seed)
                    ckpt_s = time.perf_counter() - t3
                    reg.counter("engine.checkpoints").inc()
                    reg.histogram("engine.checkpoint_seconds").observe(ckpt_s)
            if tel is not None:
                rows_step = int(x.shape[0]) * int(x.shape[1])
                rows_run += rows_step
                elapsed = time.perf_counter() - run_t0
                state_bytes = sum(
                    int(leaf.nbytes) for leaf in jax.tree_util.tree_leaves(state)
                    if hasattr(leaf, "nbytes"))
                c_steps.inc()
                c_rows.inc(rows_step)
                h_step.observe(t2 - t0)
                h_source.observe(t1 - t0)
                h_update.observe(t2 - t1)
                g_rate.set(rows_run / max(elapsed, 1e-9))
                g_bytes.set(state_bytes)
                record = {"step": step, "rows": rows_step, "rows_total": rows_run,
                          "rows_per_sec": round(rows_run / max(elapsed, 1e-9), 1),
                          "source_s": round(t1 - t0, 6),
                          "update_s": round(t2 - t1, 6),
                          "state_bytes": state_bytes}
                if ckpt_s is not None:
                    record["checkpoint_s"] = round(ckpt_s, 6)
                    record["checkpoint_step"] = step + 1
                if track and history:
                    re_last = history[-1]
                    reg.counter("engine.reassigned").inc(int(re_last.sum()))
                    record["reassign_frac"] = round(
                        float(re_last.mean()) / max(rows_step, 1), 6)
                tel.emit(record)
        self.state = state
        result = self.finalize(state)
        if track and history:
            result = dataclasses.replace(result,
                                         reassign_counts=np.stack(history))
        return result

    # ---------------------------------------------------- checkpoint/restore --

    def save_state(self, ckpt_dir: str, step: int,
                   state: EngineState | None = None,
                   seed: int | None = None) -> None:
        """Checkpoint ``state`` (default: the engine's current one) as
        step ``step`` — the number of steps already folded, i.e. the step a
        restored run resumes at. One writer per cluster: only process 0
        writes (the state is replicated across processes by construction)."""
        state = state if state is not None else self.state
        if state is None:
            raise RuntimeError("no state to checkpoint — run() first or pass "
                               "state=")
        if jax.process_index() != 0:
            return
        from repro.stream import state as state_mod

        state_mod.save_engine(ckpt_dir, step, state, extra={
            "p_pad": int(self.spec.p_pad), "n_shards": self.n_shards,
            "seed": seed})

    def restore_state(self, ckpt_dir: str) -> tuple[EngineState, int]:
        """(state, next_step) from the latest checkpoint under ``ckpt_dir`` —
        feed straight into ``run(steps, state=state, start_step=next_step)``
        to continue, or into ``replay(state=state)`` to refine the restored
        stream without re-running it."""
        from repro.stream import state as state_mod

        state, next_step, extra = state_mod.load_engine(ckpt_dir)
        p_pad = extra.get("p_pad")
        if p_pad is not None and int(p_pad) != int(self.spec.p_pad):
            raise ValueError(f"checkpoint was written at p_pad={p_pad}, this "
                             f"engine has p_pad={self.spec.p_pad}")
        self.state = state
        return state, next_step

    def run_scanned(self, xs) -> StreamResult:
        """Fold a pre-staged stream ``xs (steps, n_shards, b, p)`` as ONE jitted
        lax.scan — the hardware-rate hot loop used by benchmarks/stream_bench.py."""
        state = self.init_from_array(xs)
        if self._scan is None:
            update = self._build_update()

            @jax.jit
            def scan_all(state, xs):
                def body(st, inp):
                    step, x = inp
                    return update(st, x, step), None
                steps = xs.shape[0]
                st, _ = jax.lax.scan(body, state, (jnp.arange(steps, dtype=jnp.int32), xs))
                return st

            self._scan = scan_all
        self.state = self._scan(state, jnp.asarray(xs))
        return self.finalize(self.state)

    def init_from_array(self, xs) -> EngineState:
        km = None
        if self.kmeans is not None:
            x0 = jnp.asarray(xs[0]).reshape(-1, xs.shape[-1])
            s0 = self._sketch_local(x0, jnp.int32(0), self.n_shards)
            km = acc.kmeans_init(fold_in_str(self.spec.key, "stream-kmeans"), s0,
                                 self.kmeans.k, self.kmeans.n_init,
                                 decay=self.kmeans.decay)
        return self._fresh_state(km)

    # ------------------------------------------------------------ replaying --
    # Second-pass refinement (repro.refine): the (seed, step, shard) contract
    # regenerates every batch AND its mask, so extra passes store nothing.
    # Each pass folds a fixed-size carry — a RangeState accumulating Y = S·Q
    # (PCA power iteration) and/or a KMeans2State accumulating frozen-center
    # assignment sums (two-pass Alg. 2) — through one jitted update per step;
    # under a mesh the only cross-shard traffic is ONE psum of that fixed-size
    # delta per step, exactly like run(). The carry is scan-safe:
    # replay_scanned() folds a whole pass as one lax.scan.

    def _build_refine_update(self):
        """update(carry, x, step, q_mat, frozen, prev) → carry."""
        has_lr, has_km = self.lowrank, self.kmeans is not None

        def local_deltas(x, step, shard, q_mat, frozen, prev):
            s = self._sketch_local(x, step, shard)
            ld = (lowrank_mod.range_delta(s, q_mat, impl=self.impl)
                  if has_lr else None)
            kd = refine_mod.kmeans2_delta(s, frozen, prev) if has_km else None
            return ld, kd

        def apply(carry, deltas):
            ld, kd = deltas
            cl, ck = carry
            return (lowrank_mod.range_apply(cl, ld) if ld is not None else cl,
                    refine_mod.kmeans2_apply(ck, kd) if kd is not None else ck)

        if self.mesh is None:
            def update(carry, x, step, q_mat, frozen, prev):
                deltas = local_deltas(x[0], step, 0, q_mat, frozen, prev)
                for shard in range(1, self.n_shards):
                    d = local_deltas(x[shard], step, shard, q_mat, frozen, prev)
                    deltas = jax.tree.map(jnp.add, deltas, d)
                return apply(carry, deltas)
            return update

        axis = self.axis

        def sharded_update(carry, x, step, q_mat, frozen, prev):
            deltas = local_deltas(x[0], step, jax.lax.axis_index(axis),
                                  q_mat, frozen, prev)
            deltas = jax.lax.psum(deltas, axis)  # the only cross-shard traffic
            return apply(carry, deltas)

        return shard_map(
            sharded_update, mesh=self.mesh,
            in_specs=(P(), P(axis), P(), P(), P(), P()), out_specs=P(),
        )

    def _init_refine_carry(self):
        return (lowrank_mod.range_init(self.spec.p_pad, self.rank)
                if self.lowrank else None,
                refine_mod.kmeans2_init(self.kmeans.k, self.spec.p_pad)
                if self.kmeans is not None else None)

    def _replay_passes(self, fold_pass, passes: int,
                       state: EngineState | None) -> StreamResult:
        """Shared head/tail of replay()/replay_scanned(): per-pass basis
        orthonormalization / center rebuild around ``fold_pass(carry, q,
        frozen, prev) → carry``, then the refined finalize."""
        state = state if state is not None else self.state
        if state is None:
            raise RuntimeError("no stream folded yet — run()/run_scanned() "
                               "first; replay() refines a finished pass")
        if not (self.lowrank or self.kmeans is not None):
            raise ValueError(
                "replay() refines the low-rank PCA basis and/or streaming "
                "K-means centers; this engine tracks neither (dense moment "
                "accumulators are already exact in one pass)")
        if self.kmeans is not None and self.kmeans.decay < 1.0:
            raise ValueError(
                "replay()'s uniform Alg.-2 rebuild would un-forget the "
                "history a decay= stream deliberately down-weights; refine "
                "an undecayed engine (decay-weighted rebuilds are a ROADMAP "
                "item)")
        if passes < 1:
            raise ValueError(f"replay needs passes >= 1, got {passes}")
        m = self.spec.m
        q = q_prev = None
        if self.lowrank:
            q = refine_mod.power_orth(state.lowrank, self._omega, m)
        frozen = prev = None
        if self.kmeans is not None:
            # the best first-pass hypothesis is the frozen Alg.-2 start; prev
            # mirrors it on pass 0 (flips trivially 0 — dropped below) so the
            # jitted update keeps one signature across passes
            frozen, _ = acc.kmeans_finalize(state.kmeans)
            prev = frozen
        flips: list[int] = []
        obj = None
        lr_state = km_state = None
        for r in range(passes):
            carry = fold_pass(self._init_refine_carry(), q, frozen, prev)
            lr_state, km_state = carry
            if self.lowrank:
                q_prev, q = q, refine_mod.power_orth(lr_state, q, m)
            if self.kmeans is not None:
                if r > 0:
                    flips.append(int(km_state.flips))
                obj = km_state.obj
                prev = frozen
                frozen = refine_mod.kmeans2_centers(km_state, frozen)

        if self.lowrank:
            mean = lowrank_mod.range_finalize_mean(lr_state, m)
            count = lr_state.count
            cov = None
            cov_lowrank = refine_mod.power_finalize(lr_state, q_prev, m)
        else:
            base = self.finalize(state)
            mean, cov, count, cov_lowrank = base.mean, base.cov, base.count, None
        centers = centers_pre = None
        if self.kmeans is not None:
            centers_pre = frozen
            centers = sketch_mod.unmix_dense(centers_pre, self.spec)
        return StreamResult(mean=mean, cov=cov, count=count, centers=centers,
                            centers_pre=centers_pre, kmeans_obj=obj,
                            cov_lowrank=cov_lowrank, refine_passes=passes,
                            refine_reassigned=tuple(flips))

    def replay(self, steps: int, seed: int | None = None, passes: int = 1,
               state: EngineState | None = None) -> StreamResult:
        """Refine a finished run() by ``passes`` replays of the same source.

        PCA (cov_path="lowrank"): each pass is one power iteration — the
        replayed operator action S·Q replaces the Gaussian sketch S·Omega,
        squaring the one-pass gap ratio per pass; finalize goes through the
        same LowRankCov core solve. K-means: each pass re-assigns every row
        against frozen pass-start centers and rebuilds them from those
        consistent assignments (two-pass Alg. 2); ``refine_reassigned[r]`` is
        the rows reassigned by rebuild r+1 (observable one replay later, so
        the last rebuild's count needs a ``passes+1``-th measurement replay if
        wanted — the estimator layer's track_reassignments does exactly that).
        ``kmeans_obj`` is the objective under the LAST pass's frozen centers.
        """
        if self._refine_update is None:
            self._refine_update = jax.jit(self._build_refine_update(),
                                          donate_argnums=0)

        def fold_pass(carry, q, frozen, prev):
            for step in range(steps):
                carry = self._refine_update(carry,
                                            self._host_global_batch(seed, step),
                                            jnp.int32(step), q, frozen, prev)
            return carry

        return self._replay_passes(fold_pass, passes, state)

    def replay_scanned(self, xs, passes: int = 1,
                       state: EngineState | None = None) -> StreamResult:
        """replay() over a pre-staged stream ``xs (steps, n_shards, b, p)``,
        each pass folded as ONE jitted lax.scan (the carry is fixed-size by
        construction — scan-safety is the point of the delta algebra)."""
        if self._refine_scan is None:
            update = self._build_refine_update()

            @jax.jit
            def scan_pass(carry, xs, q, frozen, prev):
                def body(c, inp):
                    step, x = inp
                    return update(c, x, step, q, frozen, prev), None
                steps = xs.shape[0]
                c, _ = jax.lax.scan(
                    body, carry, (jnp.arange(steps, dtype=jnp.int32), xs))
                return c

            self._refine_scan = scan_pass
        xs = jnp.asarray(xs)

        def fold_pass(carry, q, frozen, prev):
            return self._refine_scan(carry, xs, q, frozen, prev)

        return self._replay_passes(fold_pass, passes, state)

    # ---------------------------------------------------------- finalizing --

    def finalize(self, state: EngineState | None = None) -> StreamResult:
        state = state if state is not None else self.state
        if state is None:
            raise RuntimeError("no stream folded yet — call run()/run_scanned(), "
                               "or pass an EngineState explicitly")
        if state.lowrank is not None:
            # RangeState carries the Thm-4 accumulators itself (see EngineState)
            mean = lowrank_mod.range_finalize_mean(state.lowrank, self.spec.m)
            count = state.lowrank.count
            cov = None
            cov_lowrank = lowrank_mod.range_finalize(state.lowrank, self.spec.m,
                                                     self._omega)
        else:
            mean = acc.moment_finalize_mean(state.moments, self.spec.m)
            count = state.moments.count
            cov = (acc.moment_finalize_cov(state.moments, self.spec.m)
                   if self.track_cov else None)
            cov_lowrank = None
        centers = centers_pre = obj = None
        if state.kmeans is not None:
            centers_pre, obj = acc.kmeans_finalize(state.kmeans)
            centers = sketch_mod.unmix_dense(centers_pre, self.spec)
        r_total = r_last = None
        if state.reassign is not None:
            r_total = np.asarray(state.reassign[0])
            r_last = np.asarray(state.reassign[1])
        return StreamResult(mean=mean, cov=cov, count=count,
                            centers=centers, centers_pre=centers_pre, kmeans_obj=obj,
                            cov_lowrank=cov_lowrank,
                            reassign_total=r_total, reassign_last=r_last)

    def assign(self, batch: SparseRows, state: EngineState | None = None) -> jax.Array:
        """Labels for already-sketched rows under the best hypothesis' centers."""
        state = state if state is not None else self.state
        if state is None or state.kmeans is None:
            raise RuntimeError("no K-means state — construct the engine with a "
                               "StreamKMeansConfig and run() a stream first")
        centers_pre, _ = acc.kmeans_finalize(state.kmeans)
        return acc.kmeans_assign(centers_pre, batch)
