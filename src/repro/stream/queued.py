"""QueueSource — live pushed chunks behind the (seed, step, shard) contract.

The StreamEngine / ``fit_stream`` / ``fit_many(source=)`` contract is a pure
function ``(seed, step, shard) → (b, p)``; a serving loop instead receives
chunks *pushed* at it. :class:`QueueSource` bridges the two: producers
``push()`` (b, p) arrays in arrival order, and the source hands chunk
``j = step · n_shards + shard`` to whoever pulls it — blocking (with a
timeout) until the producer catches up, so an engine pass can run concurrently
with ingestion.

A queue cannot *regenerate* chunks the way the contract's pure sources can, so
by default each chunk is retained after being served (``retain=True``): replay
— second-pass :func:`repro.refine` refinement, or a restarted pass — re-reads
the buffer. ``retain=False`` drops each chunk once pulled (true constant
memory); pulling a dropped chunk then raises, which is the honest answer for a
one-shot stream.

``close()`` marks the stream complete: pulls past the last pushed chunk fail
fast instead of blocking out the timeout, and ``steps(n_shards)`` reports how
many FULL steps the buffer covers (what you pass to ``engine.run`` /
``fit_stream``).
"""
from __future__ import annotations

import threading

import numpy as np


class QueueSource:
    """Thread-safe push-side adapter to the ``(seed, step, shard)`` contract.

    Producers call :meth:`push`; consumers hand the object itself to
    ``normalize_source`` / ``StreamEngine`` / ``fit_stream`` (it exposes the
    ``batch_at(step, shard)`` protocol). Chunks map to (step, shard) in push
    order: the j-th pushed chunk serves ``(step, shard) = divmod(j, n_shards)``.
    """

    def __init__(self, n_shards: int = 1, retain: bool = True,
                 timeout: float = 30.0):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)
        self.retain = bool(retain)
        self.timeout = float(timeout)
        self._chunks: dict[int, np.ndarray] = {}
        self._pushed = 0
        self._closed = False
        self._cond = threading.Condition()

    # ------------------------------------------------------------ producer --

    def push(self, rows) -> int:
        """Append one (b, p) chunk; returns its linear chunk index."""
        rows = np.asarray(rows)
        if rows.ndim != 2:
            raise ValueError(f"expected a (rows, p) chunk, got shape {rows.shape}")
        with self._cond:
            if self._closed:
                raise RuntimeError("push() after close(): the stream is complete")
            j = self._pushed
            self._chunks[j] = rows
            self._pushed += 1
            self._cond.notify_all()
            return j

    def close(self) -> None:
        """No more chunks will arrive — blocked pulls past the end fail fast."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    # ------------------------------------------------------------ consumer --

    def steps(self, n_shards: int | None = None) -> int:
        """Full (step × n_shards) blocks currently buffered."""
        with self._cond:
            return self._pushed // (n_shards or self.n_shards)

    def batch_at(self, step: int, shard: int):
        """The chunk at (step, shard) — blocks until pushed, or raises if the
        stream closed short / the chunk was already dropped (retain=False)."""
        j = step * self.n_shards + shard
        with self._cond:
            while j >= self._pushed:
                if self._closed:
                    raise RuntimeError(
                        f"chunk (step={step}, shard={shard}) is past the end of "
                        f"a closed QueueSource ({self._pushed} chunks pushed)")
                if not self._cond.wait(timeout=self.timeout):
                    raise TimeoutError(
                        f"no chunk for (step={step}, shard={shard}) after "
                        f"{self.timeout}s — producer stalled? (push() more or "
                        "close())")
            if j not in self._chunks:
                raise RuntimeError(
                    f"chunk (step={step}, shard={shard}) was already served and "
                    "dropped (retain=False); a replayable stream needs "
                    "retain=True")
            rows = self._chunks[j]
            if not self.retain:
                del self._chunks[j]
            return rows
