"""Streaming sketch engine: one-pass, sharded estimation at any p (paper §I, IV–VI).

- engine:       StreamEngine — source → sketch → accumulate → finalize as one
                jitted, optionally shard_map'd loop.
- accumulators: constant-memory delta/apply algebra (Thm-4 mean, Thm-6 cov,
                mini-batch streaming sparsified K-means).
- sharded:      one-shot shard_map reductions + the distributed-data entry
                points (shard_rows / sketch_sharded / sharded_kmeans).
- state:        the EngineState lifecycle protocol — merge algebra and
                to_arrays/from_arrays serialization shared by the engine,
                the api estimators, sketchserve snapshots, and elastic
                re-sharding (repro.cluster).
- queued:       QueueSource — live pushed chunks adapted to the
                (seed, step, shard) source contract.
"""
from repro.stream.accumulators import (  # noqa: F401
    KMeansState,
    MomentState,
    kmeans_assign,
    kmeans_finalize,
    kmeans_init,
    moment_finalize_cov,
    moment_finalize_mean,
    moment_init,
)
from repro.stream.engine import (  # noqa: F401
    EngineState,
    EngineTelemetry,
    StreamEngine,
    StreamKMeansConfig,
    StreamResult,
    batch_key,
    normalize_source,
)
from repro.stream.queued import QueueSource  # noqa: F401
from repro.stream.state import (  # noqa: F401
    engine_from_arrays,
    engine_merge,
    engine_to_arrays,
    from_arrays,
    load_engine,
    merge,
    save_engine,
    to_arrays,
)
from repro.stream.sharded import (  # noqa: F401
    shard_rows,
    sharded_cov,
    sharded_kmeans,
    sharded_kmeans_step,
    sharded_mean,
    sharded_moments,
    sketch_sharded,
)
