"""shard_map one-shot reductions over row-sharded sketches.

These are the batch (non-streaming) entry points of the same delta/psum algebra
the StreamEngine loops: each shard computes its local accumulator delta from its
rows, and the only collective is one psum of the fixed-size delta — (p,) for the
mean, (p, p) for the covariance — regardless of how many rows each shard holds.
(These absorbed the former ``repro.core.distributed`` shims: this module is
the one home of the distributed one-pass setting, ``repro.api`` the front
door over it.)

The ``repro.api`` sharded backend also streams THROUGH :func:`sharded_moments`:
its moment reducer buffers one step's shard sketches, reduces them with a
single call (one psum of the fixed-size delta), folds the result via
``moment_apply``, and drops the sketches — per-step streaming reduction, so
host memory stays constant in the stream length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.sampling import SparseRows
from repro.lowrank import range_finder as lr_range
from repro.stream import accumulators as acc


@functools.lru_cache(maxsize=None)
def _moments_fn(mesh, axes, track_cov, cov_path, p):
    """The compiled psum reduction, cached per (mesh, axes, flags, p) so the
    per-step streaming callers (repro.api sharded backend) pay tracing and
    compilation once per stream, not once per step."""

    def local(values, indices):
        delta = acc.moment_delta(SparseRows(values, indices, p), track_cov=track_cov,
                                 cov_path=cov_path)
        for a in axes:
            delta = jax.lax.psum(delta, a)
        return delta

    row_spec = P(axes if len(axes) > 1 else axes[0], None)
    return jax.jit(shard_map(local, mesh=mesh, in_specs=(row_spec, row_spec),
                             out_specs=P()))


def sharded_moments(s: SparseRows, mesh, axes=("data",), track_cov: bool = True,
                    cov_path: str = "dense") -> acc.MomentState:
    """psum-reduced MomentState for a row-sharded sketch (replicated output).

    ``cov_path="compact"`` uses the n·m² outer-product delta (no dense (n, p)
    intermediate per shard) — the γ ≪ 1 choice.
    """
    p = s.p
    n = s.values.shape[0]
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    # shard_map needs the row axis evenly divisible; zero-value pad rows add
    # nothing to sum_w / sum_wwt, and the true n overrides the count below.
    pad = -n % n_shards
    values, indices = s.values, s.indices
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        indices = jnp.pad(indices, ((0, pad), (0, 0)))

    fn = _moments_fn(mesh, tuple(axes), bool(track_cov), cov_path, p)
    st = fn(values, indices)
    return acc.MomentState(st.sum_w, st.sum_wwt, jnp.int32(n))


@functools.lru_cache(maxsize=None)
def _lowrank_fn(mesh, axes, p, ell, impl):
    """Compiled psum reduction of the low-rank range-finder delta — the
    cross-shard traffic is the fixed (p, l) + 2·(p,) state, never (p, p)."""

    def local(values, indices, omega_mat):
        delta = lr_range.range_delta(SparseRows(values, indices, p), omega_mat,
                                     impl=impl)
        for a in axes:
            delta = jax.lax.psum(delta, a)
        return delta

    row_spec = P(axes if len(axes) > 1 else axes[0], None)
    return jax.jit(shard_map(local, mesh=mesh,
                             in_specs=(row_spec, row_spec, P()),
                             out_specs=P()))


def sharded_lowrank(s: SparseRows, omega_mat: jax.Array, mesh, axes=("data",),
                    impl: str = "auto") -> lr_range.RangeState:
    """psum-reduced RangeState delta for a row-sharded sketch (replicated out).

    The streaming low-rank analogue of :func:`sharded_moments`: same zero-pad
    handling (pad rows contribute nothing; the true n overrides the count).
    """
    p = s.p
    n = s.values.shape[0]
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    pad = -n % n_shards
    values, indices = s.values, s.indices
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        indices = jnp.pad(indices, ((0, pad), (0, 0)))

    fn = _lowrank_fn(mesh, tuple(axes), p, omega_mat.shape[1], impl)
    st = fn(values, indices, omega_mat)
    return lr_range.RangeState(st.y, st.diag, st.sum_w, jnp.int32(n))


def sharded_mean(s: SparseRows, mesh, axes=("data",)) -> jax.Array:
    """Thm-4 estimator with explicit psum accumulation (cross-shard traffic: (p,))."""
    st = sharded_moments(s, mesh, axes, track_cov=False)
    return acc.moment_finalize_mean(st, s.m)


def sharded_cov(s: SparseRows, mesh, axes=("data",)) -> jax.Array:
    """Thm-6 estimator with explicit psum accumulation (cross-shard traffic: (p,p))."""
    st = sharded_moments(s, mesh, axes, track_cov=True)
    return acc.moment_finalize_cov(st, s.m)


@functools.lru_cache(maxsize=None)
def _kmeans_step_fn(mesh, axis, p, decay, track):
    """Compiled mini-batch K-means step: local masked delta per shard, ONE psum
    of the fixed-size (sums, cnts, obj, n) delta, apply on replicated state.
    Cached per (mesh, axis, p, decay, track) so streaming callers compile once."""
    from repro.core.kmeans import sparse_sq_dists

    def local(state, values, indices, mask):
        k = state.centers.shape[1]
        maskf = mask.astype(jnp.float32)
        maski = jnp.broadcast_to(mask.astype(jnp.int32)[:, None], indices.shape)

        def one(centers):
            d = sparse_sq_dists(values, indices, centers)        # (n, K)
            a = jnp.argmin(d, axis=1)
            rows = jnp.broadcast_to(a[:, None], indices.shape)
            # Zero-pad rows are REAL points at the origin to the scatter adds
            # (unlike the linear moment deltas) — the mask zeroes their
            # values, counts, and objective contributions explicitly.
            sums = jnp.zeros((k, p), jnp.float32).at[rows, indices].add(
                values.astype(jnp.float32) * maskf[:, None])
            cnts = jnp.zeros((k, p), jnp.int32).at[rows, indices].add(maski)
            obj = jnp.sum(jnp.min(d, axis=1) * maskf).astype(jnp.float32)
            return sums, cnts, obj, a.astype(jnp.int32)

        sums, cnts, obj, assign = jax.vmap(one)(state.centers)
        delta = jax.lax.psum(
            (sums, cnts, obj, jnp.sum(mask).astype(jnp.int32)), axis)
        new = acc.kmeans_apply(state, delta, decay)
        if not track:
            return new

        def reassigned(c_new, a_prev):
            a1 = jnp.argmin(sparse_sq_dists(values, indices, c_new), axis=1)
            return jnp.sum((a1.astype(jnp.int32) != a_prev)
                           * mask.astype(jnp.int32)).astype(jnp.int32)

        cnt = jax.lax.psum(jax.vmap(reassigned)(new.centers, assign), axis)
        return new, cnt

    row_spec = P(axis, None)
    out = (P(), P()) if track else P()
    return jax.jit(shard_map(local, mesh=mesh,
                             in_specs=(P(), row_spec, row_spec, P(axis)),
                             out_specs=out))


def sharded_kmeans_step(state: acc.KMeansState, s: SparseRows, mesh,
                        axis: str = "data", *, decay: float = 1.0,
                        track_reassignments: bool = False, mask=None):
    """One streaming mini-batch K-means step over a row-sharded step sketch.

    The mesh-resident analogue of ``kmeans_delta`` + ``kmeans_apply``:
    assignment stays local to each shard, the only collective is one psum of
    the fixed-size delta, and the Eq.-39 apply (with ``decay``) runs once on
    the replicated state — so sharded streaming matches the host loop to
    float-summation reordering. Returns ``(new_state, reassigned)`` where
    ``reassigned`` is the psum'd (r,) int32 reassignment count when
    ``track_reassignments`` (one extra assignment pass under the NEW centers),
    else ``None``.

    Rows are zero-padded to divide the mesh's shard count; because padded rows
    would be real origin points to the scatter adds, an explicit row ``mask``
    zeroes their contribution (multiprocess callers pass pre-assembled global
    arrays plus their own mask; single-host callers may leave ``mask=None``).
    """
    n = s.values.shape[0]
    n_shards = mesh.shape[axis]
    values, indices = s.values, s.indices
    if mask is None:
        pad = -n % n_shards
        mask = jnp.ones((n,), jnp.int32)
        if pad:
            values = jnp.pad(values, ((0, pad), (0, 0)))
            indices = jnp.pad(indices, ((0, pad), (0, 0)))
            mask = jnp.pad(mask, (0, pad))
    fn = _kmeans_step_fn(mesh, axis, s.p, float(decay),
                         bool(track_reassignments))
    out = fn(state, values, indices, mask)
    return out if track_reassignments else (out, None)


# --------------------------------------------- distributed-data entry points --
# Absorbed from the retired repro.core.distributed module (paper §I's
# distributed setting): place rows on the mesh, sketch them in place, and run
# the sparse Lloyd solver inside the mesh context so its many small
# reductions lower to the same psums.


def shard_rows(x: jax.Array, mesh, axes=("data",)) -> jax.Array:
    """Place (n, …) data row-sharded over the mesh's data axes."""
    from jax.sharding import NamedSharding

    spec = P(axes if len(axes) > 1 else axes[0], *([None] * (x.ndim - 1)))
    return jax.device_put(x, NamedSharding(mesh, spec))


def sketch_sharded(x: jax.Array, spec, mesh, axes=("data",)) -> SparseRows:
    """One-pass compress of row-sharded data; output stays row-sharded."""
    from repro.core import sketch

    xs = shard_rows(x, mesh, axes)
    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        return sketch.sketch(xs, spec)


def sharded_kmeans(s: SparseRows, k: int, key, mesh, n_init: int = 3,
                   max_iter: int = 50, tol: float = 1e-6):
    """Sparsified K-means on sharded sketches (assignment stays local; the
    center/count scatter-adds psum over the data axes)."""
    from repro.core import kmeans

    with mesh:
        return kmeans.sparse_kmeans_core(
            s.values, s.indices, s.p, k, key, n_init=n_init, max_iter=max_iter,
            tol=tol)
