"""Two-pass (Alg. 2) K-means refinement over the regenerable source.

Mini-batch streaming K-means assigns each batch against the centers AS THEY
WERE when the batch arrived, so the finalized centers inherit one round of
assignment noise: early batches were attributed to centers that have since
moved (ROADMAP "two-pass (Alg. 2) refinement"). Because every batch's sketch
regenerates from the (seed, step, shard) contract, a second pass fixes this
without storing anything: re-assign every row against FROZEN first-pass
centers, and rebuild each center as the per-coordinate mean of its
consistently-assigned sparse rows — the paper's unbiased center estimator
(the steady state of the Eq. 39 update), now over one consistent assignment.

The accumulator is the fixed-size :class:`KMeans2State`; its per-batch delta
depends only on the frozen centers (not on the accumulated state), so folds
commute and batch / stream / sharded backends produce BIT-IDENTICAL refined
centers (tests/test_refine.py asserts equality, not tolerance). The delta is
additive, so a distributed replay psums it per step exactly like the moment
deltas.

Convergence signal: each pass also counts rows whose nearest frozen center
differs from their nearest center one rebuild earlier — the same
reassignment-count signal ``SparsifiedKMeans`` tracks per step during
streaming, continued across refinement passes (it decays to zero as the
rebuilds converge to a Lloyd fixed point of the sketch).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.kmeans import sparse_sq_dists
from repro.core.sampling import SparseRows


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class KMeans2State:
    """One replay pass's fixed-size accumulators (all frozen-center driven).

    sums:  (K, p) Σ of sampled values per (cluster, coordinate)
    cnts:  (K, p) per-coordinate observation counts (int32 — exact)
    obj:   ()     Σ min-distance² under the frozen centers
    flips: ()     rows whose frozen-center label ≠ their label under the
                  previous pass's centers (0 when no previous centers)
    count: ()     rows folded
    """

    sums: jax.Array
    cnts: jax.Array
    obj: jax.Array
    flips: jax.Array
    count: jax.Array

    def tree_flatten(self):
        return (self.sums, self.cnts, self.obj, self.flips, self.count), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def kmeans2_init(k: int, p: int) -> KMeans2State:
    return KMeans2State(
        sums=jnp.zeros((k, p), jnp.float32),
        cnts=jnp.zeros((k, p), jnp.int32),
        obj=jnp.zeros((), jnp.float32),
        flips=jnp.zeros((), jnp.int32),
        count=jnp.zeros((), jnp.int32),
    )


def kmeans2_delta(batch: SparseRows, frozen: jax.Array,
                  prev: jax.Array | None = None) -> KMeans2State:
    """One batch's contribution under FROZEN centers — local, additive,
    psum-able, and independent of the accumulated state (folds commute).

    ``prev`` (the centers one rebuild earlier) enables the flip count; pass
    None on the first pass (one distance sweep instead of two).
    """
    values, indices = batch.values, batch.indices
    k, p = frozen.shape
    d = sparse_sq_dists(values, indices, frozen)               # (n, K)
    a = jnp.argmin(d, axis=1)
    rows = jnp.broadcast_to(a[:, None], indices.shape)
    v32 = values.astype(jnp.float32)
    sums = jnp.zeros((k, p), jnp.float32).at[rows, indices].add(v32)
    cnts = jnp.zeros((k, p), jnp.int32).at[rows, indices].add(1)
    if prev is None:
        flips = jnp.zeros((), jnp.int32)
    else:
        a_prev = jnp.argmin(sparse_sq_dists(values, indices, prev), axis=1)
        flips = jnp.sum(a != a_prev).astype(jnp.int32)
    return KMeans2State(sums, cnts, jnp.sum(jnp.min(d, axis=1)).astype(jnp.float32),
                        flips, jnp.int32(values.shape[0]))


def kmeans2_apply(state: KMeans2State, delta: KMeans2State) -> KMeans2State:
    """Fold a (possibly psum'd) delta into the pass accumulator."""
    return KMeans2State(state.sums + delta.sums, state.cnts + delta.cnts,
                        state.obj + delta.obj, state.flips + delta.flips,
                        state.count + delta.count)


def kmeans2_centers(state: KMeans2State, frozen: jax.Array) -> jax.Array:
    """Rebuild: per-coordinate mean of the consistently-assigned sparse rows;
    never-sampled (cluster, coordinate) cells keep their frozen value (the
    paper's never-sampled-coordinate convention, same as the streaming fold)."""
    return jnp.where(state.cnts > 0,
                     state.sums / jnp.maximum(state.cnts, 1).astype(jnp.float32),
                     frozen)
