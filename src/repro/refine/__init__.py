"""Second-pass refinement over the regenerable source — zero stored data.

The paper's sampling is single-pass, but its guarantees are per-step: the
range-finder's PCA subspace is pinned at the one-pass gap ratio, and streaming
K-means centers inherit one round of assignment noise (each batch was assigned
against the centers as they were when it arrived). Because every backend
regenerates per-batch masks from the ``(seed, step, shard)`` contract
(``core.sketch.batch_key``), extra passes cost zero stored data — replaying
the source reproduces every sketch bit-identically.

- :mod:`repro.refine.power` — PCA power iteration: replay with the Gaussian
  test matrix replaced by the current basis, Y = S·Q accumulated by the same
  ``kernels/spmm``-fed :class:`~repro.lowrank.range_finder.RangeState` (same
  mask-noise debiasing, same per-step psum), gap ratio squared per pass,
  finalized through the existing :class:`~repro.lowrank.model.LowRankCov`
  core solve.
- :mod:`repro.refine.kmeans2` — two-pass (Alg. 2) K-means: re-assign every
  row against FROZEN first-pass centers on a replay pass and rebuild centers
  from those consistent assignments (the unbiased per-coordinate center
  estimator); reassignment counts continue as the convergence signal.
- :mod:`repro.refine.replay` — the shared replay driver: one regenerated
  sketch per (step, shard) chunk per pass, fanned out to every refiner.

Front doors: ``Plan(refine_passes=q)`` + ``SparsifiedPCA.fit_refine`` /
``SparsifiedKMeans.fit_refine``, ``StreamEngine.replay()`` (scan-safe; one
fixed-size psum per step under a mesh), and ``fit_many(..., refine=True)``.
"""
from repro.refine.kmeans2 import (  # noqa: F401
    KMeans2State,
    kmeans2_apply,
    kmeans2_centers,
    kmeans2_delta,
    kmeans2_init,
)
from repro.refine.power import (  # noqa: F401
    debiased_action,
    power_finalize,
    power_orth,
    subspace_change,
)
from repro.refine.replay import replay_sketches, run_refine  # noqa: F401
