"""Replay of the regenerable source — the second-pass driver.

Every backend keys chunk j's mask with ``sketch.batch_key(spec, step, shard)``
where ``(step, shard) = plan.step_shard(j)``, so the full sketch sequence of a
finished pass regenerates BIT-IDENTICALLY from (plan, spec) plus the original
data (in-memory array or ``(seed, step, shard)`` source) — nothing was stored.
:func:`replay_sketches` is that regeneration as a generator;
:func:`run_refine` walks it once per refinement pass and fans each sketch out
to every refiner — one sketch per (step, shard) chunk per pass, shared by all
refiners exactly like the forward :class:`~repro.api.estimators.SketchCursor`
pass (the ``fit_many(refine=True)`` story).

Refiner protocol (duck-typed; implemented by ``SparsifiedPCA`` /
``SparsifiedKMeans``):

- ``_refine_pass_begin(f)``      — allocate the pass-f fold state;
- ``_refine_fold(s, step, shard)`` — fold one replayed sketch (sharded
  refiners buffer a step and psum its fixed-size delta themselves);
- ``_refine_pass_end(f, last, signal)`` — flush + rebuild (orthonormalize the
  power basis / rebuild the frozen-assignment centers);
- ``_refine_end(passes)``        — finalize the fitted attributes;
- ``_refine_needs_signal()``     — True to request ONE trailing
  measurement-only replay (fold ``f == passes``): same fold, rebuild
  discarded. It prices the LAST rebuild's reassignment count (and the true
  objective of the final centers) — the flip count between c_r and c_{r-1} is
  only observable by re-assigning, i.e. one replay later.
"""
from __future__ import annotations

from typing import Iterator, Sequence

import jax
import jax.numpy as jnp

from repro.core import sketch as sketch_mod
from repro.core.sampling import SparseRows
from repro.core.sketch import batch_key


def replay_sketches(plan, spec: sketch_mod.SketchSpec, data=None, *, source=None,
                    steps: int | None = None, seed: int | None = None,
                    chunk_rows: Sequence[int] | None = None,
                    ) -> Iterator[tuple[SparseRows, int, int]]:
    """Yield ``(sketch, step, shard)`` regenerating a finished pass exactly.

    ``data``: the SAME (rows, p) array the pass ingested — re-chunked into the
    recorded ``chunk_rows`` boundaries (the cursor's per-chunk row counts, so
    ragged partial_fit histories replay under exactly their original
    (step, shard) mask keys), or in consecutive ``plan.batch_size`` chunks
    when ``chunk_rows`` is None.
    ``source``: the pass's ``(seed, step, shard) → (b, p)`` source (already
    normalized by the caller), pulled for steps × n_shards batches.
    """
    if (data is None) == (source is None):
        raise ValueError("replay needs exactly one of data or source=")
    if data is not None:
        x = jnp.asarray(data).astype(plan.dtype)
        if x.ndim != 2 or x.shape[1] != spec.p:
            raise ValueError(f"replay data has shape {x.shape}, but the fitted "
                             f"pass was p={spec.p}")
        bs = plan.batch_size
        if chunk_rows is None:
            n = int(x.shape[0])
            chunk_rows = [min(bs, n - i) for i in range(0, n, bs)]
        elif sum(chunk_rows) != x.shape[0]:
            raise ValueError(
                f"chunk_rows sums to {sum(chunk_rows)} but the replay data "
                f"has {x.shape[0]} rows — pass the array the fitted pass "
                "consumed")
        i = 0
        for j, rows in enumerate(chunk_rows):
            step, shard = plan.step_shard(j)
            yield (sketch_mod.sketch(x[i:i + rows], spec,
                                     batch_key=batch_key(spec, step, shard),
                                     impl=plan.impl), step, shard)
            i += rows
    else:
        if steps is None:
            raise ValueError("source= replay needs steps=")
        for step in range(steps):
            for shard in range(plan.n_shards):
                rows = jnp.asarray(source(seed, step, shard)).astype(plan.dtype)
                if rows.shape[-1] != spec.p:
                    raise ValueError(f"source batch has p={rows.shape[-1]}, "
                                     f"fitted pass was p={spec.p}")
                yield (sketch_mod.sketch(rows, spec,
                                         batch_key=batch_key(spec, step, shard),
                                         impl=plan.impl), step, shard)


def run_refine(plan, spec: sketch_mod.SketchSpec, refiners: Sequence, passes: int,
               data=None, *, source=None, steps: int | None = None,
               seed: int | None = None,
               chunk_rows: Sequence[int] | None = None) -> None:
    """Drive ``passes`` refinement passes over the regenerated sketch stream.

    Each pass regenerates every (step, shard) sketch ONCE and fans it out to
    every refiner (the shared-cursor discipline, applied to replay). A trailing
    measurement-only fold runs iff some refiner requests it; refiners that
    don't are simply not fed during it.
    """
    if passes < 1:
        raise ValueError(f"refinement needs passes >= 1, got {passes}")
    refiners = list(refiners)
    signal = [r for r in refiners if r._refine_needs_signal()]
    for f in range(passes + (1 if signal else 0)):
        is_signal = f >= passes
        active = signal if is_signal else refiners
        for r in active:
            r._refine_pass_begin(f)
        for s, step, shard in replay_sketches(plan, spec, data, source=source,
                                              steps=steps, seed=seed,
                                              chunk_rows=chunk_rows):
            for r in active:
                r._refine_fold(s, step, shard)
        for r in active:
            r._refine_pass_end(f, last=(f == passes - 1), signal=is_signal)
    for r in refiners:
        r._refine_end(passes)
