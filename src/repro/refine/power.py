"""PCA power-iteration refinement over the regenerable source (ROADMAP
"single-pass accuracy ceiling").

The one-pass range-finder pins its subspace error at the one-pass gap ratio:
the basis is orth of Y' = S'·Omega for a RANDOM Omega, so the captured range
leaks tail directions in proportion to σ_{r+1}/σ_k of the debiased operator
S' = S − corr·diag(S). Every backend regenerates batch masks from the
(seed, step, shard) contract, so a replay pass costs zero stored data — and
replaying with Omega replaced by the CURRENT basis Q is exactly one step of
power iteration:

    Y_r = S·Q_{r-1}          (accumulated by the same kernels/spmm range_delta)
    Q_r = orth(Y_r − corr·(diag(S) ∘ Q_{r-1}))      (debias, then orthonormalize)

Each pass multiplies the leaked-tail fraction by another gap ratio (squares it
counting the initial sketch), while the accumulator stays the same O(l·p)
:class:`~repro.lowrank.range_finder.RangeState` — per-pass deltas psum across
shards exactly like the first pass. Finalize reuses the one-pass core solve
(:func:`~repro.lowrank.range_finder.range_finalize`) with Omega → Q_{q-1}: the
fat least-squares system Qᵀ·Y' ≈ core·(QᵀQ_{q-1}) is even better conditioned
than the Gaussian one, because Q_{q-1} already spans the captured range.

S here is the SKETCH's co-occurrence matrix, so power iteration converges to
the dense-path eigenvectors of the SAME sketched estimate Ĉ_n — the estimator
noise floor of Thm 6 is unchanged; what shrinks is the range-finder's subspace
gap on top of it (tests/test_refine.py measures dense-vs-lowrank angles).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.lowrank.model import LowRankCov
from repro.lowrank.range_finder import RangeState, range_finalize


def debiased_action(state: RangeState, q_prev: jax.Array, m: int) -> jax.Array:
    """(p, l) — the debiased operator's action S'·Q_prev / count, in closed form.

    ``state.y`` accumulated S·Q_prev over the replay; diag(S) is carried
    exactly, so the mask-noise diagonal floor is removed without another pass
    (the same move as the one-pass finalize, with Omega → Q_prev).
    """
    p = state.y.shape[0]
    corr = (p - m) / (p - 1)
    return (state.y - corr * state.diag[:, None] * q_prev) / state.count


def power_orth(state: RangeState, q_prev: jax.Array, m: int) -> jax.Array:
    """The next power-iteration basis: orth(S'·Q_prev), (p, l) orthonormal.

    Orthonormalized by SVD rather than QR so the columns come out ordered by
    singular value — the leading l/2 columns are the model-rank subspace the
    finalize will keep, which is what convergence diagnostics should watch
    (the trailing columns churn in the noise tail forever).
    """
    u, _, _ = jnp.linalg.svd(debiased_action(state, q_prev, m), full_matrices=False)
    return u


def power_finalize(state: RangeState, q_prev: jax.Array, m: int,
                   rank: int | None = None) -> LowRankCov:
    """Finalize the LAST pass's state through the one-pass core solve.

    Identical algebra to :func:`range_finalize` with the test matrix Q_prev in
    place of Omega — basis = top-l/2 left singular vectors of the debiased
    action, core = fat least-squares — so the refined model has the same rank
    and eigenvalue scaling as the one-pass model it supersedes.
    """
    return range_finalize(state, m, q_prev, rank=rank)


def subspace_change(q_new: jax.Array, q_old: jax.Array) -> float:
    """Largest principal-angle sine between two orthonormal bases — the
    per-pass convergence diagnostic (decays by the gap ratio each pass)."""
    s = jnp.linalg.svd(q_new.T @ q_old, compute_uv=False)
    return float(jnp.sqrt(jnp.maximum(0.0, 1.0 - jnp.min(s) ** 2)))
