"""Pytree utilities shared by the optimizer / checkpointing / compression layers."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def tree_size_bytes(tree: Any) -> int:
    """Total bytes across all array leaves."""
    return sum(
        np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
        for l in jax.tree_util.tree_leaves(tree)
        if hasattr(l, "shape")
    )


def tree_count_params(tree: Any) -> int:
    return sum(
        int(np.prod(l.shape))
        for l in jax.tree_util.tree_leaves(tree)
        if hasattr(l, "shape")
    )


def tree_zeros_like(tree: Any, dtype=None) -> Any:
    return jax.tree.map(lambda l: jnp.zeros(l.shape, dtype or l.dtype), tree)


def tree_map_with_path_names(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map ``fn(name, leaf)`` where name is a '/'-joined key path (for sharding rules)."""

    def _name(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        return "/".join(parts)

    return jax.tree_util.tree_map_with_path(lambda path, l: fn(_name(path), l), tree)


def tree_global_norm(tree: Any) -> jax.Array:
    """Global ℓ2 norm with f32 ACCUMULATION but no f32 materialization — a
    self-dot per leaf keeps bf16 gradients in their own dtype (a whole-tree
    astype(f32) costs 2× the gradient memory in temporaries)."""

    def leaf_sq(l):
        # contract ALL dims in place — a reshape(-1) of a sharded tensor would
        # force GSPMD to replicate it (dry-run: TBs of temp); full contraction
        # partitions cleanly into local dots + psum
        dims = tuple(range(l.ndim))
        return jax.lax.dot_general(l, l, ((dims, dims), ((), ())),
                                   preferred_element_type=jnp.float32)

    leaves = [leaf_sq(l) for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_flatten_to_vector(tree: Any) -> tuple[jax.Array, Callable[[jax.Array], Any]]:
    """Flatten all leaves into one fp32 vector; returns (vector, unflatten_fn).

    Used by the gradient sketch: the paper's estimator acts on vectors in R^p,
    so we view the whole gradient pytree as one long vector.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    sizes = [int(np.prod(s)) for s in shapes]
    vec = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves]) if leaves else jnp.zeros((0,))

    def unflatten(v: jax.Array) -> Any:
        out, off = [], 0
        for shape, dtype, size in zip(shapes, dtypes, sizes):
            out.append(v[off : off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree_util.tree_unflatten(treedef, out)

    return vec, unflatten
