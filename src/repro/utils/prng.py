"""PRNG helpers: named key folding so every subsystem derives independent streams.

All randomness in the framework flows from a single root key per run; subsystems
fold in stable string tags so that adding a new consumer never perturbs existing
streams (important for checkpoint/restart determinism).
"""
from __future__ import annotations

import hashlib

import jax
import jax.numpy as jnp


def fold_in_str(key: jax.Array, tag: str) -> jax.Array:
    """Derive a subkey from ``key`` using a stable hash of ``tag``."""
    h = int.from_bytes(hashlib.sha256(tag.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)


def rademacher(key: jax.Array, shape, dtype=jnp.float32) -> jax.Array:
    """±1 entries with equal probability (the diagonal of D in the ROS)."""
    return jax.random.rademacher(key, shape, dtype=dtype)


def key_for_step(key: jax.Array, step: jax.Array | int) -> jax.Array:
    """Per-step key (used by e.g. the gradient sketch so every step resamples R_i)."""
    return jax.random.fold_in(key, step)
