from repro.utils import prng, tree  # noqa: F401
