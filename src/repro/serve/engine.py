"""Batched serving engine: request queue → batched prefill → lockstep decode.

Static batching with early-retire masking: a wave of up to ``n_slots``
requests is admitted together (prompts right-aligned by padding to the wave's
max prompt length), decoded in lockstep with ONE jitted step per token, and
retired per-request when its budget is exhausted — finished slots continue to
decode but their outputs are masked (the standard static-batch serving
pattern; per-slot cache offsets for true continuous batching would need a
vectorized cur_len in the decode path, noted as future work in DESIGN.md).

The same queue→coalesce→one-jitted-step idiom serves the sketching side:
``repro.sketchserve.SketchService`` micro-batches same-group ingest requests
into a single sketch+fold step, the estimator analogue of this engine's
wave-batched decode.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ModelAPI


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Wave-batched greedy decoding over a fixed KV budget."""

    def __init__(self, api: ModelAPI, params, n_slots: int = 4, max_len: int = 128):
        if api.cfg.family == "audio":
            raise NotImplementedError("enc-dec serving uses launch/serve.py directly")
        self.api, self.params = api, params
        self.n_slots, self.max_len = n_slots, max_len
        self.queue: deque[Request] = deque()
        self._decode = jax.jit(lambda p, t, c, l: api.decode_fn(p, t, c, l))

    def submit(self, req: Request):
        self.queue.append(req)

    def _run_wave(self, wave: list[Request]) -> None:
        b = self.n_slots
        plen = max(len(r.prompt) for r in wave)
        prompts = np.zeros((b, plen), np.int32)
        for s, r in enumerate(wave):
            prompts[s, plen - len(r.prompt):] = r.prompt      # right-aligned
        cache = self.api.init_decode_state(b, self.max_len)
        tok = None
        for t in range(plen):
            tok, cache = self._decode(self.params, jnp.asarray(prompts[:, t:t + 1]),
                                      cache, jnp.int32(t + 1))
        cur = jnp.argmax(tok, -1).astype(jnp.int32)[:, None]
        budgets = np.array([r.max_new for r in wave] + [0] * (b - len(wave)))
        for s, r in enumerate(wave):
            r.out.append(int(cur[s, 0]))
            budgets[s] -= 1
        steps = 0
        while (budgets > 0).any() and plen + steps < self.max_len - 1:
            tok, cache = self._decode(self.params, cur, cache,
                                      jnp.int32(plen + steps + 2))
            cur = jnp.argmax(tok, -1).astype(jnp.int32)[:, None]
            for s, r in enumerate(wave):
                if budgets[s] > 0:
                    r.out.append(int(cur[s, 0]))
                    budgets[s] -= 1
                    if budgets[s] == 0:
                        r.done = True
            steps += 1
        for r in wave:
            r.done = True

    def run(self) -> list[Request]:
        finished: list[Request] = []
        while self.queue:
            wave = [self.queue.popleft() for _ in range(min(self.n_slots, len(self.queue)))]
            self._run_wave(wave)
            finished.extend(wave)
        return finished
