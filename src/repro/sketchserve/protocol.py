"""Request/response types of the sketch-serving queue.

Three request families, one response shape:

- :class:`IngestRequest` — rows for a tenant (or a whole co-registered
  group); the worker loop coalesces contiguous same-group ingests into one
  sketch+fold step (micro-batching).
- :class:`QueryRequest` — read against live estimator state: ``transform`` /
  ``predict`` (row payloads), ``components`` / ``centers`` / ``mean`` /
  ``cov`` / ``stats`` (fitted attributes). Queries trigger lazy finalization.
- :class:`AdminRequest` — tenant lifecycle (``create_tenant`` /
  ``delete_tenant``), ``snapshot``, and ``refine``.

Every request resolves to a :class:`Response` with ``status`` ∈
{"ok", "rejected", "error"} — "rejected" is admission-control backpressure
(full queue or per-group pending-row cap: resubmit later), "error" is a
request that was admitted but failed (unknown tenant, no data yet, bad op).

The same three statuses ARE the wire protocol: :func:`response_to_json`
flattens a Response (numpy payloads → nested lists) for the HTTP frontend in
:mod:`repro.sketchserve.http`, and :data:`HTTP_STATUS` fixes the status-code
mapping — ok → 200, rejected → 429 (backpressure: Retry-After and resubmit),
error → 400.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

#: Response.status → HTTP status code (the http.py frontend contract).
HTTP_STATUS = {"ok": 200, "rejected": 429, "error": 400}


@dataclasses.dataclass
class IngestRequest:
    """Rows for ``target`` (a tenant id or a group id — a tenant id addresses
    its whole group: co-registered tenants fold the same shared sketches)."""

    target: str
    rows: Any                      # (b, p) array-like


@dataclasses.dataclass
class QueryRequest:
    tenant: str
    op: str                        # transform|predict|components|centers|mean|cov|stats
    x: Any | None = None           # row payload for transform/predict


@dataclasses.dataclass
class AdminRequest:
    op: str                        # create_tenant|delete_tenant|snapshot|refine
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Response:
    status: str                    # ok | rejected | error
    result: Any = None
    error: str | None = None
    info: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    def unwrap(self) -> Any:
        """``result`` if ok, else raise (rejected and failed requests alike)."""
        if not self.ok:
            raise RuntimeError(f"request {self.status}: {self.error}")
        return self.result


def _jsonable(v):
    """Payload values → JSON-encodable: arrays nest as lists, numpy scalars
    unbox, dicts/sequences recurse."""
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, np.generic):
        return v.item()
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


def response_to_json(resp: Response) -> dict:
    """Response → JSON-safe dict (the HTTP response body)."""
    return {"status": resp.status, "result": _jsonable(resp.result),
            "error": resp.error, "info": _jsonable(resp.info)}
