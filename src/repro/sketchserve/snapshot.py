"""Snapshot/restore of a live :class:`~repro.sketchserve.service.SketchService`.

Rides the :mod:`repro.train.checkpoint` atomic-rename protocol
(``save_arrays`` / ``load_arrays``: manifest.json + arrays.npz + ``latest``
pointer), so a serving snapshot is crash-safe the same way a training
checkpoint is. What is written is exactly what a restarted process cannot
re-derive:

- per group: the Plan (as JSON; an explicit device mesh serializes as its
  GEOMETRY — axis names + shape, via ``repro.api.plan.mesh_spec`` — and is
  rebuilt over the restoring host's devices), the shared PRNG key, the
  cursor's replay counters (``chunk`` / ``count`` / ``chunk_rows`` /
  ``n_sketches``) and dimensionality ``p``, plus the retained ingest buffer
  when the group keeps one for refine replay;
- per tenant: kind, constructor params, its own Plan when it differs from the
  group's (co-registered tenants may fold differently — only the sketch
  geometry is shared), and the estimator's fold state via
  ``SketchedEstimator.state_arrays`` (the EngineState protocol wire format of
  ``repro.stream.state``);
- per service: the snapshot step counter (so a restored service's next
  ``snapshot()`` continues at step N+1 instead of clobbering the original
  run's earlier checkpoints under the same path) and the evicted-group map
  (groups parked under ``evict_dir`` stay lazily restorable after a restart).

NOT written: the SketchSpec (re-derived deterministically from
(plan, key, p) by ``cursor.ensure_spec``) and every finalized attribute
(recomputed lazily at the next query). Restore therefore resumes
*bit-identically*: the restored cursor continues at the same chunk index, so
the next ingested chunk folds under the same (step, shard) mask key it would
have in the original process, and queries before/after the round-trip agree
exactly — asserted by ``benchmarks/serve_bench.py`` and
``tests/test_sketchserve.py``.

The same format serves tenant eviction: ``save_service(svc, path,
gids=[gid])`` writes one group, and :func:`restore_group` folds a parked
group back into a LIVE service (first-touch lazy restore).

Mid-step states (a sharded reducer holding un-psum'd shard sketches, a
K-means fold between apply boundaries) refuse to snapshot with a clear error
— ingest to a step boundary first.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.api.plan import Plan, mesh_from_spec, mesh_spec
from repro.train import checkpoint


def plan_to_json(plan: Plan) -> dict:
    """Plan → JSON-safe dict. Round-trips through :func:`plan_from_json`.

    An explicit mesh serializes as its geometry (axis names + shape); the
    restoring process rebuilds an equivalent mesh over ITS devices — the live
    Device handles are process-local, the geometry is not."""
    d = {f.name: getattr(plan, f.name) for f in dataclasses.fields(plan)}
    d["mesh"] = mesh_spec(plan.mesh)
    d["dtype"] = str(np.dtype(plan.dtype))
    return d


def plan_from_json(d: dict) -> Plan:
    d = dict(d)
    d["mesh"] = mesh_from_spec(d.get("mesh"))
    return Plan(**d)


def save_service(svc, path: str, step: int = 1,
                 gids: "list[str] | None" = None) -> None:
    """Write one checkpoint step of every live group/tenant under ``path``
    (or just ``gids`` — the eviction path). The registry view is copied under
    the service's locks, so a snapshot can never see a group mid-restore; the
    state arrays themselves are read lock-free, which is safe because the
    caller guarantees no fold is in flight (worker-thread fold boundary, or a
    quiesced pool)."""
    with svc._evict_lock:
        with svc._reg_lock:
            live = dict(svc._groups)
            evicted = {gid: dict(ev) for gid, ev in svc._evicted.items()}
    if gids is None:
        items = live
    else:
        items = {gid: live[gid] for gid in gids}
        evicted = {}
    arrays: dict[str, np.ndarray] = {}
    groups: dict[str, dict] = {}
    for gid, g in items.items():
        gplan = plan_to_json(g.plan)
        ginfo: dict = {
            "plan": gplan,
            "p": None if g.cursor.spec is None else int(g.cursor.spec.p),
            "chunk": int(g.cursor.chunk),
            "count": int(g.cursor.count),
            "n_sketches": int(g.cursor.n_sketches),
            "retain_ingest": g.retain_ingest,
            "tenants": {},
        }
        arrays[f"{gid}/__key__"] = np.asarray(g.key)
        arrays[f"{gid}/__chunk_rows__"] = np.asarray(g.cursor.chunk_rows,
                                                     dtype=np.int64)
        if g.retained:
            arrays[f"{gid}/__retained__"] = np.concatenate(
                [np.asarray(c) for c in g.retained])
            arrays[f"{gid}/__retained_rows__"] = np.array(
                [c.shape[0] for c in g.retained], np.int64)
        for tid, t in g.tenants.items():
            tplan = plan_to_json(t.est.plan)
            ginfo["tenants"][tid] = {
                "kind": t.kind,
                "params": t.params,
                "plan": None if tplan == gplan else tplan,
            }
            if g.cursor.spec is not None:
                for name, v in t.est.state_arrays().items():
                    arrays[f"{gid}/{tid}/{name}"] = np.asarray(v)
        groups[gid] = ginfo
    extra = {"format": "sketchserve-v1", "groups": groups,
             "snap_step": int(step)}
    if evicted:
        extra["evicted"] = evicted
    checkpoint.save_arrays(path, step, arrays, extra=extra)


def _load_group(svc, gid: str, ginfo: dict, arrays: dict) -> None:
    """Materialize one snapshotted group (and its tenants) into ``svc``."""
    gplan = plan_from_json(ginfo["plan"])
    key = jnp.asarray(arrays[f"{gid}/__key__"])
    for tid, tinfo in ginfo["tenants"].items():
        tplan = (plan_from_json(tinfo["plan"]) if tinfo["plan"] is not None
                 else gplan)
        resp = svc._create_tenant(tid, tinfo["kind"], tplan, key, gid,
                                  ginfo["retain_ingest"],
                                  dict(tinfo["params"]))
        if not resp.ok:
            raise RuntimeError(f"restore of tenant {tid!r}: {resp.error}")
    g = svc._groups[gid]
    if f"{gid}/__retained__" in arrays:
        flat = arrays[f"{gid}/__retained__"]
        i = 0
        for n in arrays[f"{gid}/__retained_rows__"].tolist():
            g.retained.append(flat[i:i + n])
            i += n
    if ginfo["p"] is not None:
        cur = g.cursor
        cur.ensure_spec(int(ginfo["p"]))   # spec re-derives; binds reducers
        cur.chunk = int(ginfo["chunk"])
        cur.count = int(ginfo["count"])
        cur.n_sketches = int(ginfo["n_sketches"])
        cur.chunk_rows = arrays[f"{gid}/__chunk_rows__"].tolist()
        for tid, t in g.tenants.items():
            prefix = f"{gid}/{tid}/"
            sub = {k[len(prefix):]: v for k, v in arrays.items()
                   if k.startswith(prefix)}
            t.est.load_state_arrays(sub)


def restore_service(path: str, **service_kwargs):
    """Rebuild a :class:`SketchService` from the latest snapshot under
    ``path``. Returned NOT started — call ``start()`` (or use ``with``) before
    submitting; ``service_kwargs`` override queue/batch/admission settings."""
    from repro.sketchserve.service import SketchService

    arrays, extra = checkpoint.load_arrays(path)
    if extra.get("format") != "sketchserve-v1":
        raise ValueError(f"{path} is not a sketchserve snapshot "
                         f"(format={extra.get('format')!r})")
    svc = SketchService(**service_kwargs)
    for gid, ginfo in extra["groups"].items():
        _load_group(svc, gid, ginfo, arrays)
    # resume the step counter so the next snapshot() lands at N+1 under the
    # same path instead of restarting at 1 and clobbering earlier checkpoints
    svc._snap_step = int(extra.get("snap_step", 0))
    for gid, ev in extra.get("evicted", {}).items():
        svc._evicted[gid] = {"path": ev["path"],
                             "tenants": list(ev["tenants"])}
        for tid in ev["tenants"]:
            svc._evicted_tenants[tid] = gid
    return svc


def restore_group(svc, gid: str, path: str) -> None:
    """Fold one evicted group back into a LIVE service from its eviction
    snapshot (the lazy first-touch restore). The caller
    (``SketchService._ensure_live``) holds ``_evict_lock`` and has already
    removed the eviction record; ``_create_tenant`` re-registers under
    ``_reg_lock``, so concurrent submits see the group only once complete."""
    arrays, extra = checkpoint.load_arrays(path)
    if extra.get("format") != "sketchserve-v1":
        raise ValueError(f"{path} is not a sketchserve snapshot "
                         f"(format={extra.get('format')!r})")
    if gid not in extra["groups"]:
        raise KeyError(f"group {gid!r} not in snapshot at {path}")
    _load_group(svc, gid, extra["groups"][gid], arrays)
