"""Snapshot/restore of a live :class:`~repro.sketchserve.service.SketchService`.

Rides the :mod:`repro.train.checkpoint` atomic-rename protocol
(``save_arrays`` / ``load_arrays``: manifest.json + arrays.npz + ``latest``
pointer), so a serving snapshot is crash-safe the same way a training
checkpoint is. What is written is exactly what a restarted process cannot
re-derive:

- per group: the Plan (as JSON; an explicit device mesh serializes as its
  GEOMETRY — axis names + shape, via ``repro.api.plan.mesh_spec`` — and is
  rebuilt over the restoring host's devices), the shared PRNG key, the
  cursor's replay counters (``chunk`` / ``count`` / ``chunk_rows`` /
  ``n_sketches``) and dimensionality ``p``, plus the retained ingest buffer
  when the group keeps one for refine replay;
- per tenant: kind, constructor params, its own Plan when it differs from the
  group's (co-registered tenants may fold differently — only the sketch
  geometry is shared), and the estimator's fold state via
  ``SketchedEstimator.state_arrays`` (the EngineState protocol wire format of
  ``repro.stream.state``).

NOT written: the SketchSpec (re-derived deterministically from
(plan, key, p) by ``cursor.ensure_spec``) and every finalized attribute
(recomputed lazily at the next query). Restore therefore resumes
*bit-identically*: the restored cursor continues at the same chunk index, so
the next ingested chunk folds under the same (step, shard) mask key it would
have in the original process, and queries before/after the round-trip agree
exactly — asserted by ``benchmarks/serve_bench.py`` and
``tests/test_sketchserve.py``.

Mid-step states (a sharded reducer holding un-psum'd shard sketches, a
K-means fold between apply boundaries) refuse to snapshot with a clear error
— ingest to a step boundary first.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.api.plan import Plan, mesh_from_spec, mesh_spec
from repro.train import checkpoint


def plan_to_json(plan: Plan) -> dict:
    """Plan → JSON-safe dict. Round-trips through :func:`plan_from_json`.

    An explicit mesh serializes as its geometry (axis names + shape); the
    restoring process rebuilds an equivalent mesh over ITS devices — the live
    Device handles are process-local, the geometry is not."""
    d = {f.name: getattr(plan, f.name) for f in dataclasses.fields(plan)}
    d["mesh"] = mesh_spec(plan.mesh)
    d["dtype"] = str(np.dtype(plan.dtype))
    return d


def plan_from_json(d: dict) -> Plan:
    d = dict(d)
    d["mesh"] = mesh_from_spec(d.get("mesh"))
    return Plan(**d)


def save_service(svc, path: str, step: int = 1) -> None:
    """Write one checkpoint step of every live group/tenant under ``path``."""
    arrays: dict[str, np.ndarray] = {}
    groups: dict[str, dict] = {}
    for gid, g in svc._groups.items():
        gplan = plan_to_json(g.plan)
        ginfo: dict = {
            "plan": gplan,
            "p": None if g.cursor.spec is None else int(g.cursor.spec.p),
            "chunk": int(g.cursor.chunk),
            "count": int(g.cursor.count),
            "n_sketches": int(g.cursor.n_sketches),
            "retain_ingest": g.retain_ingest,
            "tenants": {},
        }
        arrays[f"{gid}/__key__"] = np.asarray(g.key)
        arrays[f"{gid}/__chunk_rows__"] = np.asarray(g.cursor.chunk_rows,
                                                     dtype=np.int64)
        if g.retained:
            arrays[f"{gid}/__retained__"] = np.concatenate(
                [np.asarray(c) for c in g.retained])
            arrays[f"{gid}/__retained_rows__"] = np.array(
                [c.shape[0] for c in g.retained], np.int64)
        for tid, t in g.tenants.items():
            tplan = plan_to_json(t.est.plan)
            ginfo["tenants"][tid] = {
                "kind": t.kind,
                "params": t.params,
                "plan": None if tplan == gplan else tplan,
            }
            if g.cursor.spec is not None:
                for name, v in t.est.state_arrays().items():
                    arrays[f"{gid}/{tid}/{name}"] = np.asarray(v)
        groups[gid] = ginfo
    checkpoint.save_arrays(path, step, arrays,
                           extra={"format": "sketchserve-v1", "groups": groups})


def restore_service(path: str, **service_kwargs):
    """Rebuild a :class:`SketchService` from the latest snapshot under
    ``path``. Returned NOT started — call ``start()`` (or use ``with``) before
    submitting; ``service_kwargs`` override queue/batch/admission settings."""
    from repro.sketchserve.service import SketchService

    arrays, extra = checkpoint.load_arrays(path)
    if extra.get("format") != "sketchserve-v1":
        raise ValueError(f"{path} is not a sketchserve snapshot "
                         f"(format={extra.get('format')!r})")
    svc = SketchService(**service_kwargs)
    for gid, ginfo in extra["groups"].items():
        gplan = plan_from_json(ginfo["plan"])
        key = jnp.asarray(arrays[f"{gid}/__key__"])
        for tid, tinfo in ginfo["tenants"].items():
            tplan = (plan_from_json(tinfo["plan"]) if tinfo["plan"] is not None
                     else gplan)
            resp = svc._create_tenant(tid, tinfo["kind"], tplan, key, gid,
                                      ginfo["retain_ingest"],
                                      dict(tinfo["params"]))
            if not resp.ok:
                raise RuntimeError(f"restore of tenant {tid!r}: {resp.error}")
        g = svc._groups[gid]
        if f"{gid}/__retained__" in arrays:
            flat = arrays[f"{gid}/__retained__"]
            i = 0
            for n in arrays[f"{gid}/__retained_rows__"].tolist():
                g.retained.append(flat[i:i + n])
                i += n
        if ginfo["p"] is not None:
            cur = g.cursor
            cur.ensure_spec(int(ginfo["p"]))   # spec re-derives; binds reducers
            cur.chunk = int(ginfo["chunk"])
            cur.count = int(ginfo["count"])
            cur.n_sketches = int(ginfo["n_sketches"])
            cur.chunk_rows = arrays[f"{gid}/__chunk_rows__"].tolist()
            for tid, t in g.tenants.items():
                prefix = f"{gid}/{tid}/"
                sub = {k[len(prefix):]: v for k, v in arrays.items()
                       if k.startswith(prefix)}
                t.est.load_state_arrays(sub)
    return svc
