"""SketchService — the online estimator-serving loop.

The sketching analogue of the LM engine in :mod:`repro.serve.engine`, built on
the same shared-queue idiom: callers ``submit()`` requests into one bounded
``queue.Queue`` and get back a ``concurrent.futures.Future``; a single worker
thread drains the queue in micro-batches. Where the LM engine coalesces
decode steps across sequences, this loop coalesces *ingest*: contiguous
same-group :class:`~repro.sketchserve.protocol.IngestRequest` rows drained in
one sweep are concatenated and folded through ONE
``SketchCursor.partial_fit`` call — one jitted sketch+fold step instead of
one per request. Coalescing changes chunk boundaries (hence which
(step, shard) mask key covers which rows) relative to one-request-per-fold,
which the estimator contract explicitly permits — every chunking is a valid
estimate; the batching is pure throughput.

Tenancy. A *tenant* is one estimator (mean / cov / pca / kmeans) with an id.
Tenants created with the same ``group=`` co-register on one shared
:class:`~repro.api.estimators.SketchCursor` — the :func:`repro.api.fit_many`
discipline — so an ingest addressed to the group compresses rows ONCE and
fans the sketch to every member (their plans must agree on the sketch
geometry fields and share a key, enforced by the same check ``fit_many``
runs). A tenant created without ``group=`` gets a private one-member group
under its own id. Per-tenant live state is sketch-sized — the reducer's
moment/lowrank state plus any retained sketch parts — never the (p, p)
accumulator on the lowrank path, which is what lets thousands of tenants
stay resident.

Admission control. Two bounds, both answered with a ``status="rejected"``
Response instead of unbounded buffering: the queue itself
(``max_queue`` requests; ``submit`` never blocks) and a per-group cap on
rows admitted but not yet folded (``max_pending_rows``). Rejected ingest is
the backpressure signal — the producer resubmits later.

Liveness. The worker thread never dies on a bad request: per-run fold
failures answer error responses, and anything that still escapes a sweep is
caught in the loop, failing the batch's unresolved futures instead of
hanging every caller. ``stop()`` resolves every already-submitted request,
then fails stragglers and all later submissions with an error response —
no Future ever dangles.

Lazy finalization. Ingest only folds; ``finalize()`` (eigendecompositions,
Lloyd iterations) runs when a query arrives for a tenant whose folded row
count moved since it last finalized. A tenant that is written often and read
rarely never pays finalize on the write path.

Because all ingest funnels through the one worker thread, the cursor sees a
single producer and the fold order is exactly queue order — results are
deterministic given the request sequence (see the thread-safety contract on
:class:`~repro.api.estimators.SketchCursor`).
"""
from __future__ import annotations

import queue
import re
import threading
import time
from concurrent.futures import Future

import jax
import numpy as np

from repro import obs
from repro.api.estimators import (SketchCursor, SparsifiedCov, SparsifiedKMeans,
                                  SparsifiedMean, SparsifiedPCA, as_key)
from repro.api.fused import _check_consumer
from repro.api.plan import Plan
from repro.sketchserve.protocol import (AdminRequest, IngestRequest,
                                        QueryRequest, Response)

ESTIMATORS = {
    "mean": SparsifiedMean,
    "cov": SparsifiedCov,
    "pca": SparsifiedPCA,
    "kmeans": SparsifiedKMeans,
}

_ID_RE = re.compile(r"^[A-Za-z0-9._-]+$")
_STOP = object()


def _ok(result=None, **info) -> Response:
    return Response("ok", result=result, info=info)


def _err(msg: str) -> Response:
    return Response("error", error=msg)


def _rejected(msg: str) -> Response:
    return Response("rejected", error=msg)


def _resolve(fut: Future, resp: Response) -> None:
    """Deliver a response unless the caller already cancelled the Future —
    set_result on a cancelled future raises, and nothing raised on the worker
    thread may kill the loop."""
    if fut.set_running_or_notify_cancel():
        fut.set_result(resp)


class _Ingest:
    """Internal queue record for an admitted ingest. The caller's
    :class:`IngestRequest` is never mutated: rows are coerced and the target
    is normalized to the group id here instead, so a retained request object
    can be logged or resubmitted unchanged."""

    __slots__ = ("gid", "rows")

    def __init__(self, gid: str, rows: np.ndarray):
        self.gid, self.rows = gid, rows


class _Tenant:
    __slots__ = ("tid", "kind", "params", "est", "group", "finalized_rows",
                 "finalize_count")

    def __init__(self, tid, kind, params, est, group):
        self.tid, self.kind, self.params = tid, kind, params
        self.est, self.group = est, group
        self.finalized_rows = -1     # cursor.count at last finalize (lazy)
        self.finalize_count = 0


class _Group:
    """One shared compression pass + the tenants riding it."""

    __slots__ = ("gid", "plan", "key", "cursor", "tenants", "pending_rows",
                 "retain_ingest", "retained")

    def __init__(self, gid: str, plan: Plan, key, retain_ingest: bool):
        self.gid = gid
        self.plan = plan
        self.key = as_key(key)
        self.cursor = SketchCursor(plan, self.key)
        self.tenants: dict[str, _Tenant] = {}
        self.pending_rows = 0        # admitted but not yet folded (admission cap)
        self.retain_ingest = bool(retain_ingest)
        self.retained: list[np.ndarray] = []  # fold-order chunks, for refine replay

    def fold(self, rows: np.ndarray, scan: str) -> None:
        """One sketch+fold step over a coalesced row block, optionally through
        the cursor's jitted lax.scan burst path when the block spans at least
        one full (batch_size × n_shards) step and every tenant folds in-scan."""
        cur = self.cursor
        use_scan = (scan == "auto"
                    and rows.shape[0] >= cur.plan.batch_size * cur.plan.n_shards
                    and cur.scan_descs() is not None)
        cur.scan = use_scan
        try:
            cur.partial_fit(rows)
        finally:
            cur.scan = False
        if self.retain_ingest:
            self.retained.append(np.asarray(rows))


def _state_nbytes(t: _Tenant) -> int:
    """Resident fold-state bytes of one tenant (reducer moment/lowrank state,
    retained sketch parts, K-means state) — what the serve bench asserts stays
    sketch-sized and row-count-independent, never (p, p)."""
    r = t.est._reducer
    trees = []
    if r is not None:
        trees.append(r.state)
        trees.append(list(r.parts))
    for attr in ("_km_state", "_km_centers"):
        trees.append(getattr(t.est, attr, None))
    return sum(int(leaf.nbytes) for leaf in jax.tree_util.tree_leaves(trees)
               if hasattr(leaf, "nbytes"))


class SketchService:
    """Async multi-tenant sketch server. See the module docstring for the
    model; the short version:

    >>> with SketchService() as svc:
    ...     svc.create_tenant("p", "pca", plan=plan, key=7, n_components=4,
    ...                       group="g")
    ...     svc.create_tenant("k", "kmeans", plan=plan, key=7, k=8, group="g")
    ...     svc.ingest("g", rows).result()          # one pass feeds both
    ...     parts = svc.query("p", "components").unwrap()

    ``submit`` is the non-blocking core (returns a Future); ``call`` /
    ``query`` / ``ingest`` / ``create_tenant`` / ... are sugar over it. All
    state mutation happens on the worker thread; admin helpers block until
    their request is processed so a subsequent ingest always sees the tenant.
    """

    #: legacy ``stats`` keys ↔ their registry counter names (``serve.<key>``)
    STAT_KEYS = ("requests", "ingest_requests", "ingest_folds", "ingest_rows",
                 "rejected", "queries", "finalizes")

    def __init__(self, *, max_queue: int = 1024, max_batch: int = 64,
                 max_pending_rows: int = 1_000_000, scan: str = "auto",
                 registry: "obs.MetricsRegistry | None" = None):
        if scan not in ("auto", "never"):
            raise ValueError(f"scan must be 'auto' or 'never', got {scan!r}")
        self.max_batch = int(max_batch)
        self.max_pending_rows = int(max_pending_rows)
        self.scan = scan
        self._queue: queue.Queue = queue.Queue(maxsize=int(max_queue))
        self._groups: dict[str, _Group] = {}
        self._tenants: dict[str, _Tenant] = {}
        # Guards tenant/group-registry reads, admission accounting, the
        # stopped flag, and the metric updates submit threads make; the
        # worker-thread metrics are single-writer (each counter is itself
        # atomic, so readers never see torn values either way).
        self._reg_lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._stopped = False
        self._snap_step = 0
        # All service observability lives in one MetricsRegistry (pass a
        # shared one to aggregate several services / the engine into a single
        # exposition endpoint).
        self.registry = registry if registry is not None else obs.MetricsRegistry()
        self._c = {k: self.registry.counter(f"serve.{k}") for k in self.STAT_KEYS}
        self._g_queue_depth = self.registry.gauge("serve.queue_depth")
        self._g_pending = self.registry.gauge("serve.pending_rows")
        self._h_coalesce = self.registry.histogram("serve.coalesced_requests")
        self._h_latency = self.registry.histogram("serve.request_seconds")

    @property
    def stats(self) -> dict:
        """Legacy counter view, snapshotted under ``_reg_lock`` so a reader
        can never observe counts torn against a concurrent submit (the old
        bare-dict copy could). The keys are :attr:`STAT_KEYS`; richer series
        (queue depth, latency quantiles, per-group folds) live on
        :attr:`registry`."""
        with self._reg_lock:
            return {k: self._c[k].value for k in self.STAT_KEYS}

    # ------------------------------------------------------------ lifecycle --

    def start(self) -> "SketchService":
        if self._stopped:
            raise RuntimeError("service already stopped")
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="sketchserve-worker")
        self._thread.start()
        return self

    def stop(self) -> None:
        """Resolve every already-submitted request, then stop the worker.
        Requests racing with (or arriving after) stop() resolve to an error
        response instead of hanging on a dead queue; a stopped service cannot
        be restarted."""
        with self._reg_lock:
            self._stopped = True
            thread, self._thread = self._thread, None
        if thread is not None:
            self._queue.put((_STOP, None))
            thread.join()
        # Safety net: anything still queued (enqueued before _stopped was
        # observable, or never drained because the service was not started)
        # must not leave its Future unresolved forever.
        self._fail_queued("service stopped")

    def __enter__(self) -> "SketchService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --------------------------------------------------------------- submit --

    def submit(self, req) -> Future:
        """Enqueue one request; never blocks and never mutates ``req``. The
        Future resolves to a :class:`Response` — ``status="rejected"`` when
        admission control (full queue / per-group pending-row cap) turns it
        away, ``status="error"`` once the service has stopped."""
        fut: Future = Future()
        if isinstance(req, IngestRequest):
            rows = np.asarray(req.rows)
            if rows.ndim != 2:
                fut.set_result(_err(f"ingest rows must be (b, p), got shape "
                                    f"{rows.shape}"))
                return fut
            n = int(rows.shape[0])
            with self._reg_lock:
                if self._stopped:
                    fut.set_result(_err("service stopped"))
                    return fut
                group = self._resolve_group(req.target)
                if group is None:
                    fut.set_result(_err(f"unknown tenant/group {req.target!r}"))
                    return fut
                spec = group.cursor.spec
                if spec is not None and rows.shape[1] != spec.p:
                    fut.set_result(_err(
                        f"group {group.gid!r} ingests p={spec.p} columns, "
                        f"got {rows.shape[1]}"))
                    return fut
                if group.pending_rows + n > self.max_pending_rows:
                    self._c["rejected"].inc()
                    fut.set_result(_rejected(
                        f"group {group.gid!r} has {group.pending_rows} rows "
                        f"pending (cap {self.max_pending_rows}); retry after "
                        "the backlog folds"))
                    return fut
                group.pending_rows += n
                fut._obs_t0 = time.perf_counter()   # submit→resolve latency
                try:
                    # target normalized to the gid on the internal record (not
                    # on req): maximal worker coalescing
                    self._queue.put_nowait((_Ingest(group.gid, rows), fut))
                    self._g_pending.inc(n)
                    self._g_queue_depth.set(self._queue.qsize())
                except queue.Full:
                    group.pending_rows -= n
                    self._c["rejected"].inc()
                    fut.set_result(_rejected(
                        f"request queue full ({self._queue.maxsize}); "
                        "retry later"))
            return fut
        if isinstance(req, AdminRequest):
            with self._reg_lock:
                stopped, setup = self._stopped, self._thread is None
            if stopped:
                fut.set_result(_err("service stopped"))
                return fut
            if setup:   # setup phase: no worker to serialize on
                fut.set_result(self._handle_admin(req))
                return fut
        elif not isinstance(req, QueryRequest):
            fut.set_result(_err(f"unknown request type {type(req).__name__}"))
            return fut
        with self._reg_lock:
            if self._stopped:
                fut.set_result(_err("service stopped"))
                return fut
            fut._obs_t0 = time.perf_counter()   # submit→resolve latency
            try:
                self._queue.put_nowait((req, fut))
                self._g_queue_depth.set(self._queue.qsize())
            except queue.Full:
                self._c["rejected"].inc()
                fut.set_result(_rejected(
                    f"request queue full ({self._queue.maxsize}); retry later"))
        return fut

    def call(self, req, timeout: float | None = 60.0) -> Response:
        """submit + wait."""
        return self.submit(req).result(timeout)

    # sugar ------------------------------------------------------------------

    def ingest(self, target: str, rows) -> Future:
        return self.submit(IngestRequest(target, rows))

    def query(self, tenant: str, op: str, x=None,
              timeout: float | None = 60.0) -> Response:
        return self.call(QueryRequest(tenant, op, x), timeout)

    def create_tenant(self, tid: str, kind: str, *, plan: Plan | None = None,
                      key=0, group: str | None = None,
                      retain_ingest: bool = False, **params) -> Response:
        resp = self.call(AdminRequest("create_tenant", dict(
            tid=tid, kind=kind, plan=plan, key=key, group=group,
            retain_ingest=retain_ingest, params=params)))
        resp.unwrap()   # raise on error — creation must not fail silently
        return resp

    def delete_tenant(self, tid: str) -> None:
        self.call(AdminRequest("delete_tenant", dict(tid=tid))).unwrap()

    def snapshot(self, path: str) -> int:
        """Checkpoint every live group/tenant (atomic-rename protocol of
        :mod:`repro.train.checkpoint`); returns the snapshot step."""
        return self.call(AdminRequest("snapshot", dict(path=path)),
                         timeout=None).unwrap()

    def refine(self, tenant: str, x=None, passes: int | None = None, *,
               tol: float | None = None, max_passes: int = 16) -> Response:
        """Second-pass replay refinement on one tenant, in the worker loop (so
        it serializes against ingest). ``x=None`` replays the group's retained
        ingest — requires ``retain_ingest=True`` at tenant creation."""
        return self.call(AdminRequest("refine", dict(
            tenant=tenant, x=x, passes=passes, tol=tol,
            max_passes=max_passes)), timeout=None)

    def tenants(self) -> list[str]:
        with self._reg_lock:
            return sorted(self._tenants)

    # ---------------------------------------------------------- worker loop --

    def _resolve_fut(self, fut: Future, resp: Response) -> None:
        """_resolve plus submit→resolve latency accounting (the ``_obs_t0``
        stamp placed at admission)."""
        t0 = getattr(fut, "_obs_t0", None)
        if t0 is not None:
            self._h_latency.observe(time.perf_counter() - t0)
        _resolve(fut, resp)

    def _loop(self) -> None:
        stop = False
        while not stop:
            items = [self._queue.get()]
            while len(items) < self.max_batch:
                try:
                    items.append(self._queue.get_nowait())
                except queue.Empty:
                    break
            self._g_queue_depth.set(self._queue.qsize())
            batch = []
            for req, fut in items:
                if req is _STOP:
                    stop = True       # drain this batch, fail later arrivals
                elif stop:
                    self._resolve_fut(fut, _err("service stopped"))
                else:
                    batch.append((req, fut))
            if batch:
                try:
                    self._process(batch)
                except Exception as e:  # noqa: BLE001 — the worker must live
                    self._fail_batch(batch, e)
            for _ in items:
                self._queue.task_done()

    def _fail_batch(self, batch, exc: Exception) -> None:
        """Last-resort guard around one _process sweep: resolve whatever the
        crashed sweep left unresolved (releasing its ingest reservations) so
        one bad batch can never hang every in-flight and future caller."""
        for req, fut in batch:
            if fut.done():
                continue
            if isinstance(req, _Ingest):
                # an unresolved ingest never reached _flush_ingest's
                # accounting, so its reservation is still held
                with self._reg_lock:
                    g = self._groups.get(req.gid)
                    if g is not None:
                        g.pending_rows -= int(req.rows.shape[0])
                self._g_pending.inc(-int(req.rows.shape[0]))
            self._resolve_fut(fut, _err(f"internal service error: {exc!r}"))

    def _fail_queued(self, msg: str) -> None:
        """Fail everything still sitting in the (dead) queue — stop() path."""
        while True:
            try:
                req, fut = self._queue.get_nowait()
            except queue.Empty:
                return
            if isinstance(req, _Ingest):
                with self._reg_lock:
                    g = self._groups.get(req.gid)
                    if g is not None:
                        g.pending_rows -= int(req.rows.shape[0])
                self._g_pending.inc(-int(req.rows.shape[0]))
            if fut is not None and not fut.done():
                self._resolve_fut(fut, _err(msg))
            self._queue.task_done()

    def _process(self, batch) -> None:
        """Serve one drained micro-batch in queue order, coalescing each
        contiguous run of same-group ingests into one fold. (Exposed for
        tests: drives the same path the worker thread runs.)"""
        pending: dict[str, list] = {}
        for req, fut in batch:
            if isinstance(req, _Ingest):
                pending.setdefault(req.gid, []).append((req, fut))
                continue
            self._flush_ingest(pending)   # queries/admin see all prior ingest
            pending = {}
            self._c["requests"].inc()
            if isinstance(req, QueryRequest):
                self._resolve_fut(fut, self._handle_query(req))
            else:
                self._resolve_fut(fut, self._handle_admin(req))
        self._flush_ingest(pending)

    def _flush_ingest(self, pending: dict[str, list]) -> None:
        for gid, items in pending.items():
            self._c["requests"].inc(len(items))
            self._c["ingest_requests"].inc(len(items))
            blocks = [req.rows for req, _ in items]
            n = sum(int(b.shape[0]) for b in blocks)
            with self._reg_lock:
                group = self._groups.get(gid)
            if group is None:   # deleted between submit and drain
                self._g_pending.inc(-n)
                for _, fut in items:
                    self._resolve_fut(fut, _err(f"unknown tenant/group {gid!r}"))
                continue
            try:
                # concatenate inside the try: column counts mismatched across
                # a coalesced run must answer error responses, not raise
                rows = blocks[0] if len(blocks) == 1 else np.concatenate(blocks)
                group.fold(rows, self.scan)
                self._c["ingest_folds"].inc()
                self._c["ingest_rows"].inc(n)
                self._h_coalesce.observe(len(items))
                for tid in group.tenants:
                    self.registry.counter("serve.tenant_folds",
                                          tenant=tid).inc()
                resp = [_ok(int(b.shape[0]), group=group.gid,
                            coalesced=len(items), count=group.cursor.count)
                        for b in blocks]
            except Exception as e:  # a bad block poisons its whole coalesced run
                resp = [_err(f"ingest failed: {e}")] * len(items)
            finally:
                with self._reg_lock:
                    group.pending_rows -= n
                self._g_pending.inc(-n)
            for (_, fut), r in zip(items, resp):
                self._resolve_fut(fut, r)

    # -------------------------------------------------------------- queries --

    def _handle_query(self, req: QueryRequest) -> Response:
        self._c["queries"].inc()
        t = self._tenants.get(req.tenant)
        if t is None:
            return _err(f"unknown tenant {req.tenant!r}")
        cur = t.group.cursor
        if req.op == "stats":
            return _ok({"kind": t.kind, "group": t.group.gid,
                        "rows": cur.count, "chunks": cur.chunk,
                        "n_sketches": cur.n_sketches,
                        "pending_rows": t.group.pending_rows,
                        "finalized_rows": t.finalized_rows,
                        "finalize_count": t.finalize_count,
                        "state_bytes": _state_nbytes(t)})
        if cur.count == 0:
            return _err(f"tenant {req.tenant!r} has no ingested rows yet")
        if t.finalized_rows != cur.count:   # lazy: only when state moved
            try:
                t.est.finalize()
            except Exception as e:
                return _err(f"finalize failed: {e}")
            t.finalized_rows = cur.count
            t.finalize_count += 1
            self._c["finalizes"].inc()
        try:
            return self._read_fitted(t, req.op, req.x)
        except AttributeError:
            return _err(f"op {req.op!r} does not apply to a {t.kind!r} tenant")
        except Exception as e:
            return _err(f"query {req.op!r} failed: {e}")

    def _read_fitted(self, t: _Tenant, op: str, x) -> Response:
        est = t.est
        if op == "mean":
            return _ok(np.asarray(est.mean_))
        if op == "cov":
            return _ok(np.asarray(est.cov_))
        if op == "components":
            return _ok({"components": np.asarray(est.components_),
                        "explained_variance": np.asarray(est.explained_variance_)})
        if op == "centers":
            return _ok(np.asarray(est.centers_))
        if op == "transform":
            if x is None:
                return _err("transform needs an x payload")
            return _ok(np.asarray(est.transform(np.asarray(x))))
        if op == "predict":
            if x is None:
                return _err("predict needs an x payload")
            return _ok(np.asarray(est.predict(np.asarray(x))))
        return _err(f"unknown query op {op!r} (transform|predict|components|"
                    "centers|mean|cov|stats)")

    # ---------------------------------------------------------------- admin --

    def _handle_admin(self, req: AdminRequest) -> Response:
        p = req.params
        try:
            if req.op == "create_tenant":
                return self._create_tenant(**p)
            if req.op == "delete_tenant":
                return self._delete_tenant(p["tid"])
            if req.op == "snapshot":
                from repro.sketchserve import snapshot as snap_mod
                self._snap_step += 1
                snap_mod.save_service(self, p["path"], step=self._snap_step)
                return _ok(self._snap_step)
            if req.op == "refine":
                return self._refine(**p)
            return _err(f"unknown admin op {req.op!r}")
        except Exception as e:
            return _err(f"admin {req.op!r} failed: {e}")

    def _create_tenant(self, tid, kind, plan, key, group, retain_ingest,
                       params) -> Response:
        if not _ID_RE.match(tid or ""):
            return _err(f"tenant id {tid!r} must match {_ID_RE.pattern}")
        if tid in self._tenants or tid in self._groups:
            return _err(f"id {tid!r} already exists")
        if kind not in ESTIMATORS:
            return _err(f"unknown kind {kind!r} (one of {sorted(ESTIMATORS)})")
        gid = group if group is not None else tid
        if not _ID_RE.match(gid):
            return _err(f"group id {gid!r} must match {_ID_RE.pattern}")
        if gid in self._tenants and gid not in self._groups:
            return _err(f"group id {gid!r} collides with a tenant id")
        g = self._groups.get(gid)
        if g is None:
            if plan is None:
                return _err(f"first tenant of group {gid!r} must carry a plan")
            g = _Group(gid, plan, key, retain_ingest)
        est = ESTIMATORS[kind](plan=plan or g.plan, key=key, **params)
        # the fit_many co-registration check: shared sketch ⇒ shared geometry+key
        _check_consumer(g.plan, est, len(g.tenants), g.key)
        if g.cursor.count > 0:
            return _err(f"group {gid!r} already ingested {g.cursor.count} rows;"
                        " tenants must co-register before ingest starts (a late"
                        " joiner would silently miss them)")
        est._cursor = g.cursor
        g.cursor.register(est)
        t = _Tenant(tid, kind, dict(params), est, g)
        with self._reg_lock:
            g.tenants[tid] = t
            self._groups[gid] = g
            self._tenants[tid] = t
        return _ok(tid, group=gid)

    def _delete_tenant(self, tid) -> Response:
        t = self._tenants.get(tid)
        if t is None:
            return _err(f"unknown tenant {tid!r}")
        g = t.group
        with self._reg_lock:
            del self._tenants[tid]
            del g.tenants[tid]
            if t.est in g.cursor.consumers:
                g.cursor.consumers.remove(t.est)
            if not g.tenants:
                del self._groups[g.gid]
        return _ok(tid, group_deleted=not g.tenants)

    def _refine(self, tenant, x, passes, tol, max_passes) -> Response:
        t = self._tenants.get(tenant)
        if t is None:
            return _err(f"unknown tenant {tenant!r}")
        g = t.group
        if x is None:
            if not g.retain_ingest:
                return _err(f"group {g.gid!r} was created with "
                            "retain_ingest=False and no x payload was given — "
                            "nothing to replay")
            if not g.retained:
                return _err("no ingested rows to replay yet")
            x = np.concatenate(g.retained)
        if t.finalized_rows != g.cursor.count:
            t.est.finalize()
            t.finalized_rows = g.cursor.count
            t.finalize_count += 1
        t.est.refine(np.asarray(x), passes, tol=tol, max_passes=max_passes)
        return _ok({"passes": int(getattr(t.est, "refine_passes_", 0)),
                    "converged": bool(getattr(t.est, "refine_converged_", False))})

    # -------------------------------------------------------------- helpers --

    def _resolve_group(self, target: str) -> _Group | None:
        """Tenant id or group id → group (caller holds _reg_lock)."""
        t = self._tenants.get(target)
        if t is not None:
            return t.group
        return self._groups.get(target)
