"""SketchService — the online estimator-serving loop.

The sketching analogue of the LM engine in :mod:`repro.serve.engine`, built on
the same shared-queue idiom: callers ``submit()`` requests into a bounded
``queue.Queue`` and get back a ``concurrent.futures.Future``; worker threads
drain the queues in micro-batches. Where the LM engine coalesces decode steps
across sequences, this loop coalesces *ingest*: contiguous same-group
:class:`~repro.sketchserve.protocol.IngestRequest` rows drained in one sweep
are concatenated and folded through ONE ``SketchCursor.partial_fit`` call —
one jitted sketch+fold step instead of one per request. Coalescing changes
chunk boundaries (hence which (step, shard) mask key covers which rows)
relative to one-request-per-fold, which the estimator contract explicitly
permits — every chunking is a valid estimate; the batching is pure
throughput.

Tenancy. A *tenant* is one estimator (mean / cov / pca / kmeans) with an id.
Tenants created with the same ``group=`` co-register on one shared
:class:`~repro.api.estimators.SketchCursor` — the :func:`repro.api.fit_many`
discipline — so an ingest addressed to the group compresses rows ONCE and
fans the sketch to every member (their plans must agree on the sketch
geometry fields and share a key, enforced by the same check ``fit_many``
runs). A tenant created without ``group=`` gets a private one-member group
under its own id. Per-tenant live state is sketch-sized — the reducer's
moment/lowrank state plus any retained sketch parts — never the (p, p)
accumulator on the lowrank path, which is what lets thousands of tenants
stay resident.

Workers and ordering. ``workers=N`` runs N worker loops over DISJOINT group
partitions: a group hashes to exactly one worker (stable crc32, so the
assignment survives restarts), every request for that group — ingest,
queries against its tenants, its admin ops — lands in that worker's queue,
and the queue is FIFO. Per group there is therefore still exactly ONE
producer into the cursor and the fold order is exactly submission order, so
per-group results are bit-identical to the single-worker service on the same
request sequence (whenever chunk boundaries agree, e.g. batch_size-multiple
requests; the per-cursor lock contract in
:class:`~repro.api.estimators.SketchCursor` is what permits the pool).
Cross-group interleaving is whatever the partition yields — groups are
independent streams, so that was never observable anyway.

Admission control. Two bounds, both answered with a ``status="rejected"``
Response instead of unbounded buffering: each worker queue (``max_queue``
requests per worker; ``submit`` never blocks) and a per-group cap on rows
admitted but not yet folded (``max_pending_rows``). Rejected ingest is the
backpressure signal — the producer resubmits later (the HTTP frontend in
:mod:`repro.sketchserve.http` surfaces it as a 429).

Supervision. A :class:`SnapshotPolicy` plus ``snapshot_dir=`` auto-snapshots
the whole service on worker 0 at fold boundaries (every N folded rows and/or
every T seconds, skipped while no new rows folded). Multi-worker snapshots
quiesce the pool first — every worker parks between folds — so the written
state is a global fold boundary; ``launch/sketch_serve.py --supervise``
closes the loop by restarting a crashed process from the latest snapshot and
replaying the continuation bit-identically.

Tenant eviction. ``ttl_s=`` / ``max_tenants=`` bound the registry in
long-lived deployments: a group idle past its TTL (or the least-recently
used groups while over the tenant bound) is *evicted to snapshot* — its
cursor+tenant state is written under ``evict_dir`` before removal — and
lazily restored on the next ingest/query/admin that touches it, resuming
bit-identically (same snapshot format as ``snapshot()``). Groups with queued
ingest are never evicted; eviction runs on each group's owner worker, so it
can never race a fold.

Liveness. A worker thread never dies on a bad request: per-run fold failures
answer error responses, and anything that still escapes a sweep is caught in
the loop, failing the batch's unresolved futures instead of hanging every
caller. ``stop()`` resolves every already-submitted request, then fails
stragglers and all later submissions with an error response — no Future
ever dangles, across every worker.

Lazy finalization. Ingest only folds; ``finalize()`` (eigendecompositions,
Lloyd iterations) runs when a query arrives for a tenant whose folded row
count moved since it last finalized. A tenant that is written often and read
rarely never pays finalize on the write path.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import re
import tempfile
import threading
import time
import zlib
from concurrent.futures import Future

import jax
import numpy as np

from repro import obs
from repro.api.estimators import (SketchCursor, SparsifiedCov, SparsifiedKMeans,
                                  SparsifiedMean, SparsifiedPCA, as_key)
from repro.api.fused import _check_consumer
from repro.api.plan import Plan
from repro.sketchserve.protocol import (AdminRequest, IngestRequest,
                                        QueryRequest, Response)

ESTIMATORS = {
    "mean": SparsifiedMean,
    "cov": SparsifiedCov,
    "pca": SparsifiedPCA,
    "kmeans": SparsifiedKMeans,
}

_ID_RE = re.compile(r"^[A-Za-z0-9._-]+$")
_STOP = object()
#: idle poll period of a worker's queue.get — bounds how late a parked-worker
#: snapshot quiesce, an every_s auto-snapshot, or a TTL sweep can fire.
_IDLE_TICK = 0.1
#: how long a snapshot waits for the other workers to reach a fold boundary.
_QUIESCE_TIMEOUT = 120.0


@dataclasses.dataclass(frozen=True)
class SnapshotPolicy:
    """Auto-snapshot cadence for a long-lived service.

    ``every_rows``: snapshot once that many NEW rows have folded since the
    last snapshot. ``every_s``: snapshot at most that often — and only when
    new rows folded since the last one, so an idle service never rewrites
    identical checkpoints. Both may be set; either firing triggers. Checks
    run on worker 0 at fold boundaries (after each drained batch and on idle
    ticks), so a snapshot never lands mid-fold.
    """

    every_rows: int | None = None
    every_s: float | None = None

    def __post_init__(self):
        if self.every_rows is None and self.every_s is None:
            raise ValueError("SnapshotPolicy needs every_rows and/or every_s")
        if self.every_rows is not None and self.every_rows <= 0:
            raise ValueError(f"every_rows must be > 0, got {self.every_rows}")
        if self.every_s is not None and self.every_s <= 0:
            raise ValueError(f"every_s must be > 0, got {self.every_s}")


def _ok(result=None, **info) -> Response:
    return Response("ok", result=result, info=info)


def _err(msg: str) -> Response:
    return Response("error", error=msg)


def _rejected(msg: str) -> Response:
    return Response("rejected", error=msg)


def _resolve(fut: Future, resp: Response) -> None:
    """Deliver a response unless the caller already cancelled the Future —
    set_result on a cancelled future raises, and nothing raised on the worker
    thread may kill the loop."""
    if fut.set_running_or_notify_cancel():
        fut.set_result(resp)


class _Quiesce:
    """Worker-0's stop-the-world for cross-worker snapshots.

    The initiator raises ``want``; every OTHER live worker parks at its next
    fold boundary (between drained batches, or on an idle tick); the
    ``held()`` block then runs with no fold in flight anywhere; releasing
    wakes the parked workers. Workers that exit (``stop()``) decrement
    ``live``, so a shutdown racing a snapshot can never strand the initiator.
    """

    def __init__(self, n: int):
        self._cv = threading.Condition()
        self._live = n
        self._want = False
        self._parked = 0
        self._gen = 0

    def worker_exit(self) -> None:
        with self._cv:
            self._live -= 1
            self._cv.notify_all()

    def park_if_wanted(self, timeout: float = _QUIESCE_TIMEOUT) -> None:
        with self._cv:
            if not self._want:
                return
            gen = self._gen
            self._parked += 1
            self._cv.notify_all()
            self._cv.wait_for(lambda: not self._want or self._gen != gen,
                              timeout)
            self._parked -= 1
            self._cv.notify_all()

    def held(self, timeout: float = _QUIESCE_TIMEOUT):
        q = self

        class _Held:
            def __enter__(self):
                with q._cv:
                    q._want = True
                    ok = q._cv.wait_for(lambda: q._parked >= q._live - 1,
                                        timeout)
                if not ok:
                    self.__exit__(None, None, None)
                    raise RuntimeError(
                        "snapshot quiesce timed out waiting for workers to "
                        "reach a fold boundary")
                return self

            def __exit__(self, *exc):
                with q._cv:
                    q._want = False
                    q._gen += 1
                    q._cv.notify_all()

        return _Held()


class _Ingest:
    """Internal queue record for an admitted ingest. The caller's
    :class:`IngestRequest` is never mutated: rows are coerced and the target
    is normalized to the group id here instead, so a retained request object
    can be logged or resubmitted unchanged."""

    __slots__ = ("gid", "rows")

    def __init__(self, gid: str, rows: np.ndarray):
        self.gid, self.rows = gid, rows


class _Tenant:
    __slots__ = ("tid", "kind", "params", "est", "group", "finalized_rows",
                 "finalize_count")

    def __init__(self, tid, kind, params, est, group):
        self.tid, self.kind, self.params = tid, kind, params
        self.est, self.group = est, group
        self.finalized_rows = -1     # cursor.count at last finalize (lazy)
        self.finalize_count = 0


class _Group:
    """One shared compression pass + the tenants riding it."""

    __slots__ = ("gid", "plan", "key", "cursor", "tenants", "pending_rows",
                 "retain_ingest", "retained", "last_access")

    def __init__(self, gid: str, plan: Plan, key, retain_ingest: bool):
        self.gid = gid
        self.plan = plan
        self.key = as_key(key)
        self.cursor = SketchCursor(plan, self.key)
        self.tenants: dict[str, _Tenant] = {}
        self.pending_rows = 0        # admitted but not yet folded (admission cap)
        self.retain_ingest = bool(retain_ingest)
        self.retained: list[np.ndarray] = []  # fold-order chunks, for refine replay
        self.last_access = time.monotonic()   # TTL / LRU eviction stamp

    def fold(self, rows: np.ndarray, scan: str) -> None:
        """One sketch+fold step over a coalesced row block, optionally through
        the cursor's jitted lax.scan burst path when the block spans at least
        one full (batch_size × n_shards) step and every tenant folds in-scan."""
        cur = self.cursor
        use_scan = (scan == "auto"
                    and rows.shape[0] >= cur.plan.batch_size * cur.plan.n_shards
                    and cur.scan_descs() is not None)
        cur.scan = use_scan
        try:
            cur.partial_fit(rows)
        finally:
            cur.scan = False
        if self.retain_ingest:
            self.retained.append(np.asarray(rows))


def _state_nbytes(t: _Tenant) -> int:
    """Resident fold-state bytes of one tenant (reducer moment/lowrank state,
    retained sketch parts, K-means state) — what the serve bench asserts stays
    sketch-sized and row-count-independent, never (p, p)."""
    r = t.est._reducer
    trees = []
    if r is not None:
        trees.append(r.state)
        trees.append(list(r.parts))
    for attr in ("_km_state", "_km_centers"):
        trees.append(getattr(t.est, attr, None))
    return sum(int(leaf.nbytes) for leaf in jax.tree_util.tree_leaves(trees)
               if hasattr(leaf, "nbytes"))


class SketchService:
    """Async multi-tenant sketch server. See the module docstring for the
    model; the short version:

    >>> with SketchService(workers=4) as svc:
    ...     svc.create_tenant("p", "pca", plan=plan, key=7, n_components=4,
    ...                       group="g")
    ...     svc.create_tenant("k", "kmeans", plan=plan, key=7, k=8, group="g")
    ...     svc.ingest("g", rows).result()          # one pass feeds both
    ...     parts = svc.query("p", "components").unwrap()

    ``submit`` is the non-blocking core (returns a Future); ``call`` /
    ``query`` / ``ingest`` / ``create_tenant`` / ... are sugar over it. All
    state mutation happens on the owning worker thread; admin helpers block
    until their request is processed so a subsequent ingest always sees the
    tenant.
    """

    #: legacy ``stats`` keys ↔ their registry counter names (``serve.<key>``)
    STAT_KEYS = ("requests", "ingest_requests", "ingest_folds", "ingest_rows",
                 "rejected", "queries", "finalizes", "snapshots", "evictions",
                 "evict_restores")

    def __init__(self, *, max_queue: int = 1024, max_batch: int = 64,
                 max_pending_rows: int = 1_000_000, scan: str = "auto",
                 registry: "obs.MetricsRegistry | None" = None,
                 workers: int = 1,
                 snapshot_policy: SnapshotPolicy | None = None,
                 snapshot_dir: str | None = None,
                 max_tenants: int | None = None, ttl_s: float | None = None,
                 evict_dir: str | None = None):
        if scan not in ("auto", "never"):
            raise ValueError(f"scan must be 'auto' or 'never', got {scan!r}")
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if snapshot_policy is not None and snapshot_dir is None:
            raise ValueError("snapshot_policy needs snapshot_dir= to write to")
        if max_tenants is not None and max_tenants < 1:
            raise ValueError(f"max_tenants must be >= 1, got {max_tenants}")
        if ttl_s is not None and ttl_s <= 0:
            raise ValueError(f"ttl_s must be > 0, got {ttl_s}")
        self.max_batch = int(max_batch)
        self.max_pending_rows = int(max_pending_rows)
        self.scan = scan
        self.n_workers = int(workers)
        self._queues: list[queue.Queue] = [
            queue.Queue(maxsize=int(max_queue)) for _ in range(self.n_workers)]
        self._groups: dict[str, _Group] = {}
        self._tenants: dict[str, _Tenant] = {}
        # Guards tenant/group-registry reads, admission accounting, the
        # stopped flag, and the metric updates submit threads make; the
        # worker-thread metrics are single-writer per series (each counter is
        # itself atomic, so readers never see torn values either way).
        self._reg_lock = threading.Lock()
        # Serializes eviction/restore transitions against each other AND
        # against snapshot's registry copy. Lock order: _evict_lock before
        # _reg_lock, everywhere.
        self._evict_lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._stopped = False
        self._quiesce = _Quiesce(self.n_workers)
        # snapshot supervision
        self.snapshot_policy = snapshot_policy
        self.snapshot_dir = snapshot_dir
        self._snap_step = 0
        self._folded_rows = 0            # under _reg_lock; feeds every_rows
        self._last_snap_rows = 0
        self._last_snap_t = time.monotonic()
        # tenant TTL / LRU eviction
        self.max_tenants = max_tenants
        self.ttl_s = ttl_s
        self.evict_dir = evict_dir
        self._evicted: dict[str, dict] = {}          # gid -> {path, tenants}
        self._evicted_tenants: dict[str, str] = {}   # tid -> gid
        self._evict_steps: dict[str, int] = {}
        self._sweep_every = min(1.0, ttl_s / 4) if ttl_s else 1.0
        self._last_sweep = [0.0] * self.n_workers
        # All service observability lives in one MetricsRegistry (pass a
        # shared one to aggregate several services / the engine into a single
        # exposition endpoint).
        self.registry = registry if registry is not None else obs.MetricsRegistry()
        self._c = {k: self.registry.counter(f"serve.{k}") for k in self.STAT_KEYS}
        self._g_queue_depth = self.registry.gauge("serve.queue_depth")
        self._g_wq = [self.registry.gauge("serve.worker_queue_depth",
                                          worker=str(i))
                      for i in range(self.n_workers)]
        self._g_pending = self.registry.gauge("serve.pending_rows")
        self._h_coalesce = self.registry.histogram("serve.coalesced_requests")
        self._h_latency = self.registry.histogram("serve.request_seconds")
        self._h_snapshot = self.registry.histogram("serve.snapshot_seconds")

    @property
    def stats(self) -> dict:
        """Legacy counter view, snapshotted under ``_reg_lock`` so a reader
        can never observe counts torn against a concurrent submit (the old
        bare-dict copy could). The keys are :attr:`STAT_KEYS`; richer series
        (queue depth, latency quantiles, per-group folds) live on
        :attr:`registry`."""
        with self._reg_lock:
            return {k: self._c[k].value for k in self.STAT_KEYS}

    # back-compat views of the single-worker attributes (tests, tooling)
    @property
    def _queue(self) -> queue.Queue:
        return self._queues[0]

    @property
    def _thread(self) -> threading.Thread | None:
        return self._threads[0] if self._threads else None

    def _worker_of(self, gid: str) -> int:
        """Stable group → worker partition (crc32, survives restarts)."""
        return zlib.crc32(gid.encode()) % self.n_workers

    # ------------------------------------------------------------ lifecycle --

    def start(self) -> "SketchService":
        if self._stopped:
            raise RuntimeError("service already stopped")
        if self._threads:
            raise RuntimeError("service already started")
        self._threads = [
            threading.Thread(target=self._loop, args=(i,), daemon=True,
                             name=f"sketchserve-worker-{i}")
            for i in range(self.n_workers)]
        for t in self._threads:
            t.start()
        return self

    def stop(self) -> None:
        """Resolve every already-submitted request, then stop the workers.
        Requests racing with (or arriving after) stop() resolve to an error
        response instead of hanging on a dead queue; a stopped service cannot
        be restarted."""
        with self._reg_lock:
            self._stopped = True
            threads, self._threads = self._threads, []
        if threads:
            for q in self._queues:
                q.put((_STOP, None))
            for t in threads:
                t.join()
        # Safety net: anything still queued (enqueued before _stopped was
        # observable, or never drained because the service was not started)
        # must not leave its Future unresolved forever.
        self._fail_queued("service stopped")

    def __enter__(self) -> "SketchService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --------------------------------------------------------------- submit --

    def submit(self, req) -> Future:
        """Enqueue one request; never blocks and never mutates ``req``. The
        Future resolves to a :class:`Response` — ``status="rejected"`` when
        admission control (full queue / per-group pending-row cap) turns it
        away, ``status="error"`` once the service has stopped. Every
        resolution — accepted, rejected, or failed at submit — lands in the
        ``serve.request_seconds`` histogram."""
        fut: Future = Future()
        fut._obs_t0 = time.perf_counter()   # submit→resolve latency, ALL paths
        if isinstance(req, IngestRequest):
            return self._submit_ingest(req, fut)
        if isinstance(req, AdminRequest):
            with self._reg_lock:
                stopped, setup = self._stopped, not self._threads
            if stopped:
                self._resolve_fut(fut, _err("service stopped"))
                return fut
            if setup:   # setup phase: no worker to serialize on
                self._resolve_fut(fut, self._handle_admin(req))
                return fut
            wid = self._route_admin(req)
        elif isinstance(req, QueryRequest):
            wid = self._route_target(req.tenant)
        else:
            self._resolve_fut(fut, _err(f"unknown request type "
                                        f"{type(req).__name__}"))
            return fut
        with self._reg_lock:
            if self._stopped:
                self._resolve_fut(fut, _err("service stopped"))
                return fut
            try:
                self._queues[wid].put_nowait((req, fut))
                self._note_queue_depth(wid)
            except queue.Full:
                self._c["rejected"].inc()
                self._resolve_fut(fut, _rejected(
                    f"request queue full ({self._queues[wid].maxsize}); "
                    "retry later"))
        return fut

    def _submit_ingest(self, req: IngestRequest, fut: Future) -> Future:
        rows = np.asarray(req.rows)
        if rows.ndim != 2:
            self._resolve_fut(fut, _err(f"ingest rows must be (b, p), got "
                                        f"shape {rows.shape}"))
            return fut
        n = int(rows.shape[0])
        for attempt in (0, 1):
            with self._reg_lock:
                if self._stopped:
                    self._resolve_fut(fut, _err("service stopped"))
                    return fut
                group = self._resolve_group(req.target)
                if group is not None:
                    spec = group.cursor.spec
                    if spec is not None and rows.shape[1] != spec.p:
                        self._resolve_fut(fut, _err(
                            f"group {group.gid!r} ingests p={spec.p} columns, "
                            f"got {rows.shape[1]}"))
                        return fut
                    if group.pending_rows + n > self.max_pending_rows:
                        self._c["rejected"].inc()
                        self._resolve_fut(fut, _rejected(
                            f"group {group.gid!r} has {group.pending_rows} "
                            f"rows pending (cap {self.max_pending_rows}); "
                            "retry after the backlog folds"))
                        return fut
                    group.pending_rows += n
                    group.last_access = time.monotonic()
                    wid = self._worker_of(group.gid)
                    try:
                        # target normalized to the gid on the internal record
                        # (not on req): maximal worker coalescing
                        self._queues[wid].put_nowait(
                            (_Ingest(group.gid, rows), fut))
                        self._g_pending.inc(n)
                        self._note_queue_depth(wid)
                    except queue.Full:
                        group.pending_rows -= n
                        self._c["rejected"].inc()
                        self._resolve_fut(fut, _rejected(
                            f"request queue full "
                            f"({self._queues[wid].maxsize}); retry later"))
                    return fut
            if attempt == 0:
                # unknown target: restore it if it was evicted, retry once
                try:
                    if not self._ensure_live(req.target):
                        break
                except Exception as e:  # noqa: BLE001
                    self._resolve_fut(fut, _err(
                        f"restore of evicted {req.target!r} failed: {e}"))
                    return fut
        self._resolve_fut(fut, _err(f"unknown tenant/group {req.target!r}"))
        return fut

    def call(self, req, timeout: float | None = 60.0) -> Response:
        """submit + wait."""
        return self.submit(req).result(timeout)

    # sugar ------------------------------------------------------------------

    def ingest(self, target: str, rows) -> Future:
        return self.submit(IngestRequest(target, rows))

    def query(self, tenant: str, op: str, x=None,
              timeout: float | None = 60.0) -> Response:
        return self.call(QueryRequest(tenant, op, x), timeout)

    def create_tenant(self, tid: str, kind: str, *, plan: Plan | None = None,
                      key=0, group: str | None = None,
                      retain_ingest: bool = False, **params) -> Response:
        resp = self.call(AdminRequest("create_tenant", dict(
            tid=tid, kind=kind, plan=plan, key=key, group=group,
            retain_ingest=retain_ingest, params=params)))
        resp.unwrap()   # raise on error — creation must not fail silently
        return resp

    def delete_tenant(self, tid: str) -> None:
        self.call(AdminRequest("delete_tenant", dict(tid=tid))).unwrap()

    def snapshot(self, path: str) -> int:
        """Checkpoint every live group/tenant (atomic-rename protocol of
        :mod:`repro.train.checkpoint`); returns the snapshot step. A
        multi-worker service quiesces the pool first, so the snapshot is a
        global fold boundary."""
        return self.call(AdminRequest("snapshot", dict(path=path)),
                         timeout=None).unwrap()

    def refine(self, tenant: str, x=None, passes: int | None = None, *,
               tol: float | None = None, max_passes: int = 16) -> Response:
        """Second-pass replay refinement on one tenant, in the worker loop (so
        it serializes against ingest). ``x=None`` replays the group's retained
        ingest — requires ``retain_ingest=True`` at tenant creation."""
        return self.call(AdminRequest("refine", dict(
            tenant=tenant, x=x, passes=passes, tol=tol,
            max_passes=max_passes)), timeout=None)

    def tenants(self) -> list[str]:
        with self._reg_lock:
            return sorted(self._tenants)

    def evicted(self) -> list[str]:
        """Group ids currently evicted to snapshot (lazily restored on touch)."""
        with self._evict_lock:
            return sorted(self._evicted)

    # -------------------------------------------------------------- routing --

    def _route_target(self, target: str) -> int:
        """Tenant/group id → owning worker. Unknown ids fall back to the id's
        own hash (covers evicted groups, whose gid keeps its partition; a
        truly unknown id just gets its error answered by whichever worker)."""
        with self._reg_lock:
            t = self._tenants.get(target)
            if t is not None:
                return self._worker_of(t.group.gid)
            if target in self._groups:
                return self._worker_of(target)
        return self._worker_of(self._evicted_tenants.get(target, target))

    def _route_admin(self, req: AdminRequest) -> int:
        p = req.params
        if req.op == "create_tenant":
            return self._worker_of(p.get("group") or p.get("tid") or "")
        if req.op in ("delete_tenant", "refine"):
            return self._route_target(p.get("tid") or p.get("tenant") or "")
        return 0    # snapshot (and unknown ops) run on the snapshot initiator

    def _note_queue_depth(self, wid: int) -> None:
        self._g_wq[wid].set(self._queues[wid].qsize())
        self._g_queue_depth.set(sum(q.qsize() for q in self._queues))

    # ---------------------------------------------------------- worker loop --

    def _resolve_fut(self, fut: Future, resp: Response) -> None:
        """_resolve plus submit→resolve latency accounting (the ``_obs_t0``
        stamp placed at submit). Every resolution — worker-side or submit-side
        fast path — funnels through here, so rejected and failed requests
        show up in ``serve.request_seconds`` too."""
        t0 = getattr(fut, "_obs_t0", None)
        if t0 is not None:
            self._h_latency.observe(time.perf_counter() - t0)
        _resolve(fut, resp)

    def _loop(self, wid: int) -> None:
        q = self._queues[wid]
        stop = False
        try:
            while not stop:
                try:
                    items = [q.get(timeout=_IDLE_TICK)]
                except queue.Empty:
                    self._tick(wid)
                    continue
                while len(items) < self.max_batch:
                    try:
                        items.append(q.get_nowait())
                    except queue.Empty:
                        break
                self._note_queue_depth(wid)
                batch = []
                for req, fut in items:
                    if req is _STOP:
                        stop = True   # drain this batch, fail later arrivals
                    elif stop:
                        self._resolve_fut(fut, _err("service stopped"))
                    else:
                        batch.append((req, fut))
                if batch:
                    try:
                        self._process(batch)
                    except Exception as e:  # noqa: BLE001 — the worker must live
                        self._fail_batch(batch, e)
                for _ in items:
                    q.task_done()
                if not stop:
                    self._tick(wid)
        finally:
            self._quiesce.worker_exit()

    def _tick(self, wid: int) -> None:
        """Fold-boundary housekeeping: worker 0 drives the auto-snapshot
        policy; every other worker answers a pending quiesce; each worker
        sweeps its OWN groups for TTL/LRU eviction (so eviction never races a
        fold — the evicting thread is the only one that folds the group)."""
        if wid == 0:
            self._maybe_auto_snapshot()
        else:
            self._quiesce.park_if_wanted()
        self._maybe_evict(wid)

    def _fail_batch(self, batch, exc: Exception) -> None:
        """Last-resort guard around one _process sweep: resolve whatever the
        crashed sweep left unresolved (releasing its ingest reservations) so
        one bad batch can never hang every in-flight and future caller."""
        for req, fut in batch:
            if fut.done():
                continue
            if isinstance(req, _Ingest):
                # an unresolved ingest never reached _flush_ingest's
                # accounting, so its reservation is still held
                with self._reg_lock:
                    g = self._groups.get(req.gid)
                    if g is not None:
                        g.pending_rows -= int(req.rows.shape[0])
                self._g_pending.inc(-int(req.rows.shape[0]))
            self._resolve_fut(fut, _err(f"internal service error: {exc!r}"))

    def _fail_queued(self, msg: str) -> None:
        """Fail everything still sitting in the (dead) queues — stop() path."""
        for wid, q in enumerate(self._queues):
            while True:
                try:
                    req, fut = q.get_nowait()
                except queue.Empty:
                    break
                if isinstance(req, _Ingest):
                    with self._reg_lock:
                        g = self._groups.get(req.gid)
                        if g is not None:
                            g.pending_rows -= int(req.rows.shape[0])
                    self._g_pending.inc(-int(req.rows.shape[0]))
                if fut is not None and not fut.done():
                    self._resolve_fut(fut, _err(msg))
                q.task_done()
            self._g_wq[wid].set(0)
        self._g_queue_depth.set(sum(q.qsize() for q in self._queues))

    def _process(self, batch) -> None:
        """Serve one drained micro-batch in queue order, coalescing each
        contiguous run of same-group ingests into one fold. (Exposed for
        tests: drives the same path the worker thread runs.)"""
        pending: dict[str, list] = {}
        for req, fut in batch:
            if isinstance(req, _Ingest):
                pending.setdefault(req.gid, []).append((req, fut))
                continue
            self._flush_ingest(pending)   # queries/admin see all prior ingest
            pending = {}
            self._c["requests"].inc()
            if isinstance(req, QueryRequest):
                self._resolve_fut(fut, self._handle_query(req))
            else:
                self._resolve_fut(fut, self._handle_admin(req))
        self._flush_ingest(pending)

    def _flush_ingest(self, pending: dict[str, list]) -> None:
        for gid, items in pending.items():
            self._c["requests"].inc(len(items))
            self._c["ingest_requests"].inc(len(items))
            blocks = [req.rows for req, _ in items]
            n = sum(int(b.shape[0]) for b in blocks)
            with self._reg_lock:
                group = self._groups.get(gid)
            if group is None:   # deleted between submit and drain
                self._g_pending.inc(-n)
                for _, fut in items:
                    self._resolve_fut(fut, _err(f"unknown tenant/group {gid!r}"))
                continue
            try:
                # concatenate inside the try: column counts mismatched across
                # a coalesced run must answer error responses, not raise
                rows = blocks[0] if len(blocks) == 1 else np.concatenate(blocks)
                group.fold(rows, self.scan)
                self._c["ingest_folds"].inc()
                self._c["ingest_rows"].inc(n)
                self._h_coalesce.observe(len(items))
                with self._reg_lock:
                    self._folded_rows += n   # feeds SnapshotPolicy.every_rows
                for tid in group.tenants:
                    self.registry.counter("serve.tenant_folds",
                                          tenant=tid).inc()
                resp = [_ok(int(b.shape[0]), group=group.gid,
                            coalesced=len(items), count=group.cursor.count)
                        for b in blocks]
            except Exception as e:  # a bad block poisons its whole coalesced run
                resp = [_err(f"ingest failed: {e}")] * len(items)
            finally:
                with self._reg_lock:
                    group.pending_rows -= n
                self._g_pending.inc(-n)
            for (_, fut), r in zip(items, resp):
                self._resolve_fut(fut, r)

    # ----------------------------------------------------------- supervision --

    def _maybe_auto_snapshot(self) -> None:
        """Worker-0 fold-boundary check of the SnapshotPolicy."""
        pol = self.snapshot_policy
        if pol is None or self._stopped:
            return
        with self._reg_lock:
            rows = self._folded_rows
        if rows == self._last_snap_rows:
            return   # nothing new folded — never rewrite identical snapshots
        now = time.monotonic()
        due = ((pol.every_rows is not None
                and rows - self._last_snap_rows >= pol.every_rows)
               or (pol.every_s is not None
                   and now - self._last_snap_t >= pol.every_s))
        if not due:
            return
        try:
            self._do_snapshot(self.snapshot_dir)
        except Exception:  # noqa: BLE001 — a failed snapshot must not kill serving
            self.registry.counter("serve.snapshot_errors").inc()

    def _do_snapshot(self, path: str) -> int:
        """One snapshot step. On a live multi-worker service, quiesce the
        pool first so no fold is in flight anywhere; on a single worker (or
        before start) the caller IS the only folder."""
        from repro.sketchserve import snapshot as snap_mod

        self._snap_step += 1
        step = self._snap_step
        t0 = time.perf_counter()
        if self._threads and self.n_workers > 1:
            with self._quiesce.held():
                snap_mod.save_service(self, path, step=step)
        else:
            snap_mod.save_service(self, path, step=step)
        self._h_snapshot.observe(time.perf_counter() - t0)
        with self._reg_lock:
            self._last_snap_rows = self._folded_rows
        self._last_snap_t = time.monotonic()
        self._c["snapshots"].inc()
        return step

    # -------------------------------------------------------------- eviction --

    def _evict_base(self) -> str:
        with self._evict_lock:
            if self.evict_dir is None:
                self.evict_dir = tempfile.mkdtemp(prefix="sketchserve-evict-")
            return self.evict_dir

    def _maybe_evict(self, wid: int) -> None:
        """TTL / LRU sweep over THIS worker's groups (rate-limited)."""
        if self.max_tenants is None and self.ttl_s is None:
            return
        now = time.monotonic()
        if now - self._last_sweep[wid] < self._sweep_every:
            return
        self._last_sweep[wid] = now
        with self._reg_lock:
            mine = [g for gid, g in self._groups.items()
                    if self._worker_of(gid) == wid]
            over = (0 if self.max_tenants is None
                    else len(self._tenants) - self.max_tenants)
        mine.sort(key=lambda g: g.last_access)
        for g in mine:
            expired = (self.ttl_s is not None
                       and now - g.last_access >= self.ttl_s)
            if not expired and over <= 0:
                break   # sorted oldest-first: nothing older follows
            if g.pending_rows:
                continue   # queued ingest — never evict under a reservation
            if self._evict_group(g):
                over -= len(g.tenants)

    def _evict_group(self, g: _Group) -> bool:
        """Evict one idle group to snapshot: write its cursor+tenant state
        under ``evict_dir/<gid>``, then drop it from the live registry. Runs
        on the group's owner worker, so no fold can be in flight."""
        from repro.sketchserve import snapshot as snap_mod

        path = os.path.join(self._evict_base(), g.gid)
        self._evict_steps[g.gid] = self._evict_steps.get(g.gid, 0) + 1
        try:
            snap_mod.save_service(self, path, step=self._evict_steps[g.gid],
                                  gids=[g.gid])
        except Exception:  # noqa: BLE001 — e.g. mid-step sharded state
            return False   # keep it live; retry at a later sweep
        with self._evict_lock:
            with self._reg_lock:
                if g.pending_rows or self._groups.get(g.gid) is not g:
                    return False   # raced with new ingest / delete — keep live
                for tid in list(g.tenants):
                    del self._tenants[tid]
                del self._groups[g.gid]
                self._evicted[g.gid] = {"path": path,
                                        "tenants": sorted(g.tenants)}
                for tid in g.tenants:
                    self._evicted_tenants[tid] = g.gid
        self._c["evictions"].inc()
        return True

    def _ensure_live(self, target: str) -> bool:
        """Restore an evicted tenant/group on first touch. Returns True if a
        restore happened (the caller should re-resolve the target), False if
        the target was never evicted. Raises if the restore itself fails (the
        eviction record is put back so a later touch can retry)."""
        with self._evict_lock:
            gid = (target if target in self._evicted
                   else self._evicted_tenants.get(target))
            if gid is None:
                return False
            ev = self._evicted.pop(gid)
            for tid in ev["tenants"]:
                self._evicted_tenants.pop(tid, None)
            try:
                from repro.sketchserve import snapshot as snap_mod
                snap_mod.restore_group(self, gid, ev["path"])
            except Exception:
                self._evicted[gid] = ev
                for tid in ev["tenants"]:
                    self._evicted_tenants[tid] = gid
                raise
        self._c["evict_restores"].inc()
        return True

    # -------------------------------------------------------------- queries --

    def _handle_query(self, req: QueryRequest) -> Response:
        self._c["queries"].inc()
        t = self._tenants.get(req.tenant)
        if t is None:
            try:
                if self._ensure_live(req.tenant):
                    t = self._tenants.get(req.tenant)
            except Exception as e:  # noqa: BLE001
                return _err(f"restore of evicted tenant {req.tenant!r} "
                            f"failed: {e}")
        if t is None:
            return _err(f"unknown tenant {req.tenant!r}")
        t.group.last_access = time.monotonic()
        cur = t.group.cursor
        if req.op == "stats":
            return _ok({"kind": t.kind, "group": t.group.gid,
                        "rows": cur.count, "chunks": cur.chunk,
                        "n_sketches": cur.n_sketches,
                        "pending_rows": t.group.pending_rows,
                        "finalized_rows": t.finalized_rows,
                        "finalize_count": t.finalize_count,
                        "state_bytes": _state_nbytes(t)})
        if cur.count == 0:
            return _err(f"tenant {req.tenant!r} has no ingested rows yet")
        if t.finalized_rows != cur.count:   # lazy: only when state moved
            try:
                t.est.finalize()
            except Exception as e:
                return _err(f"finalize failed: {e}")
            t.finalized_rows = cur.count
            t.finalize_count += 1
            self._c["finalizes"].inc()
        try:
            return self._read_fitted(t, req.op, req.x)
        except AttributeError:
            return _err(f"op {req.op!r} does not apply to a {t.kind!r} tenant")
        except Exception as e:
            return _err(f"query {req.op!r} failed: {e}")

    def _read_fitted(self, t: _Tenant, op: str, x) -> Response:
        est = t.est
        if op == "mean":
            return _ok(np.asarray(est.mean_))
        if op == "cov":
            return _ok(np.asarray(est.cov_))
        if op == "components":
            return _ok({"components": np.asarray(est.components_),
                        "explained_variance": np.asarray(est.explained_variance_)})
        if op == "centers":
            return _ok(np.asarray(est.centers_))
        if op == "transform":
            if x is None:
                return _err("transform needs an x payload")
            return _ok(np.asarray(est.transform(np.asarray(x))))
        if op == "predict":
            if x is None:
                return _err("predict needs an x payload")
            return _ok(np.asarray(est.predict(np.asarray(x))))
        return _err(f"unknown query op {op!r} (transform|predict|components|"
                    "centers|mean|cov|stats)")

    # ---------------------------------------------------------------- admin --

    def _handle_admin(self, req: AdminRequest) -> Response:
        p = req.params
        try:
            if req.op == "create_tenant":
                return self._create_tenant(**p)
            if req.op == "delete_tenant":
                return self._delete_tenant(p["tid"])
            if req.op == "snapshot":
                return _ok(self._do_snapshot(p["path"]))
            if req.op == "refine":
                return self._refine(**p)
            return _err(f"unknown admin op {req.op!r}")
        except Exception as e:
            return _err(f"admin {req.op!r} failed: {e}")

    def _create_tenant(self, tid, kind, plan, key, group, retain_ingest,
                       params) -> Response:
        if not _ID_RE.match(tid or ""):
            return _err(f"tenant id {tid!r} must match {_ID_RE.pattern}")
        if tid in self._tenants or tid in self._groups:
            return _err(f"id {tid!r} already exists")
        if tid in self._evicted_tenants or tid in self._evicted:
            return _err(f"id {tid!r} already exists (evicted to snapshot)")
        if kind not in ESTIMATORS:
            return _err(f"unknown kind {kind!r} (one of {sorted(ESTIMATORS)})")
        gid = group if group is not None else tid
        if not _ID_RE.match(gid):
            return _err(f"group id {gid!r} must match {_ID_RE.pattern}")
        if gid in self._tenants and gid not in self._groups:
            return _err(f"group id {gid!r} collides with a tenant id")
        g = self._groups.get(gid)
        if g is None:
            if plan is None:
                return _err(f"first tenant of group {gid!r} must carry a plan")
            g = _Group(gid, plan, key, retain_ingest)
        est = ESTIMATORS[kind](plan=plan or g.plan, key=key, **params)
        # the fit_many co-registration check: shared sketch ⇒ shared geometry+key
        _check_consumer(g.plan, est, len(g.tenants), g.key)
        if g.cursor.count > 0:
            return _err(f"group {gid!r} already ingested {g.cursor.count} rows;"
                        " tenants must co-register before ingest starts (a late"
                        " joiner would silently miss them)")
        est._cursor = g.cursor
        g.cursor.register(est)
        t = _Tenant(tid, kind, dict(params), est, g)
        with self._reg_lock:
            if tid in self._tenants:   # raced a same-tid create on another worker
                g.cursor.consumers.remove(est)
                return _err(f"id {tid!r} already exists")
            g.tenants[tid] = t
            self._groups[gid] = g
            self._tenants[tid] = t
        return _ok(tid, group=gid)

    def _delete_tenant(self, tid) -> Response:
        t = self._tenants.get(tid)
        if t is None:
            # deleting an evicted tenant: restore first, then drop normally
            if self._ensure_live(tid):
                t = self._tenants.get(tid)
        if t is None:
            return _err(f"unknown tenant {tid!r}")
        g = t.group
        with self._reg_lock:
            del self._tenants[tid]
            del g.tenants[tid]
            if t.est in g.cursor.consumers:
                g.cursor.consumers.remove(t.est)
            if not g.tenants:
                del self._groups[g.gid]
        return _ok(tid, group_deleted=not g.tenants)

    def _refine(self, tenant, x, passes, tol, max_passes) -> Response:
        t = self._tenants.get(tenant)
        if t is None and self._ensure_live(tenant):
            t = self._tenants.get(tenant)
        if t is None:
            return _err(f"unknown tenant {tenant!r}")
        g = t.group
        g.last_access = time.monotonic()
        if x is None:
            if not g.retain_ingest:
                return _err(f"group {g.gid!r} was created with "
                            "retain_ingest=False and no x payload was given — "
                            "nothing to replay")
            if not g.retained:
                return _err("no ingested rows to replay yet")
            x = np.concatenate(g.retained)
        if t.finalized_rows != g.cursor.count:
            t.est.finalize()
            t.finalized_rows = g.cursor.count
            t.finalize_count += 1
        t.est.refine(np.asarray(x), passes, tol=tol, max_passes=max_passes)
        return _ok({"passes": int(getattr(t.est, "refine_passes_", 0)),
                    "converged": bool(getattr(t.est, "refine_converged_", False))})

    # -------------------------------------------------------------- helpers --

    def _resolve_group(self, target: str) -> _Group | None:
        """Tenant id or group id → group (caller holds _reg_lock)."""
        t = self._tenants.get(target)
        if t is not None:
            return t.group
        return self._groups.get(target)
