"""Sketch-as-a-service: online multi-tenant estimator serving.

The subsystem that turns the one-shot ``fit`` APIs into a long-lived server:
an async request queue (:class:`SketchService`) accepting ingest / query /
admin requests, a pool of micro-batching worker loops over disjoint group
partitions (each coalescing same-group ingest into one jitted sketch+fold
step), per-tenant execution :class:`~repro.api.Plan`\\ s with admission
control, lazy finalization, crash-safe snapshot/restore over
:mod:`repro.train.checkpoint` with an auto-snapshot :class:`SnapshotPolicy`,
tenant TTL/LRU eviction to snapshot, and a stdlib HTTP frontend
(:class:`HttpFrontend`) that carries backpressure as 429s.

Start here: :mod:`repro.sketchserve.service` (the model and the loop),
:mod:`repro.sketchserve.protocol` (the request/response types and the wire
mapping), :mod:`repro.sketchserve.snapshot` (what persists and why restore
is bit-identical), :mod:`repro.sketchserve.http` (the wire layer).
``examples/sketch_service.py`` is the guided tour; ``launch/sketch_serve.py``
drives a synthetic workload end to end (``--supervise`` adds crash-restart).
"""
from repro.sketchserve.http import HttpFrontend, serve_http
from repro.sketchserve.protocol import (AdminRequest, IngestRequest,
                                        QueryRequest, Response,
                                        response_to_json)
from repro.sketchserve.service import (ESTIMATORS, SketchService,
                                       SnapshotPolicy)
from repro.sketchserve.snapshot import (restore_group, restore_service,
                                        save_service)

__all__ = [
    "AdminRequest",
    "ESTIMATORS",
    "HttpFrontend",
    "IngestRequest",
    "QueryRequest",
    "Response",
    "SketchService",
    "SnapshotPolicy",
    "response_to_json",
    "restore_group",
    "restore_service",
    "save_service",
    "serve_http",
]
