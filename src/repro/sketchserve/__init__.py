"""Sketch-as-a-service: online multi-tenant estimator serving.

The subsystem that turns the one-shot ``fit`` APIs into a long-lived server:
an async request queue (:class:`SketchService`) accepting ingest / query /
admin requests, a micro-batching worker loop that coalesces same-group
ingest into one jitted sketch+fold step, per-tenant execution
:class:`~repro.api.Plan`\\ s with admission control, lazy finalization, and
crash-safe snapshot/restore over :mod:`repro.train.checkpoint`.

Start here: :mod:`repro.sketchserve.service` (the model and the loop),
:mod:`repro.sketchserve.protocol` (the request/response types),
:mod:`repro.sketchserve.snapshot` (what persists and why restore is
bit-identical). ``examples/sketch_service.py`` is the guided tour;
``launch/sketch_serve.py`` drives a synthetic workload end to end.
"""
from repro.sketchserve.protocol import (AdminRequest, IngestRequest,
                                        QueryRequest, Response)
from repro.sketchserve.service import ESTIMATORS, SketchService
from repro.sketchserve.snapshot import restore_service, save_service

__all__ = [
    "AdminRequest",
    "ESTIMATORS",
    "IngestRequest",
    "QueryRequest",
    "Response",
    "SketchService",
    "restore_service",
    "save_service",
]
