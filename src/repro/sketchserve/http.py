"""HTTP frontend for a :class:`~repro.sketchserve.service.SketchService`.

The stdlib wire layer that makes the service reachable from outside the
process — same daemon-threaded ``ThreadingHTTPServer`` shape as the
``/metrics`` endpoint in :mod:`repro.obs.sinks`, mapped straight onto
``submit()``:

- ``POST /ingest``  body ``{"target": gid, "rows": [[...], ...]}``
- ``GET  /query?tenant=t&op=components`` (ops with an ``x`` payload —
  transform/predict — POST ``{"tenant", "op", "x"}`` instead)
- ``POST /admin``   body ``{"op": "create_tenant", "params": {...}}`` —
  a ``plan`` param travels as the :func:`~repro.sketchserve.snapshot
  .plan_from_json` dict encoding
- ``GET  /healthz`` liveness (also reports worker/tenant counts)

Response bodies are :func:`~repro.sketchserve.protocol.response_to_json`;
the HTTP status code IS the Response status
(:data:`~repro.sketchserve.protocol.HTTP_STATUS`): ok → 200, **rejected →
429** with a ``Retry-After`` header — admission-control backpressure
crossing the wire intact, so a remote producer backs off exactly like an
in-process one — and error → 400. Malformed JSON is 400 before it reaches
the queue; unknown paths are 404.

Each HTTP request blocks its (daemon) handler thread on the submitted
Future, so slow folds hold sockets, not the service: the worker pool keeps
micro-batching underneath, and concurrent HTTP producers coalesce exactly
like in-process ones.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import numpy as np

from repro.sketchserve.protocol import (HTTP_STATUS, AdminRequest,
                                        IngestRequest, QueryRequest, Response,
                                        response_to_json)

#: advisory client back-off after a 429 (seconds) — the backlog is a fold or
#: two away from draining, not minutes.
RETRY_AFTER_S = 1


class _Handler(BaseHTTPRequestHandler):
    service = None          # class attrs, bound per-server subclass
    timeout_s: float = 60.0

    # ---------------------------------------------------------------- plumbing

    def _send(self, code: int, body: dict, retry_after: bool = False) -> None:
        data = json.dumps(body).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        if retry_after:
            self.send_header("Retry-After", str(RETRY_AFTER_S))
        self.end_headers()
        self.wfile.write(data)

    def _send_response(self, resp: Response) -> None:
        self._send(HTTP_STATUS.get(resp.status, 500), response_to_json(resp),
                   retry_after=resp.status == "rejected")

    def _json_body(self) -> dict | None:
        try:
            n = int(self.headers.get("Content-Length") or 0)
            body = json.loads(self.rfile.read(n) or b"{}")
            if not isinstance(body, dict):
                raise ValueError("body must be a JSON object")
            return body
        except Exception as e:  # noqa: BLE001 — malformed input is a 400
            self._send(400, {"status": "error", "result": None,
                             "error": f"bad JSON body: {e}", "info": {}})
            return None

    def _serve(self, req) -> None:
        resp = self.service.submit(req).result(self.timeout_s)
        self._send_response(resp)

    def log_message(self, *args):  # requests must not spam the run's stdout
        pass

    # ---------------------------------------------------------------- routes

    def do_POST(self):  # noqa: N802 — BaseHTTPRequestHandler contract
        path = self.path.split("?")[0]
        body = self._json_body()
        if body is None:
            return
        try:
            if path == "/ingest":
                rows = np.asarray(body["rows"], dtype=np.float64)
                self._serve(IngestRequest(str(body["target"]), rows))
            elif path == "/query":
                x = body.get("x")
                self._serve(QueryRequest(
                    str(body["tenant"]), str(body["op"]),
                    None if x is None else np.asarray(x, dtype=np.float64)))
            elif path == "/admin":
                self._serve(_admin_from_json(body))
            else:
                self._send(404, {"status": "error", "result": None,
                                 "error": f"unknown path {path!r} "
                                          "(/ingest /query /admin /healthz)",
                                 "info": {}})
        except (KeyError, TypeError, ValueError) as e:
            self._send(400, {"status": "error", "result": None,
                             "error": f"bad request: {e!r}", "info": {}})

    def do_GET(self):  # noqa: N802
        u = urlparse(self.path)
        if u.path == "/healthz":
            svc = self.service
            self._send(200, {"status": "ok",
                             "result": {"workers": svc.n_workers,
                                        "tenants": len(svc.tenants()),
                                        "evicted": len(svc.evicted())},
                             "error": None, "info": {}})
            return
        if u.path != "/query":
            self._send(404, {"status": "error", "result": None,
                             "error": f"unknown path {u.path!r} "
                                      "(GET /query or /healthz)", "info": {}})
            return
        q = parse_qs(u.query)
        try:
            tenant, = q["tenant"]
            op, = q["op"]
        except (KeyError, ValueError):
            self._send(400, {"status": "error", "result": None,
                             "error": "GET /query needs tenant= and op=",
                             "info": {}})
            return
        self._serve(QueryRequest(tenant, op))


def _admin_from_json(body: dict) -> AdminRequest:
    """Wire admin op → AdminRequest; a create_tenant plan dict decodes
    through the snapshot Plan codec (mesh geometry + dtype strings)."""
    op = str(body["op"])
    params = dict(body.get("params") or {})
    if op == "create_tenant":
        from repro.sketchserve.snapshot import plan_from_json
        if params.get("plan") is not None:
            params["plan"] = plan_from_json(params["plan"])
        params = dict(tid=str(params.pop("tid")),
                      kind=str(params.pop("kind")),
                      plan=params.pop("plan", None),
                      key=params.pop("key", 0),
                      group=params.pop("group", None),
                      retain_ingest=bool(params.pop("retain_ingest", False)),
                      params=dict(params.pop("params", {})))
    return AdminRequest(op, params)


class HttpFrontend:
    """A daemon-threaded HTTP endpoint over one service. ``port=0`` binds an
    ephemeral port (read it back off ``.port``/``.url``); does not own the
    service's lifecycle — start/stop it separately."""

    def __init__(self, service, port: int = 0, host: str = "127.0.0.1",
                 timeout_s: float = 60.0):
        handler = type("_BoundHandler", (_Handler,),
                       {"service": service, "timeout_s": float(timeout_s)})
        self._httpd = ThreadingHTTPServer((host, int(port)), handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self.url = f"http://{host}:{self.port}"
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True, name="sketchserve-http")
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join()

    def __enter__(self) -> "HttpFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve_http(service, port: int = 0, host: str = "127.0.0.1",
               timeout_s: float = 60.0) -> HttpFrontend:
    """Expose ``service`` over HTTP; returns the live frontend."""
    return HttpFrontend(service, port, host, timeout_s)
