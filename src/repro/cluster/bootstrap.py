"""Process bootstrap for true multi-host ingest under the (seed, step, shard) grid.

The paper's sampling contract makes multi-host trivial IN PRINCIPLE — every
batch is a pure function of (seed, step, shard), so "distribute the stream"
just means "each process generates the shards it owns". This module supplies
the three pieces jax needs to make that real:

1. :func:`initialize` — ``jax.distributed`` bring-up. On CPU the collectives
   implementation must be switched to gloo BEFORE initialize (the default CPU
   backend cannot run multi-process computations at all), which is exactly the
   kind of footgun a bootstrap module exists to hide.
2. :func:`process_mesh` — a 1-D mesh whose devices are sorted by
   (process_index, id), so each process owns a CONTIGUOUS block of shard
   positions. Contiguity is what lets per-host data enter as the addressable
   block of one global array (step 3) without any permutation.
3. :func:`global_shard_batch` / :func:`global_rows` —
   ``jax.make_array_from_process_local_data``: each process materializes only
   its own shards' rows; jit then runs the SAME per-step psum the single-host
   engine runs, so results match single-process to float-summation
   reordering (asserted at 1e-5 by the CI smoke lane, tests/test_cluster.py).

Single-process calls are no-ops / identities, so code written against this
module runs unchanged on one host.
"""
from __future__ import annotations

import functools
import os

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def initialize(coordinator_address: str | None = None,
               num_processes: int | None = None,
               process_id: int | None = None, *,
               platform: str | None = None) -> bool:
    """Bring up ``jax.distributed`` for a multi-process run; returns whether a
    multi-process runtime is (now) active.

    ``num_processes in (None, 1)`` is the single-process no-op path. On CPU
    (``platform="cpu"``, the default unless JAX_PLATFORMS says otherwise) the
    collectives implementation is switched to gloo first — the default CPU
    backend refuses multi-process computations outright. Must be called
    before any JAX computation touches the backend (a jax constraint).
    """
    if num_processes in (None, 1):
        return jax.process_count() > 1
    dist_state = getattr(getattr(jax, "_src", None), "distributed", None)
    client = getattr(getattr(dist_state, "global_state", None), "client", None)
    if client is not None:  # already brought up (idempotent re-entry)
        return jax.process_count() > 1
    plat = platform or os.environ.get("JAX_PLATFORMS") or "cpu"
    if "cpu" in plat:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    return True


def is_multiprocess() -> bool:
    return jax.process_count() > 1


def process_mesh(n_shards: int | None = None, axis: str = "data") -> Mesh:
    """A 1-D ``(n_shards,)`` mesh over devices sorted by (process_index, id).

    The sort guarantees each process's devices sit at CONTIGUOUS positions
    along the shard axis — the layout :func:`global_shard_batch` assumes.
    ``n_shards=None`` uses every device. Cached per (n_shards, axis) so
    compiled shard_maps keyed on the mesh object stay cached too.
    """
    n = None if n_shards is None else int(n_shards)
    return _process_mesh_cached(n, axis)


@functools.lru_cache(maxsize=None)
def _process_mesh_cached(n_shards: int | None, axis: str) -> Mesh:
    devs = sorted(jax.devices(), key=lambda d: (d.process_index, d.id))
    n = len(devs) if n_shards is None else n_shards
    if len(devs) < n:
        raise ValueError(f"process_mesh needs {n} devices, have {len(devs)}")
    return Mesh(np.array(devs[:n]), (axis,))


def local_shards(mesh: Mesh, axis: str = "data") -> list[int]:
    """The shard positions along ``axis`` THIS process owns (shard s lives on
    the mesh's s-th device). Single-process: every shard."""
    devices = mesh.devices
    if devices.ndim != 1:
        raise ValueError(f"local_shards expects a 1-D mesh, got shape "
                         f"{devices.shape} (axes {mesh.axis_names})")
    pid = jax.process_index()
    return [i for i, d in enumerate(devices.flat) if d.process_index == pid]


def global_shard_batch(source, seed, step: int, mesh: Mesh,
                       axis: str = "data"):
    """One step's global (n_shards, b, p) batch, assembled from per-host data:
    this process generates ONLY its own shards via the (seed, step, shard)
    contract and contributes them as the addressable block of a global array
    row-sharded over ``axis``. All shards must return equal-shaped batches
    (the engine's contract)."""
    mine = local_shards(mesh, axis)
    if not mine:
        raise ValueError(f"process {jax.process_index()} owns no shards of "
                         f"mesh axis {axis!r} — shrink n_shards or the mesh")
    local = np.stack([np.asarray(source(seed, step, s)) for s in mine])
    sharding = NamedSharding(mesh, P(axis))
    if not is_multiprocess():
        return jax.device_put(local, sharding)
    return jax.make_array_from_process_local_data(sharding, local)


def global_rows(arr, mesh: Mesh, axis: str = "data"):
    """A (rows, …) array row-sharded over ``axis``, from each process's local
    block (this process's rows must be the contiguous block its mesh
    positions own — row counts must divide evenly across shards)."""
    local = np.asarray(arr)
    sharding = NamedSharding(mesh, P(axis))
    if not is_multiprocess():
        return jax.device_put(local, sharding)
    return jax.make_array_from_process_local_data(sharding, local)
