"""Multi-host bootstrap + elastic re-sharding for the (seed, step, shard) grid.

- bootstrap: ``jax.distributed`` init (gloo collectives on CPU), the
  process-ordered mesh, shard→process ownership, and per-host global-batch
  assembly via ``jax.make_array_from_process_local_data``.
- elastic:   worker-count changes as a pure remap of the logical (step, shard)
  grid — each worker replays only the shards its new layout owns; deltas are
  merged and applied once per step, so the continued run matches the original
  layout to float-summation reordering.
- heartbeat: per-host liveness stamps as a registered state kind ("hb") —
  gathered/merged through the EngineState wire format and published as
  ``cluster.*`` gauges (repro.obs).
"""
from repro.cluster.bootstrap import (  # noqa: F401
    global_rows,
    global_shard_batch,
    initialize,
    is_multiprocess,
    local_shards,
    process_mesh,
)
from repro.cluster.heartbeat import (  # noqa: F401
    Heartbeat,
    beat,
    gather,
    publish,
    publish_local,
)
from repro.cluster.elastic import (  # noqa: F401
    apply_step,
    continue_elastic,
    merge_deltas,
    partial_step_delta,
    worker_shards,
)
