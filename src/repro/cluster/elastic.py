"""Elastic re-sharding: survive worker-count changes by remapping the grid.

The logical (step, shard) grid is FIXED by the sketch-mask key discipline —
shard s of step t always folds the same rows under the same mask, no matter
which physical worker computes it. A worker-count change is therefore a pure
remap: :func:`worker_shards` assigns each of the ``n_workers`` a contiguous
block of the ``n_shards`` logical shards, and each worker replays ONLY the
shards its new block owns (the regenerable source makes a "lost" shard a
replayable PRNG key, not lost data — the property none of the related systems
have).

Per step, every worker's :func:`partial_step_delta` is taken against the SAME
replicated step-start state; the fixed-size deltas are :func:`merge_deltas`'d
(element-wise add — exactly the engine's within-step sum) and applied once by
:func:`apply_step`. Because the per-shard deltas are identical to the original
layout's and the apply happens once per step either way, a 4-worker run, its
2-worker continuation, and the single-host run agree to float-summation
reordering (tests/test_cluster.py asserts the 4→2 remap parity).

:func:`continue_elastic` is the single-host driver of that protocol (the test
and bench harness; on a real cluster each worker runs its own
``partial_step_delta`` and ships the delta, e.g. through a psum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.stream.engine import EngineState, StreamEngine


def worker_shards(n_shards: int, n_workers: int, worker: int) -> list[int]:
    """The contiguous block of logical shards worker ``worker`` owns under an
    ``n_workers``-worker layout (earlier workers take the remainder)."""
    if not 0 <= worker < n_workers:
        raise ValueError(f"worker must be in [0, {n_workers}), got {worker}")
    if n_workers > n_shards:
        raise ValueError(f"{n_workers} workers over {n_shards} logical shards "
                         "leaves workers idle — lower n_workers")
    base, rem = divmod(n_shards, n_workers)
    sizes = [base + (1 if w < rem else 0) for w in range(n_workers)]
    start = sum(sizes[:worker])
    return list(range(start, start + sizes[worker]))


def partial_step_delta(engine: StreamEngine, state: EngineState, step: int,
                       shards: list[int], seed: int | None = None):
    """One worker's summed delta for ``step``: fold ONLY ``shards``' batches
    — regenerated from the (seed, step, shard) contract and sketched under
    their grid-fixed mask keys — against the step-start ``state``."""
    if not shards:
        raise ValueError("partial_step_delta needs at least one shard")
    deltas = None
    for sh in shards:
        x = jnp.asarray(engine.source(seed, step, sh))
        d = engine._deltas(state, engine._sketch_local(x, jnp.int32(step), sh))
        deltas = d if deltas is None else jax.tree.map(jnp.add, deltas, d)
    return deltas


def merge_deltas(a, b):
    """Combine two workers' partial deltas — element-wise add, the same sum
    the engine takes within a step."""
    return jax.tree.map(jnp.add, a, b)


def apply_step(engine: StreamEngine, state: EngineState, delta) -> EngineState:
    """Apply one step's merged delta ONCE — the engine's per-step discipline
    (K-means decay and the Eq.-39 mean update happen here, exactly once)."""
    return engine._apply(state, delta)


def continue_elastic(engine: StreamEngine, steps: int, *, state: EngineState,
                     start_step: int, n_workers: int,
                     seed: int | None = None) -> EngineState:
    """Continue a (restored) run to ``steps`` under a NEW worker count.

    Single-host driver of the elastic protocol: per remaining step, each of
    the ``n_workers`` simulated workers folds its :func:`worker_shards`
    block's deltas against the shared step-start state; the deltas merge and
    apply once. Engine-level reassignment counters (if tracked) are frozen —
    they need the per-shard sketches the distributed protocol does not ship.
    """
    for step in range(start_step, steps):
        deltas = [partial_step_delta(engine, state, step,
                                     worker_shards(engine.n_shards, n_workers, w),
                                     seed)
                  for w in range(n_workers)]
        state = apply_step(engine, state, functools.reduce(merge_deltas, deltas))
    engine.state = state
    return state
