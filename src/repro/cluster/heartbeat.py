"""Per-host liveness heartbeats on the EngineState wire format.

A :class:`Heartbeat` is a tiny accumulator — (hosts, step, rows, newest /
oldest stamp time) — registered as state kind ``"hb"`` in
:mod:`repro.stream.state`. That buys the whole lifecycle for free: host
stamps serialize through ``to_arrays``/``from_arrays`` (so they ride the
``train.checkpoint`` protocol and any transport that moves checkpoint
dicts), and the cluster-wide view is literally ``merge`` over stamps —
hosts add, steps max, stamp times max/min — the same algebra every other
accumulator kind speaks.

The flow on each host::

    hb = heartbeat.beat(step, rows)          # stamp local progress
    heartbeat.publish_local(hb)              # per-host gauges (host=<pid>)
    view = heartbeat.gather(hb)              # allgather+merge (no-op 1-host)
    heartbeat.publish(view)                  # cluster.{hosts,step,rows,...}

``publish`` exposes the merged view as registry gauges, including
``cluster.heartbeat_age_s`` (now − newest stamp: is anyone alive?) and
``cluster.straggler_lag_s`` (newest − oldest stamp: is someone behind?).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import numpy as np

from repro import obs
from repro.cluster import bootstrap
from repro.stream import state as _state


@dataclasses.dataclass(frozen=True)
class Heartbeat:
    """One or more merged host stamps. Scalars (0-d arrays on the wire)."""

    hosts: Any    # stamps merged in (1 per host beat)
    step: Any     # max engine step any merged host reached
    rows: Any     # total rows folded across merged hosts
    t_last: Any   # newest stamp time (unix seconds)
    t_first: Any  # oldest stamp time


def _merge_hb(a: Heartbeat, b: Heartbeat) -> Heartbeat:
    return Heartbeat(
        hosts=a.hosts + b.hosts,
        step=np.maximum(a.step, b.step),
        rows=a.rows + b.rows,
        t_last=np.maximum(a.t_last, b.t_last),
        t_first=np.minimum(a.t_first, b.t_first))


_state.register_state(_state.StateKind(
    name="hb", cls=Heartbeat,
    fields=("hosts", "step", "rows", "t_last", "t_first"), merge=_merge_hb))


def beat(step: int, rows: int = 0, t: float | None = None) -> Heartbeat:
    """Stamp this host's progress as a single-host Heartbeat."""
    t = time.time() if t is None else float(t)
    return Heartbeat(hosts=np.int32(1), step=np.int64(step),
                     rows=np.int64(rows), t_last=np.float64(t),
                     t_first=np.float64(t))


def gather(hb: Heartbeat) -> Heartbeat:
    """The cluster-wide merged view: allgather every process's stamp (over
    the wire-format dict) and fold with the hb merge algebra. Single-process
    runs return ``hb`` unchanged."""
    if not bootstrap.is_multiprocess():
        return hb
    from jax.experimental import multihost_utils

    arrs = _state.to_arrays(hb)
    gathered = multihost_utils.process_allgather(arrs)  # leading process axis
    n = int(next(iter(gathered.values())).shape[0])
    per_host = [_state.from_arrays({k: v[i] for k, v in gathered.items()},
                                   kinds=("hb",))
                for i in range(n)]
    return functools.reduce(_state.merge, per_host)


def publish(hb: Heartbeat, registry: obs.MetricsRegistry | None = None,
            now: float | None = None) -> dict[str, float]:
    """Expose a (merged) Heartbeat as ``cluster.*`` gauges; returns the
    values set. ``heartbeat_age_s`` answers "is anyone alive?",
    ``straggler_lag_s`` answers "is someone behind?"."""
    reg = registry if registry is not None else obs.default_registry()
    now = time.time() if now is None else float(now)
    vals = {
        "cluster.hosts": float(int(hb.hosts)),
        "cluster.step": float(int(hb.step)),
        "cluster.rows": float(int(hb.rows)),
        "cluster.heartbeat_age_s": max(0.0, now - float(hb.t_last)),
        "cluster.straggler_lag_s": max(0.0, float(hb.t_last) - float(hb.t_first)),
    }
    for name, v in vals.items():
        reg.gauge(name).set(v)
    return vals


def publish_local(hb: Heartbeat, host: int | str | None = None,
                  registry: obs.MetricsRegistry | None = None) -> None:
    """Per-host gauges (``cluster.host_step{host=<pid>}`` etc.) from this
    host's own stamp — the labeled series a scraper graphs per worker."""
    reg = registry if registry is not None else obs.default_registry()
    h = str(jax.process_index() if host is None else host)
    reg.gauge("cluster.host_step", host=h).set(int(hb.step))
    reg.gauge("cluster.host_rows", host=h).set(int(hb.rows))
    reg.gauge("cluster.host_beat_t", host=h).set(float(hb.t_last))
