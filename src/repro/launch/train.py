"""Training launcher: ``python -m repro.launch.train --arch <id> [--reduced] ...``

End-to-end driver: config → mesh → sharded state → data pipeline → train loop
with async checkpointing, crash-restart, and optional sketched gradient
compression (the paper's technique as a distributed-optimization feature).

On this CPU container use ``--reduced --devices N`` (forced host devices);
on a real cluster drop both and let jax see the TPU slice.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--devices", type=int, default=0, help="force N host devices (CPU)")
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--grad-compress-gamma", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_arch
    from repro.core.grad_compress import CompressConfig
    from repro.data.pipeline import SyntheticLMSource
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models.api import get_api
    from repro.train import checkpoint
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import (TrainerConfig, abstract_state, init_state,
                                     make_dist, make_train_fn, state_shardings)

    cfg = get_arch(args.arch, reduced=args.reduced)
    api = get_api(cfg)
    if args.mesh == "host":
        n = len(jax.devices())
        mesh = make_host_mesh(max(1, n // 2), min(2, n)) if n > 1 else None
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    compress = None
    if args.grad_compress_gamma > 0:
        compress = CompressConfig(gamma=args.grad_compress_gamma)
    tcfg = TrainerConfig(
        opt=OptConfig(peak_lr=args.lr, warmup_steps=max(1, args.steps // 20),
                      total_steps=args.steps),
        accum_steps=args.accum, compress=compress,
        q_chunk=min(512, args.seq), kv_chunk=min(1024, args.seq),
        sp=mesh is not None,
    )
    key = jax.random.PRNGKey(args.seed)
    dist = make_dist(mesh, cfg, sp=tcfg.sp)
    fn = make_train_fn(api, tcfg, dist, key)

    state_specs = abstract_state(api, tcfg)
    if mesh is not None:
        shardings = state_shardings(state_specs, mesh)
        step_fn = jax.jit(fn, donate_argnums=0, out_shardings=(shardings, None))
        state = jax.device_put(init_state(api, tcfg, key), shardings)
    else:
        shardings = None
        step_fn = jax.jit(fn, donate_argnums=0)
        state = init_state(api, tcfg, key)

    source = SyntheticLMSource(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    start_step = 0
    if args.ckpt_dir:
        try:
            state, extra = checkpoint.restore(args.ckpt_dir, state_specs, shardings)
            start_step = int(extra.get("pipeline", {}).get("step", 0))
            source.state.step = start_step
            print(f"restored checkpoint at step {start_step}")
        except FileNotFoundError:
            pass

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = source.next_batch()
        if cfg.family == "vlm":
            B, S = batch["tokens"].shape
            pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
            batch["vision_embeds"] = jnp.zeros((B, cfg.n_vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.family == "audio":
            B, S = batch["tokens"].shape
            fk = jax.random.fold_in(key, step)
            batch["frames"] = 0.1 * jax.random.normal(fk, (B, S, cfg.d_model), jnp.dtype(cfg.dtype))
        state, metrics = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"step {step:5d} loss {loss:.4f} gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({(time.time()-t0):.1f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, step + 1, state,
                            extra={"pipeline": source.state.to_json()})
    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, args.steps, state,
                        extra={"pipeline": source.state.to_json()}, async_=False)
    print("done")


if __name__ == "__main__":
    main()
