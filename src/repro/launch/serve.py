"""Serving launcher: batched prefill + decode loop for any arch.

``python -m repro.launch.serve --arch glm4-9b --reduced --batch 4 --prompt-len 16 --gen 8``
"""
from __future__ import annotations

import argparse
import os
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_arch
    from repro.models.api import get_api

    cfg = get_arch(args.arch, reduced=args.reduced)
    api = get_api(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = api.init_params(key)
    B = args.batch
    max_len = args.prompt_len + args.gen
    prompt = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    if cfg.family == "audio":
        from repro.models import encdec

        frames = 0.1 * jax.random.normal(key, (B, args.prompt_len, cfg.d_model))
        cache = encdec.init_decode_cache(params, frames, cfg, max_len, dtype=jnp.float32)
        cur = jnp.zeros((B, 1), jnp.int32)
        toks = []
        for t in range(args.gen):
            logits, cache = api.decode_fn(params, cur, cache, jnp.int32(t + 1))
            cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            toks.append(cur)
        out = jnp.concatenate(toks, 1)
    else:
        # prefill then greedy decode
        if cfg.family in ("dense", "moe", "vlm"):
            logits, cache = api.prefill_fn(params, {"tokens": prompt}, cache_dtype=jnp.float32)
            cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, args.gen), (0, 0), (0, 0)))
                     for k, v in cache.items()}
        else:
            cache = api.init_decode_state(B, max_len)
            logits = None
            for t in range(args.prompt_len):
                logits, cache = api.decode_fn(params, prompt[:, t:t+1], cache, jnp.int32(t + 1))
        cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        toks = [cur]
        decode = jax.jit(api.decode_fn)
        for t in range(args.gen - 1):
            logits, cache = decode(params, cur, cache, jnp.int32(args.prompt_len + t + 1))
            cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            toks.append(cur)
        out = jnp.concatenate(toks, 1)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({B*args.gen/dt:.1f} tok/s incl. compile)")
    print("sample tokens:", out[0].tolist())


if __name__ == "__main__":
    main()
