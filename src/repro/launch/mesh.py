"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2×16×16 = 512 chips across two pods.

    Uses the first prod(shape) devices so a 512-device dry-run process can
    build both meshes.
    """
    import numpy as np

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_host_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh over forced host devices — used by multi-device CPU tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis_of(mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None
