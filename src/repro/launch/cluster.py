"""Multi-process streaming launcher: ``python -m repro.launch.cluster``.

The multi-host twin of ``repro.launch.stream``: N REAL OS processes bring up
``jax.distributed`` (gloo collectives on CPU), build the process-contiguous
mesh (``repro.cluster.process_mesh``), and fold the same (seed, step, shard)
stream — each process generates ONLY the shards it owns, the per-step psum is
the only cross-process traffic. With ``--ckpt-dir`` the run checkpoints its
EngineState periodically (process 0 writes) and ``--resume`` continues from
the latest checkpoint bit-identically.

Run it twice to see fault tolerance end to end::

    # 2 processes, 2 shards, checkpoint every 5 steps — kill it mid-run
    PYTHONPATH=src python -m repro.launch.cluster --nproc 2 --steps 20 \\
        --ckpt-dir /tmp/ck --ckpt-every 5

    # resume from the latest checkpoint and finish the same 20 steps
    PYTHONPATH=src python -m repro.launch.cluster --nproc 2 --steps 20 \\
        --ckpt-dir /tmp/ck --resume

Without ``--process-id`` the command is the COORDINATOR: it picks a free port
and spawns ``--nproc`` copies of itself as workers (the single-machine path;
on a real cluster start one worker per host with ``--process-id``/
``--coordinator`` set explicitly and skip the self-spawn).
"""
from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys
import time


def _parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nproc", type=int, default=2, help="number of processes")
    ap.add_argument("--process-id", type=int, default=None,
                    help="worker mode: this process's id (coordinator spawns these)")
    ap.add_argument("--coordinator", type=str, default=None,
                    help="host:port of process 0 (worker mode)")
    ap.add_argument("--p", type=int, default=1024)
    ap.add_argument("--gamma", type=float, default=0.1)
    ap.add_argument("--batch", type=int, default=256, help="rows per shard per step")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--shards", type=int, default=0,
                    help="logical shards (default: nproc, one per process)")
    ap.add_argument("--kmeans-k", type=int, default=0, help="0 disables K-means")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint the EngineState every N steps")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint in --ckpt-dir")
    ap.add_argument("--log-every", type=int, default=0,
                    help="every N steps: process 0 emits a structured JSONL "
                         "progress record and publishes the merged cluster "
                         "heartbeat (0 = telemetry off)")
    return ap


def _spawn(args) -> int:
    """Coordinator: free port, one worker subprocess per process id."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    cmd = [sys.executable, "-m", "repro.launch.cluster",
           "--coordinator", f"127.0.0.1:{port}"]
    for flag in ("nproc", "p", "batch", "steps", "shards", "kmeans_k", "seed",
                 "ckpt_every", "log_every"):
        cmd += [f"--{flag.replace('_', '-')}", str(getattr(args, flag))]
    cmd += ["--gamma", str(args.gamma)]
    if args.ckpt_dir:
        cmd += ["--ckpt-dir", args.ckpt_dir]
    if args.resume:
        cmd += ["--resume"]
    env = dict(os.environ, JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    procs = [subprocess.Popen(cmd + ["--process-id", str(pid)], env=env)
             for pid in range(args.nproc)]
    rc = 0
    for p in procs:
        rc = rc or p.wait()
    return rc


def _worker(args) -> int:
    from repro import cluster

    cluster.initialize(args.coordinator, args.nproc, args.process_id)

    import jax

    from repro import api
    from repro.data.pipeline import VectorStreamSource
    from repro.stream import StreamKMeansConfig

    shards = args.shards or args.nproc
    plan = api.Plan(backend="sharded", gamma=args.gamma,
                    batch_size=args.batch, n_shards=shards)
    source = VectorStreamSource(p=args.p, batch=args.batch, seed=args.seed)
    km = StreamKMeansConfig(k=args.kmeans_k) if args.kmeans_k else None
    engine = api.make_engine(plan, args.p, jax.random.PRNGKey(args.seed + 1),
                             source, kmeans=km)

    state, start = None, 0
    if args.resume:
        if not args.ckpt_dir:
            raise SystemExit("--resume needs --ckpt-dir")
        state, start = engine.restore_state(args.ckpt_dir)

    tel = None
    if args.log_every:
        from repro import obs
        from repro.stream import EngineTelemetry

        reg = obs.MetricsRegistry()
        log_every = args.log_every

        def _on_step(rec, _reg=reg):
            # every process stamps + gathers at the SAME steps (the heartbeat
            # allgather is a collective — the condition must be symmetric);
            # process 0 publishes the merged view as cluster.* gauges
            if (rec["step"] + 1) % log_every:
                return
            hb = cluster.beat(rec["step"] + 1, rows=rec["rows_total"])
            cluster.publish_local(hb, registry=_reg)
            view = cluster.gather(hb)
            if jax.process_index() == 0:
                cluster.publish(view, registry=_reg)

        logger = (obs.StepLogger(stream=sys.stderr,
                                 static={"p": args.p, "shards": shards,
                                         "nproc": args.nproc})
                  if jax.process_index() == 0 else None)
        tel = EngineTelemetry(registry=reg, step_logger=logger,
                              log_every=log_every, on_step=_on_step)

    t0 = time.time()
    res = engine.run(args.steps, seed=args.seed, state=state, start_step=start,
                     checkpoint_dir=args.ckpt_dir,
                     checkpoint_every=args.ckpt_every if args.ckpt_dir else 0,
                     telemetry=tel)
    jax.block_until_ready(res.mean)
    dt = time.time() - t0

    if jax.process_index() == 0:
        rows = int(res.count)
        folded = (args.steps - start) * shards * args.batch
        print(f"p={args.p} gamma={engine.spec.gamma:.3f} shards={shards} "
              f"processes={jax.process_count()} "
              f"(this run folded steps {start}..{args.steps - 1})")
        print(f"total rows in state: {rows:,}; folded {folded:,} rows in "
              f"{dt:.2f}s ({folded / dt:,.0f} rows/s incl. compile)")
        print(f"mean[:4] = {[round(float(v), 4) for v in res.mean[:4]]}")
        if res.centers is not None:
            print(f"kmeans: K={args.kmeans_k}, "
                  f"best accumulated obj = {float(res.kmeans_obj):.2f}")
        if tel is not None:
            hbv = {m.name: m.value for m in tel.registry.metrics()
                   if m.name.startswith("cluster.") and not m.labels}
            if hbv:
                print(f"heartbeat: hosts={hbv.get('cluster.hosts', 0):.0f} "
                      f"step={hbv.get('cluster.step', 0):.0f} "
                      f"straggler_lag={hbv.get('cluster.straggler_lag_s', 0):.3f}s")
    return 0


def main(argv=None) -> int:
    args = _parser().parse_args(argv)
    if args.process_id is None:
        return _spawn(args)
    if not args.coordinator:
        raise SystemExit("worker mode (--process-id) needs --coordinator")
    return _worker(args)


if __name__ == "__main__":
    raise SystemExit(main())
