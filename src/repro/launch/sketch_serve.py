"""Sketch-serving launcher: drive a synthetic multi-tenant workload through
:class:`repro.sketchserve.SketchService` and report throughput/latency.

``python -m repro.launch.sketch_serve --tenants 32 --groups 8 --requests 512``

Spins up the service (``--workers`` worker loops over the group partition),
creates ``--tenants`` tenants round-robin over ``--groups`` shared-sketch
groups (each group gets one PCA + one K-means co-registered on one
compression pass; extra members are means), fires ``--requests`` small
ingest requests with a query mixed in every ``--query-every``, then prints
requests/sec, fold coalescing, query p50/p99 (via
:func:`repro.obs.quantiles`), the service's submit→resolve latency
distribution, and (optionally) snapshots to ``--snapshot``.
``--metrics-port`` serves the live registry as a Prometheus-style
``/metrics`` endpoint and ``--http-port`` the full
:mod:`repro.sketchserve.http` frontend for the duration of the run.

Supervision. ``--snapshot-every-rows`` / ``--snapshot-every-s`` arm a
:class:`~repro.sketchserve.SnapshotPolicy` writing to ``--snapshot``;
``--supervise`` turns the launcher into a supervisor: it runs the same
workload in a child process and, whenever the child dies mid-run, restarts
it with ``--resume`` — the child restores from the latest snapshot, derives
how many requests each group already folded, and replays only the
remainder. The workload in these modes is deterministic (request ``r``'s
rows come from ``default_rng(f(seed, r))``, folds are serialized, the scan
burst path is pinned off), so the crashed-and-resumed run ends
bit-identical to an uninterrupted one — ``--out`` writes the final
per-group PCA components as JSON so two runs can be diffed
(``--crash-after K`` makes the first child attempt die after K acked
requests, which is the CI crash-restart smoke).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=32)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--p", type=int, default=64)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--rows-per-request", type=int, default=32)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--query-every", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--workers", type=int, default=1,
                    help="worker loops over the group partition")
    ap.add_argument("--snapshot", default=None, help="checkpoint dir (optional)")
    ap.add_argument("--snapshot-every-rows", type=int, default=None,
                    help="auto-snapshot to --snapshot every N folded rows")
    ap.add_argument("--snapshot-every-s", type=float, default=None,
                    help="auto-snapshot to --snapshot at most every T seconds")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics on this port while the run lasts")
    ap.add_argument("--http-port", type=int, default=None,
                    help="serve the HTTP frontend on this port for the run")
    ap.add_argument("--supervise", action="store_true",
                    help="run the workload in a child process; restart it "
                         "from the latest snapshot if it crashes")
    ap.add_argument("--max-restarts", type=int, default=5)
    ap.add_argument("--crash-after", type=int, default=None,
                    help="die (exit 7) after this many acked ingest requests "
                         "— crash-injection for the --supervise smoke")
    ap.add_argument("--resume", action="store_true",
                    help="restore from --snapshot and replay only the "
                         "requests not yet folded")
    ap.add_argument("--out", default=None,
                    help="write final per-group PCA components as JSON "
                         "(deterministic mode; lets two runs be diffed)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def _supervise(args) -> int:
    """Parent loop: run the workload as a child process, restarting a crashed
    child from the latest snapshot (``--resume``) up to --max-restarts times.
    The first attempt carries --crash-after if given; retries never do — the
    injected crash fires once."""
    import subprocess

    if not args.snapshot:
        raise SystemExit("--supervise needs --snapshot (the restart source)")
    base = [sys.executable, "-m", "repro.launch.sketch_serve",
            "--tenants", str(args.tenants), "--groups", str(args.groups),
            "--p", str(args.p), "--rank", str(args.rank),
            "--rows-per-request", str(args.rows_per_request),
            "--requests", str(args.requests),
            "--query-every", str(args.query_every),
            "--batch-size", str(args.batch_size),
            "--max-batch", str(args.max_batch),
            "--workers", str(args.workers),
            "--snapshot", args.snapshot, "--seed", str(args.seed)]
    if args.snapshot_every_rows is not None:
        base += ["--snapshot-every-rows", str(args.snapshot_every_rows)]
    if args.snapshot_every_s is not None:
        base += ["--snapshot-every-s", str(args.snapshot_every_s)]
    if args.out:
        base += ["--out", args.out]
    for attempt in range(args.max_restarts + 1):
        cmd = list(base)
        if attempt == 0 and args.crash_after is not None:
            cmd += ["--crash-after", str(args.crash_after)]
        if attempt > 0:
            cmd += ["--resume"]
        rc = subprocess.call(cmd)
        if rc == 0:
            print(f"supervise: workload completed after {attempt} restart(s)")
            return 0
        print(f"supervise: child exited rc={rc} (attempt {attempt}); "
              "restarting from latest snapshot")
    print(f"supervise: giving up after {args.max_restarts} restarts")
    return 1


def _block(seed: int, r: int, rows: int, p: int):
    """Request r's rows, derived from (seed, r) alone — a crashed-and-resumed
    run regenerates exactly the blocks it skips and the ones it replays."""
    import numpy as np

    return np.random.default_rng((seed + 1) * 1_000_003 + r) \
             .normal(size=(rows, p)).astype(np.float32)


def main(argv=None):
    args = _build_parser().parse_args(argv)
    if args.supervise:
        return _supervise(args)
    if (args.snapshot_every_rows or args.snapshot_every_s) and not args.snapshot:
        raise SystemExit("--snapshot-every-* needs --snapshot")

    import numpy as np

    from repro import obs
    from repro.api import Plan
    from repro.sketchserve import (SketchService, SnapshotPolicy,
                                   restore_service, serve_http)

    # deterministic mode: crash/resume parity needs per-request seeding,
    # serialized folds (fold boundaries = request boundaries), and the host
    # fold loop (the scan burst matches it only to float tolerance)
    det = bool(args.crash_after is not None or args.resume or args.out)
    policy = (SnapshotPolicy(every_rows=args.snapshot_every_rows,
                             every_s=args.snapshot_every_s)
              if (args.snapshot_every_rows or args.snapshot_every_s) else None)
    svc_kw = dict(max_batch=args.max_batch, workers=args.workers,
                  snapshot_policy=policy,
                  snapshot_dir=args.snapshot if policy else None,
                  scan="never" if det else "auto")

    rng = np.random.default_rng(args.seed)
    plan = Plan(backend="stream", gamma=0.25, batch_size=args.batch_size,
                cov_path="lowrank", rank=args.rank)
    kinds = ("pca", "kmeans", "mean")
    t0 = time.time()
    done = {g: 0 for g in range(args.groups)}   # requests already folded
    if args.resume:
        try:
            svc = restore_service(args.snapshot, **svc_kw)
        except FileNotFoundError:
            print(f"resume: no snapshot under {args.snapshot}; starting fresh")
            svc = SketchService(**svc_kw)
    else:
        svc = SketchService(**svc_kw)
    with svc:
        server = (obs.serve_metrics(svc.registry, port=args.metrics_port)
                  if args.metrics_port is not None else None)
        if server is not None:
            print(f"metrics at {server.url}")
        frontend = (serve_http(svc, port=args.http_port)
                    if args.http_port is not None else None)
        if frontend is not None:
            print(f"http frontend at {frontend.url}")
        have = set(svc.tenants())
        for i in range(args.tenants):
            gid, kind = f"g{i % args.groups}", kinds[min(i // args.groups, 2)]
            if f"t{i}" in have:   # resume: restored with the snapshot
                continue
            extra = ({"n_components": 4} if kind == "pca"
                     else {"k": 4, "algorithm": "minibatch"} if kind == "kmeans"
                     else {})
            svc.create_tenant(f"t{i}", kind, plan=plan, key=args.seed,
                              group=gid, **extra)
        if args.resume:
            for g in range(args.groups):
                rows = svc.query(f"t{g}", "stats").unwrap()["rows"]
                done[g] = rows // args.rows_per_request
            print(f"resume: {sum(done.values())}/{args.requests} requests "
                  "already folded; replaying the remainder")
        t_create = time.time() - t0

        lat: list[float] = []
        futs = []
        acked = 0
        t0 = time.time()
        for r in range(args.requests):
            g = r % args.groups
            if done[g] > 0:         # folded before the crash — skip, don't refold
                done[g] -= 1
                continue
            if det:
                rows = _block(args.seed, r, args.rows_per_request, args.p)
                svc.ingest(f"g{g}", rows).result(60).unwrap()
                acked += 1
                if args.crash_after is not None and acked >= args.crash_after:
                    print(f"crash-after: dying with {acked} acked requests",
                          flush=True)
                    os._exit(7)
            else:
                rows = rng.normal(size=(args.rows_per_request, args.p)
                                  ).astype(np.float32)
                futs.append(svc.ingest(f"g{g}", rows))
                if (r + 1) % args.query_every == 0:
                    tq = time.time()
                    svc.query(f"t{g}", "components").unwrap()
                    lat.append(time.time() - tq)
        rejected = sum(f.result().status == "rejected" for f in futs)
        dt = time.time() - t0
        if args.out:
            comps = {f"g{g}": np.asarray(
                         svc.query(f"t{g}", "components").unwrap()["components"]
                     ).tolist() for g in range(args.groups)}
            with open(args.out, "w") as f:
                json.dump(comps, f)
            print(f"per-group components -> {args.out}")
        stats = svc.stats
        lat_summary = svc.registry.histogram("serve.request_seconds").summary()
        if args.snapshot and policy is None:
            step = svc.snapshot(args.snapshot)
            print(f"snapshot step {step} -> {args.snapshot}")
        if frontend is not None:
            frontend.close()
        if server is not None:
            server.close()

    folds = max(stats["ingest_folds"], 1)
    print(f"tenants={args.tenants} groups={args.groups} "
          f"workers={args.workers} created in {t_create:.2f}s")
    print(f"{stats['ingest_requests']} ingest requests "
          f"({stats['ingest_rows']} rows) in "
          f"{dt:.2f}s = {stats['ingest_requests'] / max(dt, 1e-9):.0f} req/s, "
          f"{stats['ingest_rows'] / max(dt, 1e-9):.0f} rows/s; "
          f"{stats['ingest_requests'] / folds:.1f} requests/fold "
          f"(micro-batching), {rejected} rejected, "
          f"{stats['snapshots']} snapshots")
    if lat:
        p50, p99 = obs.quantiles((v * 1e3 for v in lat), (0.5, 0.99))
        print(f"{len(lat)} queries (lazy finalize): p50={p50:.1f}ms "
              f"p99={p99:.1f}ms")
    if lat_summary.get("count"):
        print(f"submit→resolve latency over {lat_summary['count']} requests: "
              f"p50={lat_summary['p50'] * 1e3:.2f}ms "
              f"p99={lat_summary['p99'] * 1e3:.2f}ms "
              f"max={lat_summary['max'] * 1e3:.2f}ms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
