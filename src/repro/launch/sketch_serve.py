"""Sketch-serving launcher: drive a synthetic multi-tenant workload through
:class:`repro.sketchserve.SketchService` and report throughput/latency.

``python -m repro.launch.sketch_serve --tenants 32 --groups 8 --requests 512``

Spins up the service, creates ``--tenants`` tenants round-robin over
``--groups`` shared-sketch groups (each group gets one PCA + one K-means
co-registered on one compression pass; extra members are means), fires
``--requests`` small ingest requests with a query mixed in every
``--query-every``, then prints requests/sec, fold coalescing, query p50/p99
(via :func:`repro.obs.quantiles`), the service's submit→resolve latency
distribution, and (optionally) snapshots to ``--snapshot``.
``--metrics-port`` serves the live registry as a Prometheus-style
``/metrics`` endpoint for the duration of the run.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=32)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--p", type=int, default=64)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--rows-per-request", type=int, default=32)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--query-every", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--snapshot", default=None, help="checkpoint dir (optional)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics on this port while the run lasts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import numpy as np

    from repro import obs
    from repro.api import Plan
    from repro.sketchserve import SketchService

    rng = np.random.default_rng(args.seed)
    plan = Plan(backend="stream", gamma=0.25, batch_size=args.batch_size,
                cov_path="lowrank", rank=args.rank)
    kinds = ("pca", "kmeans", "mean")
    t0 = time.time()
    with SketchService(max_batch=args.max_batch) as svc:
        server = (obs.serve_metrics(svc.registry, port=args.metrics_port)
                  if args.metrics_port is not None else None)
        if server is not None:
            print(f"metrics at {server.url}")
        for i in range(args.tenants):
            gid, kind = f"g{i % args.groups}", kinds[min(i // args.groups, 2)]
            extra = ({"n_components": 4} if kind == "pca"
                     else {"k": 4, "algorithm": "minibatch"} if kind == "kmeans"
                     else {})
            svc.create_tenant(f"t{i}", kind, plan=plan, key=args.seed,
                              group=gid, **extra)
        t_create = time.time() - t0

        lat: list[float] = []
        futs = []
        t0 = time.time()
        for r in range(args.requests):
            rows = rng.normal(size=(args.rows_per_request, args.p)).astype(np.float32)
            futs.append(svc.ingest(f"g{r % args.groups}", rows))
            if (r + 1) % args.query_every == 0:
                tq = time.time()
                svc.query(f"t{r % args.groups}", "components").unwrap()
                lat.append(time.time() - tq)
        rejected = sum(f.result().status == "rejected" for f in futs)
        dt = time.time() - t0
        stats = svc.stats
        lat_summary = svc.registry.histogram("serve.request_seconds").summary()
        if args.snapshot:
            step = svc.snapshot(args.snapshot)
            print(f"snapshot step {step} -> {args.snapshot}")
        if server is not None:
            server.close()

    folds = max(stats["ingest_folds"], 1)
    print(f"tenants={args.tenants} groups={args.groups} "
          f"created in {t_create:.2f}s")
    print(f"{args.requests} ingest requests ({stats['ingest_rows']} rows) in "
          f"{dt:.2f}s = {args.requests / dt:.0f} req/s, "
          f"{stats['ingest_rows'] / dt:.0f} rows/s; "
          f"{stats['ingest_requests'] / folds:.1f} requests/fold "
          f"(micro-batching), {rejected} rejected")
    if lat:
        p50, p99 = obs.quantiles((v * 1e3 for v in lat), (0.5, 0.99))
        print(f"{len(lat)} queries (lazy finalize): p50={p50:.1f}ms "
              f"p99={p99:.1f}ms")
    if lat_summary.get("count"):
        print(f"submit→resolve latency over {lat_summary['count']} requests: "
              f"p50={lat_summary['p50'] * 1e3:.2f}ms "
              f"p99={lat_summary['p99'] * 1e3:.2f}ms "
              f"max={lat_summary['max'] * 1e3:.2f}ms")


if __name__ == "__main__":
    main()
