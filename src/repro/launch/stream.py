"""Streaming-estimation launcher: ``python -m repro.launch.stream [flags]``.

Thin shim over the unified ``repro.api`` layer: flags build a
:class:`repro.api.Plan` (backend "stream", or "sharded" when a mesh fits) and
``api.make_engine`` constructs the streaming engine — synthetic
(seed, step, shard) vector source → per-batch-mask sketch → donated
constant-memory accumulators → finalized mean / covariance / streaming
K-means, optionally shard_map-distributed over forced host devices.

    # single device, mean+cov at p=4096, 5% sketch
    PYTHONPATH=src python -m repro.launch.stream --p 4096 --gamma 0.05 --steps 20

    # 8-way sharded with streaming K-means
    PYTHONPATH=src python -m repro.launch.stream --devices 8 --shards 8 \
        --kmeans-k 8 --steps 20

On a TPU slice drop ``--devices`` and the sketch runs the Pallas Kronecker
kernels (chunked three-pass above p = 2^15) automatically (impl="auto").
"""
from __future__ import annotations

import argparse
import os
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--p", type=int, default=4096)
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=512, help="rows per shard per step")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0, help="force N host devices (CPU)")
    ap.add_argument("--no-cov", action="store_true", help="mean-only accumulator")
    ap.add_argument("--cov-path", choices=("dense", "compact"), default="dense",
                    help="covariance delta path (compact = the γ ≪ 1 memory fix)")
    ap.add_argument("--kmeans-k", type=int, default=0, help="0 disables streaming K-means")
    ap.add_argument("--kmeans-ninit", type=int, default=3)
    ap.add_argument("--log-every", type=int, default=0,
                    help="emit a structured JSONL progress record every N steps "
                         "(0 = telemetry off)")
    ap.add_argument("--log-file", default=None,
                    help="JSONL destination for --log-every (default: stderr)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the live registry at /metrics on this port")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax

    from repro import api
    from repro.data.pipeline import VectorStreamSource
    from repro.stream import StreamKMeansConfig

    backend = "stream"
    if args.shards > 1:
        if len(jax.devices()) >= args.shards:
            backend = "sharded"
        else:
            print(f"only {len(jax.devices())} device(s); "
                  f"folding {args.shards} shards sequentially")

    plan = api.Plan(backend=backend, gamma=args.gamma, batch_size=args.batch,
                    n_shards=args.shards, cov_path=args.cov_path)
    source = VectorStreamSource(p=args.p, batch=args.batch, seed=args.seed)
    km = (StreamKMeansConfig(k=args.kmeans_k, n_init=args.kmeans_ninit)
          if args.kmeans_k else None)
    engine = api.make_engine(plan, args.p, jax.random.PRNGKey(args.seed + 1), source,
                             track_cov=not args.no_cov, kmeans=km)
    spec = engine.spec

    tel, server = None, None
    if args.log_every or args.metrics_port is not None:
        import sys

        from repro import obs
        from repro.stream import EngineTelemetry

        reg = obs.MetricsRegistry()
        logger = obs.StepLogger(
            path=args.log_file, stream=None if args.log_file else sys.stderr,
            static={"p": args.p, "shards": args.shards, "backend": backend})
        tel = EngineTelemetry(registry=reg, step_logger=logger,
                              log_every=max(args.log_every, 1))
        if args.metrics_port is not None:
            server = obs.serve_metrics(reg, port=args.metrics_port)
            print(f"metrics at {server.url}")

    t0 = time.time()
    res = engine.run(args.steps, seed=args.seed, telemetry=tel)
    jax.block_until_ready(res.mean)
    dt = time.time() - t0
    if server is not None:
        server.close()
    rows = int(res.count)
    acc_floats = spec.p_pad + (0 if args.no_cov else spec.p_pad**2)
    if km:
        acc_floats += 2 * args.kmeans_ninit * args.kmeans_k * spec.p_pad
    print(f"p={args.p} gamma={spec.gamma:.3f} (m={spec.m}) shards={args.shards} "
          f"backend={plan.backend}")
    print(f"streamed {rows:,} rows in {dt:.2f}s ({rows/dt:,.0f} rows/s incl. compile); "
          f"accumulator state: {acc_floats:,} floats (constant in stream length)")
    print(f"mean[:4] = {[round(float(v), 4) for v in res.mean[:4]]}")
    if res.cov is not None:
        print(f"cov trace = {float(res.cov.trace()):.4f}")
    if res.centers is not None:
        print(f"kmeans: K={args.kmeans_k}, best accumulated obj = {float(res.kmeans_obj):.2f}")


if __name__ == "__main__":
    main()
