import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init). 512 host devices back both production meshes:
16×16 single-pod and 2×16×16 multi-pod.

Per cell this records: memory_analysis (bytes/device — proves it fits),
cost_analysis (flops/bytes for §Roofline), and the collective mix; with
``--roofline`` it additionally runs the unrolled depth probes (single-pod
only) and emits the three roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--roofline] [--out DIR]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402


def arch_trainer_config(arch: str, shape_kind: str):
    """Per-arch memory/optimizer presets (DESIGN.md §4 notes)."""
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import TrainerConfig

    opt = OptConfig()
    if arch == "kimi-k2-1t-a32b":
        # 1T params on 16 GB chips: factored second moment, no first moment
        opt = OptConfig(momentum=False, factored=True, moment_dtype="bfloat16")
    elif arch == "qwen3-moe-235b-a22b":
        opt = OptConfig(moment_dtype="bfloat16")
    return TrainerConfig(opt=opt, sp=True)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             roofline: bool = False) -> dict:
    from repro.configs.registry import cell_is_runnable, get_arch, get_shape
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import probe_cell, roofline_terms
    from repro.roofline.hlo import collective_stats
    from repro.train.trainer import lower_cell

    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    ok, why = cell_is_runnable(arch, shape_name)
    if not ok:
        rec["status"] = "skip"
        rec["reason"] = why
        return rec

    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    tcfg = arch_trainer_config(arch, shape.kind)

    try:
        t0 = time.time()
        lowered, meta = lower_cell(cfg, shape, mesh, tcfg)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        coll = collective_stats(compiled.as_text())
        peak = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes + ma.output_size_in_bytes)
        # analytic TPU-target projection (CPU backend widens bf16 DUS to f32
        # inside fusions and charges full-size temps — see roofline/memmodel.py)
        from repro.launch.mesh import dp_axes_of
        from repro.roofline.analysis import count_params
        from repro.roofline.memmodel import peak_model
        import numpy as _np

        n_dp = int(_np.prod([mesh.shape[a] for a in dp_axes_of(mesh)]))
        n_tp = mesh.shape.get("model", 1)
        model = peak_model(
            cfg, shape, n_chips, n_dp, n_tp, count_params(cfg)["total"],
            sp=tcfg.sp, momentum=tcfg.opt.momentum, factored=tcfg.opt.factored,
            moment_bytes=2 if tcfg.opt.moment_dtype == "bfloat16" else 4,
        )
        rec.update({
            "status": "ok",
            "kind": meta["kind"],
            "n_chips": n_chips,
            "lower_s": round(t1 - t0, 1),
            "compile_s": round(t2 - t1, 1),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "peak_bytes": peak,
                "fits_16GB": peak < (16 << 30),
                "modeled_tpu_peak_bytes": model["total"],
                "modeled_components": model["components"],
                "modeled_fits_16GB": model["fits_16GB"],
            },
            "cost": {"flops_per_device": ca.get("flops", 0.0),
                     "bytes_per_device": ca.get("bytes accessed", 0.0)},
            "collectives_steady": {k: v for k, v in coll["by_kind"].items()},
        })
        del compiled, lowered
        if roofline and mesh_kind == "single":
            probe = probe_cell(cfg, shape, mesh, tcfg)
            rec["roofline"] = {
                **probe,
                "terms": roofline_terms(probe["per_device"], n_chips, cfg, shape),
            }
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug to record
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.configs.registry import ARCHS, SHAPES

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = os.path.join(args.out, f"{arch}__{shape}__{mesh_kind}.json")
                if os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skip"):
                            print(f"cached  {arch} × {shape} × {mesh_kind}")
                            continue
                rec = run_cell(arch, shape, mesh_kind, args.out, roofline=args.roofline)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" peak={rec['memory']['peak_bytes']/2**30:.1f}GB"
                             f" fits={rec['memory']['fits_16GB']}"
                             f" compile={rec['compile_s']}s")
                if status == "fail":
                    n_fail += 1
                    extra = " " + rec["error"][:160]
                print(f"{status:5s}  {arch} × {shape} × {mesh_kind}{extra}", flush=True)
    print(f"done, failures={n_fail}")
    return n_fail


if __name__ == "__main__":
    raise SystemExit(main())
