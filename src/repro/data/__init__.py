from repro.data.pipeline import (  # noqa: F401
    PipelineState,
    SketchingPipeline,
    SyntheticLMSource,
    VectorStreamSource,
)
