"""Streaming data pipeline: deterministic, resumable, shard-aware.

Design for 1000+ nodes (DESIGN.md §5):
- every batch is a pure function of (root seed, step, shard) — no coordination,
  so any worker can regenerate any batch (straggler backup dispatch = another
  worker computes the same (step, shard) batch; exactly-once by construction);
- pipeline state is one integer cursor (+ the seed), checkpointed with the model;
- an optional one-pass **sketch stage** (the paper's compression) runs over
  vector-valued streams before they leave the ingest host — the downstream PCA /
  K-means consumers never see dense data.

Real deployments swap ``SyntheticLMSource`` for a tokenized file/GCS reader with
the same (seed, step, shard) → batch contract.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sketch_mod


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int = 0

    def to_json(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_json(cls, d: dict) -> "PipelineState":
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class SyntheticLMSource:
    """Deterministic synthetic token stream (zipf-ish unigram + shifted labels)."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int, seed: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.state = PipelineState(seed=seed)
        probs = 1.0 / np.arange(1, vocab_size + 1) ** 1.1
        self._probs = probs / probs.sum()

    def _batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.state.seed, step))
        toks = rng.choice(self.vocab, size=(self.batch, self.seq + 1), p=self._probs)
        toks = toks.astype(np.int32)
        return {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> dict:
        b = self._batch_at(self.state.step)
        self.state.step += 1
        return b

    def batch_for(self, step: int) -> dict:
        """Backup-dispatch hook: regenerate any step's batch on any worker."""
        return self._batch_at(step)


class VectorStreamSource:
    """Deterministic stream of p-dimensional samples (for PCA/K-means at scale).

    Every batch is a pure function of (seed, step, shard) — the contract
    repro.stream.StreamEngine consumes — so any worker can regenerate any
    shard's batch without coordination.
    """

    def __init__(self, p: int, batch: int, seed: int = 0, mode: str = "lowrank", k: int = 8):
        self.p, self.batch, self.mode, self.k = p, batch, mode, k
        self.state = PipelineState(seed=seed)
        rng = np.random.default_rng(seed)
        u, _ = np.linalg.qr(rng.normal(size=(p, k)))
        self._u = u.astype(np.float32)
        self._lam = np.linspace(10, 2, k).astype(np.float32)

    def batch_at(self, step: int, shard: int = 0, seed: int | None = None) -> np.ndarray:
        """Regenerate the (step, shard) batch on any worker — (batch, p) f32.

        ``seed`` overrides the constructed stream seed (StreamEngine forwards
        its run seed here); None keeps ``self.state.seed``.
        """
        rng = np.random.default_rng((self.state.seed if seed is None else seed, step, shard))
        kappa = rng.normal(size=(self.batch, self.k)).astype(np.float32)
        x = (kappa * self._lam) @ self._u.T
        x += 0.05 * rng.normal(size=(self.batch, self.p)).astype(np.float32)
        return x

    def next_batch(self) -> np.ndarray:
        x = self.batch_at(self.state.step)
        self.state.step += 1
        return x


class SketchingPipeline:
    """Wraps a vector source with the paper's one-pass compression.

    Emits SparseRows batches; every batch gets an independent mask key
    (fold of the spec key and the step) — the paper's per-sample R_i property.

    This is the minimal pull-based wrapper; the full streaming subsystem
    (donated accumulators, shard_map distribution, streaming K-means) is
    ``repro.stream.StreamEngine``, which consumes the same sources via their
    (seed, step, shard) ``batch_at`` contract.
    """

    def __init__(self, source: VectorStreamSource, spec: sketch_mod.SketchSpec):
        self.source = source
        self.spec = spec

    def next_batch(self):
        step = self.source.state.step
        x = self.source.next_batch()
        bk = jax.random.fold_in(self.spec.mask_key(), step)
        return sketch_mod.sketch(jnp.asarray(x), self.spec, batch_key=bk)

    @property
    def state(self) -> PipelineState:
        return self.source.state
