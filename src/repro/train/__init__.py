from repro.train import checkpoint, optimizer, sharding, trainer  # noqa: F401
