"""Fault-tolerant checkpointing: async save, manifest-driven restore, elastic re-mesh.

Layout (one directory per step):
    ckpt_dir/step_000123/
        manifest.json      — tree structure, shapes/dtypes, mesh, pipeline state
        arrays.npz         — flattened leaves keyed by tree path

Restore is *elastic*: arrays are loaded host-side and re-placed under any target
mesh/sharding (device counts may differ between save and restore — the ZeRO/TP
layout is recomputed from the sharding rules, not read from the snapshot).
A ``latest`` pointer file enables crash-restart without coordination; writes go
through a temp dir + atomic rename so a mid-write failure never corrupts the
latest checkpoint (the standard single-writer protocol; on a real cluster, each
host writes its addressable shards — the code path is the same modulo the
gather).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

_SAVE_LOCK = threading.Lock()
_PENDING: list[threading.Thread] = []


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_leaves_with_path(tree)
    return {jax.tree_util.keystr(k): np.asarray(v) for k, v in flat}


def save(ckpt_dir: str, step: int, state: Any, extra: dict | None = None,
         async_: bool = True, keep_last: int = 3):
    """Snapshot ``state`` (+ JSON-serializable ``extra`` e.g. pipeline cursors)."""

    # materialize on host BEFORE returning (state may be donated by the next step)
    arrays = _flatten(state)
    treedef = jax.tree_util.tree_structure(state)
    _save_arrays(ckpt_dir, step, arrays, extra, async_, keep_last,
                 treedef=str(treedef))


def save_arrays(ckpt_dir: str, step: int, arrays: dict[str, Any],
                extra: dict | None = None, async_: bool = False,
                keep_last: int = 3):
    """Snapshot a flat ``{name: array}`` dict under the same atomic-rename
    protocol as :func:`save` — for callers (``repro.sketchserve``) whose state
    has no fixed pytree template to ``restore`` against; pair with
    :func:`load_arrays`, which needs no ``like``."""
    _save_arrays(ckpt_dir, step, {k: np.asarray(v) for k, v in arrays.items()},
                 extra, async_, keep_last)


def _save_arrays(ckpt_dir: str, step: int, arrays: dict, extra: dict | None,
                 async_: bool, keep_last: int, treedef: str | None = None):
    meta = {
        "step": step,
        "treedef": treedef,
        "keys": list(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "extra": extra or {},
        "time": time.time(),
    }

    def _write():
        with _SAVE_LOCK:
            final = os.path.join(ckpt_dir, f"step_{step:09d}")
            tmp = final + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
                f.write(os.path.basename(final))
            os.replace(os.path.join(ckpt_dir, "latest.tmp"), os.path.join(ckpt_dir, "latest"))
            _gc(ckpt_dir, keep_last)

    os.makedirs(ckpt_dir, exist_ok=True)
    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        _PENDING.append(t)
    else:
        _write()


def wait_for_pending():
    for t in list(_PENDING):
        t.join()
        _PENDING.remove(t)


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step_dir(ckpt_dir: str) -> str | None:
    ptr = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        return os.path.join(ckpt_dir, f.read().strip())


def load_arrays(ckpt_dir: str) -> tuple[dict[str, np.ndarray], dict]:
    """Manifest-driven load of the latest snapshot as a flat ``{name: array}``
    dict + its ``extra`` — no template required (the :func:`save_arrays`
    counterpart). Raises FileNotFoundError if no checkpoint exists."""
    d = latest_step_dir(ckpt_dir)
    if d is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    with open(os.path.join(d, "manifest.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    return {k: data[k] for k in meta["keys"]}, meta.get("extra", {})


def restore(ckpt_dir: str, like: Any, shardings: Any | None = None) -> tuple[Any, dict]:
    """Load the latest checkpoint into the structure of ``like`` and (optionally)
    re-place under new ``shardings`` — this is the elastic-restart path.

    Returns (state, extra). Raises FileNotFoundError if no checkpoint exists.
    """
    d = latest_step_dir(ckpt_dir)
    if d is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    with open(os.path.join(d, "manifest.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    flat_like = jax.tree_util.tree_leaves_with_path(like)
    leaves = []
    for k, spec in flat_like:
        ks = jax.tree_util.keystr(k)
        if ks not in data:
            raise KeyError(f"checkpoint missing leaf {ks}")
        arr = data[ks]
        want_dt = np.dtype(jax.numpy.dtype(spec.dtype)) if hasattr(spec, "dtype") else arr.dtype
        leaves.append(arr.astype(want_dt, copy=False))
    tree = jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, meta.get("extra", {})
