"""Optimizers: AdamW (configurable moment dtypes) and Adafactor-style factored
second moments for HBM-tight trillion-param configs. Pure pytree transforms —
optimizer state inherits param shardings leaf-by-leaf (ZeRO for free under
FSDP param sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_global_norm


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"     # "bfloat16" halves optimizer HBM (kimi)
    factored: bool = False            # Adafactor-style factored v for ≥2D params
    momentum: bool = True             # False drops m entirely (Adafactor classic)


def lr_at(step: jax.Array, cfg: OptConfig) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio·peak."""
    warm = cfg.peak_lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def _v_init(p: jax.Array, cfg: OptConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    if cfg.factored and p.ndim >= 2:
        return {
            "row": jnp.zeros(p.shape[:-1], dt),
            "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], dt),
        }
    return jnp.zeros(p.shape, dt)


def init_opt_state(params: Any, cfg: OptConfig) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    state = {
        "v": jax.tree.map(lambda p: _v_init(p, cfg), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.momentum:
        state["m"] = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return state


def _v_update(v, g2, cfg: OptConfig):
    if isinstance(v, dict):  # factored
        row = cfg.b2 * v["row"].astype(jnp.float32) + (1 - cfg.b2) * jnp.mean(g2, axis=-1)
        col = cfg.b2 * v["col"].astype(jnp.float32) + (1 - cfg.b2) * jnp.mean(g2, axis=-2)
        dt = v["row"].dtype
        return {"row": row.astype(dt), "col": col.astype(dt)}
    return (cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g2).astype(v.dtype)


def _v_hat(v):
    if isinstance(v, dict):
        row = v["row"].astype(jnp.float32)
        col = v["col"].astype(jnp.float32)
        denom = jnp.maximum(jnp.mean(row, axis=-1, keepdims=True), 1e-30)
        return row[..., None] * col[..., None, :] / denom[..., None]
    return v.astype(jnp.float32)


# leaves above this size run their update as a lax.map over the leading axis —
# keeps f32 optimizer temporaries to one slice instead of the full stacked
# tensor (dry-run finding: whole-tree f32 chains on 1T-param expert stacks
# cost ~45 GB/device of temp; chunked they cost 1/n_layers of that)
BIG_LEAF_BYTES = 64 << 20


def adamw_update(grads: Any, params: Any, state: dict, cfg: OptConfig):
    """One AdamW step with global-norm clipping. Returns (new_params, new_state, stats).

    All per-leaf math happens in a SINGLE fused function (no whole-tree f32
    intermediates); large stacked leaves are processed slice-by-slice.
    """
    step = state["step"]
    gnorm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1c = 1 - cfg.b1 ** (step + 1).astype(jnp.float32)
    b2c = 1 - cfg.b2 ** (step + 1).astype(jnp.float32)
    lr = lr_at(step, cfg)

    def leaf_math(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        new_m = None
        if cfg.momentum:
            m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
            new_m = m32.astype(m.dtype)
            mhat = m32 / b1c
        else:
            mhat = g32
        new_v = _v_update(v, g32 * g32, cfg)
        vhat = _v_hat(new_v) / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # no decay on norms/scalars
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, new_m, new_v

    def _is_big(p):
        # layer-stacked tensors only (small leading axis): scanning the vocab
        # axis of an embedding would be thousands of tiny steps
        return p.size * 4 > BIG_LEAF_BYTES and p.ndim >= 2 and 1 < p.shape[0] <= 512

    is_f = lambda x: isinstance(x, dict) and "row" in x  # noqa: E731
    if cfg.momentum:
        def upd(p, g, m, v):
            if _is_big(p):
                return jax.lax.map(lambda a: leaf_math(a[0], a[1], a[2], a[3]), (p, g, m, v))
            return leaf_math(p, g, m, v)

        triples = jax.tree.map(upd, params, grads, state["m"], state["v"], is_leaf=is_f)
    else:
        def upd_nm(p, g, v):
            if _is_big(p):
                return jax.lax.map(lambda a: leaf_math(a[0], a[1], None, a[2]), (p, g, v))
            return leaf_math(p, g, None, v)

        triples = jax.tree.map(upd_nm, params, grads, state["v"], is_leaf=is_f)

    leaf_of = lambda x: isinstance(x, tuple) and len(x) == 3  # noqa: E731
    new_params = jax.tree.map(lambda t: t[0], triples, is_leaf=leaf_of)
    new_state = {"v": jax.tree.map(lambda t: t[2], triples, is_leaf=leaf_of), "step": step + 1}
    if cfg.momentum:
        new_state["m"] = jax.tree.map(lambda t: t[1], triples, is_leaf=leaf_of)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
