"""Name-based sharding rules: param path regex → PartitionSpec.

TP+FSDP by default: the ``model`` axis carries tensor/expert/vocab parallelism,
the data axes carry FSDP (ZeRO-3-style parameter sharding). SSM params are
FSDP-only (1–2 B-param models don't need TP; avoids unaligned splits of the
fused in_proj). A dim is only sharded when divisible by the axis size —
otherwise the rule falls back to replication on that dim (logged by the
dry-run as a "sharding fallback").
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.utils.tree import tree_map_with_path_names

# (regex over 'path/to/leaf', spec builder) — first match wins.
# fsdp = data axes tuple, tp = 'model'.
RULES: list[tuple[str, Any]] = [
    (r"embed$", lambda fsdp, tp: P(tp, fsdp)),
    (r"lm_head$", lambda fsdp, tp: P(fsdp, tp)),
    (r"attn/wq$|attn/wk$|attn/wv$|xattn/wq$|xattn/wk$|xattn/wv$", lambda fsdp, tp: P(fsdp, tp)),
    (r"attn/wo$|xattn/wo$", lambda fsdp, tp: P(tp, fsdp)),
    (r"mlp/gate$|mlp/up$|shared/gate$|shared/up$", lambda fsdp, tp: P(fsdp, tp)),
    (r"mlp/down$|shared/down$", lambda fsdp, tp: P(tp, fsdp)),
    (r"moe/router$", lambda fsdp, tp: P(fsdp, None)),
    (r"moe/w_gate$|moe/w_up$", lambda fsdp, tp: P(tp, fsdp, None)),
    (r"moe/w_down$", lambda fsdp, tp: P(tp, None, fsdp)),
    (r"mamba/in_proj$", lambda fsdp, tp: P(fsdp, None)),
    (r"mamba/out_proj$", lambda fsdp, tp: P(tp, fsdp)),
    (r"mamba/conv_w$|mamba/conv_b$", lambda fsdp, tp: P()),
    (r".*", lambda fsdp, tp: P()),          # norms, scalars, biases → replicated
]


def _fits(dim: int | None, axes, mesh: Mesh) -> bool:
    if dim is None or axes is None:
        return True
    size = int(np.prod([mesh.shape[a] for a in (axes if isinstance(axes, tuple) else (axes,))]))
    return dim % size == 0


def spec_for(name: str, shape: tuple[int, ...], mesh: Mesh, scanned: bool,
             dp_only: bool = False) -> P:
    """Resolve the sharding spec for one param; scanned params get a leading
    (replicated) layer dim prepended. ``dp_only`` folds the model axis into
    FSDP (no tensor parallelism) — the right strategy for small-dense cells
    where TP collectives dominate (§Perf)."""
    if dp_only:
        fsdp = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
        tp = None
    else:
        fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        tp = "model" if "model" in mesh.axis_names else None
    body_shape = shape[1:] if scanned else shape
    for pat, builder in RULES:
        if re.search(pat, name):
            spec = builder(fsdp, tp)
            parts = list(spec)
            # pad/trim to rank, drop axes that don't divide the dim
            parts = (parts + [None] * len(body_shape))[: len(body_shape)]
            parts = [p if _fits(body_shape[i], p, mesh) else None for i, p in enumerate(parts)]
            if scanned:
                parts = [None] + parts
            return P(*parts)
    raise AssertionError("unreachable — catch-all rule")


def param_shardings(param_specs: Any, mesh: Mesh, dp_only: bool = False) -> Any:
    """NamedShardings for a param pytree (from jax.eval_shape or real arrays).

    Params under 'layers/' are stacked (scanned) — detected by name prefix.
    """

    def f(name, leaf):
        scanned = name.startswith(("layers/", "enc_layers/", "dec_layers/"))
        spec = spec_for(name, tuple(leaf.shape), mesh, scanned, dp_only)
        return NamedSharding(mesh, spec)

    return tree_map_with_path_names(f, param_specs)


def batch_shardings(batch_specs: Any, mesh: Mesh, dp_only: bool = False) -> Any:
    """Batch dims sharded over the data axes; everything else replicated.

    positions (3, B, S) put B on axis 1; scalars replicated.
    """
    axes = ("pod", "data", "model") if dp_only else ("pod", "data")
    fsdp = tuple(a for a in axes if a in mesh.axis_names)

    def f(name, leaf):
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return NamedSharding(mesh, P())
        if name.endswith("positions"):
            return NamedSharding(mesh, P(None, fsdp, *([None] * (len(leaf.shape) - 2))))
        if leaf.shape[0] % int(np.prod([mesh.shape[a] for a in fsdp])) == 0:
            return NamedSharding(mesh, P(fsdp, *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P())

    return tree_map_with_path_names(f, batch_specs)


def cache_shardings(cache_specs: Any, mesh: Mesh, seq_axis_to_model: bool = True) -> Any:
    """Decode caches: (L, B, S, kv, hd) → batch over data axes; sequence over
    ``model`` (SP decode — lets 500k caches fit; attention reduces over shards).
    SSM states (L, B, H, N, P): heads over model when divisible."""
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = int(np.prod([mesh.shape[a] for a in fsdp]))
    n_tp = mesh.shape.get("model", 1)

    def f(name, leaf):
        sh = leaf.shape
        if len(sh) == 5 and name.split("/")[-1] in ("k", "v", "xk", "xv", "pre_k", "pre_v"):
            b_ok = sh[1] % n_dp == 0
            s_ok = seq_axis_to_model and sh[2] % n_tp == 0
            return NamedSharding(mesh, P(None, fsdp if b_ok else None,
                                         "model" if s_ok else None, None, None))
        if len(sh) == 5 and name.endswith("ssm"):
            b_ok = sh[1] % n_dp == 0
            h_ok = sh[2] % n_tp == 0
            return NamedSharding(mesh, P(None, fsdp if b_ok else None,
                                         "model" if h_ok else None, None, None))
        if len(sh) == 4 and name.endswith("conv"):
            b_ok = sh[1] % n_dp == 0
            c_ok = sh[3] % n_tp == 0
            return NamedSharding(mesh, P(None, fsdp if b_ok else None, None,
                                         "model" if c_ok else None))
        if len(sh) >= 1 and sh[0] % n_dp == 0:
            return NamedSharding(mesh, P(fsdp, *([None] * (len(sh) - 1))))
        return NamedSharding(mesh, P())

    return tree_map_with_path_names(f, cache_specs)
