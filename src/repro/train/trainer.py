"""Distributed trainer: pjit train/serve steps, sharded state, AOT lowering.

Everything the launcher and the dry-run share lives here:
  - make_dist(mesh, cfg):       distribution context (TP/FSDP/EP/SP knobs)
  - build_state_specs(...):     abstract state pytree + NamedShardings
  - make_train_step(...):       jitted (state, batch) → (state, metrics)
  - lower_cell(...):            AOT .lower() for any (arch × shape × mesh) cell
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.grad_compress import CompressConfig, compress_grads
from repro.launch.mesh import dp_axes_of, tp_axis_of
from repro.models.api import ModelAPI, get_api, input_specs
from repro.models.transformer import NO_DIST, Dist
from repro.train import optimizer as opt_mod
from repro.train import sharding as shard_mod
from repro.utils.prng import fold_in_str


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    opt: opt_mod.OptConfig = opt_mod.OptConfig()
    accum_steps: int = 1
    compress: CompressConfig | None = None
    q_chunk: int = 512
    kv_chunk: int = 1024
    sp: bool = False
    use_ep: bool = True
    donate: bool = True
    dp_only: bool = False        # fold the model axis into FSDP/batch (no TP)


def make_dist(mesh, cfg: ModelConfig, sp: bool = False, use_ep: bool = True,
              dp_only: bool = False) -> Dist:
    if mesh is None:
        return NO_DIST
    if dp_only:
        return Dist(mesh=mesh, dp_axes=tuple(mesh.axis_names), tp_axis=None,
                    head_axis=None, kv_head_axis=None, use_ep=False, sp=False)
    dp = dp_axes_of(mesh)
    tp = tp_axis_of(mesh)
    n_tp = mesh.shape.get("model", 1)
    # uneven head sharding (GSPMD pads, e.g. 56 heads → 4/4/…/3) beats
    # replicating attention across the model axis (dry-run: 114 GB → fits)
    head_ok = bool(cfg.n_heads) and cfg.n_heads >= n_tp
    kv_ok = bool(cfg.n_kv_heads) and cfg.n_kv_heads >= n_tp
    return Dist(
        mesh=mesh, dp_axes=dp, tp_axis=tp,
        head_axis=tp if head_ok else None,
        kv_head_axis=tp if kv_ok else None,
        use_ep=use_ep, sp=sp,
    )


# ------------------------------------------------------------ state specs ---

def abstract_params(api: ModelAPI):
    return jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0)))


def abstract_state(api: ModelAPI, tcfg: TrainerConfig):
    params = abstract_params(api)
    opt = jax.eval_shape(lambda: opt_mod.init_opt_state(params, tcfg.opt))
    state = {"params": params, "opt": opt}
    if tcfg.compress is not None and tcfg.compress.error_feedback:
        state["residual"] = jax.eval_shape(
            lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        )
    return state


def state_shardings(state_specs: Any, mesh, dp_only: bool = False) -> Any:
    """Param shardings extend leaf-wise to optimizer moments & residuals."""
    p_shard = shard_mod.param_shardings(state_specs["params"], mesh, dp_only)

    def like_params(tree):
        flat_p = jax.tree_util.tree_leaves_with_path(state_specs["params"])
        shapes = {jax.tree_util.keystr(k): tuple(v.shape) for k, v in flat_p}
        shard_by_key = {
            jax.tree_util.keystr(k): s
            for (k, _), s in zip(flat_p, jax.tree_util.tree_leaves(p_shard))
        }

        def f(path, leaf):
            ks = jax.tree_util.keystr(path)
            if ks in shapes and shapes[ks] == tuple(leaf.shape):
                return shard_by_key[ks]
            return NamedSharding(mesh, P())

        return jax.tree_util.tree_map_with_path(f, tree)

    def greedy(leaf):
        """Factored-moment leaves: shard the first model-divisible dim over TP
        and the next fsdp-divisible dim over the data axes."""
        fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        n_tp = mesh.shape.get("model", 1)
        n_dp = int(np.prod([mesh.shape[a] for a in fsdp]))
        parts = [None] * len(leaf.shape)
        for i, d in enumerate(leaf.shape):
            if d % n_tp == 0 and d > 1:
                parts[i] = "model"
                break
        for i, d in enumerate(leaf.shape):
            if parts[i] is None and d % n_dp == 0 and d > 1:
                parts[i] = fsdp
                break
        return NamedSharding(mesh, P(*parts))

    opt_sh = {
        "v": jax.tree_util.tree_map(greedy, state_specs["opt"]["v"])
        if _has_factored(state_specs["opt"]["v"]) else like_params(state_specs["opt"]["v"]),
        "step": NamedSharding(mesh, P()),
    }
    if "m" in state_specs["opt"]:
        opt_sh["m"] = like_params(state_specs["opt"]["m"])
    out = {"params": p_shard, "opt": opt_sh}
    if "residual" in state_specs:
        out["residual"] = like_params(state_specs["residual"])
    return out


def _has_factored(v_tree) -> bool:
    return any(isinstance(x, dict) and "row" in x
               for x in jax.tree_util.tree_leaves(v_tree, is_leaf=lambda y: isinstance(y, dict)))


def init_state(api: ModelAPI, tcfg: TrainerConfig, key) -> dict:
    params = api.init_params(key)
    state = {"params": params, "opt": opt_mod.init_opt_state(params, tcfg.opt)}
    if tcfg.compress is not None and tcfg.compress.error_feedback:
        state["residual"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


# -------------------------------------------------------------- train step --

def make_train_fn(api: ModelAPI, tcfg: TrainerConfig, dist: Dist, key):
    """The pure (state, batch) → (state, metrics) function (before jit)."""
    gc_key = fold_in_str(key, "grad-compress")

    def loss_fn(params, batch):
        loss, metrics = api.loss_fn(params, batch, dist, q_chunk=tcfg.q_chunk,
                                    kv_chunk=tcfg.kv_chunk)
        return loss, metrics

    def train_step(state, batch):
        if tcfg.accum_steps > 1:
            def micro(carry, mb):
                acc_g, acc_l = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(state["params"], mb)
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), None

            mb_batch = jax.tree.map(
                lambda x: x.reshape((tcfg.accum_steps, x.shape[0] // tcfg.accum_steps) + x.shape[1:])
                if hasattr(x, "shape") and x.ndim >= 1 else x,
                batch,
            )
            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (grads, loss), _ = jax.lax.scan(micro, (zero_g, 0.0), mb_batch)
            grads = jax.tree.map(lambda g: g / tcfg.accum_steps, grads)
            loss = loss / tcfg.accum_steps
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch)

        new_state = dict(state)
        stats = {}
        if tcfg.compress is not None:
            grads, new_res, wire = compress_grads(
                grads, gc_key, state["opt"]["step"], tcfg.compress,
                residual=state.get("residual"),
            )
            if new_res is not None:
                new_state["residual"] = new_res
            stats["wire_floats"] = jnp.float32(wire)
        new_params, new_opt, opt_stats = opt_mod.adamw_update(
            grads, state["params"], state["opt"], tcfg.opt)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        return new_state, {"loss": loss, **stats, **opt_stats, **{k: v for k, v in metrics.items()}}

    return train_step


# ----------------------------------------------------------- AOT lowering ---

def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, tcfg: TrainerConfig | None = None,
               key=None):
    """AOT-lower the right step for one (arch × shape × mesh) cell.

    train  → train_step(state, batch)
    prefill→ prefill_fn(params, batch)
    decode → decode_fn(params, token, cache, cur_len)
    Returns (lowered, meta dict).
    """
    tcfg = tcfg or TrainerConfig()
    key = key if key is not None else jax.random.PRNGKey(0)
    api = get_api(cfg)
    dist = make_dist(mesh, cfg, sp=tcfg.sp, use_ep=tcfg.use_ep, dp_only=tcfg.dp_only)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        state_specs = abstract_state(api, tcfg)
        st_sh = state_shardings(state_specs, mesh, tcfg.dp_only)
        b_sh = shard_mod.batch_shardings(specs["batch"], mesh, tcfg.dp_only)
        fn = make_train_fn(api, tcfg, dist, key)
        jfn = jax.jit(
            fn,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, NamedSharding(mesh, P())),
            donate_argnums=(0,) if tcfg.donate else (),
        )
        lowered = jfn.lower(state_specs, specs["batch"])
        return lowered, {"kind": "train"}

    params_specs = abstract_params(api)
    p_sh = shard_mod.param_shardings(params_specs, mesh, tcfg.dp_only)

    if shape.kind == "prefill":
        b_sh = shard_mod.batch_shardings(specs["batch"], mesh, tcfg.dp_only)

        def prefill_step(params, batch):
            return api.prefill_fn(params, batch, dist, q_chunk=tcfg.q_chunk,
                                  kv_chunk=tcfg.kv_chunk)

        # caches/states must come out sharded (batch→data, seq→model), else the
        # stacked (L,B,S,kv,hd) output replicates across the model axis
        out_spec = jax.eval_shape(prefill_step, params_specs, specs["batch"])
        fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

        def out_sharding_for(leaf):
            if leaf is None:
                return None
            sh = tuple(leaf.shape)
            if len(sh) == 2:   # last-token logits (B, V)
                ok_b = sh[0] % int(np.prod([mesh.shape[a] for a in fsdp])) == 0
                return NamedSharding(mesh, P(fsdp if ok_b else None, None))
            return None        # placeholder; 5D/4D handled below by cache rules

        logits_sh = jax.tree.map(out_sharding_for, out_spec[0]) if out_spec[0] is not None else None
        cache_sh = shard_mod.cache_shardings(out_spec[1], mesh) if out_spec[1] is not None else None
        jfn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                      out_shardings=(logits_sh, cache_sh))
        lowered = jfn.lower(params_specs, specs["batch"])
        return lowered, {"kind": "prefill"}

    # decode: one token against a seq_len cache
    cache_specs = specs["cache"]
    c_sh = shard_mod.cache_shardings(cache_specs, mesh)
    tok_sh = shard_mod.batch_shardings(specs["token"], mesh)

    def serve_step(params, token, cache, cur_len):
        return api.decode_fn(params, token, cache, cur_len, dist)

    jfn = jax.jit(
        serve_step,
        in_shardings=(p_sh, tok_sh, c_sh, NamedSharding(mesh, P())),
        donate_argnums=(2,) if tcfg.donate else (),
    )
    lowered = jfn.lower(params_specs, specs["token"], cache_specs, specs["cur_len"])
    return lowered, {"kind": "decode"}
