"""Unified repro.api estimator layer: backend equivalence (batch == stream ==
sharded at 1e-5 for mean/cov/PCA/K-means), the fit/partial_fit/finalize
contract, DCT end-to-end, spec validation, compact-path covariance, and the
one-PRNG-story gradient compressor."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy.optimize import linear_sum_assignment

from repro.api import (
    GradCompressor,
    Plan,
    SparsifiedCov,
    SparsifiedKMeans,
    SparsifiedMean,
    SparsifiedPCA,
    fit_many,
    make_engine,
)
from repro.core import sketch
from repro.core.grad_compress import CompressConfig, mask_spec
from repro.core.sampling import sample_indices
from repro.core.sketch import batch_key
from tests.conftest import make_clusters

KEY = jax.random.PRNGKey(0)
BACKENDS = ("batch", "stream", "sharded")


def _plan(**kw):
    kw.setdefault("backend", "batch")
    kw.setdefault("gamma", 0.25)
    kw.setdefault("batch_size", 200)
    return Plan(**kw)


def _lowrank(n=1200, p=64, k=4):
    """Well-separated spectrum so eigenvectors are stable across reorderings."""
    u, _ = jnp.linalg.qr(jax.random.normal(KEY, (p, k)))
    lam = jnp.asarray([9.0, 6.0, 4.0, 2.5])
    z = jax.random.normal(jax.random.fold_in(KEY, 1), (n, k)) * lam
    return z @ u.T + 0.05 * jax.random.normal(jax.random.fold_in(KEY, 2), (n, p))


# ------------------------------------------------- backend equivalence ------


@pytest.mark.parametrize("backend", ("stream", "sharded"))
def test_mean_cov_backends_match_batch(backend):
    """The acceptance bar: flipping Plan.backend re-runs the same job to 1e-5
    (same per-(step, shard) sketches, different fold order)."""
    x = jax.random.normal(KEY, (1000, 64))
    ref = SparsifiedCov(_plan(), key=7).fit(x)
    alt = SparsifiedCov(_plan(backend=backend), key=7).fit(x)
    np.testing.assert_allclose(np.asarray(alt.mean_), np.asarray(ref.mean_),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(alt.cov_), np.asarray(ref.cov_),
                               rtol=1e-4, atol=1e-5)
    assert alt.count_ == ref.count_ == 1000

    m_ref = SparsifiedMean(_plan(), key=7).fit(x)
    m_alt = SparsifiedMean(_plan(backend=backend), key=7).fit(x)
    np.testing.assert_allclose(np.asarray(m_alt.mean_), np.asarray(m_ref.mean_),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ("stream", "sharded"))
def test_pca_backends_match_batch(backend):
    x = _lowrank()
    ref = SparsifiedPCA(4, _plan(), key=5).fit(x)
    alt = SparsifiedPCA(4, _plan(backend=backend), key=5).fit(x)
    np.testing.assert_allclose(np.asarray(alt.explained_variance_),
                               np.asarray(ref.explained_variance_), rtol=1e-5)
    # eigenvectors are sign-ambiguous: align, then compare
    signs = np.sign(np.sum(np.asarray(alt.components_) * np.asarray(ref.components_),
                           axis=1, keepdims=True))
    np.testing.assert_allclose(np.asarray(alt.components_) * signs,
                               np.asarray(ref.components_), atol=1e-5)


@pytest.mark.parametrize("backend", ("stream", "sharded"))
@pytest.mark.parametrize("algorithm", ("lloyd", "minibatch"))
def test_kmeans_backends_match_batch(backend, algorithm):
    """Hungarian-aligned centers and the objective agree across backends."""
    x, labels, _ = make_clusters(KEY, n=1000, p=64, k=4)
    ref = SparsifiedKMeans(4, _plan(), key=9, algorithm=algorithm).fit(x)
    alt = SparsifiedKMeans(4, _plan(backend=backend), key=9, algorithm=algorithm).fit(x)
    np.testing.assert_allclose(float(alt.objective_), float(ref.objective_), rtol=1e-5)
    d = np.linalg.norm(np.asarray(alt.centers_)[:, None]
                       - np.asarray(ref.centers_)[None], axis=-1)
    ri, ci = linear_sum_assignment(d)
    assert float(d[ri, ci].max()) < 1e-5 * (1 + float(np.abs(ref.centers_).max()))
    if algorithm == "lloyd":
        # assignments identical up to the same center permutation
        perm = np.empty(4, dtype=int)
        perm[ci] = ri
        assert np.array_equal(perm[np.asarray(alt.labels_)], np.asarray(ref.labels_))


def test_partial_fit_matches_fit():
    """Feeding the stream in batch_size pieces == one fit of the concatenation."""
    x = jax.random.normal(KEY, (600, 32))
    plan = _plan(backend="stream", batch_size=100)
    whole = SparsifiedCov(plan, key=3).fit(x)
    inc = SparsifiedCov(plan, key=3)
    for i in range(6):
        inc.partial_fit(x[i * 100:(i + 1) * 100])
    inc.finalize()
    np.testing.assert_array_equal(np.asarray(inc.cov_), np.asarray(whole.cov_))
    np.testing.assert_array_equal(np.asarray(inc.mean_), np.asarray(whole.mean_))


def test_fit_stream_consumes_pipeline_source():
    from repro.data.pipeline import VectorStreamSource

    src = VectorStreamSource(p=64, batch=128, seed=3)
    est = SparsifiedMean(_plan(backend="stream", batch_size=128), key=2)
    est.fit_stream(src, steps=3)
    assert est.count_ == 384 and est.mean_.shape == (64,)


# --------------------------------------------- fit_many: one shared sketch --


@pytest.mark.parametrize("backend", BACKENDS)
def test_fit_many_equals_separate_fits(backend):
    """The tentpole acceptance bar: ONE compression pass feeding every consumer
    reproduces the separate fits on every backend."""
    x, labels, _ = make_clusters(KEY, n=1000, p=64, k=4)
    plan = _plan(backend=backend)
    mean_c = SparsifiedMean(plan, key=7)
    cov_c = SparsifiedCov(plan, key=7)
    pca_c = SparsifiedPCA(4, plan, key=7)
    km_l = SparsifiedKMeans(4, plan, key=7)
    km_m = SparsifiedKMeans(4, plan, key=7, algorithm="minibatch")
    run = fit_many(plan, [mean_c, cov_c, pca_c, km_l, km_m], x)
    assert run.count == 1000 and run.n_sketches == 5 and len(run) == 5

    mean_s = SparsifiedMean(plan, key=7).fit(x)
    cov_s = SparsifiedCov(plan, key=7).fit(x)
    pca_s = SparsifiedPCA(4, plan, key=7).fit(x)
    km_ls = SparsifiedKMeans(4, plan, key=7).fit(x)
    km_ms = SparsifiedKMeans(4, plan, key=7, algorithm="minibatch").fit(x)

    np.testing.assert_allclose(np.asarray(mean_c.mean_), np.asarray(mean_s.mean_),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cov_c.cov_), np.asarray(cov_s.cov_),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(pca_c.components_),
                               np.asarray(pca_s.components_), atol=1e-5)
    np.testing.assert_allclose(np.asarray(pca_c.explained_variance_),
                               np.asarray(pca_s.explained_variance_), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(km_l.centers_), np.asarray(km_ls.centers_),
                               atol=1e-5)
    assert np.array_equal(np.asarray(km_l.labels_), np.asarray(km_ls.labels_))
    np.testing.assert_allclose(np.asarray(km_m.centers_), np.asarray(km_ms.centers_),
                               atol=1e-5)
    assert mean_c.count_ == cov_c.count_ == km_l.count_ == 1000


def test_fit_many_sketches_once_per_chunk(monkeypatch):
    """The whole point: sketch() runs once per (step, shard) chunk, NOT once
    per consumer per chunk."""
    calls = {"n": 0}
    real = sketch.sketch

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(sketch, "sketch", counting)
    x = jax.random.normal(KEY, (600, 64))
    plan = _plan()  # batch_size=200 → 3 chunks
    consumers = [SparsifiedPCA(4, plan, key=7), SparsifiedCov(plan, key=7),
                 SparsifiedKMeans(4, plan, key=7)]
    run = fit_many(plan, consumers, x)
    assert calls["n"] == 3 == run.n_sketches
    calls["n"] = 0
    SparsifiedPCA(4, plan, key=7).fit(x)
    SparsifiedCov(plan, key=7).fit(x)
    SparsifiedKMeans(4, plan, key=7).fit(x)
    assert calls["n"] == 9  # separate fits: one pass per consumer


def test_fit_many_from_source():
    """The (seed, step, shard) source contract through the shared pass."""
    from repro.data.pipeline import VectorStreamSource

    plan = _plan(backend="stream", batch_size=128)
    mean_c, cov_c = SparsifiedMean(plan, key=2), SparsifiedCov(plan, key=2)
    run = fit_many(plan, [mean_c, cov_c],
                   source=VectorStreamSource(p=64, batch=128, seed=3), steps=3)
    assert run.count == 384
    ref = SparsifiedMean(plan, key=2).fit_stream(
        VectorStreamSource(p=64, batch=128, seed=3), steps=3)
    np.testing.assert_array_equal(np.asarray(mean_c.mean_), np.asarray(ref.mean_))
    assert cov_c.cov_.shape == (64, 64)


def test_fit_many_continued_ingest():
    """finalize=False + run.partial_fit extends the SHARED pass for everyone."""
    x = jax.random.normal(KEY, (400, 32))
    plan = _plan(backend="stream", batch_size=100)
    mean_c, cov_c = SparsifiedMean(plan, key=3), SparsifiedCov(plan, key=3)
    run = fit_many(plan, [mean_c, cov_c], x[:200], finalize=False)
    run.partial_fit(x[200:]).finalize()
    whole = SparsifiedCov(plan, key=3).fit(x)
    np.testing.assert_array_equal(np.asarray(cov_c.cov_), np.asarray(whole.cov_))
    np.testing.assert_array_equal(np.asarray(mean_c.mean_), np.asarray(whole.mean_))
    assert mean_c.count_ == 400


def test_reset_detaches_from_shared_cursor():
    """reset() must unregister from a live shared pass — the old run keeps
    feeding the OTHER consumers only, never the reset estimator."""
    x = jax.random.normal(KEY, (400, 32))
    plan = _plan(backend="stream", batch_size=100)
    mean_c, cov_c = SparsifiedMean(plan, key=3), SparsifiedCov(plan, key=3)
    run = fit_many(plan, [mean_c, cov_c], x[:200], finalize=False)
    mean_c.reset()
    run.partial_fit(x[200:])            # only cov_c still rides the shared pass
    assert mean_c.count_ == 0 and cov_c.count_ == 400
    run.finalize()                      # skips the detached mean_c, fits cov_c
    assert not mean_c._fitted and cov_c._fitted
    whole = SparsifiedCov(plan, key=3).fit(x)
    np.testing.assert_array_equal(np.asarray(cov_c.cov_), np.asarray(whole.cov_))
    # the reset estimator refits independently, untouched by the old run
    mean_c.fit(x[:100])
    assert mean_c.count_ == 100


def test_fit_many_validation():
    x = jnp.ones((8, 16))
    plan = _plan()
    with pytest.raises(ValueError, match="at least one"):
        fit_many(plan, [], x)
    with pytest.raises(ValueError, match="exactly one"):
        fit_many(plan, [SparsifiedMean(plan, key=0)])
    with pytest.raises(ValueError, match="exactly one"):
        fit_many(plan, [SparsifiedMean(plan, key=0)], x, source=lambda s, t, sh: x)
    with pytest.raises(ValueError, match="steps"):
        fit_many(plan, [SparsifiedMean(plan, key=0)], source=lambda s, t, sh: x)
    with pytest.raises(ValueError, match="same key"):
        fit_many(plan, [SparsifiedMean(plan, key=0), SparsifiedCov(plan, key=1)], x)
    with pytest.raises(ValueError, match="gamma"):
        fit_many(plan, [SparsifiedMean(_plan(gamma=0.5), key=0)], x)
    with pytest.raises(TypeError, match="SketchedEstimator"):
        fit_many(plan, [GradCompressor()], x)
    with pytest.raises(TypeError, match="SketchedEstimator"):
        fit_many(plan, [np.ones((4, 4))], x)  # key-less object in position 0


def test_sharded_moments_stream_constant_memory():
    """The sharded moment path is per-step psum streaming now — nothing is
    retained past its step (the old concat()-then-reduce kept everything)."""
    x = jax.random.normal(KEY, (1000, 64))
    est = SparsifiedCov(_plan(backend="sharded"), key=7).fit(x)
    assert est._reducer.parts == [] and est._reducer._step_parts == []
    assert int(est._reducer.state.count) == 1000
    # … while Lloyd K-means still retains the sketch it clusters (Alg. 1)
    km = SparsifiedKMeans(3, _plan(backend="sharded"), key=7).fit(x)
    assert len(km._reducer.parts) == 5


# -------------------------------------- satellite: minibatch tail flush -----


def test_minibatch_tail_flush_and_interleaved_finalize():
    """Row counts that are no multiple of batch_size·n_shards leave a pending
    half step; finalize() flushes it and acts as a checkpoint that
    partial_fit can continue from."""
    x, _, _ = make_clusters(KEY, n=1100, p=32, k=3)
    plan = _plan(backend="stream", batch_size=100, n_shards=2)
    est = SparsifiedKMeans(3, plan, key=5, algorithm="minibatch")
    est.partial_fit(x[:500])            # 5 chunks = 2 full steps + 1 pending shard
    assert est._km_pending is not None
    est.finalize()
    assert est._km_pending is None and est.count_ == 500
    c1 = np.asarray(est.centers_)
    assert np.isfinite(c1).all()
    est.partial_fit(x[500:])            # 6 more chunks, ends on a half step again
    est.finalize()
    assert est.count_ == 1100 and est.centers_.shape == (3, 32)
    assert np.isfinite(np.asarray(est.centers_)).all()
    assert not np.allclose(np.asarray(est.centers_), c1)  # the tail data counted


def test_minibatch_ragged_tail_with_decay():
    """Ragged tails × decay < 1 (the forgetting factor): pending half steps
    flush correctly under float counts, the per-step reassignment history has
    one entry per APPLIED step at every partial_fit/finalize checkpoint, and
    the decayed counts stay positive and bounded by b·n_shards/(1−decay)."""
    decay = 0.8
    x, _, _ = make_clusters(KEY, n=1030, p=16, k=3)
    plan = _plan(backend="stream", batch_size=100, n_shards=2)
    est = SparsifiedKMeans(3, plan, key=5, algorithm="minibatch", decay=decay)

    est.partial_fit(x[:330])            # 4 chunks: 2 applied steps incl. tail30
    est.finalize()                      #   → the pending (step 1, shard 1) flushes
    assert est.count_ == 330
    assert est.reassign_counts_ is not None and len(est.reassign_counts_) == 2
    counts = np.asarray(est._km_state.counts)
    assert counts.dtype == np.float32   # decay ⇒ float counts
    assert (counts >= 0).all() and counts.sum() > 0
    bound = 100 * 2 / (1 - decay)       # decay bounds any cell's count
    assert counts.max() <= bound + 1e-3

    est.partial_fit(x[330:])            # 7 more chunks, ends on a half step
    est.finalize()
    assert est.count_ == 1030
    # 11 chunks / 2 shards → 6 applied steps total (finalize flushed the tail)
    assert len(est.reassign_counts_) == 6
    assert (np.asarray(est.reassign_counts_) >= 0).all()
    assert est.reassign_fraction_.shape == (6,)
    assert np.all(est.reassign_fraction_ <= 1.0)
    counts = np.asarray(est._km_state.counts)
    assert (counts >= 0).all() and counts.max() <= bound + 1e-3
    assert np.isfinite(np.asarray(est.centers_)).all()


def test_minibatch_zero_row_batch_is_noop():
    x, _, _ = make_clusters(KEY, n=300, p=32, k=3)
    plan = _plan(backend="stream", batch_size=100)
    est = SparsifiedKMeans(3, plan, key=5, algorithm="minibatch")
    est.partial_fit(x)
    st = est._km_state
    est.partial_fit(jnp.zeros((0, 32)))  # zero-row batch: nothing folds
    assert est._km_state is st and est.count_ == 300
    est.finalize()
    assert est.count_ == 300
    # zero rows as the ONLY input: spec exists but there is nothing to finalize
    est2 = SparsifiedKMeans(3, plan, key=5, algorithm="minibatch")
    est2.partial_fit(jnp.zeros((0, 32)))
    with pytest.raises(RuntimeError, match="no batches"):
        est2.finalize()


# ------------------------------------------ satellite: sketch() utility -----


def test_sketch_on_unfitted_does_not_pin():
    """sketch() is a read-only utility: on a fresh estimator it derives a
    throwaway spec — no p pinning, no reducer allocation."""
    est = SparsifiedMean(_plan(), key=0)
    s = est.sketch(jnp.ones((4, 64)))
    assert s.n == 4
    assert est.spec_ is None and est._reducer is None
    est.partial_fit(jnp.ones((8, 32)))  # a different p still fits fine
    assert est.spec_.p == 32


def test_sketch_mask_key_per_call():
    """Repeated sketch() calls reuse the spec's one-shot mask (documented);
    mask_key= draws an independent mask per call."""
    est = SparsifiedMean(_plan(), key=0).fit(jax.random.normal(KEY, (64, 64)))
    x = jnp.ones((16, 64))
    s1, s2 = est.sketch(x), est.sketch(x)
    np.testing.assert_array_equal(np.asarray(s1.indices), np.asarray(s2.indices))
    s3 = est.sketch(x, mask_key=1)
    assert not np.array_equal(np.asarray(s3.indices), np.asarray(s1.indices))
    np.testing.assert_array_equal(
        np.asarray(est.sketch(x, mask_key=1).indices), np.asarray(s3.indices))


# ------------------------------------------------------ satellite: DCT ------


def test_dct_pca_end_to_end():
    """transform="dct" (no padding, η=0.5) through the full PCA path."""
    x = _lowrank(p=60)  # non-power-of-two: DCT needs no padding
    plan = _plan(transform="dct", gamma=0.3)
    est = SparsifiedPCA(4, plan, key=11).fit(x)
    assert est.components_.shape == (4, 60)
    from repro.core import pca

    ev = float(pca.explained_variance(est.components_, x))
    ev_dense = float(pca.explained_variance(pca.pca(x, 4).components, x))
    assert ev > 0.9 * ev_dense, (ev, ev_dense)
    # stream backend reproduces it
    est_s = SparsifiedPCA(4, plan.replace(backend="stream"), key=11).fit(x)
    signs = np.sign(np.sum(np.asarray(est_s.components_) * np.asarray(est.components_),
                           axis=1, keepdims=True))
    np.testing.assert_allclose(np.asarray(est_s.components_) * signs,
                               np.asarray(est.components_), atol=1e-5)


def test_dct_kmeans_end_to_end():
    x, labels, _ = make_clusters(KEY, n=900, p=48, k=3)
    from repro.core import kmeans as km

    est = SparsifiedKMeans(3, _plan(transform="dct", gamma=0.4), key=13).fit(x)
    acc = km.clustering_accuracy(est.labels_, labels, 3)
    assert acc > 0.95, acc
    # predict on fresh rows from the same clusters stays consistent
    pred = est.predict(x[:200])
    assert float(np.mean(np.asarray(pred) == np.asarray(est.labels_[:200]))) > 0.95


# ------------------------------------------- satellite: spec validation -----


def test_make_spec_validates_gamma_and_clamps_m():
    with pytest.raises(ValueError, match="gamma"):
        sketch.make_spec(64, KEY, gamma=1.5)
    with pytest.raises(ValueError, match="gamma"):
        sketch.make_spec(64, KEY, gamma=0.0)
    with pytest.raises(ValueError, match="m must be"):
        sketch.make_spec(64, KEY, m=65)
    with pytest.raises(ValueError, match="m must be"):
        sketch.make_spec(64, KEY, m=0)
    # gamma=1 rounds to exactly p_pad and stays a valid sampler
    spec = sketch.make_spec(60, KEY, gamma=1.0)
    assert spec.m == spec.p_pad == 64
    assert sketch.make_spec(64, KEY, gamma=1e-9).m == 1


def test_gamma_unified_and_compression_ratio_at_padded_p():
    """γ is canonically m / p_pad; storage ratio is against the ORIGINAL p."""
    spec = sketch.make_spec(1000, KEY, gamma=0.25)       # p_pad = 1024
    assert spec.p_pad == 1024 and spec.m == 256
    assert spec.gamma == 256 / 1024
    assert sketch.compression_ratio(spec) == pytest.approx(256 * 8 / 4000)
    # sketched rows live in the padded domain, where both definitions agree
    s = sketch.sketch(jnp.ones((4, 1000)), spec)
    assert s.p == spec.p_pad
    with pytest.warns(DeprecationWarning, match="p_pad"):
        assert s.gamma == spec.gamma


# -------------------------------------- satellite: compact-path cov ---------


@pytest.mark.parametrize("backend", BACKENDS)
def test_compact_cov_path_matches_dense(backend):
    """cov_path="compact" (no dense (b, p) intermediate) == "dense" on every
    backend — the γ ≪ 1 streaming memory fix behind MomentState."""
    x = jax.random.normal(KEY, (500, 64))
    dense = SparsifiedCov(_plan(backend=backend, gamma=0.1), key=4).fit(x)
    compact = SparsifiedCov(_plan(backend=backend, gamma=0.1, cov_path="compact"),
                            key=4).fit(x)
    np.testing.assert_allclose(np.asarray(compact.cov_), np.asarray(dense.cov_),
                               rtol=1e-4, atol=1e-4)


def test_engine_compact_cov_path():
    """The same fix through the StreamEngine plumbing (api.make_engine)."""
    x = jax.random.normal(KEY, (4, 1, 50, 64))

    def source(seed, step, shard):
        return np.asarray(x[step, shard])

    plan = Plan(backend="stream", gamma=0.1, batch_size=50)
    res_d = make_engine(plan, 64, jax.random.PRNGKey(2), source).run(4)
    res_c = make_engine(plan.replace(cov_path="compact"), 64,
                        jax.random.PRNGKey(2), source).run(4)
    np.testing.assert_allclose(np.asarray(res_c.cov), np.asarray(res_d.cov),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------- cov original domain ------


def test_cov_original_domain_roundtrip():
    """(HD)ᵀ Ĉ_pre (HD) lands near the dense empirical second moment."""
    x = _lowrank(n=4000, p=32)
    est = SparsifiedCov(_plan(gamma=0.5, batch_size=1000), key=6).fit(x)
    c = est.cov_original()
    assert c.shape == (32, 32)
    from repro.core import estimators

    c_emp = np.asarray(estimators.empirical_cov(x))
    rel = np.linalg.norm(np.asarray(c) - c_emp, 2) / np.linalg.norm(c_emp, 2)
    assert rel < 0.15, rel


# ---------------------------------------------- grad compressor story -------


def test_grad_compressor_shares_batch_key_discipline():
    """The compressor's per-step mask IS sample_indices(batch_key(spec, step, 0))
    — one PRNG/bookkeeping story with the data sketch (ROADMAP open item)."""
    cfg = CompressConfig(gamma=0.25, chunk_p=256, error_feedback=False)
    key = jax.random.PRNGKey(5)
    vec = jax.random.normal(KEY, (1024,))
    from repro.core import ros
    from repro.core.grad_compress import compress_decompress

    g_hat, vals = compress_decompress(vec, key, jnp.int32(7), cfg)
    spec = mask_spec(cfg, key)
    idx = sample_indices(batch_key(spec, jnp.int32(7), 0), 4, 256, cfg.m)
    y = ros.precondition(vec.reshape(4, 256), spec.signs_key(), "hadamard")
    np.testing.assert_array_equal(np.asarray(vals),
                                  np.asarray(jnp.take_along_axis(y, idx, -1)))
    # unbiased round trip reconstructs the vector in expectation; here just
    # check the estimator's projection identity R Rᵀ y at kept coordinates
    assert g_hat.shape == vec.shape


def test_grad_compressor_stateful_front_door():
    g = {"a": jax.random.normal(KEY, (300,)), "b": jax.random.normal(KEY, (40, 10))}
    gc = GradCompressor(CompressConfig(gamma=0.1, chunk_p=256), key=3)
    g1 = gc.transform(g)
    assert gc.step_ == 1 and gc.residual_ is not None and gc.wire_floats_ > 0
    assert jax.tree.structure(g1) == jax.tree.structure(g)
    # error feedback: residual carries the un-sent mass
    vec = jnp.concatenate([g["a"], g["b"].reshape(-1)])
    v1 = jnp.concatenate([g1["a"], g1["b"].reshape(-1)])
    rvec = jnp.concatenate([gc.residual_["a"], gc.residual_["b"].reshape(-1)])
    np.testing.assert_allclose(np.asarray(v1 + rvec), np.asarray(vec), atol=1e-5)
    # deterministic per step: a reset compressor reproduces step 0 exactly
    g1b = GradCompressor(CompressConfig(gamma=0.1, chunk_p=256), key=3).transform(g)
    np.testing.assert_array_equal(np.asarray(g1["a"]), np.asarray(g1b["a"]))


# ----------------------------------------- pre-API entry points still work --


def test_preexisting_entry_points_import_and_run():
    """Every pre-API public entry point still imports and runs from its home
    (the distributed one-pass reductions live in repro.stream.sharded)."""
    from repro.core import estimators, kmeans as km_mod, pca as pca_mod
    from repro.stream import sharded as dist

    x = jax.random.normal(KEY, (64, 32))
    spec = sketch.make_spec(32, jax.random.PRNGKey(1), gamma=0.5)
    s = sketch.sketch(x, spec)
    mesh = jax.make_mesh((1,), ("data",))
    np.testing.assert_allclose(np.asarray(dist.sharded_mean(s, mesh)),
                               np.asarray(estimators.mean_estimator(s)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(dist.sharded_cov(s, mesh)),
                               np.asarray(estimators.cov_estimator(s)), atol=1e-4)
    mu, a, obj, it = dist.sharded_kmeans(s, 3, jax.random.PRNGKey(2), mesh,
                                         n_init=2, max_iter=10)
    assert mu.shape == (3, 32)
    # batch_key is importable from its historical home too
    from repro.stream import batch_key as bk

    assert bk is batch_key
    res = pca_mod.sparsified_pca(s, spec, 2)
    assert res.components.shape == (2, 32)


def test_plan_validation():
    with pytest.raises(ValueError, match="backend"):
        Plan(backend="nope", gamma=0.1)
    with pytest.raises(ValueError, match="cov_path"):
        Plan(gamma=0.1, cov_path="sparse")
    with pytest.raises(ValueError, match="n_shards"):
        Plan(gamma=0.1, n_shards=0)
    with pytest.raises(ValueError, match="m >= 2"):
        SparsifiedCov(Plan(m=1), key=0).fit(jnp.ones((8, 16)))
    with pytest.raises(ValueError, match="p="):
        est = SparsifiedMean(_plan(), key=0)
        est.partial_fit(jnp.ones((8, 16)))
        est.partial_fit(jnp.ones((8, 32)))
    with pytest.raises(RuntimeError, match="no batches"):
        SparsifiedMean(_plan(), key=0).finalize()
    # an out-of-range CompressConfig fails at spec construction, not in the sampler
    with pytest.raises(ValueError, match="m must be"):
        mask_spec(CompressConfig(gamma=1.5, chunk_p=1024), KEY)


# ----------------------------------------------- sharded, for real ----------


@pytest.mark.slow
def test_sharded_backend_matches_batch_on_8_devices():
    """The acceptance test at real multi-device scale: Plan(backend="sharded",
    n_shards=8) over 8 forced host devices == batch, to 1e-5 (subprocess so
    the session keeps the single real device). 1160 rows / batch 80 = 15 chunks
    — NOT a multiple of n_shards, so the sharded moment path's trailing
    partial step must be psum-flushed at reduce time (dropping it would shift
    the mean/cov visibly)."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src", JAX_PLATFORMS="cpu")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from scipy.optimize import linear_sum_assignment
        from repro.api import Plan, SparsifiedCov, SparsifiedKMeans

        x = jax.random.normal(jax.random.PRNGKey(0), (1160, 64))
        plan = Plan(backend="batch", gamma=0.25, batch_size=80, n_shards=8)
        assert SparsifiedCov(plan.replace(backend="sharded"), key=7).fit(x).count_ == 1160
        ref = SparsifiedCov(plan, key=7).fit(x)
        alt = SparsifiedCov(plan.replace(backend="sharded"), key=7).fit(x)
        np.testing.assert_allclose(np.asarray(alt.mean_), np.asarray(ref.mean_), atol=1e-5)
        np.testing.assert_allclose(np.asarray(alt.cov_), np.asarray(ref.cov_), atol=1e-4)

        k1 = SparsifiedKMeans(4, plan, key=9).fit(x)
        k8 = SparsifiedKMeans(4, plan.replace(backend="sharded"), key=9).fit(x)
        np.testing.assert_allclose(float(k8.objective_), float(k1.objective_), rtol=1e-4)
        d = np.linalg.norm(np.asarray(k8.centers_)[:, None]
                           - np.asarray(k1.centers_)[None], axis=-1)
        ri, ci = linear_sum_assignment(d)
        assert float(d[ri, ci].max()) < 1e-4
        print("api-sharded-8dev OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"


# ------------------------------------------------- scanned ingest (scan=) ---


def test_fit_many_scan_matches_host_loop():
    """fit_many(scan=True) — the lax.scan hot loop — reproduces the host
    chunk loop on every scan-eligible consumer: stream moments, lowrank-range
    PCA, minibatch K-means (with the reassignment signal), including a ragged
    tail that the host loop picks up after the scanned full steps."""
    x = _lowrank(n=440, p=64)
    plan = _plan(backend="stream", batch_size=100, n_shards=2)
    plan_lr = plan.replace(cov_path="lowrank", rank=16)

    def consumers():
        return [SparsifiedMean(plan, key=1),
                SparsifiedPCA(3, plan_lr, key=1),
                SparsifiedKMeans(3, plan, key=1, algorithm="minibatch")]

    host = consumers()
    scanned = consumers()
    fit_many(plan, host, x)
    run = fit_many(plan, scanned, x, scan=True)

    # the scan consumed 2 full steps (400 rows); the 40-row tail host-folded
    assert run.cursor.chunk_rows == [100, 100, 100, 100, 40]
    assert run.count == 440 and run.n_sketches == 5
    for h, s in zip(host, scanned):
        assert h.count_ == s.count_ == 440
    np.testing.assert_allclose(np.asarray(scanned[0].mean_),
                               np.asarray(host[0].mean_), atol=1e-5)
    np.testing.assert_allclose(np.abs(np.asarray(scanned[1].components_)),
                               np.abs(np.asarray(host[1].components_)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(scanned[2].centers_),
                               np.asarray(host[2].centers_), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(scanned[2].reassign_counts_),
                                  np.asarray(host[2].reassign_counts_))


def test_fit_many_scan_extends_the_pass():
    """SharedSketchRun.partial_fit keeps scanning: two scanned feeds ≡ one
    host-loop fit of the concatenation (same chunks, same keys)."""
    x = _lowrank(n=800, p=64)
    plan = _plan(backend="stream", batch_size=100, n_shards=2)
    whole = SparsifiedMean(plan, key=1)
    fit_many(plan, [whole], x)
    piecewise = SparsifiedMean(plan, key=1)
    run = fit_many(plan, [piecewise], x[:400], finalize=False, scan=True)
    run.partial_fit(x[400:]).finalize()
    assert piecewise.count_ == 800
    np.testing.assert_allclose(np.asarray(piecewise.mean_),
                               np.asarray(whole.mean_), atol=1e-5)


def test_fit_many_scan_validation():
    """scan=True rejects consumers whose folds can't run inside lax.scan
    (retained sketches / shard_map reductions) and source-driven ingest."""
    x = _lowrank(n=400, p=64)
    plan = _plan(backend="stream", batch_size=100)
    with pytest.raises(ValueError, match="lax.scan"):
        fit_many(plan, [SparsifiedKMeans(3, plan, key=1)], x, scan=True)  # lloyd
    batch = _plan(backend="batch", batch_size=100)
    with pytest.raises(ValueError, match="lax.scan"):
        fit_many(batch, [SparsifiedCov(batch, key=1)], x, scan=True)
    with pytest.raises(ValueError, match="scan=True"):
        fit_many(plan, [SparsifiedMean(plan, key=1)],
                 source=lambda s, t, sh: x[:100], steps=2, seed=0, scan=True)
