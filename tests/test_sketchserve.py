"""The sketch-serving subsystem (repro.sketchserve): service lifecycle parity
with direct fits, shared-sketch groups, micro-batch coalescing, admission
control, lazy finalization, snapshot/restore bit-identity, the multi-worker
pool (per-group ordering, stop/submit races), the auto-snapshot policy,
tenant TTL/LRU eviction, the QueueSource stream adapter, and the SketchCursor
concurrent-producer contract."""
import queue
import threading
import time

import jax
import numpy as np
import pytest

from repro.api import (Plan, SparsifiedCov, SparsifiedKMeans, SparsifiedMean,
                       SparsifiedPCA, fit_many)
from repro.sketchserve import (AdminRequest, IngestRequest, QueryRequest,
                               SketchService, SnapshotPolicy, restore_service)
from repro.stream import QueueSource
from tests.conftest import make_clusters, spiked

KEY = jax.random.PRNGKey(0)
P = 32
BS = 64


def _plan(**kw):
    base = dict(backend="stream", gamma=0.5, batch_size=BS)
    base.update(kw)
    return Plan(**base)


def _x(n=256, p=P, seed=0):
    return np.asarray(spiked(jax.random.PRNGKey(seed), n, p, 3),
                      np.float32)


# ------------------------------------------------------------ fit parity ----


@pytest.mark.parametrize("kind,params,op,attr", [
    ("mean", {}, "mean", "mean_"),
    ("cov", {}, "cov", "cov_"),
    ("pca", {"n_components": 3}, "components", None),
    ("kmeans", {"k": 3}, "centers", "centers_"),
])
def test_served_tenant_matches_direct_fit(kind, params, op, attr):
    """Queue → coalesce → fold → lazy finalize ends bit-identical to the
    direct estimator fit: requests sized in batch_size multiples keep the
    chunk boundaries (hence (step, shard) mask keys) exactly fit(x)'s.
    scan='never' pins both sides to the same host fold loop."""
    x = _x(256)
    plan = _plan() if kind != "pca" else _plan(cov_path="lowrank", rank=12)
    from repro.sketchserve.service import ESTIMATORS

    direct = ESTIMATORS[kind](plan=plan, key=3, **params).fit(x)
    with SketchService(scan="never") as svc:
        svc.create_tenant("t", kind, plan=plan, key=3, **params)
        futs = [svc.ingest("t", x[i:i + 2 * BS]) for i in range(0, 256, 2 * BS)]
        assert all(f.result().ok for f in futs)
        got = svc.query("t", op).unwrap()
    if kind == "pca":
        np.testing.assert_array_equal(got["components"],
                                      np.asarray(direct.components_))
        np.testing.assert_array_equal(got["explained_variance"],
                                      np.asarray(direct.explained_variance_))
    else:
        np.testing.assert_array_equal(got, np.asarray(getattr(direct, attr)))


def test_group_shares_one_compression_pass():
    """Co-registered tenants ride ONE cursor: n_sketches counts chunks, not
    chunks × tenants, and both results equal the fit_many twins."""
    x = _x(256)
    plan = _plan(cov_path="lowrank", rank=12)
    with SketchService(scan="never") as svc:
        svc.create_tenant("p", "pca", plan=plan, key=7, n_components=3,
                          group="g")
        svc.create_tenant("k", "kmeans", plan=_plan(), key=7, k=3, group="g",
                          algorithm="minibatch")
        svc.ingest("g", x).result()
        st = svc.query("p", "stats").unwrap()
        assert st["n_sketches"] == st["chunks"] == 4      # 256 rows / bs=64
        comps = svc.query("p", "components").unwrap()["components"]
        centers = svc.query("k", "centers").unwrap()
    pca = SparsifiedPCA(3, plan, key=7)
    km = SparsifiedKMeans(3, _plan(), key=7, algorithm="minibatch")
    fit_many(plan, [pca, km], x)
    np.testing.assert_array_equal(comps, np.asarray(pca.components_))
    np.testing.assert_array_equal(centers, np.asarray(km.centers_))


def test_group_geometry_and_key_checks():
    plan = _plan()
    with SketchService() as svc:
        svc.create_tenant("a", "mean", plan=plan, key=1, group="g")
        # sketch geometry must agree across the shared pass
        with pytest.raises(RuntimeError, match="gamma"):
            svc.create_tenant("b", "mean", plan=_plan(gamma=0.25), key=1,
                              group="g")
        # shared sketch ⇒ shared randomness
        with pytest.raises(RuntimeError, match="key"):
            svc.create_tenant("c", "mean", plan=plan, key=2, group="g")
        # late joiners would silently miss folded rows — refused
        svc.ingest("g", _x(BS)).result()
        with pytest.raises(RuntimeError, match="already ingested"):
            svc.create_tenant("d", "mean", plan=plan, key=1, group="g")
        # duplicate ids, unknown kinds
        with pytest.raises(RuntimeError, match="exists"):
            svc.create_tenant("a", "mean", plan=plan, key=1)
        with pytest.raises(RuntimeError, match="kind"):
            svc.create_tenant("e", "median", plan=plan, key=1)


# ---------------------------------------------------------- micro-batching --


def _drain(svc):
    """Pull everything submit() queued and serve it through one worker sweep
    (the un-started-service idiom: deterministic micro-batch contents)."""
    items = []
    while True:
        try:
            items.append(svc._queue.get_nowait())
        except queue.Empty:
            break
    svc._process(items)


def test_contiguous_ingest_coalesces_into_one_fold():
    svc = SketchService()          # not started: we drive the drain by hand
    plan = _plan()
    svc.create_tenant("t", "mean", plan=plan, key=1)
    futs = [svc.ingest("t", _x(BS, seed=i)) for i in range(3)]
    _drain(svc)
    acks = [f.result(0) for f in futs]
    assert all(a.ok and a.info["coalesced"] == 3 for a in acks)
    assert svc.stats["ingest_folds"] == 1          # ONE sketch+fold sweep
    assert svc.stats["ingest_requests"] == 3
    # a query splits the run: ingest-query-ingest = two folds, ordered
    f1 = svc.ingest("t", _x(BS))
    q = svc.submit(QueryRequest("t", "stats"))
    f2 = svc.ingest("t", _x(BS))
    _drain(svc)
    assert f1.result(0).ok and f2.result(0).ok
    assert q.result(0).unwrap()["rows"] == 4 * BS   # saw f1, not f2
    assert svc.stats["ingest_folds"] == 3


def test_coalesced_fold_is_a_valid_estimate():
    """Coalescing moves chunk boundaries (different (step, shard) keys than
    request-at-a-time folding) — the estimate stays unbiased. Ragged tiny
    requests coalesce into one pass whose mean matches the data's."""
    rng = np.random.default_rng(1)
    mu = rng.normal(size=P).astype(np.float32)
    blocks = [mu + 0.1 * rng.normal(size=(17, P)).astype(np.float32)
              for _ in range(40)]
    svc = SketchService()
    svc.create_tenant("t", "mean", plan=_plan(gamma=0.5), key=1)
    futs = [svc.ingest("t", b) for b in blocks]
    _drain(svc)
    assert all(f.result(0).ok for f in futs)
    assert svc.stats["ingest_folds"] == 1
    with svc:
        got = svc.query("t", "mean").unwrap()
        assert svc.query("t", "stats").unwrap()["rows"] == 40 * 17
    np.testing.assert_allclose(got, np.concatenate(blocks).mean(0), atol=0.05)


def test_scan_burst_path_matches_host_loop():
    """A drained burst spanning full steps goes through the jitted lax.scan
    ingest; results match the host loop to float-summation reordering."""
    x = _x(4 * BS)
    outs = {}
    for mode in ("auto", "never"):
        with SketchService(scan=mode) as svc:
            svc.create_tenant("t", "pca", plan=_plan(cov_path="lowrank",
                                                     rank=12),
                              key=3, n_components=3)
            svc.ingest("t", x).result()
            outs[mode] = svc.query("t", "components").unwrap()["components"]
            assert svc._groups["t"].cursor.scan is False   # reset after burst
    np.testing.assert_allclose(outs["auto"], outs["never"], atol=1e-5)


# ------------------------------------------------------- admission control --


def test_admission_rejects_with_backpressure():
    svc = SketchService(max_pending_rows=2 * BS, max_queue=3)
    svc.create_tenant("t", "mean", plan=_plan(), key=1)
    a = svc.ingest("t", _x(2 * BS))                 # admitted: hits the cap
    b = svc.ingest("t", _x(BS))                     # over the row cap
    assert b.result(0).status == "rejected" and "pending" in b.result(0).error
    c = svc.ingest("unknown", _x(1))                # unknown target: error
    assert c.result(0).status == "error"
    _drain(svc)
    assert a.result(0).ok
    d = svc.ingest("t", _x(BS))                     # backlog folded: admitted
    assert not d.done()
    # queue-depth cap: fill the (tiny) queue, next submit bounces
    e = [svc.ingest("t", _x(1)) for _ in range(3)]
    assert e[-1].result(0).status == "rejected"
    assert "queue full" in e[-1].result(0).error
    assert svc.stats["rejected"] >= 2


def test_mismatched_width_coalesced_run_answers_errors_and_survives():
    """Two same-group ingests with different column counts coalesce into one
    run whose concatenate fails: every request in the run gets an error
    response, the pending-row reservation is released, and the fold path
    keeps serving — the failure must never escape and kill the worker."""
    svc = SketchService()
    svc.create_tenant("t", "mean", plan=_plan(), key=1)
    a = svc.ingest("t", _x(BS))
    bad = svc.ingest("t", np.zeros((4, P + 1), np.float32))
    _drain(svc)
    assert a.result(0).status == "error"
    assert "ingest failed" in bad.result(0).error
    assert svc._groups["t"].pending_rows == 0
    ok = svc.ingest("t", _x(BS))               # the next fold succeeds
    _drain(svc)
    assert ok.result(0).ok
    # once the group's width is pinned by a fold, mismatches bounce at submit
    # (per-request error, no longer able to poison a coalesced run)
    bad2 = svc.ingest("t", np.zeros((4, P + 1), np.float32))
    assert bad2.done() and "columns" in bad2.result(0).error
    assert svc._groups["t"].pending_rows == 0


def test_worker_survives_internal_errors(monkeypatch):
    """An exception escaping a _process sweep fails that batch's futures with
    an error response instead of silently killing the single worker thread."""
    with SketchService() as svc:
        svc.create_tenant("t", "mean", plan=_plan(), key=1)

        def boom(req):
            raise RuntimeError("boom")

        monkeypatch.setattr(svc, "_handle_query", boom)
        r = svc.query("t", "stats", timeout=5)
        assert r.status == "error" and "boom" in r.error
        monkeypatch.undo()
        assert svc._thread.is_alive()          # worker lived through it
        assert svc.ingest("t", _x(BS)).result(5).ok
        assert svc.query("t", "mean", timeout=5).ok


def test_stop_fails_late_submissions_instead_of_hanging():
    """After stop(), every request family resolves immediately with an error
    response — nothing enqueues into the dead queue and hangs forever — and
    rejected ingest never leaks a pending-row reservation."""
    svc = SketchService()
    svc.create_tenant("t", "mean", plan=_plan(), key=1)
    with svc:
        assert svc.ingest("t", _x(BS)).result(5).ok
    for f in (svc.ingest("t", _x(BS)),
              svc.submit(QueryRequest("t", "stats")),
              svc.submit(AdminRequest("delete_tenant", dict(tid="t")))):
        assert f.done() and "stopped" in f.result(0).error
    assert svc._groups["t"].pending_rows == 0
    with pytest.raises(RuntimeError, match="stopped"):
        svc.start()                            # no restart onto dead state


def test_submit_never_mutates_caller_request():
    """A retained IngestRequest keeps its original target and rows payload —
    coercion and group-id normalization happen on the internal queue record."""
    svc = SketchService()
    svc.create_tenant("t", "mean", plan=_plan(), key=1, group="g")
    rows = [[1.0] * P]
    req = IngestRequest("t", rows)
    fut = svc.submit(req)
    assert req.target == "t" and req.rows is rows
    _drain(svc)
    assert fut.result(0).ok and fut.result(0).info["group"] == "g"


def test_lazy_finalization_only_on_stale_reads():
    with SketchService() as svc:
        svc.create_tenant("t", "pca", plan=_plan(cov_path="lowrank", rank=12),
                          key=3, n_components=3)
        # reads before any ingest are an error, not a crash
        assert "no ingested rows" in svc.query("t", "components").error
        svc.ingest("t", _x(2 * BS)).result()
        svc.query("t", "components").unwrap()
        svc.query("t", "transform", _x(8)).unwrap()
        assert svc.query("t", "stats").unwrap()["finalize_count"] == 1  # reused
        svc.ingest("t", _x(2 * BS)).result()
        svc.query("t", "components").unwrap()       # state moved: refinalize
        assert svc.query("t", "stats").unwrap()["finalize_count"] == 2
        # op/kind mismatch answers an error response
        assert svc.query("t", "centers").status == "error"
        assert svc.query("t", "nope").status == "error"


# ------------------------------------------------------- snapshot/restore ---


def test_snapshot_restore_bit_identical_and_resumable(tmp_path):
    x, more = _x(4 * BS), _x(2 * BS, seed=9)
    plan = _plan(cov_path="lowrank", rank=12)
    with SketchService() as svc:
        svc.create_tenant("p", "pca", plan=plan, key=7, n_components=3,
                          group="g", retain_ingest=True)
        svc.create_tenant("k", "kmeans", plan=_plan(), key=7, k=3, group="g",
                          algorithm="minibatch")
        svc.create_tenant("solo", "cov", plan=_plan(gamma=0.25), key=5)
        svc.ingest("g", x).result()
        svc.ingest("solo", x).result()
        comps = svc.query("p", "components").unwrap()
        assert svc.snapshot(str(tmp_path)) == 1
        svc.ingest("g", more).result()
        cont = svc.query("p", "components").unwrap()

    svc2 = restore_service(str(tmp_path))
    with svc2:
        # identical reads...
        comps2 = svc2.query("p", "components").unwrap()
        np.testing.assert_array_equal(comps["components"], comps2["components"])
        st = svc2.query("solo", "stats").unwrap()
        assert st["rows"] == 4 * BS and st["chunks"] == 4
        # ...and identical continuation: same rows → same (step, shard) keys
        svc2.ingest("g", more).result()
        cont2 = svc2.query("p", "components").unwrap()
        np.testing.assert_array_equal(cont["components"], cont2["components"])
        # the retained ingest buffer survives too (refine replay after restore)
        r = svc2.refine("p", passes=1)
        assert r.ok and r.result["passes"] == 1
    # an empty (never-ingested) tenant snapshots and restores as empty
    with SketchService() as s3:
        s3.create_tenant("fresh", "mean", plan=_plan(), key=0)
        s3.snapshot(str(tmp_path / "empty"))
    with restore_service(str(tmp_path / "empty")) as s4:
        assert "no ingested rows" in s4.query("fresh", "mean").error


def test_snapshot_mesh_plan_roundtrip(tmp_path):
    """A Plan holding an explicit mesh snapshots as its GEOMETRY (axis names
    + shape, repro.api.plan.mesh_spec) and restores as an equivalent mesh on
    the restoring host's devices — bit-identical queries either side."""
    mesh = jax.make_mesh((1,), ("data",))
    x = _x(2 * BS)
    with SketchService() as svc:
        svc.create_tenant("t", "mean", plan=_plan(backend="sharded", mesh=mesh),
                          key=1)
        svc.ingest("t", x).result()
        ref = svc.query("t", "mean").unwrap()
        svc.snapshot(str(tmp_path))
    with restore_service(str(tmp_path)) as s2:
        got = s2.query("t", "mean").unwrap()
        np.testing.assert_array_equal(ref, got)
        restored = s2._groups["t"].plan.mesh
        assert restored is not None
        assert restored.axis_names == ("data",) and restored.shape["data"] == 1


# ------------------------------------------------------------ QueueSource ---


def test_queue_source_feeds_fit_stream():
    """QueueSource bridges pushed chunks to the (seed, step, shard) contract:
    fit_stream over the queue == fit over the concatenation."""
    x = _x(4 * BS)
    qs = QueueSource()
    for i in range(0, 4 * BS, BS):
        qs.push(x[i:i + BS])
    qs.close()
    plan = _plan()
    est = SparsifiedMean(plan, key=3).fit_stream(qs, steps=qs.steps())
    ref = SparsifiedMean(plan, key=3).fit(x)
    np.testing.assert_array_equal(np.asarray(est.mean_), np.asarray(ref.mean_))
    # retained chunks replay (a second pass re-reads the buffer)
    est2 = SparsifiedMean(plan, key=3).fit_stream(qs, steps=qs.steps())
    np.testing.assert_array_equal(np.asarray(est2.mean_), np.asarray(est.mean_))


def test_queue_source_contract_errors():
    qs = QueueSource(retain=False, timeout=0.05)
    qs.push(np.zeros((4, P), np.float32))
    qs.batch_at(0, 0)
    with pytest.raises(RuntimeError, match="dropped"):
        qs.batch_at(0, 0)                      # retain=False: served once
    with pytest.raises(TimeoutError, match="stalled"):
        qs.batch_at(1, 0)                      # producer never caught up
    qs.close()
    with pytest.raises(RuntimeError, match="closed"):
        qs.batch_at(1, 0)                      # past the end fails fast now
    with pytest.raises(RuntimeError, match="close"):
        qs.push(np.zeros((4, P), np.float32))
    with pytest.raises(ValueError, match="shape"):
        QueueSource().push(np.zeros(4, np.float32))


# -------------------------------------------------------- multi-worker pool --


def test_multiworker_per_group_results_bit_identical():
    """The disjoint group partition keeps one producer per cursor: the same
    request sequence through 4 workers ends bit-identical PER GROUP to the
    single-worker service (batch_size-multiple blocks + scan='never' pin the
    chunk boundaries and the host fold loop)."""
    n_groups, plan = 6, _plan(cov_path="lowrank", rank=12)
    blocks = [(f"g{r % n_groups}", _x(BS, seed=r)) for r in range(18)]

    def run(workers):
        with SketchService(workers=workers, scan="never") as svc:
            for g in range(n_groups):
                svc.create_tenant(f"t{g}", "pca", plan=plan, key=7,
                                  n_components=3, group=f"g{g}")
            futs = [svc.ingest(gid, b) for gid, b in blocks]
            assert all(f.result(60).ok for f in futs)
            return {g: svc.query(f"t{g}", "components").unwrap()["components"]
                    for g in range(n_groups)}

    one, four = run(1), run(4)
    for g in range(n_groups):
        np.testing.assert_array_equal(one[g], four[g])


def test_multiworker_routing_is_disjoint_and_stable():
    svc = SketchService(workers=4)
    owners = {g: svc._worker_of(g) for g in (f"g{i}" for i in range(64))}
    assert set(owners.values()) == set(range(4))   # every worker owns groups
    svc2 = SketchService(workers=4)
    assert owners == {g: svc2._worker_of(g) for g in owners}  # restart-stable


def test_multiworker_stop_races_inflight_ingest():
    """stop() racing a storm of in-flight ingest across ≥2 workers: every
    Future resolves (ok, rejected, or 'service stopped' — never dangles), the
    pending-row accounting lands at exactly 0, and the pending gauge agrees
    (the _fail_queued release path, per queue)."""
    n_groups = 8
    svc = SketchService(workers=4, max_queue=16)
    for g in range(n_groups):
        svc.create_tenant(f"t{g}", "mean", plan=_plan(), key=1, group=f"g{g}")
    futs: list = []
    start = threading.Barrier(3)

    def producer(seed):
        rng = np.random.default_rng(seed)
        start.wait()
        for r in range(120):
            g = int(rng.integers(n_groups))
            futs.append(svc.ingest(f"g{g}", _x(BS, seed=r)))

    svc.start()
    threads = [threading.Thread(target=producer, args=(s,)) for s in (1, 2)]
    for t in threads:
        t.start()
    start.wait()                     # both producers firing
    svc.stop()                       # races the in-flight storm
    for t in threads:
        t.join()
    for f in futs:
        assert f.done(), "a Future was left unresolved by stop()"
        assert f.result(0).status in ("ok", "rejected", "error")
    for g in range(n_groups):
        grp = svc._groups.get(f"g{g}")
        assert grp is None or grp.pending_rows == 0
    assert svc.registry.gauge("serve.pending_rows").value == 0
    assert svc.registry.gauge("serve.queue_depth").value == 0


def test_rejected_requests_are_latency_accounted():
    """Satellite: the submit-side rejected/stopped fast paths must route
    through _resolve_fut — rejections (and unknown-target errors) appear in
    serve.request_seconds alongside accepted requests."""
    svc = SketchService(max_pending_rows=BS)
    svc.create_tenant("t", "mean", plan=_plan(), key=1)
    h = svc.registry.histogram("serve.request_seconds")
    base = h.count
    svc.ingest("t", _x(BS))                        # admitted (queued)
    assert svc.ingest("t", _x(BS)).result(0).status == "rejected"
    assert svc.ingest("nope", _x(1)).result(0).status == "error"
    assert h.count == base + 2, (
        "rejected + error fast paths missing from the histogram")
    _drain(svc)                                    # resolves the admitted one
    assert h.count == base + 3


# ------------------------------------------------------ snapshot supervision --


def test_snapshot_policy_validation():
    with pytest.raises(ValueError, match="every_rows"):
        SnapshotPolicy()
    with pytest.raises(ValueError, match="every_rows"):
        SnapshotPolicy(every_rows=0)
    with pytest.raises(ValueError, match="snapshot_dir"):
        SketchService(snapshot_policy=SnapshotPolicy(every_rows=1))


def test_auto_snapshot_every_rows(tmp_path):
    d = str(tmp_path / "auto")
    with SketchService(scan="never",
                       snapshot_policy=SnapshotPolicy(every_rows=2 * BS),
                       snapshot_dir=d) as svc:
        svc.create_tenant("t", "mean", plan=_plan(), key=1)
        for i in range(4):
            svc.ingest("t", _x(BS, seed=i)).result(30).unwrap()
        deadline = time.monotonic() + 30
        while svc.stats["snapshots"] < 2:
            assert time.monotonic() < deadline, "every_rows policy never fired"
            time.sleep(0.02)
        # idle: no new rows folded → no further snapshots rewrite the dir
        n = svc.stats["snapshots"]
        time.sleep(0.35)
        assert svc.stats["snapshots"] == n
    with restore_service(d) as svc2:
        assert svc2.query("t", "stats").unwrap()["rows"] % BS == 0


def test_auto_snapshot_every_s(tmp_path):
    d = str(tmp_path / "auto")
    with SketchService(scan="never",
                       snapshot_policy=SnapshotPolicy(every_s=0.05),
                       snapshot_dir=d) as svc:
        svc.create_tenant("t", "mean", plan=_plan(), key=1)
        svc.ingest("t", _x(BS)).result(30).unwrap()
        deadline = time.monotonic() + 30
        while svc.stats["snapshots"] < 1:
            assert time.monotonic() < deadline, "every_s policy never fired"
            time.sleep(0.02)
        n = svc.stats["snapshots"]
        time.sleep(0.3)                 # idle — the timer alone must NOT fire
        assert svc.stats["snapshots"] == n
        svc.ingest("t", _x(BS, seed=1)).result(30).unwrap()
        deadline = time.monotonic() + 30
        while svc.stats["snapshots"] < n + 1:   # new rows → fires again
            assert time.monotonic() < deadline
            time.sleep(0.02)


def test_restored_snapshot_step_continues(tmp_path):
    """Satellite: snapshot → restore → snapshot lands at step N+1 — a
    restored service must never clobber the original run's earlier
    checkpoints under the same path."""
    d = str(tmp_path / "snap")
    with SketchService() as svc:
        svc.create_tenant("t", "mean", plan=_plan(), key=1)
        svc.ingest("t", _x(BS)).result()
        assert svc.snapshot(d) == 1
        assert svc.snapshot(d) == 2
    with restore_service(d) as svc2:
        assert svc2.snapshot(d) == 3
    with restore_service(d) as svc3:
        assert svc3.snapshot(d) == 4


def test_multiworker_snapshot_quiesces_at_fold_boundary(tmp_path):
    """A snapshot on a live 4-worker service quiesces the pool: the written
    state restores cleanly and the service keeps serving afterwards."""
    d = str(tmp_path / "snap")
    with SketchService(workers=4, scan="never") as svc:
        for g in range(8):
            svc.create_tenant(f"t{g}", "mean", plan=_plan(), key=1,
                              group=f"g{g}")
        futs = [svc.ingest(f"g{r % 8}", _x(BS, seed=r)) for r in range(24)]
        step = svc.snapshot(d)         # races the in-flight folds
        assert step == 1
        assert all(f.result(60).ok for f in futs)
        assert svc.ingest("g0", _x(BS)).result(30).ok   # still serving
    with restore_service(d) as svc2:
        rows = svc2.query("t0", "stats").unwrap()["rows"]
        assert rows % BS == 0          # a fold boundary, never mid-fold


# ---------------------------------------------------------- tenant eviction --


def test_ttl_eviction_and_lazy_restore(tmp_path):
    """An idle group past ttl_s is evicted to snapshot and lazily restored
    bit-identically on the next query; an ACTIVE group is left alone."""
    with SketchService(scan="never", ttl_s=0.25,
                       evict_dir=str(tmp_path)) as svc:
        svc.create_tenant("idle", "pca", plan=_plan(cov_path="lowrank",
                                                    rank=12),
                          key=3, n_components=3)
        svc.create_tenant("hot", "mean", plan=_plan(), key=1)
        svc.ingest("idle", _x(2 * BS)).result(30).unwrap()
        ref = svc.query("idle", "components").unwrap()["components"]
        deadline = time.monotonic() + 30
        while "idle" not in svc.evicted():
            assert time.monotonic() < deadline, "TTL eviction never fired"
            svc.ingest("hot", _x(BS)).result(30)       # keeps "hot" live
            time.sleep(0.03)
        assert "idle" not in svc.tenants() and "hot" in svc.tenants()
        assert svc.stats["evictions"] >= 1
        # first touch lazily restores, bit-identical
        got = svc.query("idle", "components").unwrap()["components"]
        np.testing.assert_array_equal(ref, got)
        assert "idle" in svc.tenants() and not svc.evicted()
        assert svc.stats["evict_restores"] == 1
        # and the restored cursor continues folding
        assert svc.ingest("idle", _x(BS, seed=5)).result(30).ok


def test_max_tenants_evicts_lru_group(tmp_path):
    with SketchService(scan="never", max_tenants=2,
                       evict_dir=str(tmp_path)) as svc:
        for i in range(3):
            svc.create_tenant(f"t{i}", "mean", plan=_plan(), key=1)
            svc.ingest(f"t{i}", _x(BS, seed=i)).result(30).unwrap()
        deadline = time.monotonic() + 30
        while len(svc.tenants()) > 2:
            assert time.monotonic() < deadline, "max_tenants never enforced"
            time.sleep(0.03)
        # t0 was touched least recently → it is the evicted one
        assert svc.evicted() == ["t0"]
        # evicted state still answers (lazy restore) and matches the fold
        m = svc.query("t0", "mean").unwrap()
        ref = SparsifiedMean(_plan(), key=1).fit(_x(BS, seed=0))
        np.testing.assert_array_equal(m, np.asarray(ref.mean_))


def test_eviction_skips_groups_with_pending_ingest(tmp_path):
    """A group with admitted-but-unfolded rows is never evicted (the queued
    request would resolve against a missing group)."""
    svc = SketchService(ttl_s=0.01, evict_dir=str(tmp_path))   # not started
    svc.create_tenant("t", "mean", plan=_plan(), key=1)
    fut = svc.ingest("t", _x(BS))              # reservation held, never folds
    time.sleep(0.05)
    svc._maybe_evict(0)                        # the sweep the worker would run
    assert svc.evicted() == [] and "t" in svc.tenants()
    _drain(svc)
    assert fut.result(0).ok


# ------------------------------------- concurrent producers (the contract) --


def test_concurrent_partial_fit_serializes_correctly():
    """The SketchCursor thread-safety contract: N producer threads hammering
    one SharedSketchRun serialize whole-call — no lost chunks, exact counts,
    and the mean is a valid estimate no matter the interleaving."""
    rng = np.random.default_rng(2)
    mu = rng.normal(size=P).astype(np.float32)
    n_threads, per_thread = 4, 6
    blocks = [[mu + 0.1 * rng.normal(size=(BS, P)).astype(np.float32)
               for _ in range(per_thread)] for _ in range(n_threads)]
    plan = _plan(gamma=0.5)
    run = fit_many(plan, [SparsifiedMean(plan, key=1),
                          SparsifiedCov(plan, key=1)],
                   np.zeros((0, P), np.float32), finalize=False)
    start = threading.Barrier(n_threads)
    errs = []

    def producer(i):
        try:
            start.wait()
            for b in blocks[i]:
                run.partial_fit(b)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=producer, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    total = n_threads * per_thread
    assert run.count == total * BS
    assert run.n_sketches == total                 # every chunk folded once
    assert run.cursor.chunk_rows == [BS] * total
    run.finalize()
    assert all(c.count_ == total * BS for c in run)
    np.testing.assert_allclose(np.asarray(run[0].mean_), mu, atol=0.05)
