"""Multi-device semantics tests — run in subprocesses with 8 forced host devices
(the test session itself must keep the single real device)."""
import os
import subprocess
import sys
import textwrap

import pytest

# every test here spawns a fresh interpreter and re-jits on 8 host devices —
# minutes each, so they live in the slow lane (CI runs them separately).
pytestmark = pytest.mark.slow

ENV = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src", JAX_PLATFORMS="cpu")


def run_script(body: str, timeout: int = 600):
    code = textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], env=ENV, cwd=os.path.dirname(os.path.dirname(__file__)),
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_estimators_match_single_device():
    run_script("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_host_mesh
        from repro.core import estimators, sketch
        from repro.stream import sharded as dist

        mesh = make_host_mesh(4, 2)
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (256, 64))
        spec = sketch.make_spec(64, jax.random.PRNGKey(1), gamma=0.3)

        s_single = sketch.sketch(x, spec)
        mean_single = estimators.mean_estimator(s_single)
        cov_single = estimators.cov_estimator(s_single)

        s_shard = dist.sketch_sharded(x, spec, mesh, axes=("data",))
        mean_d = dist.sharded_mean(s_shard, mesh)
        cov_d = dist.sharded_cov(s_shard, mesh)
        np.testing.assert_allclose(np.asarray(mean_d), np.asarray(mean_single), atol=1e-5)
        np.testing.assert_allclose(np.asarray(cov_d), np.asarray(cov_single), atol=1e-3)
        print("estimators-match OK")
    """)


def test_distributed_kmeans_matches():
    """Sharded K-means reaches the same solution as single-device — up to a
    cluster permutation: sharding reorders the scatter-add reductions, and the
    O(1e-7) objective perturbation can flip the argmin between *equally good*
    n_init runs whose clusters differ only in label order. The sketch itself is
    bit-identical; we therefore compare objective, Hungarian-aligned centers,
    and permutation-matched assignments (the sharding-invariant quantities)."""
    run_script("""
        import jax, jax.numpy as jnp, numpy as np
        from scipy.optimize import linear_sum_assignment
        from repro.launch.mesh import make_host_mesh
        from repro.core import kmeans as km, sketch
        from repro.stream import sharded as dist

        mesh = make_host_mesh(8, 1)
        key = jax.random.PRNGKey(0)
        k, p, n = 4, 64, 512
        centers = jax.random.normal(key, (k, p)) * 3
        labels = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, k)
        x = centers[labels] + 0.3 * jax.random.normal(jax.random.PRNGKey(2), (n, p))
        spec = sketch.make_spec(p, jax.random.PRNGKey(3), gamma=0.4)
        s = sketch.sketch(x, spec)
        mu1, a1, o1, _ = km.sparse_kmeans_core(s.values, s.indices, s.p, k, jax.random.PRNGKey(4))
        s_d = dist.sketch_sharded(x, spec, mesh)
        assert bool(jnp.all(s.values == s_d.values)) and bool(jnp.all(s.indices == s_d.indices))
        mu2, a2, o2, _ = dist.sharded_kmeans(s_d, k, jax.random.PRNGKey(4), mesh)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4)
        a1, a2 = np.asarray(a1), np.asarray(a2)
        conf = np.zeros((k, k))
        for i in range(k):
            for j in range(k):
                conf[i, j] = np.sum((a1 == i) & (a2 == j))
        ri, ci = linear_sum_assignment(-conf)
        assert conf[ri, ci].sum() == n, "assignments differ beyond a relabelling"
        mu2_aligned = np.asarray(mu2)[ci]
        np.testing.assert_allclose(mu2_aligned, np.asarray(mu1), atol=1e-4)
        print("kmeans-match OK")
    """)


def test_moe_ep_matches_local():
    run_script("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_host_mesh
        from repro.models import moe

        mesh = make_host_mesh(2, 4)
        key = jax.random.PRNGKey(0)
        d, f, E, k = 32, 64, 8, 2
        B, S = 4, 16
        p = moe.init_moe_params(key, d, f, E, 1, f, jnp.float32)
        x = jax.random.normal(key, (B, S, d))
        y_loc, aux_loc = moe.moe_apply_local(p, x.reshape(-1, d), k, 100.0)
        y_ep, aux_ep = jax.jit(lambda p, x: moe.moe_apply_ep(
            p, x, k, 100.0, mesh, ("data",), "model"))(p, x)
        np.testing.assert_allclose(np.asarray(y_ep).reshape(-1, d), np.asarray(y_loc),
                                   atol=2e-4, rtol=2e-3)
        np.testing.assert_allclose(float(aux_ep), float(aux_loc), rtol=1e-4)
        # gradients flow through the all_to_all dispatch
        g = jax.grad(lambda pp: jax.jit(lambda p, x: moe.moe_apply_ep(
            p, x, k, 100.0, mesh, ("data",), "model"))(pp, x)[0].sum())(p)
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
        print("moe-ep OK")
    """)


def test_perworker_grad_estimator_matches_reference():
    """shard_map psum estimator == the Thm-4 formula computed single-process,
    with exactly the same per-worker masks."""
    run_script("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_host_mesh
        from repro.core import ros
        from repro.core.grad_compress import CompressConfig, mask_spec, perworker_mean_estimate
        from repro.core.sampling import sample_indices
        from repro.core.sketch import batch_key

        mesh = make_host_mesh(8, 1)
        key = jax.random.PRNGKey(0)
        p_dim = 1 << 12
        cfg = CompressConfig(gamma=0.25, chunk_p=1 << 10, error_feedback=False, mode="per-worker")
        grads = jax.random.normal(key, (8, p_dim))
        step = jnp.int32(3)

        def local(g):
            return perworker_mean_estimate(g[0], key, step, cfg, ("data",))[None]

        fn = shard_map(local, mesh=mesh, in_specs=P("data", None), out_specs=P("data", None))
        est = fn(grads)[0]

        # reference: replicate the per-worker math explicitly — masks derive
        # from the SAME (seed, step, shard) batch_key discipline as the stream
        spec = mask_spec(cfg, key)
        signs_key = spec.signs_key()
        acc = 0.0
        for w in range(8):
            chunks = grads[w].reshape(-1, cfg.chunk_p)
            y = ros.precondition(chunks, signs_key, "hadamard")
            idx = sample_indices(batch_key(spec, step, w), y.shape[0], cfg.chunk_p, cfg.m)
            vals = jnp.take_along_axis(y, idx, -1)
            scat = jnp.zeros_like(y).at[jnp.arange(y.shape[0])[:, None], idx].set(vals)
            acc = acc + scat * (cfg.chunk_p / cfg.m)
        ref = ros.unmix(acc / 8, signs_key, "hadamard").reshape(-1)
        np.testing.assert_allclose(np.asarray(est), np.asarray(ref), atol=1e-4)
        print("per-worker estimator OK")
    """)


def test_train_checkpoint_elastic_restore():
    run_script("""
        import dataclasses, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_host_mesh
        from repro.configs.registry import get_arch, get_shape
        from repro.models.api import get_api
        from repro.train import checkpoint
        from repro.train.trainer import (TrainerConfig, abstract_state, init_state,
                                         make_dist, make_train_fn, state_shardings)
        from repro.train.optimizer import OptConfig

        cfg = get_arch("glm4-9b", reduced=True)
        api = get_api(cfg)
        tcfg = TrainerConfig(opt=OptConfig(peak_lr=1e-2, warmup_steps=2, total_steps=20),
                             q_chunk=8, kv_chunk=8)
        key = jax.random.PRNGKey(0)

        mesh1 = make_host_mesh(4, 2)
        dist = make_dist(mesh1, cfg)
        fn = make_train_fn(api, tcfg, dist, key)
        st_specs = abstract_state(api, tcfg)
        sh1 = state_shardings(st_specs, mesh1)
        state = jax.device_put(init_state(api, tcfg, key), sh1)
        step = jax.jit(fn, donate_argnums=0)
        B, S = 8, 16
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}
        losses = []
        for i in range(6):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0], losses
        with tempfile.TemporaryDirectory() as d:
            checkpoint.save(d, 6, state, extra={"pipeline": {"step": 6}}, async_=False)
            # elastic restore onto a DIFFERENT mesh layout
            mesh2 = make_host_mesh(2, 4)
            sh2 = state_shardings(st_specs, mesh2)
            state2, extra = checkpoint.restore(d, st_specs, sh2)
            assert extra["pipeline"]["step"] == 6
            dist2 = make_dist(mesh2, cfg)
            fn2 = make_train_fn(api, tcfg, dist2, key)
            state2, m2 = jax.jit(fn2)(state2, batch)
            assert np.isfinite(m2["loss"]) and float(m2["loss"]) <= losses[-1] + 0.5
        print("elastic checkpoint OK, losses:", [round(l,3) for l in losses])
    """, timeout=900)
