"""repro.obs telemetry: registry thread-safety, span aggregation, JSONL
round-trip, exposition/endpoint, and the observe-only contracts — engine runs
bit-identically with telemetry on, and SketchService counters reconcile
exactly with known request totals."""
import io
import json
import threading
import urllib.request

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import sketch
from repro.stream import EngineTelemetry, StreamEngine, StreamKMeansConfig

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------- registry -----


def test_counter_histogram_concurrent_exact_totals():
    """8 threads hammer one counter + one histogram; totals are EXACT."""
    reg = obs.MetricsRegistry()
    c = reg.counter("hammer.count")
    h = reg.histogram("hammer.obs", window=64)
    n_threads, n_iter = 8, 2000

    def work(tid):
        for i in range(n_iter):
            c.inc()
            h.observe(float(tid))

    threads = [threading.Thread(target=work, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * n_iter
    assert h.count == n_threads * n_iter
    # sum of tid over all observations: n_iter * (0+1+...+7)
    assert h.sum == n_iter * sum(range(n_threads))


def test_label_sets_are_independent_series():
    reg = obs.MetricsRegistry()
    reg.counter("c", group="a").inc(2)
    reg.counter("c", group="b").inc(5)
    assert reg.counter("c", group="a").value == 2
    assert reg.counter("c", group="b").value == 5
    # same name+labels → the same object (cached identity)
    assert reg.counter("c", group="a") is reg.counter("c", group="a")


def test_histogram_summary_quantiles_and_window():
    reg = obs.MetricsRegistry()
    h = reg.histogram("lat", window=8)
    for v in range(100):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100 and s["sum"] == sum(range(100))
    assert s["min"] == 0.0 and s["max"] == 99.0
    # reservoir kept the last 8 observations (92..99)
    assert 92.0 <= s["p50"] <= 99.0


def test_disabled_registry_is_shared_noop():
    reg = obs.MetricsRegistry(enabled=False)
    c, g, h = reg.counter("a"), reg.gauge("b"), reg.histogram("c")
    assert c is g is h              # ONE shared null object — zero retention
    c.inc(); g.set(4.0); h.observe(1.0)
    assert c.value == 0 and reg.metrics() == [] and reg.snapshot() == {}


def test_quantiles_helper():
    p50, p99 = obs.quantiles([1.0, 2.0, 3.0, 4.0], (0.5, 0.99))
    assert p50 == pytest.approx(2.5)
    assert all(np.isnan(v) for v in obs.quantiles([], (0.5, 0.9)))


# ---------------------------------------------------------------- spans -----


def test_span_nesting_and_totals():
    reg = obs.MetricsRegistry()
    with obs.span("outer", reg):
        assert obs.current_path() == "outer"
        with obs.span("inner", reg):
            assert obs.current_path() == "outer.inner"
        with obs.span("inner", reg):
            pass
    totals = obs.span_totals(reg)
    assert totals["outer"]["count"] == 1
    assert totals["outer.inner"]["count"] == 2
    assert totals["outer"]["total_s"] >= totals["outer.inner"]["total_s"]


def test_timed_splits_first_call():
    reg = obs.MetricsRegistry()

    @obs.timed("fn", reg)
    def fn(x):
        return x + 1

    assert fn(1) == 2 and fn(2) == 3 and fn(3) == 4
    totals = obs.span_totals(reg)
    assert totals["fn"]["count"] == 3
    assert totals["fn.first"]["count"] == 1


# ---------------------------------------------------------------- JSONL -----


def test_steplogger_jsonl_roundtrip_and_downsampling():
    buf = io.StringIO()
    log = obs.StepLogger(stream=buf, every=3, static={"run": "t"})
    logged = [log.log(step=s, loss=float(s)) for s in range(10)]
    assert logged == [s % 3 == 0 for s in range(10)]
    log.log(step=98, force=True, note="final")
    recs = obs.read_jsonl(io.StringIO(buf.getvalue()))
    assert [r["step"] for r in recs] == [0, 3, 6, 9, 98]
    assert all(r["run"] == "t" and "t" in r for r in recs)
    assert recs[-1]["note"] == "final"


def test_steplogger_coerces_numpy(tmp_path):
    path = str(tmp_path / "steps.jsonl")
    log = obs.StepLogger(path=path)
    log.log(step=np.int64(0), v=np.float32(1.5), arr=np.arange(3))
    (rec,) = obs.read_jsonl(path)
    assert rec["step"] == 0 and rec["v"] == 1.5 and rec["arr"] == [0, 1, 2]
    json.dumps(rec)   # everything JSON-native after the round trip


# ------------------------------------------------- exposition + endpoint ----


def test_render_exposition_snapshot():
    reg = obs.MetricsRegistry()
    reg.counter("serve.requests", tenant="t0").inc(3)
    reg.gauge("queue.depth").set(2)
    h = reg.histogram("lat.s")
    for v in (0.5, 1.0, 1.5, 2.0):
        h.observe(v)
    text = obs.render_exposition(reg)
    assert '# TYPE serve_requests counter' in text
    assert 'serve_requests{tenant="t0"} 3' in text
    assert "queue_depth 2" in text
    assert "# TYPE lat_s summary" in text
    assert 'lat_s{quantile="0.5"}' in text
    assert "lat_s_count 4" in text and "lat_s_sum 5" in text
    assert obs.render_exposition(reg) == text   # deterministic


def test_render_exposition_survives_inf_and_nan():
    """Regression: ±Inf gauges/histogram sums used to raise OverflowError in
    the sample formatter (int(inf)), killing the whole /metrics scrape. The
    Prometheus text format spells them +Inf / -Inf (and NaN stays NaN)."""
    reg = obs.MetricsRegistry()
    reg.gauge("ratio.up").set(float("inf"))
    reg.gauge("ratio.down").set(float("-inf"))
    reg.gauge("ratio.nan").set(float("nan"))
    h = reg.histogram("weird.s")
    h.observe(float("inf"))           # poisons the sum, not the scrape
    h.observe(1.0)
    text = obs.render_exposition(reg)
    assert "ratio_up +Inf" in text
    assert "ratio_down -Inf" in text
    assert "ratio_nan NaN" in text
    assert "weird_s_sum +Inf" in text and "weird_s_count 2" in text


def test_metrics_server_endpoint():
    reg = obs.MetricsRegistry()
    reg.counter("up").inc()
    with obs.serve_metrics(reg) as srv:
        text = urllib.request.urlopen(srv.url, timeout=10).read().decode()
        assert "up 1" in text
        js = json.loads(urllib.request.urlopen(
            srv.url + ".json", timeout=10).read().decode())
        assert js["up"]["value"] == 1


# ----------------------------------------------- engine: observe-only -------


def test_engine_telemetry_is_bit_identical():
    """Telemetry on vs off: EVERY finalized output is bit-identical, and the
    registry/JSONL agree with the known step/row totals."""
    p, b, steps = 64, 32, 5
    spec = sketch.make_spec(p, jax.random.PRNGKey(1), gamma=0.25)
    data = np.asarray(jax.random.normal(KEY, (steps, b, p)))

    def source(seed, step, shard):
        return data[step]

    def make_engine():
        return StreamEngine(spec, source, track_cov=True,
                            kmeans=StreamKMeansConfig(k=3, n_init=2,
                                                      track_reassignments=True))

    res_plain = make_engine().run(steps)

    reg = obs.MetricsRegistry()
    buf = io.StringIO()
    tel = EngineTelemetry(registry=reg,
                          step_logger=obs.StepLogger(stream=buf), log_every=2)
    res_tel = make_engine().run(steps, telemetry=tel)

    for field in ("mean", "cov", "centers"):
        a, bb = getattr(res_plain, field), getattr(res_tel, field)
        assert np.array_equal(np.asarray(a), np.asarray(bb)), field
    assert np.array_equal(res_plain.reassign_counts, res_tel.reassign_counts)

    assert reg.counter("engine.steps").value == steps
    assert reg.counter("engine.rows").value == steps * b
    assert reg.histogram("engine.step_seconds").count == steps
    assert reg.gauge("engine.state_bytes").value > 0
    totals = obs.span_totals(reg)
    assert totals["engine.update"]["count"] == steps
    recs = obs.read_jsonl(io.StringIO(buf.getvalue()))
    assert [r["step"] for r in recs] == [0, 2, 4]
    assert recs[-1]["rows_total"] == steps * b
    assert all("reassign_frac" in r for r in recs)


def test_engine_telemetry_on_step_callback():
    spec = sketch.make_spec(32, jax.random.PRNGKey(2), gamma=0.25)
    data = np.asarray(jax.random.normal(KEY, (3, 16, 32)))
    seen = []
    tel = EngineTelemetry(registry=obs.MetricsRegistry(),
                          on_step=seen.append)
    StreamEngine(spec, lambda s, t, sh: data[t], track_cov=False).run(
        3, telemetry=tel)
    assert [r["step"] for r in seen] == [0, 1, 2]
    assert all(r["rows"] == 16 for r in seen)


# --------------------------------------------- serving: exact reconcile -----


def test_sketchserve_metrics_reconcile_exactly():
    from repro.api import Plan
    from repro.sketchserve import SketchService

    rng = np.random.default_rng(0)
    plan = Plan(backend="stream", gamma=0.25, batch_size=64,
                cov_path="lowrank", rank=4)
    n_req, rows_per = 24, 8
    with SketchService(max_batch=16) as svc:
        svc.create_tenant("t0", "pca", plan=plan, key=1, n_components=2,
                          group="g")
        svc.create_tenant("t1", "mean", plan=plan, key=1, group="g")
        futs = [svc.ingest("g", rng.normal(size=(rows_per, 64))
                           .astype(np.float32)) for _ in range(n_req)]
        assert all(f.result(60).ok for f in futs)
        svc.query("t0", "components").unwrap()
        stats = svc.stats
        reg = svc.registry

        assert stats["ingest_requests"] == n_req
        assert stats["ingest_rows"] == n_req * rows_per
        assert stats["queries"] == 1
        # total served: 24 ingests + 1 query + 2 admin (create_tenant)
        assert stats["requests"] == n_req + 3
        # coalescing: every ingest request is accounted to exactly one fold
        h = reg.histogram("serve.coalesced_requests")
        assert h.sum == n_req and h.count == stats["ingest_folds"]
        # per-tenant fold counts: both group members advance together
        assert (reg.counter("serve.tenant_folds", tenant="t0").value
                == reg.counter("serve.tenant_folds", tenant="t1").value
                == stats["ingest_folds"])
        # everything admitted was folded: the pending gauge is back to zero
        assert reg.gauge("serve.pending_rows").value == 0
        # every request's submit→resolve latency was observed
        assert reg.histogram("serve.request_seconds").count >= n_req + 1
        # the legacy dict view is one consistent snapshot (a mapping)
        assert set(SketchService.STAT_KEYS) <= set(stats)


def test_sketchserve_rejection_counted():
    from repro.api import Plan
    from repro.sketchserve import SketchService

    plan = Plan(backend="stream", gamma=0.25, batch_size=64,
                cov_path="lowrank", rank=4)
    svc = SketchService(max_pending_rows=4)   # not started: queue never drains
    svc.create_tenant("t", "mean", plan=plan, key=1)
    first = svc.ingest("t", np.zeros((3, 64), np.float32))
    assert first.done() is False                        # admitted, pending
    resp = svc.ingest("t", np.zeros((3, 64), np.float32)).result(5)
    assert resp.status == "rejected"
    assert svc.stats["rejected"] == 1
    assert svc.registry.gauge("serve.pending_rows").value == 3
    svc.stop()


# ------------------------------------------------------- cluster heartbeat --


def test_heartbeat_merge_wire_publish():
    from repro import cluster
    from repro.stream import state as state_mod

    a = cluster.beat(5, rows=100, t=1000.0)
    b = cluster.beat(7, rows=50, t=1002.5)
    m = state_mod.merge(a, b)
    assert int(m.hosts) == 2 and int(m.step) == 7 and int(m.rows) == 150

    rt = state_mod.from_arrays(state_mod.to_arrays(m), kinds=("hb",))
    assert int(rt.hosts) == 2 and float(rt.t_first) == 1000.0

    reg = obs.MetricsRegistry()
    vals = cluster.publish(cluster.gather(m), registry=reg, now=1010.0)
    assert vals["cluster.hosts"] == 2.0
    assert vals["cluster.heartbeat_age_s"] == pytest.approx(7.5)
    assert vals["cluster.straggler_lag_s"] == pytest.approx(2.5)
    cluster.publish_local(a, host=3, registry=reg)
    assert reg.gauge("cluster.host_step", host="3").value == 5.0


# ------------------------------------------------------ kernel dispatch -----


def test_kernel_dispatch_counters():
    from repro.kernels import ops

    reg = obs.MetricsRegistry()
    prev = obs.set_default_registry(reg)
    try:
        x = jax.random.normal(KEY, (4, 64))
        signs = np.where(np.arange(64) % 2 == 0, 1.0, -1.0).astype(np.float32)
        ops.hd_precondition(x, signs, mode="ref")
        ops.hd_precondition(x, signs, mode="ref")
        c = reg.counter("kernels.dispatch", op="hd_precondition", path="ref")
        assert c.value == 2
    finally:
        obs.set_default_registry(prev)
