"""ROS preconditioning: unitarity, inversion, smoothing guarantees (Thm 1, Cor 2).

Property-style sweeps are seeded pytest.mark.parametrize grids (no hypothesis
dependency): each case derives (shape, data) deterministically from its seed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.fft as sf

from repro.core import ros

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("p", [2, 8, 64, 128, 1024])
def test_fwht_matches_dense_hadamard(p):
    x = jax.random.normal(KEY, (5, p))
    h = ros.hadamard_matrix(p)
    np.testing.assert_allclose(ros.fwht(x), x @ h.T, atol=1e-4)


@pytest.mark.parametrize("p", [4, 32, 256])
def test_fwht_self_inverse_and_isometry(p):
    x = jax.random.normal(KEY, (7, p))
    y = ros.fwht(x)
    np.testing.assert_allclose(ros.fwht(y), x, atol=1e-4)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=1), jnp.linalg.norm(x, axis=1), rtol=1e-5
    )


@pytest.mark.parametrize("p", [10, 100, 784, 1000])
def test_dct_matches_scipy(p):
    x = np.random.default_rng(p).normal(size=(4, p)).astype(np.float32)
    np.testing.assert_allclose(
        ros._dct_ii_ortho(jnp.asarray(x)), sf.dct(x, axis=-1, norm="ortho"), atol=1e-3
    )
    np.testing.assert_allclose(
        ros._dct_iii_ortho(jnp.asarray(sf.dct(x, axis=-1, norm="ortho"))), x, atol=1e-3
    )


@pytest.mark.parametrize("transform", ["hadamard", "dct"])
@pytest.mark.parametrize("p", [100, 512, 784])
def test_precondition_unmix_roundtrip(transform, p):
    x = jax.random.normal(KEY, (6, p))
    y = ros.precondition(x, KEY, transform, p_orig=p)
    assert y.shape[-1] == ros.pad_len(p, transform)
    np.testing.assert_allclose(ros.unmix(y, KEY, transform, p_orig=p), x, atol=1e-4)
    # isometry survives padding
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=1), jnp.linalg.norm(x, axis=1), rtol=1e-4
    )


def test_smoothing_cor2():
    """Cor. 2: after ROS, max |entry| of unit-norm samples ≲ √(2/η·log(2np/α)/p)."""
    n, p = 256, 512
    x = jnp.zeros((n, p)).at[jnp.arange(n), jax.random.randint(KEY, (n,), 0, p)].set(1.0)
    # spiky input: ‖X‖_max = 1 (worst case). After ROS every entry is O(1/√p).
    y = ros.precondition(x, KEY, "hadamard")
    from repro.core.bounds import ros_max_entry_bound

    bound = ros_max_entry_bound(n, p, alpha=0.01)
    assert float(jnp.max(jnp.abs(y))) <= bound
    assert float(jnp.max(jnp.abs(y))) >= (1.0 - 1e-5) / np.sqrt(p)  # can't beat perfect spread


@pytest.mark.parametrize("seed", range(20))
def test_property_hd_is_orthonormal(seed):
    """Property: HD preserves inner products (orthonormality), any size/seed."""
    rng = np.random.default_rng(seed)
    p = 1 << int(rng.integers(1, 10))
    n = int(rng.integers(1, 9))
    key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
    x = jax.random.normal(key, (n, p))
    y = ros.precondition(x, key, "hadamard")
    np.testing.assert_allclose(y @ y.T, x @ x.T, atol=1e-3 * p)
