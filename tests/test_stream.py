"""Streaming sketch engine (repro.stream): streaming==batch, chunked FWHT at
large p, sharded==single-device, and mini-batch streaming K-means quality."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimators, kmeans as km, sampling, sketch
from repro.kernels import fwht, ref
from repro.stream import StreamEngine, StreamKMeansConfig, batch_key
from tests.conftest import make_clusters

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------- streaming equals batch ---

def test_streaming_equals_batch_mean_cov():
    """Engine over B=4 batches == one-shot Thm-4/Thm-6 estimators on the
    concatenation of the SAME per-(step, shard) sketches, to 1e-5."""
    p, m, b, steps = 64, 16, 40, 4
    spec = sketch.make_spec(p, jax.random.PRNGKey(1), m=m)
    x_all = jax.random.normal(KEY, (steps * b, p))

    def source(seed, step, shard):
        return np.asarray(x_all[step * b:(step + 1) * b])

    res = StreamEngine(spec, source, track_cov=True).run(steps)

    batches = [sketch.sketch(x_all[i * b:(i + 1) * b], spec,
                             batch_key=batch_key(spec, i, 0)) for i in range(steps)]
    s_all = sampling.SparseRows(jnp.concatenate([s.values for s in batches]),
                                jnp.concatenate([s.indices for s in batches]),
                                spec.p_pad)
    np.testing.assert_allclose(res.mean, estimators.mean_estimator(s_all),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(res.cov, estimators.cov_estimator(s_all),
                               rtol=1e-4, atol=1e-5)
    assert float(res.count) == steps * b


def test_engine_consumes_pipeline_source():
    """VectorStreamSource's (seed, step, shard) batch_at contract plugs in."""
    from repro.data.pipeline import VectorStreamSource

    src = VectorStreamSource(p=64, batch=32, seed=3)
    spec = sketch.make_spec(64, jax.random.PRNGKey(4), gamma=0.25)
    res = StreamEngine(spec, src, track_cov=False).run(3)
    assert res.mean.shape == (64,)
    assert float(res.count) == 96
    assert res.cov is None


def test_scanned_run_matches_eager_loop():
    """run_scanned (one lax.scan) is bit-identical to the step-at-a-time loop."""
    p, b, steps = 64, 32, 5
    spec = sketch.make_spec(p, jax.random.PRNGKey(5), gamma=0.25)
    data = jax.random.normal(KEY, (steps, 1, b, p))

    def source(seed, step, shard):
        return np.asarray(data[step, shard])

    eng = StreamEngine(spec, source, kmeans=StreamKMeansConfig(k=3, n_init=2))
    res_loop = eng.run(steps)
    res_scan = eng.run_scanned(np.asarray(data))
    np.testing.assert_array_equal(np.asarray(res_loop.mean), np.asarray(res_scan.mean))
    np.testing.assert_array_equal(np.asarray(res_loop.cov), np.asarray(res_scan.cov))
    np.testing.assert_array_equal(np.asarray(res_loop.centers), np.asarray(res_scan.centers))


# ------------------------------------------------------- chunked FWHT -------

@pytest.mark.parametrize("p", [1 << 16, 1 << 17])
def test_chunked_fwht_matches_reference_large_p(p):
    """The three-pass Kronecker schedule == the butterfly oracle above the old
    MAX_P = 2^15 single-tile ceiling (interpret mode, CPU)."""
    n = 2
    key = jax.random.PRNGKey(p)
    x = jax.random.normal(key, (n, p), jnp.float32)
    s = jax.random.rademacher(jax.random.fold_in(key, 1), (p,), jnp.float32)
    y = fwht.hd_precondition(x, s, interpret=True)
    np.testing.assert_allclose(y, ref.ref_hd_precondition(x, s), atol=5e-4)


@pytest.mark.slow
def test_chunked_fwht_three_factor_branch():
    """p = 2^19 exercises the a > 1 outer-factor pass (a=2, b=c=512)."""
    p = 1 << 19
    assert fwht.factor_p3(p) == (2, 512, 512)
    x = jax.random.normal(KEY, (1, p), jnp.float32)
    s = jax.random.rademacher(jax.random.PRNGKey(1), (p,), jnp.float32)
    y = fwht.hd_precondition_chunked(x, s, interpret=True)
    np.testing.assert_allclose(y, ref.ref_hd_precondition(x, s), atol=1e-3)


def test_factor_p3_properties():
    for logp in range(1, 28):
        a, b, c = fwht.factor_p3(1 << logp)
        assert a * b * c == 1 << logp
        assert max(a, b, c) <= 512
    with pytest.raises(ValueError):
        fwht.factor_p3(3 << 10)
    with pytest.raises(ValueError):
        fwht.factor_p3(1 << 28)


def test_ros_kernel_impl_roundtrip_large_p():
    """precondition(impl=interpret) routes through the chunked kernel and stays
    an isometry (so all the paper's guarantees carry over at p = 2^16)."""
    from repro.core import ros

    p = 1 << 16
    x = jax.random.normal(KEY, (2, p))
    y = ros.precondition(x, KEY, "hadamard", impl="interpret")
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=1),
                               jnp.linalg.norm(x, axis=1), rtol=1e-4)
    np.testing.assert_allclose(ros.unmix(y, KEY, "hadamard"), x, atol=1e-3)


# ------------------------------------------ mini-batch streaming K-means ----

def test_streaming_kmeans_matches_batch_accuracy():
    """One-pass mini-batch streaming K-means reaches >= the clustering accuracy
    of the full-Lloyd sparse_kmeans_core on the blobs fixture."""
    x, labels, true_centers = make_clusters(KEY, n=1500, p=128, k=5)
    b = 150
    spec = sketch.make_spec(128, jax.random.PRNGKey(2), gamma=0.25)

    def source(seed, step, shard):
        return np.asarray(x[step * b:(step + 1) * b])

    eng = StreamEngine(spec, source, kmeans=StreamKMeansConfig(k=5, n_init=3))
    res = eng.run(10)
    s_all = sketch.sketch(x, spec)
    acc_stream = km.clustering_accuracy(eng.assign(s_all), labels, 5)
    mu, a_b, _, _ = km.sparse_kmeans_core(s_all.values, s_all.indices, s_all.p, 5,
                                          spec.signs_key(), n_init=3, max_iter=50)
    acc_batch = km.clustering_accuracy(a_b, labels, 5)
    assert acc_stream >= acc_batch, (acc_stream, acc_batch)
    # unmixed centers land near the true generating centers
    from scipy.optimize import linear_sum_assignment

    d = np.linalg.norm(np.asarray(res.centers)[:, None, :]
                       - np.asarray(true_centers)[None], axis=-1)
    ri, ci = linear_sum_assignment(d)
    assert float(d[ri, ci].mean()) < 2.0


def test_stream_launcher_smoke(capsys):
    """The CLI driver wires source→engine→finalize end-to-end."""
    from repro.launch import stream as launch_stream

    launch_stream.main(["--p", "256", "--gamma", "0.1", "--steps", "2",
                        "--batch", "32", "--no-cov"])
    out = capsys.readouterr().out
    assert "streamed 64 rows" in out


# ------------------------------------------------------ sharded streaming ---

@pytest.mark.slow
def test_sharded_streaming_matches_single_device():
    """8-way shard_map streaming == single-device streaming, bit-for-bit here
    (identical per-(step, shard) sketches; one psum of the deltas per step).
    Subprocess so the test session keeps the real single device."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src", JAX_PLATFORMS="cpu")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import sketch
        from repro.stream import StreamEngine, StreamKMeansConfig

        mesh = jax.make_mesh((8,), ("data",))
        p, b, steps = 256, 16, 5
        spec = sketch.make_spec(p, jax.random.PRNGKey(1), gamma=0.25)
        data = jax.random.normal(jax.random.PRNGKey(0), (steps, 8, b, p))

        def source(seed, step, shard):
            return np.asarray(data[step, shard])

        cfg = dict(n_shards=8, kmeans=StreamKMeansConfig(k=4, n_init=2))
        res1 = StreamEngine(spec, source, **cfg).run(steps)
        res8 = StreamEngine(spec, source, mesh=mesh, **cfg).run(steps)
        np.testing.assert_allclose(np.asarray(res8.mean), np.asarray(res1.mean), atol=1e-5)
        np.testing.assert_allclose(np.asarray(res8.cov), np.asarray(res1.cov), atol=1e-5)
        np.testing.assert_allclose(np.asarray(res8.centers), np.asarray(res1.centers), atol=1e-5)
        assert float(res8.count) == steps * 8 * b

        # one-shot shard_map reductions handle row counts that don't divide the
        # mesh (zero-pad rows contribute nothing; count stays the true n)
        from repro.core import estimators
        from repro.stream import sharded as dist
        x = jax.random.normal(jax.random.PRNGKey(2), (100, p))
        s = sketch.sketch(x, spec)
        np.testing.assert_allclose(np.asarray(dist.sharded_mean(s, mesh)),
                                   np.asarray(estimators.mean_estimator(s)), atol=1e-5)
        np.testing.assert_allclose(np.asarray(dist.sharded_cov(s, mesh)),
                                   np.asarray(estimators.cov_estimator(s)), atol=1e-4)
        print("sharded-streaming OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
