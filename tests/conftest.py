"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512 host devices."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_clusters(key, n, p, k, sep=3.0, noise=0.5):
    """Well-separated Gaussian blobs (paper Fig. 6 style). Returns (X, labels, centers)."""
    import jax.numpy as jnp

    ck, lk, nk = jax.random.split(key, 3)
    centers = jax.random.normal(ck, (k, p)) * sep
    labels = jax.random.randint(lk, (n,), 0, k)
    x = centers[labels] + noise * jax.random.normal(nk, (n, p))
    return x, labels, centers
