"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512 host devices."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_clusters(key, n, p, k, sep=3.0, noise=0.5):
    """Well-separated Gaussian blobs (paper Fig. 6 style). Returns (X, labels, centers)."""
    import jax.numpy as jnp

    ck, lk, nk = jax.random.split(key, 3)
    centers = jax.random.normal(ck, (k, p)) * sep
    labels = jax.random.randint(lk, (n,), 0, k)
    x = centers[labels] + noise * jax.random.normal(nk, (n, p))
    return x, labels, centers


def spiked(key, n, p, k, noise=1e-2, lam_hi=10.0, lam_lo=7.0):
    """Spiked covariance model: k planted directions over a small iso floor.
    THE spectral test model (test_lowrank, test_refine; benchmarks keep their
    own copy in benchmarks/common.py — tests must not import benchmarks)."""
    import jax.numpy as jnp

    u, _ = jnp.linalg.qr(jax.random.normal(key, (p, k)))
    lam = jnp.linspace(lam_hi, lam_lo, k)
    z = jax.random.normal(jax.random.fold_in(key, 1), (n, k)) * lam
    return z @ u.T + noise * jax.random.normal(jax.random.fold_in(key, 2), (n, p))


def max_angle_sin(a, b):
    """Largest principal-angle sine between the row spaces of a and b, in f64
    (the angles of interest sit at/below f32 resolution)."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    a /= np.linalg.norm(a, axis=1, keepdims=True)
    b /= np.linalg.norm(b, axis=1, keepdims=True)
    s = np.linalg.svd(a @ b.T, compute_uv=False)
    return float(np.sqrt(np.maximum(0.0, 1.0 - s**2)).max())
