"""Low-rank spectral subsystem (repro.lowrank): spmm kernels vs oracles, the
range-finder's linear delta algebra, FD's deterministic guarantee, lowrank ≡
dense PCA subspace across batch/stream/sharded (ragged trailing step
included), engine + psum plumbing, O(l·p) memory, and the streaming K-means
satellites (reassignment-count convergence signal, decay/forgetting drift)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import lowrank as lr
from repro.api import Plan, SparsifiedCov, SparsifiedKMeans, SparsifiedPCA, fit_many, make_engine
from repro.core import estimators, sketch
from repro.core.sampling import SparseRows, sample_indices
from repro.kernels import ref, spmm as spmm_mod
from repro.stream import StreamEngine, StreamKMeansConfig, accumulators as acc
from repro.stream import sharded as sharded_mod
from tests.conftest import make_clusters, max_angle_sin, spiked as _spiked

KEY = jax.random.PRNGKey(0)
BACKENDS = ("batch", "stream", "sharded")


def spiked(n, p, k, **kw):
    return _spiked(KEY, n, p, k, **kw)


# ------------------------------------------------------- spmm kernels -------


@pytest.mark.parametrize("n,m,p,ell", [(16, 8, 64, 8), (8, 5, 32, 16), (33, 7, 128, 24)])
def test_spmm_kernels_match_oracle(n, m, p, ell):
    """Pallas spmm/spmm_t (interpret mode on CPU) == the jnp oracles; n=33
    exercises the ragged row-block padding (pad rows must contribute nothing)."""
    key = jax.random.fold_in(KEY, n * p)
    values = jax.random.normal(key, (n, m))
    indices = sample_indices(jax.random.fold_in(key, 1), n, p, m)
    dense = jax.random.normal(jax.random.fold_in(key, 2), (p, ell))

    t_ref = ref.ref_spmm(values, indices, dense)
    t_k = spmm_mod.spmm(values, indices, dense, interpret=True)
    np.testing.assert_allclose(np.asarray(t_k), np.asarray(t_ref), atol=1e-5)

    y_ref = ref.ref_spmm_t(values, indices, t_ref, p)
    y_k = spmm_mod.spmm_t(values, indices, t_ref, p, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=1e-4)


# --------------------------------------------------- accumulator algebra ----


def test_range_state_delta_algebra_is_linear():
    """Folding per-batch deltas == one delta of the concatenation — the
    property the per-step psum (and streaming == batch) rests on."""
    p, m, ell = 64, 16, 8
    spec = sketch.make_spec(p, jax.random.PRNGKey(1), m=m)
    om = lr.omega(spec.key, spec.p_pad, ell)
    x = jax.random.normal(KEY, (120, p))
    parts = [sketch.sketch(x[i * 40:(i + 1) * 40], spec,
                           batch_key=sketch.batch_key(spec, i, 0)) for i in range(3)]
    st = lr.range_init(spec.p_pad, ell)
    for s in parts:
        st = lr.range_update(st, s, om)
    s_all = SparseRows(jnp.concatenate([s.values for s in parts]),
                       jnp.concatenate([s.indices for s in parts]), spec.p_pad)
    one = lr.range_delta(s_all, om)
    np.testing.assert_allclose(np.asarray(st.y), np.asarray(one.y), rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st.diag), np.asarray(one.diag), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st.sum_w), np.asarray(one.sum_w),
                               rtol=1e-5, atol=1e-4)
    assert int(st.count) == int(one.count) == 120
    # mean finalize matches the Thm-4 estimator exactly
    np.testing.assert_allclose(np.asarray(lr.range_finalize_mean(st, m)),
                               np.asarray(estimators.mean_estimator(s_all)),
                               rtol=1e-5, atol=1e-5)


def test_fd_deterministic_guarantee():
    """Liberty's FD bound: 0 ≼ S − BᵀB ≼ (‖A‖_F²/(l−k))·I for every k < l."""
    p, m, ell = 32, 16, 12
    spec = sketch.make_spec(p, jax.random.PRNGKey(2), m=m)
    x = spiked(300, p, 3, noise=0.05)
    st = lr.fd_init(spec.p_pad, ell)
    parts = []
    for i in range(6):
        s = sketch.sketch(x[i * 50:(i + 1) * 50], spec,
                          batch_key=sketch.batch_key(spec, i, 0))
        parts.append(s)
        st = lr.fd_update(st, s)
    s_all = SparseRows(jnp.concatenate([s.values for s in parts]),
                       jnp.concatenate([s.indices for s in parts]), spec.p_pad)
    w = np.asarray(s_all.to_dense(), np.float64)
    s_mat = w.T @ w
    b = np.asarray(st.sketch, np.float64)
    gap = np.linalg.eigvalsh(s_mat - b.T @ b)
    fro2 = float(np.sum(w**2))
    assert gap.min() > -1e-2 * fro2 / ell          # PSD up to float error
    assert gap.max() <= fro2 / (ell - 3) + 1e-3 * fro2


# ----------------------------------- lowrank ≡ dense across the backends ----


@pytest.mark.parametrize("method", ("range", "fd"))
def test_lowrank_pca_subspace_all_backends(method):
    """cov_path="lowrank" recovers the dense-path top-k subspace on every
    backend; n=2150 with batch_size=200 leaves a ragged 150-row trailing step.
    Backends must agree on the lowrank result bit-for-bit (same linear folds,
    FD folds in the same sequential order everywhere)."""
    p, k, n, ell = 64, 4, 2150, 32
    x = spiked(n, p, k)
    dense = SparsifiedPCA(k, Plan(gamma=0.5, batch_size=200), key=3).fit(x)
    fits = {}
    for backend in BACKENDS:
        plan = Plan(backend=backend, gamma=0.5, batch_size=200, cov_path="lowrank",
                    rank=ell, lowrank_method=method)
        est = SparsifiedPCA(k, plan, key=3).fit(x)
        fits[backend] = est
        assert est.count_ == n
        assert est.cov_lowrank_ is not None
        assert est.components_.shape == (k, p)
        # small-scale bound; the tight 1e-3 acceptance bar runs in the slow
        # lane (test_lowrank_pca_acceptance_principal_angles) at its n
        assert max_angle_sin(est.components_, dense.components_) < 5e-2
        # eigenvalues track the dense spectrum (FD's shrink biases them low by
        # up to the accumulated δ — Liberty's bound — so it gets more slack)
        np.testing.assert_allclose(np.asarray(est.explained_variance_),
                                   np.asarray(dense.explained_variance_),
                                   rtol=0.1 if method == "range" else 0.3)
    for backend in ("stream", "sharded"):
        np.testing.assert_array_equal(np.asarray(fits[backend].components_),
                                      np.asarray(fits["batch"].components_))


@pytest.mark.slow
def test_lowrank_pca_acceptance_principal_angles():
    """The acceptance bar: Plan(cov_path="lowrank", rank=l ≥ 4k) recovers the
    dense-path top-k subspace to principal angles ≤ 1e-3 on the synthetic
    spiked model, on batch, stream, and sharded — with a ragged trailing
    step (80000 = 19.5 × 4096) and an O(l·p) accumulator throughout."""
    p, k, n, ell = 128, 4, 80000, 96
    x = spiked(n, p, k, noise=1e-3)
    plan0 = Plan(gamma=0.8, batch_size=4096)
    dense = SparsifiedPCA(k, plan0, key=3).fit(x)
    for backend in BACKENDS:
        plan = plan0.replace(backend=backend, cov_path="lowrank", rank=ell)
        est = SparsifiedPCA(k, plan, key=3).fit(x)
        sin = max_angle_sin(est.components_, dense.components_)
        assert sin <= 1e-3, (backend, sin)
        # the accumulator really is O(l·p): no leaf anywhere near (p, p)
        leaves = jax.tree.leaves(est._reducer.state)
        assert max(leaf.size for leaf in leaves) <= est.spec_.p_pad * ell


def test_lowrank_never_materializes_pp():
    """No (p, p) array exists anywhere in the lowrank reducer state."""
    p, ell = 256, 16
    x = spiked(1024, p, 4)
    est = SparsifiedPCA(4, Plan(backend="stream", gamma=0.25, batch_size=256,
                                cov_path="lowrank", rank=ell), key=1).fit(x)
    leaves = jax.tree.leaves(est._reducer.state)
    assert max(leaf.size for leaf in leaves) == p * ell  # y is the largest
    assert all(leaf.shape != (p, p) for leaf in leaves)
    assert est._reducer.parts == []                      # nothing retained
    assert est._reducer.state.nbytes() < 4 * p * p       # ≪ the (p,p) f32 acc
    assert est.cov_lowrank_.nbytes() <= (ell // 2 + 1) * p * 4 + ell * 4


# ---------------------------------------------------- engine + psum path ----


def test_engine_lowrank_matches_estimator_and_scan():
    """StreamEngine(cov_path="lowrank") == SparsifiedPCA.fit_stream over the
    identical (seed, step, shard) chunks, and run_scanned == run."""
    p, k, ell, b, steps = 64, 3, 16, 50, 8
    data = jax.random.normal(KEY, (steps, 1, b, p)) + 2.0

    def source(seed, step, shard):
        return np.asarray(data[step, shard])

    plan = Plan(backend="stream", gamma=0.5, batch_size=b, cov_path="lowrank", rank=ell)
    est = SparsifiedPCA(k, plan, key=9).fit_stream(source, steps=steps)

    eng = make_engine(plan, p, 9, source)
    res = eng.run(steps)
    assert res.cov is None and res.cov_lowrank is not None
    np.testing.assert_allclose(
        np.asarray(sketch.unmix_dense(res.mean[None], eng.spec)[0]),
        np.asarray(est.mean_), atol=1e-4)
    comps_pre, evals = res.cov_lowrank.top(k)
    comps = sketch.unmix_dense(comps_pre, eng.spec)
    # engine fuses sketch+delta+apply in ONE jit, the estimator in three —
    # float reordering through an eigensolve, so tight-but-not-bitwise
    assert max_angle_sin(comps, est.components_) < 1e-3
    np.testing.assert_allclose(np.asarray(evals),
                               np.asarray(est.explained_variance_), rtol=1e-4)

    res_scan = eng.run_scanned(np.asarray(data))
    np.testing.assert_allclose(np.asarray(res_scan.cov_lowrank.eigenvalues),
                               np.asarray(res.cov_lowrank.eigenvalues), rtol=1e-5)


def test_sharded_lowrank_psum_equals_local_delta():
    """sharded_lowrank (1-device mesh here; 8-device in the slow test) == the
    plain local delta, including the zero-pad ragged-rows path."""
    p, m, ell = 64, 16, 8
    spec = sketch.make_spec(p, jax.random.PRNGKey(4), m=m)
    om = lr.omega(spec.key, spec.p_pad, ell)
    s = sketch.sketch(jax.random.normal(KEY, (37, p)), spec)  # 37: pad path
    mesh = jax.make_mesh((1,), ("data",))
    st = sharded_mod.sharded_lowrank(s, om, mesh, ("data",))
    ref_delta = lr.range_delta(s, om)
    np.testing.assert_allclose(np.asarray(st.y), np.asarray(ref_delta.y), rtol=1e-5,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st.diag), np.asarray(ref_delta.diag), rtol=1e-5)
    assert int(st.count) == 37


@pytest.mark.slow
def test_sharded_lowrank_8dev_matches_single_device():
    """The fixed (p, l) delta psums across a REAL 8-device mesh to the
    single-device stream result (subprocess keeps this session on one device)."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src", JAX_PLATFORMS="cpu")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import sketch
        from repro.stream import StreamEngine

        mesh = jax.make_mesh((8,), ("data",))
        p, b, steps, ell = 128, 16, 5, 24
        spec = sketch.make_spec(p, jax.random.PRNGKey(1), gamma=0.25)
        data = jax.random.normal(jax.random.PRNGKey(0), (steps, 8, b, p))

        def source(seed, step, shard):
            return np.asarray(data[step, shard])

        cfg = dict(n_shards=8, cov_path="lowrank", rank=ell)
        eng1 = StreamEngine(spec, source, **cfg)
        eng8 = StreamEngine(spec, source, mesh=mesh, **cfg)
        res1, res8 = eng1.run(steps), eng8.run(steps)
        np.testing.assert_allclose(np.asarray(res8.mean), np.asarray(res1.mean), atol=1e-5)
        # the psum'd accumulator equals the sequential fold up to float
        # reordering (eigenVECTORS of this unstructured stream are nearly
        # degenerate, so the state — not the finalized basis — is the check)
        st1, st8 = eng1.state.lowrank, eng8.state.lowrank
        scale = float(jnp.abs(st1.y).max())
        np.testing.assert_allclose(np.asarray(st8.y), np.asarray(st1.y),
                                   atol=1e-5 * scale)
        np.testing.assert_allclose(np.asarray(st8.diag), np.asarray(st1.diag),
                                   rtol=1e-5)
        assert int(st8.count) == int(st1.count) == steps * 8 * b
        np.testing.assert_allclose(np.asarray(res8.cov_lowrank.eigenvalues),
                                   np.asarray(res1.cov_lowrank.eigenvalues), rtol=1e-4)
        print("sharded-lowrank-8dev OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"


# ------------------------------------------------------ fit_many fan-out ----


def test_fit_many_mixes_lowrank_and_dense_consumers(monkeypatch):
    """One shared sketch pass can feed a lowrank PCA and a dense Cov at once —
    cov_path/rank are fold choices, not sketch geometry."""
    calls = {"n": 0}
    real = sketch.sketch

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(sketch, "sketch", counting)
    x = spiked(600, 64, 4)
    plan = Plan(gamma=0.5, batch_size=200)
    pca_lr = SparsifiedPCA(4, plan.replace(cov_path="lowrank", rank=16), key=7)
    cov_d = SparsifiedCov(plan, key=7)
    run = fit_many(plan, [pca_lr, cov_d], x)
    assert calls["n"] == 3 == run.n_sketches
    sep = SparsifiedPCA(4, plan.replace(cov_path="lowrank", rank=16), key=7).fit(x)
    np.testing.assert_array_equal(np.asarray(pca_lr.components_),
                                  np.asarray(sep.components_))
    assert cov_d.cov_.shape == (64, 64)


# ------------------------------------------------------------ validation ----


def test_plan_lowrank_validation():
    with pytest.raises(ValueError, match="rank"):
        Plan(gamma=0.1, cov_path="lowrank")                 # rank required
    with pytest.raises(ValueError, match="rank"):
        Plan(gamma=0.1, rank=8)                             # rank needs lowrank
    with pytest.raises(ValueError, match="lowrank_method"):
        Plan(gamma=0.1, cov_path="lowrank", rank=8, lowrank_method="nyst")
    with pytest.raises(ValueError, match="cov_path"):
        Plan(gamma=0.1, cov_path="sparse")
    with pytest.raises(ValueError, match="PCA-only"):
        SparsifiedCov(Plan(gamma=0.5, cov_path="lowrank", rank=8), key=0).fit(
            jnp.ones((8, 16)))
    with pytest.raises(ValueError, match="exceeds"):       # rank > p_pad
        SparsifiedPCA(2, Plan(gamma=0.5, cov_path="lowrank", rank=64),
                      key=0).fit(jnp.ones((8, 16)))
    with pytest.raises(ValueError, match="n_components"):  # k > model rank
        SparsifiedPCA(5, Plan(gamma=0.5, cov_path="lowrank", rank=8),
                      key=0).fit(jnp.ones((8, 16)))
    with pytest.raises(ValueError, match="estimator-layer"):
        make_engine(Plan(backend="stream", gamma=0.5, cov_path="lowrank", rank=8,
                         lowrank_method="fd"), 16, 0, lambda s, t, sh: None)
    with pytest.raises(ValueError, match="rank"):
        StreamEngine(sketch.make_spec(16, KEY, gamma=0.5), lambda s, t, sh: None,
                     cov_path="lowrank")                   # engine needs rank too


# ------------------------------- streaming K-means satellites ----------------


def test_minibatch_reassignment_counts_converge():
    """Overlapping clusters keep flipping assignments early; the per-step
    reassignment counts decay as the online means settle — the convergence
    signal of the ROADMAP streaming-K-means item."""
    x, _, _ = make_clusters(KEY, n=3000, p=16, k=4, sep=1.0, noise=1.2)
    plan = Plan(backend="stream", gamma=0.5, batch_size=100)
    est = SparsifiedKMeans(4, plan, key=5, algorithm="minibatch").fit(x)
    h = est.reassign_counts_
    assert h is not None and len(h) == 30 and h.dtype.kind == "i"
    assert h[:15].sum() > 4 * h[15:].sum()      # early churn, late quiet
    assert est.reassign_fraction_.shape == (30,)
    assert float(est.reassign_fraction_[-1]) <= 0.05
    # lloyd never tracks (it is not a streaming fold)
    ll = SparsifiedKMeans(4, plan, key=5).fit(x)
    assert ll.reassign_counts_ is None
    # and tracking can be turned off
    off = SparsifiedKMeans(4, plan, key=5, algorithm="minibatch",
                           track_reassignments=False).fit(x)
    assert off.reassign_counts_ is None
    np.testing.assert_array_equal(np.asarray(off.centers_), np.asarray(est.centers_))


def test_kmeans_decay_tracks_drifting_stream():
    """The forgetting factor: when the clusters jump halfway through the
    stream, decayed counts let the centers follow; undecayed counts anchor
    them to stale history. Reassignment counts spike exactly at the drift."""
    from scipy.optimize import linear_sum_assignment

    k, p = 3, 32
    c1 = jax.random.normal(jax.random.fold_in(KEY, 1), (k, p)) * 3.0
    c2 = -c1

    def phase(centers, sub):
        lab = jax.random.randint(jax.random.fold_in(KEY, 10 + sub), (2000,), 0, k)
        return centers[lab] + 0.3 * jax.random.normal(
            jax.random.fold_in(KEY, 20 + sub), (2000, p))

    x = jnp.concatenate([phase(c1, 0), phase(c2, 1)])
    plan = Plan(backend="stream", gamma=0.5, batch_size=100)

    def dist_to(est, target):
        d = np.linalg.norm(np.asarray(est.centers_)[:, None]
                           - np.asarray(target)[None], axis=-1)
        ri, ci = linear_sum_assignment(d)
        return float(d[ri, ci].mean())

    plain = SparsifiedKMeans(k, plan, key=5, algorithm="minibatch").fit(x)
    dec = SparsifiedKMeans(k, plan, key=5, algorithm="minibatch", decay=0.5).fit(x)
    assert dist_to(dec, c2) < 1.0 < dist_to(plain, c2)
    assert dec._km_state.counts.dtype == jnp.float32     # decay ⇒ float counts
    assert plain._km_state.counts.dtype == jnp.int32     # default stays exact
    # the drift announces itself in the convergence signal: the spike at the
    # phase boundary (step 20) dwarfs the settled tail before it
    h = dec.reassign_counts_
    assert h[20:26].sum() > 10 * max(1, h[14:20].sum())


def test_kmeans_decay_validation_and_engine_plumbing():
    with pytest.raises(ValueError, match="decay"):
        SparsifiedKMeans(3, Plan(gamma=0.5), decay=1.5)
    with pytest.raises(ValueError, match="decay"):
        SparsifiedKMeans(3, Plan(gamma=0.5), decay=0.9)   # lloyd can't forget
    with pytest.raises(ValueError, match="decay"):
        StreamKMeansConfig(k=3, decay=0.0)

    # engine accepts the decay config and the run stays finite
    p, b = 32, 40
    x = jax.random.normal(KEY, (5, 1, b, p))

    def source(seed, step, shard):
        return np.asarray(x[step, shard])

    spec = sketch.make_spec(p, jax.random.PRNGKey(3), gamma=0.5)
    eng = StreamEngine(spec, source, track_cov=False,
                       kmeans=StreamKMeansConfig(k=3, n_init=2, decay=0.8))
    res = eng.run(5)
    assert np.isfinite(np.asarray(res.centers)).all()
    assert eng.state.kmeans.counts.dtype == jnp.float32
