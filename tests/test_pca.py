"""Sparsified PCA: planted-subspace recovery, streaming == batch, Table-I effect."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimators, pca, sampling, sketch

KEY = jax.random.PRNGKey(0)


def planted_data(key, n, p, k, lam):
    """x_i = Σ_j κ_ij λ_j u_j — the paper's generative model (§V experiments)."""
    ku, kk = jax.random.split(key)
    u, _ = jnp.linalg.qr(jax.random.normal(ku, (p, k)))
    kappa = jax.random.normal(kk, (n, k))
    x = (kappa * jnp.asarray(lam)[None, :]) @ u.T
    return x, u.T  # (n, p), (k, p)


def test_dense_pca_recovers_planted():
    x, u = planted_data(KEY, 2000, 64, 3, [10.0, 8.0, 6.0])
    res = pca.pca(x, 3)
    g = jnp.abs(res.components @ u.T)
    assert float(jnp.min(jnp.max(g, axis=1))) > 0.99


def test_sparsified_pca_recovers_planted():
    p, n, k = 256, 4096, 5
    x, u = planted_data(KEY, n, p, k, [10.0, 8.0, 6.0, 4.0, 2.0])
    spec = sketch.make_spec(p, jax.random.PRNGKey(1), gamma=0.3)
    s = sketch.sketch(x, spec)
    res = pca.sparsified_pca(s, spec, k)
    assert int(pca.recovered_components(res.components, u, thresh=0.9)) >= 4
    # explained variance close to ideal
    ev = float(pca.explained_variance(res.components, x))
    ev_ideal = float(pca.explained_variance(u, x))
    assert ev > 0.9 * ev_ideal


def test_streaming_pca_equals_batch():
    p, n, k = 128, 1024, 3
    x, u = planted_data(KEY, n, p, k, [10.0, 5.0, 2.0])
    spec = sketch.make_spec(p, jax.random.PRNGKey(2), gamma=0.4)
    st = estimators.stream_init(spec.p_pad)
    parts = []
    for i in range(4):
        b = sketch.sketch(x[i * 256 : (i + 1) * 256], spec, batch_key=jax.random.fold_in(spec.mask_key(), i))
        st = estimators.stream_update(st, b)
        parts.append(b)
    res_stream = pca.pca_from_stream(st, spec, k)
    allb = sampling.SparseRows(
        jnp.concatenate([b.values for b in parts]), jnp.concatenate([b.indices for b in parts]), spec.p_pad
    )
    res_batch = pca.sparsified_pca(allb, spec, k)
    np.testing.assert_allclose(res_stream.eigenvalues, res_batch.eigenvalues, rtol=1e-4)
    np.testing.assert_allclose(jnp.abs(res_stream.components @ res_batch.components.T),
                               jnp.eye(k), atol=1e-3)


def test_recovered_components_one_to_one():
    """Table-I metric: one estimate aligned with TWO true PCs is credited once.

    The old per-true-component max over the Gram matrix counted est[0] for both
    e0 and e1 here (inflating Table I); greedy one-to-one matching does not.
    """
    u = jnp.eye(4)[:2]                                    # true PCs: e0, e1
    est = jnp.stack([(u[0] + u[1]) / jnp.sqrt(2.0),       # overlaps both at 0.707
                     jnp.eye(4)[2]])                      # orthogonal to both
    assert int(pca.recovered_components(est, u, thresh=0.6)) == 1
    # a clean one-to-one alignment still counts fully (order/sign agnostic)
    est2 = jnp.stack([-u[1], u[0]])
    assert int(pca.recovered_components(est2, u, thresh=0.95)) == 2
    # nothing above threshold → zero
    assert int(pca.recovered_components(jnp.eye(4)[2:4], u, thresh=0.9)) == 0


def test_preconditioning_improves_pc_recovery():
    """Table I: spiky PCs (canonical basis vectors) need the ROS to be found."""
    p, n, k = 128, 1024, 5
    lam = jnp.asarray([10.0, 9.0, 8.0, 7.0, 6.0])
    u = jnp.eye(p)[:k]  # principal components are canonical basis vectors
    kappa = jax.random.normal(KEY, (n, k))
    x = (kappa * lam[None, :]) @ u

    gamma = 0.15
    spec = sketch.make_spec(p, jax.random.PRNGKey(3), gamma=gamma)
    s_pre = sketch.sketch(x, spec)
    rec_pre = int(pca.recovered_components(
        pca.sparsified_pca(s_pre, spec, k).components, u, thresh=0.9))

    s_raw = sampling.subsample(x, jax.random.PRNGKey(4), spec.m)
    res_raw = pca.sparsified_pca(s_raw, spec, k, preconditioned=False)
    rec_raw = int(pca.recovered_components(res_raw.components, u, thresh=0.9))
    assert rec_pre > rec_raw, f"precond {rec_pre} vs raw {rec_raw}"
