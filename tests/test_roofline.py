"""Roofline machinery: HLO collective parser, memory model, param counting."""
import jax
import numpy as np

from repro.configs.registry import get_arch, get_shape
from repro.roofline.analysis import count_params, model_flops, probe_depths
from repro.roofline.hlo import collective_stats
from repro.roofline.memmodel import peak_model

HLO = """
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %x), replica_groups=[16,16]<=[256], to_apply=%add
  %ag.1 = bf16[64,256]{1,0} all-gather(bf16[4,256]{1,0} %y), replica_groups=[16,16]<=[256], dimensions={0}
  %rs = f32[2,8]{1,0} reduce-scatter(f32[32,8]{1,0} %z), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[4,4]{1,0} collective-permute(f32[4,4]{1,0} %w), source_target_pairs={{0,1}}
  %aa = (f32[8,8]{1,0}) all-to-all(f32[8,8]{1,0} %v), replica_groups=[32,8]<=[256]
"""


def test_collective_parser_kinds_and_bytes():
    st = collective_stats(HLO)
    bk = st["by_kind"]
    # all-reduce: 2·B·(g−1)/g with g=16, B=16·128·4
    assert np.isclose(bk["all-reduce"]["wire_bytes"], 2 * 16 * 128 * 4 * 15 / 16)
    # all-gather: result bytes × (g−1)/g
    assert np.isclose(bk["all-gather"]["wire_bytes"], 64 * 256 * 2 * 15 / 16)
    # reduce-scatter uses the (larger) operand
    assert np.isclose(bk["reduce-scatter"]["wire_bytes"], 32 * 8 * 4 * 3 / 4)
    assert np.isclose(bk["collective-permute"]["wire_bytes"], 4 * 4 * 4)
    assert bk["all-to-all"]["count"] == 1
    assert st["total_wire_bytes"] > 0


def test_count_params_families():
    kimi = count_params(get_arch("kimi-k2-1t-a32b"))
    assert 0.9e12 < kimi["total"] < 1.2e12, kimi["total"]        # ~1T total
    assert 25e9 < kimi["active"] < 40e9, kimi["active"]           # ~32B active
    ds = count_params(get_arch("deepseek-coder-33b"))
    assert 30e9 < ds["total"] < 40e9, ds["total"]
    mb = count_params(get_arch("mamba2-1.3b"))
    assert 1.0e9 < mb["total"] < 1.8e9, mb["total"]
    q3 = count_params(get_arch("qwen3-moe-235b-a22b"))
    assert 2.0e11 < q3["total"] < 2.7e11 and 1.8e10 < q3["active"] < 2.6e10


def test_model_flops_scaling():
    cfg = get_arch("glm4-9b")
    t = model_flops(cfg, get_shape("train_4k"))
    p = model_flops(cfg, get_shape("prefill_32k"))
    assert np.isclose(t / p, 3.0, rtol=1e-6)      # 6ND vs 2ND at equal tokens
    d = model_flops(cfg, get_shape("decode_32k"))
    assert d < p / 1000                            # one token per sequence


def test_probe_depths_respect_period():
    assert probe_depths(get_arch("glm4-9b")) == (1, 2)
    assert probe_depths(get_arch("zamba2-1.2b")) == (6, 12)


def test_memmodel_sane_and_monotone():
    cfg = get_arch("glm4-9b")
    shape = get_shape("train_4k")
    n = count_params(cfg)["total"]
    m256 = peak_model(cfg, shape, 256, 16, 16, n)
    m512 = peak_model(cfg, shape, 512, 32, 16, n)
    assert m512["total"] < m256["total"]           # more chips → less per chip
    assert 2 << 30 < m256["total"] < 20 << 30      # sane absolute range
    # decode fits easily
    md = peak_model(cfg, get_shape("decode_32k"), 256, 16, 16, n)
    assert md["total"] < m256["total"]
