"""Sampling matrices: exact-m sparsity, distinctness, uniform marginals (Lemma B5).

Property-style sweeps are seeded pytest.mark.parametrize grids (no hypothesis
dependency): each case derives (shape, data) deterministically from its seed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sampling

KEY = jax.random.PRNGKey(0)


def test_exact_m_distinct_sorted():
    idx = sampling.sample_indices(KEY, 100, 64, 16)
    assert idx.shape == (100, 16)
    assert bool(jnp.all(jnp.diff(idx, axis=1) > 0))  # sorted & distinct
    assert bool(jnp.all((idx >= 0) & (idx < 64)))


def test_lemma_b5_uniform_marginals():
    """Each coordinate kept w.p. m/p — χ² sanity check over many draws."""
    n, p, m = 20000, 32, 8
    idx = sampling.sample_indices(KEY, n, p, m)
    counts = np.bincount(np.asarray(idx).ravel(), minlength=p)
    expected = n * m / p
    # std of binomial(n, m/p) ≈ √(n·γ(1−γ)); allow 5σ
    sigma = np.sqrt(n * (m / p) * (1 - m / p))
    assert np.all(np.abs(counts - expected) < 5 * sigma)


def test_subsample_to_dense_roundtrip():
    y = jax.random.normal(KEY, (10, 64))
    s = sampling.subsample(y, KEY, 16)
    d = s.to_dense()
    assert int(jnp.sum(d != 0)) <= 10 * 16
    # kept entries match the original exactly
    rows = jnp.arange(10)[:, None]
    np.testing.assert_allclose(d[rows, s.indices], s.values)
    np.testing.assert_allclose(s.values, y[rows, s.indices])


def test_sparserows_is_pytree():
    s = sampling.subsample(jax.random.normal(KEY, (4, 32)), KEY, 8)
    s2 = jax.tree.map(lambda a: a * 2, s)
    assert isinstance(s2, sampling.SparseRows)
    assert s2.p == 32
    np.testing.assert_allclose(s2.values, s.values * 2)
    # jit through it
    f = jax.jit(lambda sr: sr.to_dense().sum())
    f(s)


def test_norm_reduction_cor3():
    """Cor. 3: after preconditioning, ‖w‖² ≈ (m/p)·‖x‖² up to log factors."""
    from repro.core import ros
    from repro.core.bounds import rho_bound

    n, p, m = 128, 512, 64
    x = jnp.zeros((n, p)).at[:, 0].set(1.0)  # adversarial: all energy in one coord
    y = ros.precondition(x, KEY, "hadamard")
    s = sampling.subsample(y, jax.random.PRNGKey(1), m)
    ratios = jnp.sum(s.values**2, axis=1) / jnp.sum(x**2, axis=1)
    rho = rho_bound(n, p, m, alpha=0.01)
    assert float(jnp.max(ratios)) <= rho
    # without preconditioning the same data keeps either all or none of the norm
    s0 = sampling.subsample(x, jax.random.PRNGKey(2), m)
    r0 = jnp.sum(s0.values**2, axis=1) / jnp.sum(x**2, axis=1)
    assert set(np.unique(np.asarray(r0))) <= {0.0, 1.0}


def test_counts_per_coordinate_exact_past_2p24():
    """Regression: the Eq.-39 weights must stay exact past 2^24 rows per
    coordinate. A float32 scatter-add saturates there (16777216 + 1 == 16777216
    in f32), silently turning long-stream running means into a fixed-rate EMA;
    int32 accumulation folded in chunks stays exact."""
    p = 8
    chunk = jnp.zeros((1 << 16, 64), jnp.int32)          # 2^22 hits on coord 0
    total = jnp.zeros((p,), jnp.int32)
    for _ in range(4):                                   # … ×4 → exactly 2^24
        total = total + sampling.counts_per_coordinate(chunk, p)
    total = total + sampling.counts_per_coordinate(jnp.zeros((1, 3), jnp.int32), p)
    assert total.dtype == jnp.int32
    assert int(total[0]) == (1 << 24) + 3
    # the old failure mode, demonstrated: f32 cannot even represent the answer
    assert float(jnp.float32(1 << 24) + jnp.float32(3)) != float((1 << 24) + 3)
    # call sites that need float weights cast the exact counts (the dtype kwarg)
    as_f32 = sampling.counts_per_coordinate(chunk, p, dtype=jnp.float32)
    assert as_f32.dtype == jnp.float32 and float(as_f32[0]) == float(1 << 22)


def test_sparserows_gamma_deprecated():
    """γ is canonically m / p_pad (SketchSpec.gamma); the row-domain m / p is
    deprecated because the two disagree at padded (non-power-of-two) p."""
    s = sampling.subsample(jax.random.normal(KEY, (4, 32)), KEY, 8)
    with pytest.warns(DeprecationWarning, match="p_pad"):
        g = s.gamma
    assert g == 0.25
    # raw-constructed rows (the unpadded-p case the deprecation exists for)
    # warn too, and the warning points at the replacement
    raw = sampling.SparseRows(jnp.ones((2, 250)), jnp.tile(jnp.arange(250), (2, 1)),
                              p=1000)
    with pytest.warns(DeprecationWarning, match="spec.gamma") as rec:
        assert raw.gamma == 0.25
    assert len(rec) == 1


@pytest.mark.parametrize("seed", range(25))
def test_property_exact_sparsity(seed):
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 101))
    m = max(1, int(rng.uniform(0.05, 1.0) * p))
    key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
    y = jax.random.normal(key, (3, p)) + 1.0  # nonzero everywhere
    s = sampling.subsample(y, key, m)
    d = s.to_dense()
    assert bool(jnp.all(jnp.sum(d != 0, axis=1) == m))
