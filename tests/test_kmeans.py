"""Cluster solvers: accuracy on separated blobs, center consistency, baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kmeans as km
from tests.conftest import make_clusters

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def blobs():
    return make_clusters(KEY, n=1500, p=128, k=5)


def _center_err(c, true):
    from scipy.optimize import linear_sum_assignment

    d = np.linalg.norm(np.asarray(c)[:, None, :] - np.asarray(true)[None, :, :], axis=-1)
    ri, ci = linear_sum_assignment(d)
    return float(d[ri, ci].mean())


def test_standard_kmeans(blobs):
    x, labels, centers = blobs
    res = km.kmeans(x, 5, jax.random.PRNGKey(1), n_init=3, max_iter=50)
    assert km.clustering_accuracy(res.assignments, labels, 5) > 0.95
    assert _center_err(res.centers, centers) < 1.0


@pytest.mark.parametrize("precondition", [True, False])
def test_sparsified_kmeans(blobs, precondition):
    x, labels, centers = blobs
    res = km.sparsified_kmeans(
        x, 5, jax.random.PRNGKey(2), gamma=0.25, precondition=precondition, n_init=3, max_iter=50
    )
    assert km.clustering_accuracy(res.assignments, labels, 5) > 0.9
    if precondition:
        # one-pass center estimates are consistent (paper §VII-B)
        assert _center_err(res.centers, centers) < 2.0


def test_two_pass_improves_centers(blobs):
    x, labels, centers = blobs
    r1 = km.sparsified_kmeans(x, 5, jax.random.PRNGKey(3), gamma=0.15, n_init=3, max_iter=50)
    r2 = km.sparsified_kmeans(x, 5, jax.random.PRNGKey(3), gamma=0.15, two_pass=True, n_init=3, max_iter=50)
    assert _center_err(r2.centers, centers) <= _center_err(r1.centers, centers) + 1e-6


def test_feature_extraction_center_inconsistency(blobs):
    """Pseudo-inverse-lifted FE centers are far worse than sparsified centers —
    the paper's core argument for per-sample sampling operators (Fig. 9)."""
    x, labels, centers = blobs
    fe = km.feature_extraction_kmeans(x, 5, m=32, key=jax.random.PRNGKey(4), n_init=3, max_iter=50)
    sp = km.sparsified_kmeans(x, 5, jax.random.PRNGKey(5), gamma=0.25, n_init=3, max_iter=50)
    assert km.clustering_accuracy(fe.assignments, labels, 5) > 0.9  # assignments fine
    assert _center_err(fe.centers, centers) > 3 * _center_err(sp.centers, centers)


def test_feature_selection_runs(blobs):
    x, labels, _ = blobs
    fs = km.feature_selection_kmeans(x, 5, m=32, key=jax.random.PRNGKey(6), n_init=3, max_iter=50)
    assert km.clustering_accuracy(fs.assignments, labels, 5) > 0.8


def test_empty_cluster_guard():
    """K > #distinct points: counts==0 coordinates keep previous centers, no NaNs."""
    x = jnp.ones((10, 16))
    res = km.kmeans(x, 3, KEY, n_init=1, max_iter=5)
    assert bool(jnp.all(jnp.isfinite(res.centers)))


def test_sparse_assign_matches_dense_when_full():
    """γ=1 (m=p): sparsified metric reduces to the plain Euclidean metric."""
    x, _, _ = make_clusters(jax.random.PRNGKey(9), n=50, p=32, k=3)
    idx = jnp.tile(jnp.arange(32, dtype=jnp.int32)[None], (50, 1))
    d_sparse = km.sparse_sq_dists(x, idx, x[:3])
    d_dense = km.dense_sq_dists(x, x[:3])
    np.testing.assert_allclose(d_sparse, d_dense, rtol=1e-3, atol=1e-3)
