"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes + finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, get_arch
from repro.models.api import get_api, input_specs

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def make_batch(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
        batch["vision_embeds"] = jax.random.normal(KEY, (B, cfg.n_vision_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(KEY, (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_train_step(arch):
    cfg = get_arch(arch, reduced=True)
    api = get_api(cfg)
    params = api.init_params(KEY)
    batch = make_batch(cfg)

    def step(p):
        loss, metrics = api.loss_fn(p, batch, q_chunk=8, kv_chunk=8)
        return loss

    loss, grads = jax.value_and_grad(step)(params)
    assert np.isfinite(float(loss)), arch
    # sane initialization: loss near log(V)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 2.0, (arch, float(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), arch
    # at least one nonzero gradient
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat), arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_decode_step(arch):
    cfg = get_arch(arch, reduced=True)
    api = get_api(cfg)
    params = api.init_params(KEY)
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab_size)
    if cfg.family == "audio":
        from repro.models import encdec

        frames = jax.random.normal(KEY, (B, S, cfg.d_model))
        cache = encdec.init_decode_cache(params, frames, cfg, max_len=S, dtype=jnp.float32)
    else:
        cache = api.init_decode_state(B, S)
    logits, new_cache = api.decode_fn(params, tok, cache, jnp.int32(1))
    assert logits.shape == (B, cfg.vocab_size), arch
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_input_specs_cover_all_shapes(arch):
    """input_specs builds ShapeDtypeStructs for every runnable cell without allocation."""
    from repro.configs.base import SHAPES, cell_is_runnable

    cfg = get_arch(arch, reduced=True)
    for sname, shape in SHAPES.items():
        ok, _ = cell_is_runnable(arch, sname)
        if not ok:
            continue
        specs = input_specs(cfg, shape.reduced())
        leaves = jax.tree.leaves(specs)
        assert all(isinstance(l, (jax.ShapeDtypeStruct, int)) for l in leaves), (arch, sname)


def test_decode_matches_forward_dense():
    """Incremental decode reproduces the full forward logits (glm4 reduced)."""
    from repro.models import transformer as tr

    cfg = get_arch("glm4-9b", reduced=True)
    params = tr.init_lm_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, 8), 0, cfg.vocab_size)
    logits_full, _ = tr.forward(params, tokens, cfg, q_chunk=8, kv_chunk=8)
    cache = tr.init_kv_cache(cfg, B, 8, jnp.float32)
    outs = []
    for t in range(8):
        lg, cache = tr.decode_step(params, tokens[:, t : t + 1], cache, jnp.int32(t + 1), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, logits_full, atol=2e-2, rtol=2e-2)


def test_decode_matches_forward_gemma_pattern():
    """Sliding-window + dual-theta layers decode == forward (gemma3 reduced)."""
    from repro.models import transformer as tr

    cfg = get_arch("gemma3-1b", reduced=True)
    params = tr.init_lm_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, 16), 0, cfg.vocab_size)
    logits_full, _ = tr.forward(params, tokens, cfg, q_chunk=8, kv_chunk=8)
    cache = tr.init_kv_cache(cfg, B, 16, jnp.float32)
    outs = []
    for t in range(16):
        lg, cache = tr.decode_step(params, tokens[:, t : t + 1], cache, jnp.int32(t + 1), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, logits_full, atol=2e-2, rtol=2e-2)


def test_decode_matches_forward_hybrid():
    from repro.models import hybrid

    cfg = get_arch("zamba2-1.2b", reduced=True)
    params = hybrid.init_hybrid_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, 8), 0, cfg.vocab_size)
    logits_full = hybrid.forward(params, tokens, cfg, q_chunk=8, kv_chunk=8)
    state = hybrid.init_decode_state(cfg, B, 8, jnp.float32)
    outs = []
    for t in range(8):
        lg, state = hybrid.decode_step(params, tokens[:, t : t + 1], state, jnp.int32(t + 1), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, logits_full, atol=2e-2, rtol=2e-2)


def test_decode_matches_forward_mamba():
    from repro.models import mamba_lm

    cfg = get_arch("mamba2-1.3b", reduced=True)
    params = mamba_lm.init_mamba_lm_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, 8), 0, cfg.vocab_size)
    logits_full = mamba_lm.forward(params, tokens, cfg)
    state = mamba_lm.init_decode_state(cfg, B, jnp.float32)
    outs = []
    for t in range(8):
        lg, state = mamba_lm.decode_step(params, tokens[:, t : t + 1], state, jnp.int32(t + 1), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, logits_full, atol=2e-2, rtol=2e-2)


def test_prefill_matches_decode_tail():
    """prefill(prompt) then one decode == forward over prompt+1 (glm4 reduced)."""
    from repro.models import transformer as tr

    cfg = get_arch("glm4-9b", reduced=True)
    params = tr.init_lm_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, 9), 0, cfg.vocab_size)
    logits_full, _ = tr.forward(params, tokens, cfg, q_chunk=8, kv_chunk=8)
    pre_logits, cache = tr.prefill(params, tokens[:, :8], cfg, q_chunk=8, kv_chunk=8,
                                   cache_dtype=jnp.float32)
    np.testing.assert_allclose(pre_logits, logits_full[:, 7], atol=2e-2, rtol=2e-2)
    # pad cache to length 9 then decode token 9
    cache = {k: jnp.pad(v, ((0, 0),) * 2 + ((0, 1),) + ((0, 0),) * 2) for k, v in cache.items()}
    lg, _ = tr.decode_step(params, tokens[:, 8:9], cache, jnp.int32(9), cfg)
    np.testing.assert_allclose(lg, logits_full[:, 8], atol=2e-2, rtol=2e-2)
