"""The EngineState lifecycle protocol (repro.stream.state): serialization
roundtrips, the merge algebra, engine checkpoint → restore → continue
bit-identity, estimator-level crash recovery on every backend, refine() over a
restored state, and the elastic worker-remap parity (repro.cluster.elastic)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (Plan, SparsifiedCov, SparsifiedKMeans, SparsifiedMean,
                       SparsifiedPCA, fit_many, restore_run)
from repro.core import sketch as sketch_mod
from repro.cluster import continue_elastic, worker_shards
from repro.lowrank import fd_init, fd_update, range_init
from repro.stream import accumulators as acc
from repro.stream import state as state_mod
from repro.stream.engine import StreamEngine, StreamKMeansConfig
from repro.core.sampling import SparseRows

P_DIM = 32
B = 24


def _source(seed, step, shard):
    k = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed or 0), step), shard)
    return jax.random.normal(k, (B, P_DIM))


def _spec(key=0, gamma=0.4):
    return sketch_mod.make_spec(P_DIM, jax.random.PRNGKey(key), gamma=gamma)


def _sketch(spec, seed, step, shard):
    from repro.core.sketch import batch_key, sketch

    return sketch(_source(seed, step, shard), spec,
                  batch_key=batch_key(spec, step, shard))


# ------------------------------------------------------------- the protocol --


def test_to_from_arrays_roundtrip_all_kinds():
    spec = _spec()
    s = _sketch(spec, 0, 0, 0)
    st_m = acc.moment_apply(acc.moment_init(spec.p_pad, track_cov=True),
                            acc.moment_delta(s, track_cov=True))
    st_k = acc.kmeans_apply(
        acc.kmeans_init(jax.random.PRNGKey(1), s, 3), acc.kmeans_delta(
            acc.kmeans_init(jax.random.PRNGKey(1), s, 3), s))
    st_f = fd_update(fd_init(spec.p_pad, 8), s)
    for st in (st_m, st_k, st_f, range_init(spec.p_pad, 8)):
        arrs = state_mod.to_arrays(st)
        back = state_mod.from_arrays(arrs)
        assert type(back) is type(st)
        for leaf_a, leaf_b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))
    # optional field: a mean-only MomentState drops sum_wwt and restores None
    st_mean = acc.moment_init(spec.p_pad, track_cov=False)
    arrs = state_mod.to_arrays(st_mean)
    assert "moment.sum_wwt" not in arrs
    assert state_mod.from_arrays(arrs).sum_wwt is None
    # empty dict → no state
    assert state_mod.from_arrays({}) is None
    # kinds= restriction skips kinds the caller did not ask for
    assert state_mod.from_arrays(state_mod.to_arrays(st_k),
                                 kinds=("moment",)) is None


def test_merge_algebra():
    spec = _spec()
    s1, s2 = _sketch(spec, 0, 0, 0), _sketch(spec, 0, 0, 1)
    # moment: merge == having folded both (linear)
    init = lambda: acc.moment_init(spec.p_pad, track_cov=True)  # noqa: E731
    fold = lambda st, s: acc.moment_apply(st, acc.moment_delta(  # noqa: E731
        s, track_cov=True))
    both = fold(fold(init(), s1), s2)
    merged = state_mod.merge(fold(init(), s1), fold(init(), s2))
    np.testing.assert_allclose(np.asarray(merged.sum_w), np.asarray(both.sum_w),
                               atol=1e-5)
    assert int(merged.count) == int(both.count)
    # kmeans: count-weighted center merge == folding both delta streams
    km0 = acc.kmeans_init(jax.random.PRNGKey(2), s1, 3)
    a = acc.kmeans_apply(km0, acc.kmeans_delta(km0, s1))
    b = acc.kmeans_apply(km0, acc.kmeans_delta(km0, s2))
    m = state_mod.merge(a, b)
    seq = acc.kmeans_apply(km0, tuple(
        x + y for x, y in zip(acc.kmeans_delta(km0, s1),
                              acc.kmeans_delta(km0, s2))))
    np.testing.assert_allclose(np.asarray(m.centers), np.asarray(seq.centers),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(m.counts), np.asarray(seq.counts))
    # fd: merge keeps the sketch width and adds the scalars
    fa = fd_update(fd_init(spec.p_pad, 8), s1)
    fb = fd_update(fd_init(spec.p_pad, 8), s2)
    fm = state_mod.merge(fa, fb)
    assert fm.sketch.shape == fa.sketch.shape
    assert int(fm.count) == int(fa.count) + int(fb.count)
    # cross-kind merges refuse
    with pytest.raises(TypeError, match="cannot merge"):
        state_mod.merge(a, fa)
    with pytest.raises(TypeError, match="not a registered"):
        state_mod.kind_of(object())


# ----------------------------------------------- engine checkpoint/restore --


def test_engine_checkpoint_restore_continue_bit_identical(tmp_path):
    """Crash mid-stream at step 3 of 7: restore from the periodic checkpoint
    and continue — the final state is BIT-identical to the uninterrupted run
    (the (seed, step, shard) contract regenerates everything not stored)."""
    spec = _spec()
    km = StreamKMeansConfig(k=3, n_init=2, track_reassignments=True)
    mk = lambda: StreamEngine(spec, _source, n_shards=2, kmeans=km)  # noqa: E731

    full = mk().run(7, seed=5)
    eng = mk()
    eng.run(7, seed=5, checkpoint_dir=str(tmp_path), checkpoint_every=3)

    # a fresh process: new engine, restore, continue from the LATEST (step-6)
    # checkpoint — then also from the step-3 one via a second dir
    eng2 = mk()
    state, next_step = eng2.restore_state(str(tmp_path))
    assert next_step == 6
    res = eng2.run(7, seed=5, state=state, start_step=next_step)
    np.testing.assert_array_equal(np.asarray(res.mean), np.asarray(full.mean))
    np.testing.assert_array_equal(np.asarray(res.cov), np.asarray(full.cov))
    np.testing.assert_array_equal(np.asarray(res.centers),
                                  np.asarray(full.centers))
    np.testing.assert_array_equal(res.reassign_total, full.reassign_total)
    assert int(res.count) == int(full.count) == 7 * 2 * B


def test_engine_reassign_counts_from_run():
    """run() surfaces the per-step reassignment counts computed INSIDE the
    jitted update: (steps, n_init) history plus running totals."""
    spec = _spec()
    km = StreamKMeansConfig(k=3, n_init=2, track_reassignments=True)
    res = StreamEngine(spec, _source, n_shards=2, kmeans=km).run(5, seed=1)
    assert res.reassign_counts.shape == (5, 2)
    np.testing.assert_array_equal(res.reassign_counts.sum(0), res.reassign_total)
    np.testing.assert_array_equal(res.reassign_counts[-1], res.reassign_last)
    # every count is bounded by the rows folded that step
    assert (res.reassign_counts <= 2 * B).all()


def test_engine_state_arrays_roundtrip():
    spec = _spec()
    km = StreamKMeansConfig(k=3, n_init=2, track_reassignments=True)
    eng = StreamEngine(spec, _source, n_shards=2, kmeans=km)
    eng.run(3, seed=2)
    arrs = state_mod.engine_to_arrays(eng.state)
    back = state_mod.engine_from_arrays(arrs)
    for a, b in zip(jax.tree.leaves(eng.state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- elastic re-sharding --


def test_worker_shards_partition():
    for n_shards, n_workers in ((8, 3), (4, 4), (5, 2)):
        blocks = [worker_shards(n_shards, n_workers, w) for w in range(n_workers)]
        flat = [s for b in blocks for s in b]
        assert flat == list(range(n_shards))  # disjoint, contiguous, complete
    with pytest.raises(ValueError, match="idle"):
        worker_shards(2, 4, 0)
    with pytest.raises(ValueError, match="worker must be"):
        worker_shards(4, 2, 2)


def test_elastic_remap_4_to_2_parity(tmp_path):
    """Checkpoint a 4-shard run at step 3, then finish it under a 2-worker
    layout: each worker replays only the shards its new block owns, deltas
    merge and apply once per step — final state matches the uninterrupted
    run to float-summation reordering (1e-5)."""
    spec = _spec()
    km = StreamKMeansConfig(k=3, n_init=2)
    mk = lambda: StreamEngine(spec, _source, n_shards=4, kmeans=km)  # noqa: E731

    full = mk().run(6, seed=9)
    eng = mk()
    eng.run(3, seed=9)
    eng.save_state(str(tmp_path), 3, seed=9)
    eng2 = mk()
    state, next_step = eng2.restore_state(str(tmp_path))
    assert next_step == 3
    continue_elastic(eng2, 6, state=state, start_step=3, n_workers=2, seed=9)
    res = eng2.finalize()
    np.testing.assert_allclose(np.asarray(res.mean), np.asarray(full.mean),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(res.cov), np.asarray(full.cov),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(res.centers),
                               np.asarray(full.centers), atol=1e-5)
    assert int(res.count) == int(full.count)


# ------------------------------------------- estimator crash recovery --------


@pytest.mark.parametrize("backend", ("batch", "stream", "sharded"))
def test_estimator_checkpoint_restore_continue(backend, tmp_path):
    """Crash mid-ingest: checkpoint after half the rows, restore into a FRESH
    estimator, fold the rest — fitted results equal the uninterrupted fit
    exactly (the restored cursor resumes at the same chunk index, so the
    remaining chunks fold under identical (step, shard) mask keys)."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (8 * B, P_DIM)))
    plan = Plan(backend=backend, gamma=0.4, batch_size=B,
                n_shards=1 if backend != "sharded" else 1)
    ref = SparsifiedCov(plan, key=3).fit(x)

    est = SparsifiedCov(plan, key=3)
    est.partial_fit(x[:4 * B])
    est.checkpoint(str(tmp_path))
    del est

    est2 = SparsifiedCov(plan, key=3).restore(str(tmp_path))
    est2.partial_fit(x[4 * B:])
    est2.finalize()
    np.testing.assert_array_equal(np.asarray(est2.cov_), np.asarray(ref.cov_))
    np.testing.assert_array_equal(np.asarray(est2.mean_), np.asarray(ref.mean_))
    assert est2.count_ == ref.count_ == 8 * B


def test_kmeans_minibatch_checkpoint_restore(tmp_path):
    """The K-means fold state (centers/counts/obj) and the reassignment
    history both survive the round trip; continuation is bit-identical."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (8 * B, P_DIM)))
    plan = Plan(backend="stream", gamma=0.4, batch_size=B, n_shards=2)
    ref = SparsifiedKMeans(3, plan, key=5, algorithm="minibatch").fit(x)

    est = SparsifiedKMeans(3, plan, key=5, algorithm="minibatch")
    est.partial_fit(x[:4 * B])
    est.checkpoint(str(tmp_path))
    est2 = SparsifiedKMeans(3, plan, key=5, algorithm="minibatch")
    est2.restore(str(tmp_path))
    est2.partial_fit(x[4 * B:])
    est2.finalize()
    np.testing.assert_array_equal(np.asarray(est2.centers_),
                                  np.asarray(ref.centers_))
    np.testing.assert_array_equal(est2.reassign_counts_, ref.reassign_counts_)


def test_refine_over_restored_state(tmp_path):
    """refine() on a restored estimator == refine() on the original: the
    checkpoint carries everything the replay needs."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (8 * B, P_DIM)))
    plan = Plan(backend="stream", gamma=0.5, batch_size=B)
    ref = SparsifiedKMeans(3, plan, key=7, algorithm="minibatch").fit(x)
    ref.refine(x, passes=1)

    est = SparsifiedKMeans(3, plan, key=7, algorithm="minibatch").fit(x)
    est.checkpoint(str(tmp_path))
    est2 = SparsifiedKMeans(3, plan, key=7, algorithm="minibatch")
    est2.restore(str(tmp_path)).finalize()
    est2.refine(x, passes=1)
    np.testing.assert_array_equal(np.asarray(est2.centers_),
                                  np.asarray(ref.centers_))
    assert est2.refine_passes_ == ref.refine_passes_ == 1


def test_fused_run_checkpoint_restore(tmp_path):
    """A SharedSketchRun checkpoints every consumer + the ONE shared cursor;
    restore_run resumes the shared pass bit-identically."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (8 * B, P_DIM)))
    plan = Plan(backend="stream", gamma=0.4, batch_size=B)
    mk = lambda: [SparsifiedMean(plan, key=1),  # noqa: E731
                  SparsifiedKMeans(3, plan, key=1, algorithm="minibatch")]
    ref_mean, ref_km = mk()
    fit_many(plan, [ref_mean, ref_km], x)

    c1 = mk()
    run = fit_many(plan, c1, x[:4 * B], finalize=False)
    run.checkpoint(str(tmp_path))
    c2 = mk()
    run2 = restore_run(str(tmp_path), plan, c2)
    assert run2.count == 4 * B
    run2.partial_fit(x[4 * B:]).finalize()
    np.testing.assert_array_equal(np.asarray(c2[0].mean_),
                                  np.asarray(ref_mean.mean_))
    np.testing.assert_array_equal(np.asarray(c2[1].centers_),
                                  np.asarray(ref_km.centers_))
    # wrong consumer count refuses
    with pytest.raises(ValueError, match="consumers"):
        restore_run(str(tmp_path), plan, [SparsifiedMean(plan, key=1)])


def test_no_bespoke_export_path_left():
    """The tentpole's grep check: the bespoke _export_state path is gone —
    every layer speaks SketchedEstimator.state_arrays / the stream.state
    protocol."""
    import repro.api.estimators as est_mod
    import repro.sketchserve.snapshot as snap_mod

    assert not hasattr(SparsifiedPCA(2, Plan(gamma=0.5)), "_export_state")
    for mod in (est_mod, snap_mod):
        src = open(mod.__file__).read()
        assert "_export_state" not in src


@pytest.mark.slow
def test_sharded_crash_recovery_4_devices(tmp_path):
    """Crash recovery under the REAL sharded backend (4 forced host devices,
    subprocess): checkpoint mid-stream, restore in a new estimator, continue —
    equal to the uninterrupted sharded fit."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src", JAX_PLATFORMS="cpu")
    code = textwrap.dedent(f"""
        import jax, numpy as np
        from repro.api import Plan, SparsifiedCov, SparsifiedKMeans

        B = {B}
        x = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (16 * B, {P_DIM})))
        plan = Plan(backend="sharded", gamma=0.4, batch_size=B, n_shards=4)
        for cls, kw in ((SparsifiedCov, {{}}),
                        (SparsifiedKMeans, dict(k=3, algorithm="minibatch"))):
            args = (kw.pop("k"),) if "k" in kw else ()
            ref = cls(*args, plan, key=3, **kw).fit(x)
            est = cls(*args, plan, key=3, **kw)
            est.partial_fit(x[:8 * B])
            est.checkpoint({str(tmp_path)!r})
            est2 = cls(*args, plan, key=3, **kw).restore({str(tmp_path)!r})
            est2.partial_fit(x[8 * B:])
            est2.finalize()
            a = est2.cov_ if hasattr(est2, "cov_") else est2.centers_
            b = ref.cov_ if hasattr(ref, "cov_") else ref.centers_
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout
