"""The fused one-pass sketch kernel vs its composed oracle, and its wiring
into the real ingest path (kernels.ops dispatch + core.sketch).

The kernel is the streaming-ingest fast path (precondition → sample in one
VMEM round trip); these tests pin (a) oracle parity across the Kronecker
regimes and ragged row counts, (b) the dispatch seams — the composed
chunked-FWHT + gather fallback above the single-tile ceiling, and (c) that
``core.sketch`` produces the SAME sketch through the fused path as through
the jnp butterfly path (bit-identical indices; values to float tolerance).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch as sk
from repro.core.sampling import sample_indices
from repro.kernels import fwht, ops, ref, sketch_fused

KEY = jax.random.PRNGKey(0)


def _case(seed, n, p, m):
    key = jax.random.fold_in(KEY, seed)
    x = jax.random.normal(key, (n, p), jnp.float32)
    s = jax.random.rademacher(jax.random.fold_in(key, 1), (p,), jnp.float32)
    idx = jnp.sort(jax.lax.top_k(jax.random.uniform(
        jax.random.fold_in(key, 2), (n, p)), m)[1].astype(jnp.int32), axis=-1)
    return x, s, idx


@pytest.mark.parametrize("n,p,m", [
    (10, 128, 8),     # a == 1 (p ≤ 256: single Kronecker factor)
    (33, 256, 16),    # a == 1 boundary
    (9, 512, 32),     # a > 1 (two-factor Kronecker)
    (21, 4096, 64),   # a > 1, wide
])
def test_fused_matches_composed_oracle(n, p, m):
    x, s, idx = _case(n * p, n, p, m)
    a, b = fwht.factor_p(p)
    assert (a == 1) == (p <= 256)
    y = sketch_fused.sketch_fused(x, s, idx, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.ref_sketch_fused(x, s, idx)),
                               atol=3e-4)


@pytest.mark.parametrize("n", [1, 7, 127, 130])
def test_fused_ragged_row_counts(n):
    """Row counts that don't divide block_rows exercise the pad/slice path."""
    x, s, idx = _case(1000 + n, n, 512, 24)
    y = sketch_fused.sketch_fused(x, s, idx, interpret=True)
    assert y.shape == (n, 24)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.ref_sketch_fused(x, s, idx)),
                               atol=3e-4)


def test_fused_rejects_past_single_tile_ceiling():
    n, p, m = 2, 2 * fwht.MAX_P_SINGLE, 8
    x, s, idx = _case(7, n, p, m)
    with pytest.raises(ValueError, match="ceiling"):
        sketch_fused.sketch_fused(x, s, idx, interpret=True)


def test_ops_dispatch_modes_agree():
    x, s, idx = _case(3, 12, 512, 32)
    y_i = ops.sketch_fused(x, s, idx, mode="interpret")
    y_r = ops.sketch_fused(x, s, idx, mode="ref")
    np.testing.assert_allclose(np.asarray(y_i), np.asarray(y_r), atol=3e-4)


@pytest.mark.slow
def test_ops_composed_fallback_above_ceiling():
    """p > MAX_P_FUSED: kernel modes compose chunked FWHT + gather instead of
    erroring — same values as the oracle."""
    n, p, m = 4, 2 * fwht.MAX_P_SINGLE, 16
    x, s, idx = _case(11, n, p, m)
    y = ops.sketch_fused(x, s, idx, mode="interpret")
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.ref_sketch_fused(x, s, idx)),
                               atol=2e-3)


@pytest.mark.parametrize("p,gamma", [(512, 0.1), (300, 0.2), (2048, 0.05)])
def test_core_sketch_fused_path_equals_jnp_path(p, gamma):
    """core.sketch impl="interpret" takes the fused kernel path; it must
    produce the SAME sketch as the jnp butterfly + subsample path — indices
    bit-identical (same key, same draw shape), values to float tolerance.
    p=300 exercises the non-pow2 pad inside the fused branch."""
    n = 40
    x = jax.random.normal(jax.random.fold_in(KEY, p), (n, p), jnp.float32)
    spec = sk.make_spec(p, jax.random.PRNGKey(5), gamma=gamma)
    s_fused = sk.sketch(x, spec, impl="interpret")
    s_jnp = sk.sketch(x, spec, impl="jnp")
    assert s_fused.p == s_jnp.p == spec.p_pad
    np.testing.assert_array_equal(np.asarray(s_fused.indices),
                                  np.asarray(s_jnp.indices))
    np.testing.assert_allclose(np.asarray(s_fused.values),
                               np.asarray(s_jnp.values), atol=3e-4)


def test_fused_branch_index_draw_matches_subsample():
    """The fused branch draws indices with sample_indices under the SAME
    (key, (n, p_pad)) as subsample's internal draw — the PRNG contract that
    keeps the two ingest paths interchangeable mid-stream."""
    p, m, n = 512, 51, 13
    spec = sk.make_spec(p, jax.random.PRNGKey(9), m=m)
    x = jax.random.normal(KEY, (n, p), jnp.float32)
    s_jnp = sk.sketch(x, spec, impl="jnp")
    idx = sample_indices(spec.mask_key(), n, spec.p_pad, m)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(s_jnp.indices))
