"""Serving engine semantics + the fused sketch kernel vs its composition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core import ros, sampling
from repro.kernels.sketch_fused import sketch_fused
from repro.models.api import get_api
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("p,m,n", [(256, 16, 10), (1024, 64, 33)])
def test_sketch_fused_matches_composition(p, m, n):
    x = jax.random.normal(KEY, (n, p), jnp.float32)
    signs = jax.random.rademacher(jax.random.PRNGKey(1), (p,), jnp.float32)
    idx = sampling.sample_indices(jax.random.PRNGKey(2), n, p, m)
    fused = sketch_fused(x, signs, idx, interpret=True)
    y = ros.fwht(x * signs[None, :])
    ref = jnp.take_along_axis(y, idx, axis=-1)
    np.testing.assert_allclose(fused, ref, atol=2e-4)


def test_sketch_fused_equals_core_sketch():
    """Fused kernel reproduces core.sketch's values given the same indices."""
    from repro.core import sketch as sk

    p, n = 512, 12
    x = jax.random.normal(KEY, (n, p), jnp.float32)
    spec = sk.make_spec(p, jax.random.PRNGKey(3), gamma=0.1)
    s = sk.sketch(x, spec)
    signs = ros.signs_for(spec.signs_key(), spec.p_pad, jnp.float32)
    fused = sketch_fused(x, signs, s.indices, interpret=True)
    np.testing.assert_allclose(fused, s.values, atol=2e-4)


def test_serve_engine_greedy_matches_sequential():
    """Wave-batched engine output == one-by-one greedy decoding."""
    cfg = get_arch("glm4-9b", reduced=True)
    api = get_api(cfg)
    params = api.init_params(KEY)

    prompts = [np.array([3, 5, 7], np.int32), np.array([11, 13, 17], np.int32)]
    eng = ServeEngine(api, params, n_slots=2, max_len=16)
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr, max_new=4))
    done = eng.run()
    assert len(done) == 2 and all(r.done and len(r.out) == 4 for r in done)

    # sequential reference per request (same right-aligned batch semantics)
    for r, pr in zip(done, prompts):
        cache = api.init_decode_state(1, 16)
        tok = None
        for t, token in enumerate(pr):
            tok, cache = api.decode_fn(params, jnp.asarray([[token]], jnp.int32),
                                       cache, jnp.int32(t + 1))
        outs = [int(jnp.argmax(tok, -1)[0])]
        for s in range(3):
            tok, cache = api.decode_fn(params, jnp.asarray([[outs[-1]]], jnp.int32),
                                       cache, jnp.int32(len(pr) + s + 2))
            outs.append(int(jnp.argmax(tok, -1)[0]))
        assert outs == r.out, (outs, r.out)


def test_serve_engine_multiple_waves():
    cfg = get_arch("mamba2-1.3b", reduced=True)
    api = get_api(cfg)
    params = api.init_params(KEY)
    eng = ServeEngine(api, params, n_slots=2, max_len=12)
    for i in range(5):  # 5 requests > 2 slots → 3 waves
        eng.submit(Request(rid=i, prompt=np.array([1 + i, 2 + i], np.int32), max_new=3))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out) == 3 for r in done)
