"""repro.cluster bootstrap units + the 2-process jax.distributed smoke lane.

The slow test is the CI acceptance gate for multi-host ingest: two REAL OS
processes (gloo CPU collectives) run the same sharded fit — engine and
estimator layer — and must match a single-process run to 1e-5. Everything the
processes exchange is the per-step psum'd delta; the data itself regenerates
per-host from the (seed, step, shard) contract.
"""
import json
import os
import socket
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro import cluster
from repro.api.plan import mesh_from_spec, mesh_spec

# --------------------------------------------------------- bootstrap units --


def test_initialize_single_process_is_noop():
    assert cluster.initialize() is False
    assert cluster.initialize(num_processes=1) is False
    assert cluster.is_multiprocess() is False


def test_process_mesh_contiguous_and_cached():
    m = cluster.process_mesh(1)
    assert m.axis_names == ("data",)
    assert m.devices.shape == (1,)
    assert cluster.process_mesh(1) is m  # cached → shard_map caches stay warm
    with pytest.raises(ValueError, match="devices"):
        cluster.process_mesh(4096)


def test_local_shards_single_process_owns_all():
    m = cluster.process_mesh(1)
    assert cluster.local_shards(m) == [0]
    with pytest.raises(ValueError, match="1-D"):
        cluster.local_shards(jax.sharding.Mesh(
            np.array(jax.devices()[:1]).reshape(1, 1), ("a", "b")))


def test_global_rows_single_process():
    m = cluster.process_mesh(1)
    arr = np.arange(12, dtype=np.float32).reshape(4, 3)
    out = cluster.global_rows(arr, m)
    np.testing.assert_array_equal(np.asarray(out), arr)
    assert out.sharding.mesh.axis_names == ("data",)


def test_mesh_spec_roundtrip():
    m = jax.make_mesh((1,), ("data",))
    spec = mesh_spec(m)
    assert spec == {"axis_names": ["data"], "shape": [1]}
    m2 = mesh_from_spec(spec)
    assert m2.axis_names == ("data",)
    assert dict(m2.shape) == {"data": 1}
    assert mesh_spec(None) is None
    assert mesh_from_spec(None) is None


# ------------------------------------------------- the 2-process smoke lane --

_FIT = """
import jax
import numpy as np
from repro.api import Plan, SparsifiedCov, SparsifiedKMeans, fit_many
from repro.core import sketch as sketch_mod
from repro.stream.engine import StreamEngine, StreamKMeansConfig

B, P = 32, 24

def source(seed, step, shard):
    k = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed or 0), step), shard)
    return jax.random.normal(k, (B, P))

def run(mesh):
    plan = Plan(backend="sharded", gamma=0.4, batch_size=B, n_shards=2)
    cov = SparsifiedCov(plan, key=7)
    km = SparsifiedKMeans(3, plan, key=7, algorithm="minibatch")
    fit_many(plan, [cov, km], source=source, steps=5, seed=11)

    spec = sketch_mod.make_spec(P, jax.random.PRNGKey(7), gamma=0.4)
    eng = StreamEngine(spec, source, n_shards=2, mesh=mesh,
                       kmeans=StreamKMeansConfig(3, n_init=2))
    res = eng.run(5, seed=11)
    return {
        "mean": np.asarray(cov.mean_).tolist(),
        "cov_tr": float(np.trace(np.asarray(cov.cov_))),
        "count": int(cov.count_),
        "centers": np.asarray(km.centers_).tolist(),
        "reassign": np.asarray(km.reassign_counts_).tolist(),
        "eng_mean": np.asarray(res.mean).tolist(),
        "eng_cov_tr": float(np.trace(np.asarray(res.cov))),
        "eng_centers": np.asarray(res.centers).tolist(),
        "eng_count": int(res.count),
    }
"""

_WORKER = _FIT + """
import sys
from repro import cluster

pid, nproc, port = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
cluster.initialize(f"127.0.0.1:{port}", nproc, pid)
out = run(cluster.process_mesh(2))
if pid == 0:
    import json
    print("RESULT" + json.dumps(out))
"""

_REF = _FIT + """
import json
print("RESULT" + json.dumps(run(jax.make_mesh((2,), ("data",)))))
"""


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@pytest.mark.slow
def test_two_process_sharded_matches_single_process(tmp_path):
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env.update(PYTHONPATH="src", JAX_PLATFORMS="cpu")

    worker = tmp_path / "worker.py"
    worker.write_text(textwrap.dedent(_WORKER))
    port = _free_port()
    procs = [subprocess.Popen(
        [sys.executable, str(worker), str(pid), "2", str(port)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for pid in range(2)]
    outs = [p.communicate(timeout=600) for p in procs]
    for p, (o, e) in zip(procs, outs):
        assert p.returncode == 0, f"worker failed:\n{e[-4000:]}"
    got = json.loads(outs[0][0].split("RESULT", 1)[1])

    ref_env = dict(env, XLA_FLAGS="--xla_force_host_platform_device_count=2")
    ref_out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_REF)], env=ref_env,
        capture_output=True, text=True, timeout=600)
    assert ref_out.returncode == 0, ref_out.stderr[-4000:]
    ref = json.loads(ref_out.stdout.split("RESULT", 1)[1])

    for k in ("mean", "centers", "eng_mean", "eng_centers"):
        np.testing.assert_allclose(got[k], ref[k], atol=1e-5)
    for k in ("cov_tr", "eng_cov_tr"):
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-5)
    assert got["count"] == ref["count"] == 5 * 2 * 32
    assert got["eng_count"] == ref["eng_count"]
    assert got["reassign"] == ref["reassign"]
