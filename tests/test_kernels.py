"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode.

Property-style sweeps are seeded pytest.mark.parametrize grids (no hypothesis
dependency): each case derives (shape, data) deterministically from its seed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fwht, ops, ref, sparse_assign

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("p", [64, 128, 256, 512, 2048, 8192])
@pytest.mark.parametrize("n", [1, 16, 37])
def test_fwht_kernel_shapes(p, n):
    x = jax.random.normal(KEY, (n, p), jnp.float32)
    s = jax.random.rademacher(jax.random.PRNGKey(1), (p,), jnp.float32)
    y = fwht.hd_precondition(x, s, interpret=True)
    np.testing.assert_allclose(y, ref.ref_hd_precondition(x, s), atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_kernel_dtypes(dtype):
    p, n = 512, 9
    x = jax.random.normal(KEY, (n, p)).astype(dtype)
    s = jax.random.rademacher(jax.random.PRNGKey(1), (p,), jnp.float32).astype(dtype)
    y = fwht.hd_precondition(x, s, interpret=True)
    r = ref.ref_hd_precondition(x.astype(jnp.float32), s.astype(jnp.float32))
    tol = 2e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(y.astype(jnp.float32), r, atol=tol)


def test_fwht_kernel_rejects_non_pow2():
    with pytest.raises(ValueError):
        fwht.factor_p(100)


@pytest.mark.parametrize("shape", [(33, 256, 16, 5), (64, 1024, 64, 10), (17, 512, 128, 3), (5, 128, 2, 2)])
def test_sparse_assign_kernel_shapes(shape):
    n, p, m, k = shape
    kv, ki, kc = jax.random.split(jax.random.PRNGKey(n), 3)
    vals = jax.random.normal(kv, (n, m), jnp.float32)
    u = jax.random.uniform(ki, (n, p))
    idx = jnp.sort(jax.lax.top_k(u, m)[1].astype(jnp.int32), axis=-1)
    ctr = jax.random.normal(kc, (k, p), jnp.float32)
    d, a = sparse_assign.sparse_assign(vals, idx, ctr, interpret=True)
    dr, ar = ref.ref_sparse_assign(vals, idx, ctr)
    np.testing.assert_allclose(d, dr, atol=1e-3)
    assert bool(jnp.all(a == ar))


@pytest.mark.parametrize("seed", range(10))
def test_property_fwht_kernel_random(seed):
    """Seeded sweep over random (p, n): kernel == butterfly oracle."""
    rng = np.random.default_rng(seed)
    p = 1 << int(rng.integers(6, 12))
    n = int(rng.integers(1, 25))
    key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
    x = jax.random.normal(key, (n, p), jnp.float32)
    s = jax.random.rademacher(jax.random.fold_in(key, 1), (p,), jnp.float32)
    y = fwht.hd_precondition(x, s, interpret=True)
    np.testing.assert_allclose(y, ref.ref_hd_precondition(x, s), atol=2e-4)


def test_ops_wrappers_dispatch():
    x = jax.random.normal(KEY, (8, 256), jnp.float32)
    s = jax.random.rademacher(jax.random.PRNGKey(1), (256,), jnp.float32)
    np.testing.assert_allclose(
        ops.hd_precondition(x, s, mode="interpret"),
        ops.hd_precondition(x, s, mode="ref"),
        atol=2e-4,
    )
    vals = jax.random.normal(KEY, (8, 16), jnp.float32)
    idx = jnp.sort(jax.lax.top_k(jax.random.uniform(KEY, (8, 256)), 16)[1].astype(jnp.int32), axis=-1)
    ctr = jax.random.normal(KEY, (4, 256), jnp.float32)
    d1, a1 = ops.sparse_assign(vals, idx, ctr, mode="interpret")
    d2, a2 = ops.sparse_assign(vals, idx, ctr, mode="ref")
    np.testing.assert_allclose(d1, d2, atol=1e-3)
    assert bool(jnp.all(a1 == a2))


def test_kernel_assign_fn_in_lloyd():
    """The kernel adapter slots into the Lloyd loop and matches the ref path."""
    from repro.core import kmeans as km

    n, p, m, k = 60, 128, 16, 3
    kv, ki = jax.random.split(KEY)
    vals = jax.random.normal(kv, (n, m), jnp.float32)
    idx = jnp.sort(jax.lax.top_k(jax.random.uniform(ki, (n, p)), m)[1].astype(jnp.int32), axis=-1)
    mu_ref, a_ref, o_ref, _ = km.sparse_kmeans_core(vals, idx, p, k, KEY, n_init=2, max_iter=10)
    fn = __import__("repro.kernels.ops", fromlist=["kernel_assign_fn"]).kernel_assign_fn("ref")
    mu_k, a_k, o_k, _ = km.sparse_kmeans_core(vals, idx, p, k, KEY, n_init=2, max_iter=10, assign_fn=fn)
    np.testing.assert_allclose(mu_ref, mu_k, atol=1e-4)
    assert bool(jnp.all(a_ref == a_k))


# -------------------------- satellite: spmm VMEM-budget fallback boundary ---
# ops._sparse_mode holds the spmm kernels to a ~12 MB VMEM footprint
# (the (p, l) operand block + the (block_rows, p) densify scratch, no p-tiling
# yet — ROADMAP); past it, "kernel" silently falls back to the jnp path. The
# switch point was untested: pin it exactly at the documented ceiling.

_SPMM_BUDGET = ops._SPMM_VMEM_BUDGET


def _spmm_vmem(p, ell):
    from repro.kernels import spmm as spmm_mod

    return (p * ell + spmm_mod.default_block_rows(p) * p) * 4


@pytest.mark.parametrize("ell,expect", [
    (255, "kernel"),   # just below: (8192·255 + 128·8192)·4 = 12 550 144 B
    (256, "kernel"),   # exactly AT the 12 MB ceiling (≤ keeps the kernel)
    (257, "ref"),      # one column over: 12 615 680 B > 12 MB → jnp fallback
])
def test_sparse_mode_fallback_engages_exactly_at_budget(ell, expect):
    """p=8192 has block_rows=128, so l walks the footprint across the ceiling
    in exact 32 KiB steps — the fallback must flip between at and above."""
    p = 8192
    vmem = _spmm_vmem(p, ell)
    assert (vmem <= _SPMM_BUDGET) == (expect == "kernel"), (vmem, _SPMM_BUDGET)
    assert ops._sparse_mode("kernel", p, ell) == expect


@pytest.mark.parametrize("p,expect", [
    (4096, "kernel"),   # 4096·(128+128)·4 = 4 MB
    (8192, "kernel"),   # 8 MB
    (16384, "ref"),     # 16 MB > 12 MB — the l=128 ceiling sits here
    (32768, "ref"),     # 24 MB (block_rows drops to 64, still over)
])
def test_sparse_mode_p_sweep_at_l128(p, expect):
    """The documented l=128 regime: kernels below the ceiling, jnp past it,
    always agreeing with the footprint formula (block_rows shrinks with p)."""
    assert ops._sparse_mode("kernel", p, 128) == expect
    vmem = _spmm_vmem(p, 128)
    assert (vmem <= _SPMM_BUDGET) == (expect == "kernel")


def test_sparse_mode_vocabulary_and_interpret():
    """"auto" resolves by backend (ref on CPU); Plan.impl spellings like "jnp"
    normalize to ref instead of reaching a Pallas compile; "interpret" is
    exempt from the VMEM budget (host interpreter has no VMEM)."""
    assert ops._sparse_mode("auto", 1 << 20, 128) == "ref"      # CPU CI host
    assert ops._sparse_mode("jnp", 256, 8) == "ref"
    assert ops._sparse_mode("ref", 256, 8) == "ref"
    assert ops._sparse_mode("interpret", 1 << 20, 128) == "interpret"


def test_spmm_kernel_matches_oracle_at_boundary_p():
    """Numeric check AT the fallback-boundary dimensionality (p=8192): the
    interpreted kernel and the jnp oracle agree to 1e-5 on both products, so
    flipping across the ceiling cannot change results beyond float noise.
    Small row count + block_rows=8 keep the interpreted densify loop fast."""
    from repro.kernels import spmm as spmm_mod

    n, m, p, ell = 8, 4, 8192, 16
    key = jax.random.fold_in(KEY, 8192)
    values = jax.random.normal(key, (n, m))
    idx = jnp.sort(jax.lax.top_k(jax.random.uniform(
        jax.random.fold_in(key, 1), (n, p)), m)[1].astype(jnp.int32), axis=-1)
    dense = jax.random.normal(jax.random.fold_in(key, 2), (p, ell))

    t_ref = ref.ref_spmm(values, idx, dense)
    t_k = spmm_mod.spmm(values, idx, dense, block_rows=8, interpret=True)
    np.testing.assert_allclose(np.asarray(t_k), np.asarray(t_ref), atol=1e-5)
    y_ref = ref.ref_spmm_t(values, idx, t_ref, p)
    y_k = spmm_mod.spmm_t(values, idx, t_ref, p, block_rows=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=1e-5)
