"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode.

Property-style sweeps are seeded pytest.mark.parametrize grids (no hypothesis
dependency): each case derives (shape, data) deterministically from its seed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fwht, ops, ref, sparse_assign

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("p", [64, 128, 256, 512, 2048, 8192])
@pytest.mark.parametrize("n", [1, 16, 37])
def test_fwht_kernel_shapes(p, n):
    x = jax.random.normal(KEY, (n, p), jnp.float32)
    s = jax.random.rademacher(jax.random.PRNGKey(1), (p,), jnp.float32)
    y = fwht.hd_precondition(x, s, interpret=True)
    np.testing.assert_allclose(y, ref.ref_hd_precondition(x, s), atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_kernel_dtypes(dtype):
    p, n = 512, 9
    x = jax.random.normal(KEY, (n, p)).astype(dtype)
    s = jax.random.rademacher(jax.random.PRNGKey(1), (p,), jnp.float32).astype(dtype)
    y = fwht.hd_precondition(x, s, interpret=True)
    r = ref.ref_hd_precondition(x.astype(jnp.float32), s.astype(jnp.float32))
    tol = 2e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(y.astype(jnp.float32), r, atol=tol)


def test_fwht_kernel_rejects_non_pow2():
    with pytest.raises(ValueError):
        fwht.factor_p(100)


@pytest.mark.parametrize("shape", [(33, 256, 16, 5), (64, 1024, 64, 10), (17, 512, 128, 3), (5, 128, 2, 2)])
def test_sparse_assign_kernel_shapes(shape):
    n, p, m, k = shape
    kv, ki, kc = jax.random.split(jax.random.PRNGKey(n), 3)
    vals = jax.random.normal(kv, (n, m), jnp.float32)
    u = jax.random.uniform(ki, (n, p))
    idx = jnp.sort(jax.lax.top_k(u, m)[1].astype(jnp.int32), axis=-1)
    ctr = jax.random.normal(kc, (k, p), jnp.float32)
    d, a = sparse_assign.sparse_assign(vals, idx, ctr, interpret=True)
    dr, ar = ref.ref_sparse_assign(vals, idx, ctr)
    np.testing.assert_allclose(d, dr, atol=1e-3)
    assert bool(jnp.all(a == ar))


@pytest.mark.parametrize("seed", range(10))
def test_property_fwht_kernel_random(seed):
    """Seeded sweep over random (p, n): kernel == butterfly oracle."""
    rng = np.random.default_rng(seed)
    p = 1 << int(rng.integers(6, 12))
    n = int(rng.integers(1, 25))
    key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
    x = jax.random.normal(key, (n, p), jnp.float32)
    s = jax.random.rademacher(jax.random.fold_in(key, 1), (p,), jnp.float32)
    y = fwht.hd_precondition(x, s, interpret=True)
    np.testing.assert_allclose(y, ref.ref_hd_precondition(x, s), atol=2e-4)


def test_ops_wrappers_dispatch():
    x = jax.random.normal(KEY, (8, 256), jnp.float32)
    s = jax.random.rademacher(jax.random.PRNGKey(1), (256,), jnp.float32)
    np.testing.assert_allclose(
        ops.hd_precondition(x, s, mode="interpret"),
        ops.hd_precondition(x, s, mode="ref"),
        atol=2e-4,
    )
    vals = jax.random.normal(KEY, (8, 16), jnp.float32)
    idx = jnp.sort(jax.lax.top_k(jax.random.uniform(KEY, (8, 256)), 16)[1].astype(jnp.int32), axis=-1)
    ctr = jax.random.normal(KEY, (4, 256), jnp.float32)
    d1, a1 = ops.sparse_assign(vals, idx, ctr, mode="interpret")
    d2, a2 = ops.sparse_assign(vals, idx, ctr, mode="ref")
    np.testing.assert_allclose(d1, d2, atol=1e-3)
    assert bool(jnp.all(a1 == a2))


def test_kernel_assign_fn_in_lloyd():
    """The kernel adapter slots into the Lloyd loop and matches the ref path."""
    from repro.core import kmeans as km

    n, p, m, k = 60, 128, 16, 3
    kv, ki = jax.random.split(KEY)
    vals = jax.random.normal(kv, (n, m), jnp.float32)
    idx = jnp.sort(jax.lax.top_k(jax.random.uniform(ki, (n, p)), m)[1].astype(jnp.int32), axis=-1)
    mu_ref, a_ref, o_ref, _ = km.sparse_kmeans_core(vals, idx, p, k, KEY, n_init=2, max_iter=10)
    fn = __import__("repro.kernels.ops", fromlist=["kernel_assign_fn"]).kernel_assign_fn("ref")
    mu_k, a_k, o_k, _ = km.sparse_kmeans_core(vals, idx, p, k, KEY, n_init=2, max_iter=10, assign_fn=fn)
    np.testing.assert_allclose(mu_ref, mu_k, atol=1e-4)
    assert bool(jnp.all(a_ref == a_k))


# ----------------------------- tiled spmm: VMEM planning + dtype handling ---
# The spmm kernels tile BOTH grid axes (kernels/spmm.py), so plan_tiles must
# find a (block_rows, block_cols) pair fitting the ONE budget at any p — the
# old "fall back to jnp past ~2^15" ceiling is gone, and ops._sparse_mode
# sizes the footprint at the ACTUAL operand dtypes (the old gate hard-coded
# 4-byte items and disagreed with the planner's own budget).

_SPMM_BUDGET = ops._SPMM_VMEM_BUDGET


def _spmm_vmem(p, ell, value_dtype=jnp.float32, dense_dtype=jnp.float32):
    from repro.kernels import spmm as spmm_mod

    br, pb = spmm_mod.plan_tiles(p, ell, value_dtype, dense_dtype)
    return spmm_mod.tile_vmem_bytes(p, ell, value_dtype, dense_dtype, br, pb)


@pytest.mark.parametrize("p", [4096, 8192, 16384, 32768, 1 << 16, 1 << 20])
@pytest.mark.parametrize("dtypes", [
    (jnp.float32, jnp.float32),
    (jnp.bfloat16, jnp.bfloat16),
    (jnp.bfloat16, jnp.float32),
])
def test_sparse_mode_keeps_kernel_at_any_p_l128(p, dtypes):
    """The l=128 regime across dtypes: the planned tiles always fit the
    budget (column blocks shrink instead of falling back), so the gate keeps
    the kernel at every p — including the old jnp-fallback sizes 2^14..2^20."""
    vd, dd = dtypes
    assert _spmm_vmem(p, 128, vd, dd) <= _SPMM_BUDGET
    assert ops._sparse_mode("kernel", p, 128, vd, dd) == "kernel"


def test_sparse_mode_gate_agrees_with_planner():
    """The dispatch gate and the tile planner share ONE footprint model: the
    gate's decision must equal the planner's own fits-the-budget check,
    dtype by dtype (this is the single-sourcing the old gate lacked)."""
    for p, ell in [(8192, 256), (1 << 16, 128), (4096, 512)]:
        for vd, dd in [(jnp.float32, jnp.float32), (jnp.bfloat16, jnp.float32),
                       (jnp.float64, jnp.float64)]:
            fits = _spmm_vmem(p, ell, vd, dd) <= _SPMM_BUDGET
            assert (ops._sparse_mode("kernel", p, ell, vd, dd) == "kernel") == fits


def test_sparse_mode_vocabulary_and_interpret():
    """"auto" resolves by backend (ref on CPU); Plan.impl spellings like "jnp"
    normalize to ref instead of reaching a Pallas compile; "interpret" is
    exempt from the VMEM budget (host interpreter has no VMEM)."""
    assert ops._sparse_mode("auto", 1 << 20, 128) == "ref"      # CPU CI host
    assert ops._sparse_mode("jnp", 256, 8) == "ref"
    assert ops._sparse_mode("ref", 256, 8) == "ref"
    assert ops._sparse_mode("interpret", 1 << 20, 128) == "interpret"


def test_plan_tiles_respects_budget_and_alignment():
    """plan_tiles output is a pow2 column block ≥ 256 (lane-aligned) whose
    footprint fits the budget, at representative (p, l, dtype) corners."""
    from repro.kernels import spmm as spmm_mod

    for p, ell, vd, dd in [(512, 8, jnp.float32, jnp.float32),
                           (1 << 16, 128, jnp.float32, jnp.float32),
                           (1 << 20, 64, jnp.bfloat16, jnp.bfloat16),
                           (12288, 32, jnp.float64, jnp.float64)]:
        br, pb = spmm_mod.plan_tiles(p, ell, vd, dd)
        assert pb >= 256 and (pb & (pb - 1)) == 0
        assert br >= 8
        assert spmm_mod.tile_vmem_bytes(p, ell, vd, dd, br, pb) <= _SPMM_BUDGET


def test_spmm_tiled_matches_oracle_across_column_blocks():
    """Multi-column-block parity: force small tiles so the grid walks several
    column blocks (and padded p), checking the masked densify scatters each
    index into exactly its own block on both products."""
    from repro.kernels import spmm as spmm_mod

    n, m, p, ell = 24, 6, 1500, 16   # pads to 3 × 512 column blocks
    key = jax.random.fold_in(KEY, 1500)
    values = jax.random.normal(key, (n, m))
    idx = jnp.sort(jax.lax.top_k(jax.random.uniform(
        jax.random.fold_in(key, 1), (n, p)), m)[1].astype(jnp.int32), axis=-1)
    dense = jax.random.normal(jax.random.fold_in(key, 2), (p, ell))

    t_ref = ref.ref_spmm(values, idx, dense)
    t_k = spmm_mod.spmm(values, idx, dense, block_rows=8, block_cols=512,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(t_k), np.asarray(t_ref), atol=1e-5)
    y_ref = ref.ref_spmm_t(values, idx, t_ref, p)
    y_k = spmm_mod.spmm_t(values, idx, t_ref, p, block_rows=8, block_cols=512,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=1e-4)


@pytest.mark.slow
def test_spmm_tiled_matches_oracle_at_p64k():
    """The acceptance shape: p=2^16 at l=128 compiles (interpret mode) and
    matches the jnp oracles with NO ref fallback selected by the gate."""
    from repro.kernels import spmm as spmm_mod

    n, m, p, ell = 8, 4, 1 << 16, 128
    assert ops._sparse_mode("kernel", p, ell) == "kernel"
    key = jax.random.fold_in(KEY, p)
    values = jax.random.normal(key, (n, m))
    idx = jnp.sort(jax.lax.top_k(jax.random.uniform(
        jax.random.fold_in(key, 1), (n, p)), m)[1].astype(jnp.int32), axis=-1)
    dense = jax.random.normal(jax.random.fold_in(key, 2), (p, ell))

    t_ref = ref.ref_spmm(values, idx, dense)
    t_k = spmm_mod.spmm(values, idx, dense, block_rows=8, interpret=True)
    np.testing.assert_allclose(np.asarray(t_k), np.asarray(t_ref), atol=1e-5)
    y_ref = ref.ref_spmm_t(values, idx, t_ref, p)
    y_k = spmm_mod.spmm_t(values, idx, t_ref, p, block_rows=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=1e-4)


@pytest.mark.parametrize("vd,dd,out", [
    (jnp.bfloat16, jnp.bfloat16, jnp.float32),   # bf16·bf16 accumulates in f32
    (jnp.bfloat16, jnp.float32, jnp.float32),
    (jnp.float32, jnp.bfloat16, jnp.float32),
    (jnp.float32, jnp.float32, jnp.float32),
])
def test_spmm_mixed_dtype_parity(vd, dd, out):
    """Kernel and oracle share ONE promotion rule (promoted_dtypes /
    _spmm_out_dtype): mixed-dtype operands produce the same values to
    tolerance AND the same output dtype (the old kernel silently cast dense
    to values.dtype, degrading f32 operands to bf16 compute)."""
    n, m, p, ell = 16, 4, 512, 8
    key = jax.random.fold_in(KEY, 99)
    values = jax.random.normal(key, (n, m)).astype(vd)
    idx = jnp.sort(jax.lax.top_k(jax.random.uniform(
        jax.random.fold_in(key, 1), (n, p)), m)[1].astype(jnp.int32), axis=-1)
    dense = jax.random.normal(jax.random.fold_in(key, 2), (p, ell)).astype(dd)
    from repro.kernels import spmm as spmm_mod

    tol = 1e-5 if (vd, dd) == (jnp.float32, jnp.float32) else 5e-2
    t_ref = ref.ref_spmm(values, idx, dense)
    t_k = spmm_mod.spmm(values, idx, dense, block_rows=8, interpret=True)
    assert t_k.dtype == t_ref.dtype == out
    np.testing.assert_allclose(np.asarray(t_k), np.asarray(t_ref), atol=tol)

    t32 = t_ref.astype(dd)
    y_ref = ref.ref_spmm_t(values, idx, t32, p)
    y_k = spmm_mod.spmm_t(values, idx, t32, p, block_rows=8, interpret=True)
    assert y_k.dtype == y_ref.dtype == out
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=tol * 4)
